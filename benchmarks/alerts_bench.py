"""Standing-alert benchmark -> BENCH_alerts.json.

Measures the push-based alert subsystem (device-evaluated predicates fused
into the write step, compact fired-set readback) against the baseline it
replaces — poll-everything: after every batch, gather + ``device_get`` the
finalized measures of **all** alerted readers and run the state machine on
host (``repro.streams.alerts.PollOracle``).

Both paths share the (frontier-sparse) device write step; what differs is
the per-batch DETECTION cost layered on top, and that is what the gated
``speedup`` measures — each timed after the device step completed, so
neither number hides a sync on the other's work:

  push  — ``AlertSet.collect()``: one scalar count readback plus, when
          something fired, the fixed-shape compact index/value buffer.
          O(fired), independent of the alert count.
  poll  — ``PollOracle.poll()``: gather + ``device_get`` the finalized
          measures of all alerted readers, then the host state machine.
          O(alerts), every batch, fired or not.

End-to-end step medians (write+detect for both paths) are reported
alongside (``push_step_ms`` / ``poll_step_ms``); on hosts where the device
sweep dominates they converge, which is exactly why the detection-path
latency is the gated metric.

Sections:

  * ``sizes``   — detection latency push vs poll at an alert-count ladder
                  (quick: 2k/20k; full: 10k/100k/1M).
  * ``gate``    — the ISSUE gate point: 100k alerts (20k quick) at ~0.1%
                  fired fraction; ``--check`` enforces the push-vs-poll
                  detection speedup floor (1.5x quick, 5x full) plus the
                  committed baseline band.
  * ``fired_fraction_sweep`` — same point at ~0.01%/0.1%/1%/10% target
                  fired fractions: the push win shrinks as the fired set
                  approaches the alert count (compact readback degenerates
                  toward poll).
  * ``detect``  — p50/p99 detection latency under sustained pipelined
                  ingest: wall-clock from a batch's dispatch into the ring
                  to its fired set landing on host at the ring boundary.
  * ``stacked`` — when >1 device is attached (the mesh-8 CI entry forces 8
                  host devices): per-shard fired sets gathered with one
                  collective, push readback vs a full-PAO poll readback.

Run:  PYTHONPATH=src python -m benchmarks.run --alerts [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.harness import (
    Phases,
    Watchdog,
    check_gates,
    env_fingerprint,
    export_trajectory,
    load_baselines,
    percentiles,
)
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine, bucket_batch
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.streams.alerts import AlertSet, AlertSpec, PollOracle, _reader_nodes
from repro.streams.ingest import IngestPipeline

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_alerts.json")

QUICK = dict(sizes=(2_000, 20_000), gate=20_000, reps=20, warmup=12,
             batch=256, detect_s=1.5, budget_s=900)
FULL = dict(sizes=(10_000, 100_000, 1_000_000), gate=100_000, reps=12,
            warmup=16, batch=512, detect_s=6.0, budget_s=3_600)

WINDOW = 8
GATE_FRAC = 0.001          # the ISSUE's 0.1% fired-fraction gate point
SWEEP_FRACS = (0.0001, 0.001, 0.01, 0.1)


# ------------------------------------------------------------------- fixture
def _build(n_alerts: int):
    """All-push sum engine whose overlay has at least ``n_alerts`` readers
    (every result always fresh — the continuous-query configuration alerts
    require). rmat leaves roughly half the ids without in-edges (non-readers),
    so size with headroom and retry larger once if the draw lands short."""
    for factor in (2.6, 4.0):
        n = max(512, int(factor * n_alerts))
        g = rmat_graph(n, 6 * n, seed=0)
        bp = build_bipartite(g)
        ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
        dec = np.full(ov.n_nodes, D.PUSH, np.int64)
        eng = EagrEngine(ov, dec, make_aggregate("sum"),
                         WindowSpec("tuple", WINDOW))
        if len(np.flatnonzero(eng.plan.routes.reader_node >= 0)) >= n_alerts:
            return eng
    return eng  # _alert_bases raises with the observed reader count


def _batches(eng, batch: int, *, n_batches: int = 16, seed: int = 1):
    writer_bases = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(seed)
    return [(rng.choice(writer_bases, size=batch).astype(np.int64),
             rng.integers(0, 64, batch).astype(np.float32))
            for _ in range(n_batches)]


def _alert_bases(eng, n_alerts: int) -> np.ndarray:
    bases = np.flatnonzero(eng.plan.routes.reader_node >= 0)
    if len(bases) < n_alerts:
        raise RuntimeError(f"fixture has {len(bases)} readers < "
                           f"{n_alerts} alerts")
    return bases[:n_alerts].astype(np.int64)


def _measures(eng, bases: np.ndarray) -> np.ndarray:
    import jax
    import jax.numpy as jnp

    nodes, _ = _reader_nodes(eng.plan, bases)
    fin = np.asarray(jax.device_get(
        eng.agg.finalize(eng.state.pao[jnp.asarray(nodes.astype(np.int32))])),
        np.float32)
    return fin.reshape(len(bases), -1)[:, 0]


def _calibrate(eng, bases, batches, *, frac: float, warmup: int,
               batch_size: int) -> AlertSpec:
    """A delta spec targeting roughly ``frac`` of the alerts firing per
    step. The delta predicate re-bases its reference on every fire, so the
    firing rate stays stationary under stationary load (an absolute
    threshold drifts in and out of reach as windows slide): set ``dthr`` at
    the per-step |measure delta| quantile that leaves the wanted share of
    changed readers outside it. Measured fractions are reported alongside —
    crossing dynamics keep this approximate."""
    prev = None
    deltas: list[np.ndarray] = []
    changed = []
    for i in range(warmup):
        ids, vals = batches[i % len(batches)]
        eng.write_batch(ids, vals, batch_size=batch_size)
        m = _measures(eng, bases)
        if prev is not None:
            d = np.abs(m - prev)
            d = d[d > 0]
            if len(d):
                deltas.append(d)
                changed.append(len(d))
        prev = m
    pool = np.concatenate(deltas) if deltas else np.ones(1, np.float32)
    c_bar = max(1.0, float(np.mean(changed))) if changed else 1.0
    ratio = float(np.clip(frac * len(bases) / c_bar, 1e-4, 0.9))
    return AlertSpec(delta=float(np.quantile(pool, 1.0 - ratio)))


def _attach(eng, bases: np.ndarray, spec: AlertSpec) -> AlertSet:
    al = AlertSet()
    al.register(0, spec, bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    return al


def _detach(eng) -> None:
    eng.alerts = None
    eng._rebind()


def _median_ms(samples: list[float]) -> float:
    return round(sorted(samples)[len(samples) // 2] * 1e3, 3)


def _push_poll_point(eng, bases, spec, batches, *, reps: int,
                     batch_size: int) -> dict:
    """Detection latency per batch, push vs poll, on identical stationary
    load. Each detection sample is timed AFTER ``block_until_ready`` on the
    step, so it is pure detection-path cost: push pays the compact readback
    (O(fired)), poll pays the O(alerts) gather + transfer + host state
    machine. End-to-end step medians ride along."""
    import jax

    out: dict = {"n_alerts": int(len(bases))}
    al = _attach(eng, bases, spec)
    for i in range(2):  # compile the fused step outside the clock
        eng.write_batch(*batches[i % len(batches)], batch_size=batch_size)
    al.collect()
    al.pop_fired()
    step_s, det_s, fired = [], [], 0
    for i in range(reps):
        ids, vals = batches[i % len(batches)]
        t0 = time.perf_counter()
        eng.write_batch(ids, vals, batch_size=batch_size)
        jax.block_until_ready(eng.state.now)
        t1 = time.perf_counter()
        al.collect()
        t2 = time.perf_counter()
        step_s.append(t2 - t0)
        det_s.append(t2 - t1)
        fired += sum(len(b) for b in al.pop_fired())
    out["push_detect_ms"] = _median_ms(det_s)
    out["push_step_ms"] = _median_ms(step_s)
    out["push_fired_frac"] = round(fired / (reps * len(bases)), 6)

    oracle = PollOracle(al)
    _detach(eng)
    oracle.resync(eng)
    for i in range(2):
        eng.write_batch(*batches[i % len(batches)], batch_size=batch_size)
        oracle.poll(eng, float(eng._now_host) - 1.0)
    step_s, det_s, fired = [], [], 0
    for i in range(reps):
        ids, vals = batches[i % len(batches)]
        t0 = time.perf_counter()
        eng.write_batch(ids, vals, batch_size=batch_size)
        jax.block_until_ready(eng.state.now)
        t1 = time.perf_counter()
        fired += len(oracle.poll(eng, float(eng._now_host) - 1.0))
        t2 = time.perf_counter()
        step_s.append(t2 - t0)
        det_s.append(t2 - t1)
    out["poll_detect_ms"] = _median_ms(det_s)
    out["poll_step_ms"] = _median_ms(step_s)
    out["poll_fired_frac"] = round(fired / (reps * len(bases)), 6)
    out["speedup"] = round(out["poll_detect_ms"] /
                           max(out["push_detect_ms"], 1e-3), 2)
    out["speedup_step"] = round(out["poll_step_ms"] /
                                max(out["push_step_ms"], 1e-3), 2)
    return out


# ------------------------------------------------------------ detect latency
def _detect_latency(eng, bases, spec, batches, *, duration_s: float,
                    batch_size: int) -> dict:
    """p50/p99 wall-clock from a device batch's dispatch into the ingest
    ring to its fired set landing on host at the ring boundary — the
    detection latency a push consumer observes under sustained load."""
    al = _attach(eng, bases, spec)
    pipe = IngestPipeline([eng], depth=2, device_batch=bucket_batch(
        max(1024, batch_size)))
    t_disp: dict[int, float] = {}
    lat: list[float] = []
    seen = al.seq_done
    t0 = time.perf_counter()
    i = 0
    while time.perf_counter() - t0 < duration_s:
        prev = al.seq
        pipe.submit(*batches[i % len(batches)])
        tnow = time.perf_counter()
        for k in range(prev, al.seq):
            t_disp[k] = tnow
        for k in range(seen, al.seq_done):
            lat.append(tnow - t_disp.pop(k, tnow))
        seen = al.seq_done
        i += 1
    elapsed = time.perf_counter() - t0
    pipe.flush()
    fired = sum(len(b) for b in al.pop_fired())
    _detach(eng)
    out = percentiles(lat) if lat else {}
    out["events_per_s"] = round(pipe.stats.events_in / elapsed)
    out["device_batches"] = int(al.seq)
    out["fired"] = int(fired)
    return out


# ----------------------------------------------------------------- stacked
def _stacked_section(quick: bool) -> dict | None:
    """Per-shard fired sets under one psum'd count collective (mesh CI). The
    poll baseline reads the whole stacked PAO back and predicates on host —
    the transfer the compact readback avoids."""
    import jax

    if jax.device_count() < 2:
        return None
    from repro.distributed.eagr_shard import partition_overlay
    from repro.distributed.stacked import StackedShardedEngine

    n, e = (2_000, 12_000) if quick else (6_000, 36_000)
    S = min(8, jax.device_count())
    reps = 12 if quick else 20
    batch = 256
    g = rmat_graph(n, e, seed=7)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dec = np.full(ov.n_nodes, D.PUSH, np.int64)
    sharded = partition_overlay(ov, dec, n_shards=S, seed=0)
    eng = StackedShardedEngine(sharded, make_aggregate("sum"),
                               WindowSpec("tuple", WINDOW))
    writer_bases = np.array(sorted(
        {b for p in sharded.shard_plans for b in p.writer_row_of_base}),
        np.int64)
    reader_bases = np.array(sorted(
        {b for p in sharded.shard_plans for b in p.reader_node_of_base}),
        np.int64)
    rng = np.random.default_rng(5)
    batches = [(rng.choice(writer_bases, size=batch),
                rng.integers(0, 64, batch).astype(np.float32))
               for _ in range(8)]
    for ids, vals in batches[:6]:  # fill windows before thresholding
        eng.write_batch(ids, vals, batch_size=batch)

    al = AlertSet()
    al.register(0, AlertSpec(above=0.0), reader_bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    m0 = al._measures_host(eng, al._plans(eng))
    # re-register with per-reader headroom so only measure excursions fire
    eng.alerts = None
    al = AlertSet()
    al.register(0, AlertSpec(above=(m0 * 1.05 + 1.0)),
                reader_bases.tolist(), dynamic=False)
    eng.attach_alerts(al)

    for i in range(2):
        eng.write_batch(*batches[i % len(batches)], batch_size=batch)
    al.collect()
    al.pop_fired()
    det_s, fired = [], 0
    for i in range(reps):
        eng.write_batch(*batches[i % len(batches)], batch_size=batch)
        jax.block_until_ready(eng.state.now)
        t0 = time.perf_counter()
        al.collect()   # psum'd global count: one scalar readback
        det_s.append(time.perf_counter() - t0)
        fired += sum(len(b) for b in al.pop_fired())
    push_ms = _median_ms(det_s)

    agg = eng.agg
    eng.alerts = None

    def poll_detect():
        pao = np.asarray(jax.device_get(eng.state.pao))
        fin = np.asarray(agg.FINALIZE(
            pao.reshape(-1, pao.shape[-1])), np.float32)
        return int(np.count_nonzero(fin.reshape(len(fin), -1)[:, 0] > 0))

    poll_detect()
    det_s = []
    for i in range(reps):
        eng.write_batch(*batches[i % len(batches)], batch_size=batch)
        jax.block_until_ready(eng.state.now)
        t0 = time.perf_counter()
        poll_detect()
        det_s.append(time.perf_counter() - t0)
    poll_ms = _median_ms(det_s)
    return {
        "n_shards": S,
        "n_alerts": int(len(reader_bases)),
        "push_detect_ms": push_ms,
        "poll_full_pao_ms": poll_ms,
        "speedup": round(poll_ms / max(push_ms, 1e-3), 2),
        "fired": int(fired),
    }


# --------------------------------------------------------------------- main
def run_alerts_bench(quick: bool = False, check: bool = False,
                     out_path: str = OUT_PATH) -> dict:
    cfg = QUICK if quick else FULL
    phases = Phases()
    report: dict = {
        "bench": "alerts",
        "quick": quick,
        "fingerprint": env_fingerprint(),
        "window": WINDOW,
        "batch": cfg["batch"],
        "gate_frac": GATE_FRAC,
        "sizes": {},
    }
    prev_sparse = os.environ.get("EAGR_SPARSE_WRITE")
    os.environ["EAGR_SPARSE_WRITE"] = "1"
    try:
        with Watchdog(cfg["budget_s"], label="alerts_bench"):
            gate_eng = None
            gate_bases = gate_batches = None
            for n_alerts in cfg["sizes"]:
                with phases.phase(f"size_{n_alerts}"):
                    eng = _build(n_alerts)
                    batches = _batches(eng, cfg["batch"])
                    bases = _alert_bases(eng, n_alerts)
                    spec = _calibrate(eng, bases, batches, frac=GATE_FRAC,
                                      warmup=cfg["warmup"],
                                      batch_size=cfg["batch"])
                    row = _push_poll_point(eng, bases, spec, batches,
                                           reps=cfg["reps"],
                                           batch_size=cfg["batch"])
                    report["sizes"][str(n_alerts)] = row
                    print(f"alerts/size[{n_alerts}]: detect push "
                          f"{row['push_detect_ms']}ms poll "
                          f"{row['poll_detect_ms']}ms = {row['speedup']}x "
                          f"(fired_frac push {row['push_fired_frac']})",
                          flush=True)
                    if n_alerts == cfg["gate"]:
                        gate_eng, gate_bases, gate_batches = \
                            eng, bases, batches
            report["gate"] = dict(report["sizes"][str(cfg["gate"])])

            with phases.phase("fired_fraction_sweep"):
                sweep = {}
                for frac in SWEEP_FRACS:
                    spec = _calibrate(gate_eng, gate_bases, gate_batches,
                                      frac=frac, warmup=6,
                                      batch_size=cfg["batch"])
                    row = _push_poll_point(gate_eng, gate_bases, spec,
                                           gate_batches, reps=cfg["reps"],
                                           batch_size=cfg["batch"])
                    key = "frac_" + f"{frac:g}".replace("0.", "0_")
                    sweep[key] = row
                    print(f"alerts/sweep[{key}]: detect push "
                          f"{row['push_detect_ms']}ms poll "
                          f"{row['poll_detect_ms']}ms = {row['speedup']}x "
                          f"(fired_frac push {row['push_fired_frac']})",
                          flush=True)
                report["fired_fraction_sweep"] = sweep

            with phases.phase("detect"):
                spec = _calibrate(gate_eng, gate_bases, gate_batches,
                                  frac=GATE_FRAC, warmup=6,
                                  batch_size=cfg["batch"])
                report["detect"] = _detect_latency(
                    gate_eng, gate_bases, spec, gate_batches,
                    duration_s=cfg["detect_s"], batch_size=cfg["batch"])
                print(f"alerts/detect: {report['detect']}", flush=True)

            with phases.phase("stacked"):
                st = _stacked_section(quick)
                if st is not None:
                    report["stacked"] = st
                    print(f"alerts/stacked: {st}", flush=True)
    finally:
        if prev_sparse is None:
            os.environ.pop("EAGR_SPARSE_WRITE", None)
        else:
            os.environ["EAGR_SPARSE_WRITE"] = prev_sparse

    report["phase_seconds"] = phases.seconds
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    export_trajectory("alerts", {
        "quick": quick,
        "gate_n_alerts": report["gate"]["n_alerts"],
        "speedup_push_vs_poll": report["gate"]["speedup"],
        "push_detect_ms": report["gate"]["push_detect_ms"],
        "poll_detect_ms": report["gate"]["poll_detect_ms"],
        "p99_detect_ms": report["detect"].get("p99_ms"),
    })

    if check:
        all_b = load_baselines()
        view = {"tolerance": all_b.get("tolerance", 0.30),
                "alerts": all_b.get("alerts", {}).get(
                    "quick" if quick else "full", {})}
        check_gates(report, [
            # ISSUE gate: push beats poll-everything at the 100k/0.1% point
            # (>=5x full; the quick CI floor is conservative — small fixture,
            # cheap transfers)
            {"path": "gate.speedup", "floor": 1.5 if quick else 5.0,
             "baseline": "speedup_push_vs_poll"},
            {"path": "detect.p99_ms", "direction": "lower",
             "baseline": "p99_detect_ms"},
        ], baselines=view, section="alerts", label="alerts")
    return report


if __name__ == "__main__":
    import sys

    run_alerts_bench(quick="--quick" in sys.argv,
                     check="--check" in sys.argv)
