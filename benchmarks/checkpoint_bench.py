"""Durable-session benchmark -> BENCH_checkpoint.json.

Measures the recovery-time claim behind ``EagrSession.save``/``restore``:
a restore deserializes the committed plan tables, window rings and PAOs and
re-adopts them onto fresh engines — it never re-runs overlay construction
(``construct_vnm``) or plan compilation (``compile_plan``), so time-to-first-
answer after a crash is bounded by checkpoint I/O, not by the build pipeline.

Phases:

  cold_build     graph -> EagrSession -> register -> first update + read
                 (construction + cost model + compile + first dispatch: the
                 price a crash without checkpoints pays)
  save           quiesced blocking ``session.save`` (serialize + fsync view
                 of the full session: plans, windows, PAOs, master journal)
  restore        ``EagrSession.restore`` from the committed manifest + the
                 same first read, answer asserted bit-identical to the
                 pre-save session
  restore_reshard  the same checkpoint restored onto a different shard
                 layout (plan re-derivation, window re-slicing) — priced
                 separately because it *does* re-run decide/compile per shard

Full mode runs the paper-scale 1M-node / 10M-edge power-law graph (the
acceptance floor: restore >= 5x faster than cold build); quick mode a
20k/120k R-MAT (CI, conservative floor). ``--check`` gates the
restore-vs-cold speedup against ``BENCH_baselines.json``.

Run:  PYTHONPATH=src python -m benchmarks.run --checkpoint [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.harness import (
    Phases,
    Watchdog,
    check_gates,
    env_fingerprint,
    export_trajectory,
    load_baselines,
)
from repro.graphs.generators import powerlaw_graph, rmat_graph
from repro.session import EagrSession, Query
from repro.core.window import WindowSpec

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_checkpoint.json")

QUICK = dict(gen="rmat", n_nodes=20_000, n_edges=120_000, shards=None,
             reshard=2, n_updates=8, batch=1_024, budget_s=900)
FULL = dict(gen="powerlaw", n_nodes=1_000_000, n_edges=10_000_000,
            shards=None, reshard=4, n_updates=8, batch=8_192, budget_s=3_600)

WINDOW = 8


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def _traffic(session: EagrSession, cfg: dict, seed: int = 1) -> list:
    writers = np.array(session.writers)
    rng = np.random.default_rng(seed)
    return [(rng.choice(writers, size=cfg["batch"]).astype(np.int64),
             rng.integers(0, 64, cfg["batch"]).astype(np.float32))
            for _ in range(cfg["n_updates"])]


def run_checkpoint_bench(quick: bool = False, check: bool = False,
                         out_path: str = OUT_PATH) -> dict:
    cfg = QUICK if quick else FULL
    phases = Phases()
    report: dict = {
        "bench": "checkpoint",
        "quick": quick,
        "fingerprint": env_fingerprint(),
        "graph": {k: cfg[k] for k in ("gen", "n_nodes", "n_edges")},
        "window": WINDOW,
        "shards": cfg["shards"] or 0,
    }
    ckpt_dir = tempfile.mkdtemp(prefix="eagr_bench_ckpt_")
    try:
        with Watchdog(cfg["budget_s"], label="checkpoint_bench"):
            if cfg["gen"] == "rmat":
                g = rmat_graph(cfg["n_nodes"], cfg["n_edges"], seed=0)
            else:
                g = powerlaw_graph(cfg["n_nodes"], cfg["n_edges"],
                                   sharing=0.5, seed=0)

            # ---- cold build: everything a crash without checkpoints re-pays
            with phases.phase("cold_build"):
                t0 = time.perf_counter()
                session = EagrSession(g, shards=cfg["shards"])
                totals = session.register(
                    Query(agg="sum", window=WindowSpec("tuple", WINDOW)))
                for ids, vals in _traffic(session, cfg):
                    session.update(ids, vals)
                probe = np.array(session.readers[:64], np.int64)
                want = np.asarray(session.read(totals, probe))
                cold_s = time.perf_counter() - t0
            report["cold_build_s"] = round(cold_s, 3)
            print(f"checkpoint/cold_build: {cold_s:.2f}s "
                  f"({cfg['n_nodes']:,} nodes, {cfg['shards'] or 0} shards)",
                  flush=True)

            # ---- save: quiesced, blocking (serialize + atomic commit)
            with phases.phase("save"):
                t0 = time.perf_counter()
                step = session.save(ckpt_dir, blocking=True)
                save_s = time.perf_counter() - t0
            nbytes = _dir_bytes(ckpt_dir)
            report["save_s"] = round(save_s, 3)
            report["checkpoint_bytes"] = nbytes
            report["save_mb_per_s"] = round(nbytes / 2**20 / save_s, 1)
            print(f"checkpoint/save: step {step} in {save_s:.2f}s "
                  f"({nbytes / 2**20:.1f} MiB, "
                  f"{report['save_mb_per_s']} MiB/s)", flush=True)

            # ---- restore: manifest -> live session -> first answer
            with phases.phase("restore"):
                t0 = time.perf_counter()
                restored = EagrSession.restore(ckpt_dir)
                (totals_r,) = restored.queries
                got = np.asarray(restored.read(totals_r, probe))
                restore_s = time.perf_counter() - t0
            np.testing.assert_array_equal(got, want)
            report["restore_s"] = round(restore_s, 3)
            report["speedup_restore_vs_cold"] = round(cold_s / restore_s, 2)
            print(f"checkpoint/restore: {restore_s:.2f}s to first "
                  f"bit-identical answer = "
                  f"{report['speedup_restore_vs_cold']}x cold build",
                  flush=True)

            # ---- restore onto a different layout (re-derives plans)
            with phases.phase("restore_reshard"):
                t0 = time.perf_counter()
                resharded = EagrSession.restore(ckpt_dir,
                                                shards=cfg["reshard"])
                (totals_m,) = resharded.queries
                got_m = np.asarray(resharded.read(totals_m, probe))
                reshard_s = time.perf_counter() - t0
            np.testing.assert_allclose(got_m, want, rtol=1e-5)
            report["restore_reshard_s"] = round(reshard_s, 3)
            report["reshard_to"] = cfg["reshard"]
            print(f"checkpoint/restore_reshard: -> {cfg['reshard']} shards "
                  f"in {reshard_s:.2f}s", flush=True)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    report["phase_seconds"] = phases.seconds
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    export_trajectory("checkpoint", {
        "quick": quick,
        "cold_build_s": report["cold_build_s"],
        "save_s": report["save_s"],
        "restore_s": report["restore_s"],
        "speedup_restore_vs_cold": report["speedup_restore_vs_cold"],
    })

    if check:
        all_b = load_baselines()
        view = {"tolerance": all_b.get("tolerance", 0.30),
                "checkpoint": all_b.get("checkpoint", {}).get(
                    "quick" if quick else "full", {})}
        check_gates(report, [
            # acceptance: restore of the 1M/10M session >= 5x faster than
            # the cold build->construct->compile path; quick floor is
            # conservative (small graph, construction is cheap there).
            {"path": "speedup_restore_vs_cold",
             "floor": 2.0 if quick else 5.0,
             "baseline": "speedup_restore_vs_cold"},
        ], baselines=view, section="checkpoint", label="checkpoint")
    return report


if __name__ == "__main__":
    import sys

    run_checkpoint_bench(quick="--quick" in sys.argv,
                         check="--check" in sys.argv)
