"""Shared benchmark machinery: system setup, throughput measurement."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import Bipartite, build_bipartite
from repro.core.engine import EagrEngine
from repro.core.iob import construct_iob
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.streams.traces import batched_playback, generate_trace


@dataclasses.dataclass
class BenchResult:
    name: str
    events_per_s: float
    extras: dict = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        ex = " ".join(f"{k}={v}" for k, v in self.extras.items())
        return f"{self.name},{self.events_per_s:.0f} ev/s,{ex}"


def build_overlay(bp: Bipartite, algorithm: str, *, max_iterations: int = 4,
                  seed: int = 0):
    if algorithm == "iob":
        return construct_iob(bp, max_iterations=max_iterations)
    return construct_vnm(bp, variant=algorithm, max_iterations=max_iterations,
                         seed=seed)


def make_system(
    *,
    n_nodes: int = 20_000,
    n_edges: int = 120_000,
    aggregate: str = "sum",
    algorithm: str = "vnm_n",
    decisions: str = "mincut",        # 'mincut' | 'all_push' | 'all_pull' | 'greedy'
    write_read_ratio: float = 1.0,
    window: int = 8,
    hops: int = 1,
    split: bool = False,
    seed: int = 0,
    backend: str | None = None,       # engine substrate: pallas | xla | xla_unrolled
):
    """Graph -> bipartite -> overlay -> decisions -> engine + trace freqs."""
    g = rmat_graph(n_nodes, n_edges, seed=seed)
    bp = build_bipartite(g, hops=hops, two_hop_cap=64 if hops == 2 else None)
    if decisions in ("all_push", "all_pull"):
        # baselines share no partial aggregates (paper §5.1 comparison systems)
        from repro.core.overlay import all_pull_overlay
        ov = all_pull_overlay(bp.reader_inputs, bp.writers)
        stats = None
    else:
        ov, stats = build_overlay(bp, algorithm, seed=seed)
    trace = generate_trace(bp.writers, np.array(list(bp.reader_inputs)),
                           n_events=1, write_read_ratio=write_read_ratio,
                           seed=seed, n_base=g.n_nodes)
    cm = D.cost_model_for(aggregate, window=window)
    if decisions == "all_push":
        dec = np.full(ov.n_nodes, D.PUSH)
    elif decisions == "all_pull":
        dec = np.array([D.PUSH if k == "W" else D.PULL for k in ov.kinds])
    elif decisions == "greedy":
        dec = D.decide_greedy(ov, trace.write_freq, trace.read_freq, cm,
                              window=window)
    else:
        dec, _ = D.decide_mincut(ov, trace.write_freq, trace.read_freq, cm,
                                 window=window)
    if split:
        ov, dec, _ = D.split_nodes(ov, dec, trace.write_freq, trace.read_freq,
                                   cm, window=window)
    agg = (make_aggregate(aggregate, k=5, domain=64) if aggregate == "topk"
           else make_aggregate(aggregate))
    eng = EagrEngine(ov, dec, agg, WindowSpec("tuple", window), backend=backend)
    return eng, bp, g, stats


def measure_throughput(eng: EagrEngine, bp, *, n_events: int = 60_000,
                       write_read_ratio: float = 1.0, batch: int = 2048,
                       seed: int = 1, warmup_batches: int = 4) -> float:
    """End-to-end events/s over a Zipfian trace (paper §5.1 metric)."""
    readers = np.array(list(bp.reader_inputs))
    trace = generate_trace(bp.writers, readers, n_events,
                           write_read_ratio=write_read_ratio, seed=seed)
    batches = list(batched_playback(trace, batch))
    # warmup = compile
    for kind, ids, vals in batches[:warmup_batches]:
        if kind == "write":
            eng.write_batch(ids, vals, batch_size=batch)
        else:
            eng.read_batch(ids, batch_size=batch)
    t0 = time.perf_counter()
    n = 0
    for kind, ids, vals in batches[warmup_batches:]:
        if kind == "write":
            eng.write_batch(ids, vals, batch_size=batch)
        else:
            eng.read_batch(ids, batch_size=batch)
        n += len(ids)
    import jax
    jax.block_until_ready(eng.state.pao)
    dt = time.perf_counter() - t0
    return n / dt
