"""Construction-at-scale benchmark -> BENCH_construct.json.

Measures the compile-side pipeline ``graph -> build_bipartite -> construct_vnm
(-> decide_mincut)`` across graph sizes (12k R-MAT like BENCH_engine, then
120k / 1M power-law), with the per-phase breakdown from
``ConstructionStats.phase_seconds`` and the sharing index achieved. At the
smallest size the object-based reference engine is timed too, so the JSON
records the vectorized speedup on the same box — that ratio (and the SI, which
is deterministic for a fixed seed) is what ``--check`` gates against
``BENCH_baselines.json``: machine-independent structural regressions, not
runner speed.

Run:  PYTHONPATH=src python -m benchmarks.run --construct [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import dataflow as D
from repro.core.bipartite import build_bipartite
from repro.core.vnm import construct_vnm
from repro.graphs.generators import powerlaw_graph, rmat_graph

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_construct.json")
BASELINE_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_baselines.json")

# decide_mincut stays on the object overlay (Dinic per pruned component);
# past this size it is excluded rather than dominating the report
MINCUT_MAX_NODES = 200_000

FULL_SIZES = [
    ("12k", "rmat", 12_000, 72_000),
    ("120k", "powerlaw", 120_000, 720_000),
    ("1M", "powerlaw", 1_000_000, 10_000_000),
]
QUICK_SIZES = [("4k", "rmat", 4_000, 24_000)]


def _one_size(name: str, gen: str, n_nodes: int, n_edges: int,
              *, with_reference: bool) -> dict:
    t0 = time.perf_counter()
    g = (rmat_graph(n_nodes, n_edges, seed=0) if gen == "rmat"
         else powerlaw_graph(n_nodes, n_edges, sharing=0.5, seed=0))
    gen_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    bp = build_bipartite(g)
    bipartite_s = time.perf_counter() - t0

    ov, stats = construct_vnm(bp, variant="vnm_a", max_iterations=4, seed=0)

    mincut_s = None
    if ov.n_nodes <= MINCUT_MAX_NODES:
        wf = np.ones(bp.n_base)
        cm = D.cost_model_for("sum", window=8)
        t0 = time.perf_counter()
        D.decide_mincut(ov, wf, wf, cm, window=8)
        mincut_s = round(time.perf_counter() - t0, 3)

    ref_s = None
    if with_reference:
        _, ref_stats = construct_vnm(bp, variant="vnm_a", max_iterations=4,
                                     seed=0, reference=True)
        ref_s = round(ref_stats.seconds, 3)

    row = {
        "name": name,
        "generator": gen,
        "n_nodes": n_nodes,
        "graph_edges": int(g.n_edges),
        "bipartite_edges": int(bp.n_edges),
        "graph_gen_s": round(gen_s, 3),
        "bipartite_s": round(bipartite_s, 3),
        "construct_s": round(stats.seconds, 3),
        "phase_seconds": {k: round(v, 3) for k, v in stats.phase_seconds.items()},
        "iterations": stats.iterations,
        "bicliques": stats.bicliques,
        "overlay_nodes": int(ov.n_nodes),
        "overlay_edges": int(ov.n_edges),
        "si": round(ov.sharing_index(bp.n_edges), 4),
        "mincut_s": mincut_s,
        "reference_construct_s": ref_s,
    }
    if ref_s is not None and stats.seconds > 0:
        row["speedup_vs_reference"] = round(ref_s / stats.seconds, 2)
    return row


def _check(report: dict, quick: bool) -> None:
    with open(BASELINE_PATH) as f:
        baselines = json.load(f)
    base = baselines.get("construct", {}).get("quick" if quick else "full")
    if base is None:
        print("check: no committed construct baseline for this mode",
              flush=True)
        return
    tol = float(baselines.get("tolerance", 0.30))
    lo = 1.0 - tol
    gated = report["sizes"][0]  # the reference-timed size
    failures = []
    got = gated.get("speedup_vs_reference")
    b = base["speedup_vs_reference_min"]
    if got is None or got < b * lo:
        failures.append(
            f"baseline regression: construct speedup vs reference "
            f"{got}x < {b}x * {lo:.2f} (BENCH_baselines.json)")
    else:
        print(f"check OK: speedup vs reference {got}x >= floor of "
              f"baseline {b}x", flush=True)
    got_si = gated["si"]
    b_si = base["si_min"]
    if got_si < b_si * lo:
        failures.append(
            f"baseline regression: sharing index {got_si} < "
            f"{b_si} * {lo:.2f} (BENCH_baselines.json)")
    else:
        print(f"check OK: sharing index {got_si} >= floor of baseline {b_si}",
              flush=True)
    if failures:
        raise SystemExit("\n".join(failures))


def run_construct_bench(quick: bool = False, check: bool = False,
                        out_path: str = OUT_PATH) -> dict:
    sizes = QUICK_SIZES if quick else FULL_SIZES
    report = {
        "bench": "construction",
        "quick": quick,
        "algorithm": "vnm_a",
        "max_iterations": 4,
        "mincut_max_nodes": MINCUT_MAX_NODES,
        "sizes": [],
    }
    for i, (name, gen, n_nodes, n_edges) in enumerate(sizes):
        row = _one_size(name, gen, n_nodes, n_edges, with_reference=(i == 0))
        report["sizes"].append(row)
        print(f"construct/{name}: {row}", flush=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)
    if check:
        _check(report, quick)
    return report


if __name__ == "__main__":
    import sys
    run_construct_bench(quick="--quick" in sys.argv,
                        check="--check" in sys.argv)
