"""Structural-churn benchmark -> BENCH_dynamic.json.

Measures §3.3 incremental plan maintenance against the full-rebuild path it
replaces: per churn burst, the wall-clock of ``EagrEngine.apply_delta``
(journaled delta -> device-resident PatchProgram apply -> PAO refresh) versus
a fresh ``compile_plan`` over the same overlay — at churn ratios touching
0.1%, 1%, and 10% of the readers per burst. Also reports structural updates/s
through the patch path and how many bursts fell back to a recompile.

The ``device_patch`` section isolates the table-update step itself: the one
donated ``apply_patch_step`` call (zero host->device table uploads) against
the PR-3-era host-authoritative sync it replaced — a faithful replica of the
bucketed-scatter path (per-table jitted scatters fed from host edit arrays,
host-computed touched rows, wholesale decision/demand re-uploads) — and
against a wholesale table re-upload.

``--check`` gates the measured speedups AND the device-patch latency against
the committed ``BENCH_baselines.json`` (±tolerance, redisbench-admin style)
in addition to the absolute floors, so a regression on either axis fails CI.

Run:  PYTHONPATH=src python -m benchmarks.run --dynamic [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, compile_plan
from repro.core.plan_patch import apply_patch_step
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dynamic.json")
BASELINES_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_baselines.json")

RATIOS = (0.001, 0.01, 0.1)


# --------------------------------------------------- PR-3 sync-path replica
# The host-authoritative device sync this PR retired: per-table jitted slot
# scatters whose (bucketed) edit arrays live on the host (implicit h2d per
# call), touched rows computed host-side and uploaded, and the demand /
# decision tables pulled, rewritten and re-uploaded wholesale. Kept here as
# the benchmark baseline the device-resident program must beat.
@jax.jit
def _legacy_slot_scatter(seg, src, sign, lvl, slot, seg_v, src_v, sign_v):
    return (seg.at[lvl, slot].set(seg_v, mode="drop"),
            src.at[lvl, slot].set(src_v, mode="drop"),
            sign.at[lvl, slot].set(sign_v, mode="drop"))


@jax.jit
def _legacy_touched_scatter(touched, lvls, rows):
    return touched.at[lvls].set(rows, mode="drop")


def _legacy_sync(arrays, prog_host, host) -> list:
    """Replay one lowered patch the PR-3 way. Returns the output arrays so
    the caller can block on them; nothing is installed."""
    out = []
    for name in ("push", "pull"):
        t = getattr(arrays, name)
        tp = getattr(prog_host, name)
        mirror = getattr(host, name).mirror
        out.extend(_legacy_slot_scatter(t.seg, t.src, t.sign, tp.lvl, tp.slot,
                                        tp.seg, tp.src, tp.sign))
        # PR 3 re-uploaded the touched ROW of every changed level from the
        # host-authoritative mirror, count-bucketed — replicate that
        L = mirror.touched.shape[0]
        lv = np.unique(np.concatenate([tp.t_lvl[tp.t_lvl < L],
                                       tp.row_lvl[tp.row_lvl < L]]))
        k = 8
        while k < lv.size:
            k *= 4
        lvp = np.full(k, 2 ** 30, np.int32)
        lvp[: lv.size] = lv
        rows = mirror.touched[np.clip(lvp, 0, L - 1)]
        out.append(_legacy_touched_scatter(t.touched, lvp, rows))
    # wholesale demand/decision resync (the PR-3 behavior when either moved)
    dd = np.array(arrays.demand_dst)
    ds = np.array(arrays.demand_src)
    out.append(jnp.asarray(dd))
    out.append(jnp.asarray(ds))
    out.append(jnp.asarray(host.decision[: len(host.decision)]
                           .astype(np.int32)))
    return out


def _wholesale_resync(host, arrays) -> list:
    """The heavy-churn fallback of the host-authoritative design: re-upload
    every table from the host mirror."""
    out = []
    for name in ("push", "pull"):
        m = getattr(host, name).mirror
        th = getattr(host, name)
        out.extend([jnp.asarray(m.seg), jnp.asarray(m.src),
                    jnp.asarray(m.sign), jnp.asarray(m.touched),
                    jnp.asarray(th.tob), jnp.asarray(th.fot)])
    return out


def _bench_device_patch(eng, dyn, rng, readers, n_base: int, bursts: int,
                        n_ops: int) -> dict:
    """Isolate the table-update step: device-resident ``apply_patch_step``
    (edits only, one donated call) vs the legacy scatter sync vs a wholesale
    re-upload, on identical lowered deltas."""
    eng.plan.host.enable_mirror(eng.plan)
    apply_s, step_s, legacy_s, resync_s = [], [], [], []
    for _ in range(bursts):
        _churn_ops(dyn, rng, readers, n_base, n_ops)
        delta = dyn.drain_delta()
        t0 = time.perf_counter()
        res = eng.apply_delta(delta)
        jax.block_until_ready(eng.state.pao)
        apply_s.append(time.perf_counter() - t0)
        if res.recompiled or res.program is None:
            continue
        # re-apply the same program to a throwaway copy: the program sets
        # absolute values, so this is idempotent — pure device-step timing
        copy = jax.tree.map(jnp.copy, eng.plan.arrays)
        jax.block_until_ready(copy)
        t0 = time.perf_counter()
        out = apply_patch_step(eng.plan.meta, copy, res.program)
        jax.block_until_ready(out)
        step_s.append(time.perf_counter() - t0)
        prog_host = jax.device_get(res.program)
        t0 = time.perf_counter()
        jax.block_until_ready(_legacy_sync(eng.plan.arrays, prog_host,
                                           eng.plan.host))
        legacy_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(_wholesale_resync(eng.plan.host,
                                               eng.plan.arrays))
        resync_s.append(time.perf_counter() - t0)
    if not step_s:
        return {"bursts": 0, "ops_per_burst": n_ops}
    med = statistics.median
    step, legacy = med(step_s), med(legacy_s)
    return {
        "bursts": len(step_s),
        "ops_per_burst": n_ops,
        "apply_s_median": round(med(apply_s), 5),
        "step_s_median": round(step, 6),
        "legacy_scatter_sync_s_median": round(legacy, 6),
        "wholesale_resync_s_median": round(med(resync_s), 6),
        "speedup_vs_scatter_sync": round(legacy / step, 2) if step else None,
    }


def _churn_ops(dyn: DynamicOverlay, rng, readers, n_base: int, n_ops: int):
    """One burst: a mix of edge adds (70%) and deletes (30%)."""
    for _ in range(n_ops):
        r = int(rng.choice(readers))
        if rng.random() < 0.7 or not dyn.reader_inputs.get(r):
            dyn.add_edge(int(rng.integers(0, n_base)), r)
        else:
            dyn.delete_edge(int(next(iter(dyn.reader_inputs[r]))), r)


def run_dynamic_bench(quick: bool = False, out_path: str = OUT_PATH,
                      check: bool = False) -> dict:
    graph = dict(n_nodes=2_000, n_edges=12_000) if quick else \
        dict(n_nodes=6_000, n_edges=36_000)
    bursts = 8 if quick else 15
    g = rmat_graph(seed=0, **graph)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    ris = bp.reader_input_sets()
    dyn = DynamicOverlay.from_overlay(ov, ris)
    ov0 = dyn.to_overlay(prune=False)
    rng = np.random.default_rng(1)
    wf = rng.zipf(1.6, graph["n_nodes"]).clip(1, 1000).astype(np.float64)
    rf = wf[rng.permutation(graph["n_nodes"])]
    dec, _ = D.decide_mincut(ov0, wf, rf, D.cost_model_for("sum"))

    eng = EagrEngine(ov0, dec, make_aggregate("sum"), WindowSpec("tuple", 8),
                     headroom=2.0)
    readers = np.array(list(ris))
    writers = bp.writers

    def write():
        ids = rng.choice(writers, 256)
        vals = rng.normal(size=256).astype(np.float32)
        eng.write_batch(ids, vals, batch_size=256)

    # warm: compile the write/read/refresh programs once
    write()
    eng.read_batch(rng.choice(readers, 256), batch_size=256)
    dyn.add_edge(int(writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    write()

    # Full-rebuild baseline: what every structural update costs without the
    # patch path. Two components, reported separately:
    #   * compile: to_overlay + compile_plan table build (repeatable median)
    #   * retrace: the first write+read through the freshly shaped plan —
    #     natural padding drifts under churn, so the pre-patch flow pays this
    #     jit recompile whenever any padded dim moves (the common case).
    compile_s = []
    for _ in range(3):
        t0 = time.perf_counter()
        ov_now = dyn.to_overlay(prune=False)
        plan2 = compile_plan(ov_now, eng.plan.decision,
                             backend=eng.plan.meta.backend)
        compile_s.append(time.perf_counter() - t0)
    compile_median = statistics.median(compile_s)
    eng2 = EagrEngine(ov_now, eng.plan.decision, make_aggregate("sum"),
                      WindowSpec("tuple", 8), plan=plan2)
    t0 = time.perf_counter()
    eng2.write_batch(rng.choice(writers, 256),
                     rng.normal(size=256).astype(np.float32), batch_size=256)
    eng2.read_batch(rng.choice(readers, 256), batch_size=256)
    jax.block_until_ready(eng2.state.pao)
    retrace_s = time.perf_counter() - t0
    del eng2
    rebuild_median = compile_median + retrace_s

    report = {
        "bench": "dynamic_churn",
        "device": jax.default_backend(),
        "graph": graph,
        "n_readers": int(len(readers)),
        "bursts_per_ratio": bursts,
        "rebuild_compile_s_median": round(compile_median, 4),
        "rebuild_retrace_s": round(retrace_s, 4),
        "rebuild_total_s": round(rebuild_median, 4),
        "ratios": {},
    }
    for ratio in RATIOS:
        n_ops = max(1, int(len(readers) * ratio))
        patch_s, recompiles = [], 0
        for _ in range(bursts):
            _churn_ops(dyn, rng, readers, graph["n_nodes"], n_ops)
            delta = dyn.drain_delta()
            t0 = time.perf_counter()
            res = eng.apply_delta(delta)
            jax.block_until_ready(eng.state.pao)
            patch_s.append(time.perf_counter() - t0)
            recompiles += bool(res.recompiled)
            write()
        med = statistics.median(patch_s)
        row = {
            "ops_per_burst": n_ops,
            "patch_s_median": round(med, 5),
            "patch_s_p90": round(sorted(patch_s)[int(0.9 * len(patch_s))], 5),
            "updates_per_s": round(n_ops / med) if med else None,
            "recompile_fallbacks": recompiles,
            "speedup_patch_vs_rebuild": round(rebuild_median / med, 2)
            if med else None,
        }
        report["ratios"][str(ratio)] = row
        print(f"dynamic/churn={ratio:.3%}: {row}", flush=True)

    n_ops = max(1, int(len(readers) * 0.01))
    report["device_patch"] = _bench_device_patch(
        eng, dyn, rng, readers, graph["n_nodes"],
        bursts=8 if quick else 12, n_ops=n_ops)
    print(f"dynamic/device_patch: {report['device_patch']}", flush=True)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    if check:
        _check_report(report, quick)
    return report


def _check_report(report: dict, quick: bool) -> None:
    """Regression gates: absolute floors, plus the committed-baseline
    comparison (redisbench-admin style — fail when a metric regresses past
    the tolerance band around the checked-in reference numbers)."""
    # absolute floors are a coarse backstop (the committed baselines below
    # are the real gate); the full-mode floor is calibrated against the
    # 10%-churn ratio, whose rebuild baseline got cheaper as compile_plan
    # and the retrace path sped up
    floor = 3.0 if quick else 4.0
    worst = min(r["speedup_patch_vs_rebuild"]
                for r in report["ratios"].values())
    if worst < floor:
        raise SystemExit(
            f"patch-path regression: min speedup {worst:.1f}x < {floor}x")
    dp = report["device_patch"]
    if "apply_s_median" not in dp:
        raise SystemExit(
            "device-patch regression: no in-capacity burst completed "
            f"(every burst fell back to a recompile: {dp})")
    if dp.get("speedup_vs_scatter_sync") is not None \
            and dp["speedup_vs_scatter_sync"] < 1.0:
        raise SystemExit(
            "device-patch regression: the zero-upload apply_patch_step "
            f"({dp['step_s_median']}s) lost to the legacy scatter sync "
            f"({dp['legacy_scatter_sync_s_median']}s)")
    msgs = [f"min patch speedup {worst:.1f}x >= {floor}x"]
    try:
        with open(BASELINES_PATH) as f:
            baselines = json.load(f)
        base = baselines["dynamic"]["quick" if quick else "full"]
        tol = float(baselines.get("tolerance", 0.30))
    except (OSError, KeyError):
        print("check: no committed baseline for this mode — floors only",
              flush=True)
        base, tol = None, 0.30
    if base is not None:
        lo = 1.0 - tol
        hi = 1.0 + tol
        b = base["speedup_patch_vs_rebuild_min"]
        if worst < b * lo:
            raise SystemExit(
                f"baseline regression: min patch-vs-rebuild speedup "
                f"{worst:.1f}x < {b}x * {lo:.2f} (BENCH_baselines.json)")
        msgs.append(f"patch-vs-rebuild {worst:.1f}x within {tol:.0%} of "
                    f"baseline {b}x")
        bdp = base["device_patch"]
        got = dp["apply_s_median"]
        if got > bdp["apply_s_median"] * hi:
            raise SystemExit(
                f"baseline regression: zero-upload patch latency {got}s > "
                f"{bdp['apply_s_median']}s * {hi:.2f} (BENCH_baselines.json)")
        msgs.append(f"device-patch apply {got}s within {tol:.0%} of "
                    f"baseline {bdp['apply_s_median']}s")
        bs = bdp.get("speedup_vs_scatter_sync")
        gs = dp.get("speedup_vs_scatter_sync")
        if bs is not None and gs is not None and gs < bs * lo:
            raise SystemExit(
                f"baseline regression: device-patch speedup vs scatter sync "
                f"{gs}x < {bs}x * {lo:.2f} (BENCH_baselines.json)")
    print("check passed: " + "; ".join(msgs), flush=True)


if __name__ == "__main__":
    import sys
    run_dynamic_bench(quick="--quick" in sys.argv, check="--check" in sys.argv)
