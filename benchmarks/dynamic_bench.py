"""Structural-churn benchmark -> BENCH_dynamic.json.

Measures §3.3 incremental plan maintenance against the full-rebuild path it
replaces: per churn burst, the wall-clock of ``EagrEngine.apply_delta``
(journaled delta -> in-place PlanArrays patch -> PAO refresh) versus a fresh
``compile_plan`` over the same overlay — at churn ratios touching 0.1%, 1%,
and 10% of the readers per burst. Also reports structural updates/s through
the patch path and how many bursts fell back to a recompile.

Run:  PYTHONPATH=src python -m benchmarks.run --dynamic [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax
import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, compile_plan
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dynamic.json")

RATIOS = (0.001, 0.01, 0.1)


def _churn_ops(dyn: DynamicOverlay, rng, readers, n_base: int, n_ops: int):
    """One burst: a mix of edge adds (70%) and deletes (30%)."""
    for _ in range(n_ops):
        r = int(rng.choice(readers))
        if rng.random() < 0.7 or not dyn.reader_inputs.get(r):
            dyn.add_edge(int(rng.integers(0, n_base)), r)
        else:
            dyn.delete_edge(int(next(iter(dyn.reader_inputs[r]))), r)


def run_dynamic_bench(quick: bool = False, out_path: str = OUT_PATH,
                      check: bool = False) -> dict:
    graph = dict(n_nodes=2_000, n_edges=12_000) if quick else \
        dict(n_nodes=6_000, n_edges=36_000)
    bursts = 8 if quick else 15
    g = rmat_graph(seed=0, **graph)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    ris = bp.reader_input_sets()
    dyn = DynamicOverlay.from_overlay(ov, ris)
    ov0 = dyn.to_overlay(prune=False)
    rng = np.random.default_rng(1)
    wf = rng.zipf(1.6, graph["n_nodes"]).clip(1, 1000).astype(np.float64)
    rf = wf[rng.permutation(graph["n_nodes"])]
    dec, _ = D.decide_mincut(ov0, wf, rf, D.cost_model_for("sum"))

    eng = EagrEngine(ov0, dec, make_aggregate("sum"), WindowSpec("tuple", 8),
                     headroom=2.0)
    readers = np.array(list(ris))
    writers = bp.writers

    def write():
        ids = rng.choice(writers, 256)
        vals = rng.normal(size=256).astype(np.float32)
        eng.write_batch(ids, vals, batch_size=256)

    # warm: compile the write/read/refresh programs once
    write()
    eng.read_batch(rng.choice(readers, 256), batch_size=256)
    dyn.add_edge(int(writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    write()

    # Full-rebuild baseline: what every structural update costs without the
    # patch path. Two components, reported separately:
    #   * compile: to_overlay + compile_plan table build (repeatable median)
    #   * retrace: the first write+read through the freshly shaped plan —
    #     natural padding drifts under churn, so the pre-patch flow pays this
    #     jit recompile whenever any padded dim moves (the common case).
    compile_s = []
    for _ in range(3):
        t0 = time.perf_counter()
        ov_now = dyn.to_overlay(prune=False)
        plan2 = compile_plan(ov_now, eng.plan.decision,
                             backend=eng.plan.meta.backend)
        compile_s.append(time.perf_counter() - t0)
    compile_median = statistics.median(compile_s)
    eng2 = EagrEngine(ov_now, eng.plan.decision, make_aggregate("sum"),
                      WindowSpec("tuple", 8), plan=plan2)
    t0 = time.perf_counter()
    eng2.write_batch(rng.choice(writers, 256),
                     rng.normal(size=256).astype(np.float32), batch_size=256)
    eng2.read_batch(rng.choice(readers, 256), batch_size=256)
    jax.block_until_ready(eng2.state.pao)
    retrace_s = time.perf_counter() - t0
    del eng2
    rebuild_median = compile_median + retrace_s

    report = {
        "bench": "dynamic_churn",
        "device": jax.default_backend(),
        "graph": graph,
        "n_readers": int(len(readers)),
        "bursts_per_ratio": bursts,
        "rebuild_compile_s_median": round(compile_median, 4),
        "rebuild_retrace_s": round(retrace_s, 4),
        "rebuild_total_s": round(rebuild_median, 4),
        "ratios": {},
    }
    for ratio in RATIOS:
        n_ops = max(1, int(len(readers) * ratio))
        patch_s, recompiles = [], 0
        for _ in range(bursts):
            _churn_ops(dyn, rng, readers, graph["n_nodes"], n_ops)
            delta = dyn.drain_delta()
            t0 = time.perf_counter()
            res = eng.apply_delta(delta)
            jax.block_until_ready(eng.state.pao)
            patch_s.append(time.perf_counter() - t0)
            recompiles += bool(res.recompiled)
            write()
        med = statistics.median(patch_s)
        row = {
            "ops_per_burst": n_ops,
            "patch_s_median": round(med, 5),
            "patch_s_p90": round(sorted(patch_s)[int(0.9 * len(patch_s))], 5),
            "updates_per_s": round(n_ops / med) if med else None,
            "recompile_fallbacks": recompiles,
            "speedup_patch_vs_rebuild": round(rebuild_median / med, 2)
            if med else None,
        }
        report["ratios"][str(ratio)] = row
        print(f"dynamic/churn={ratio:.3%}: {row}", flush=True)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    if check:
        floor = 3.0 if quick else 10.0
        worst = min(r["speedup_patch_vs_rebuild"]
                    for r in report["ratios"].values())
        if worst < floor:
            raise SystemExit(
                f"patch-path regression: min speedup {worst:.1f}x < {floor}x")
        print(f"check passed: min patch speedup {worst:.1f}x >= {floor}x")
    return report


if __name__ == "__main__":
    import sys
    run_dynamic_bench(quick="--quick" in sys.argv, check="--check" in sys.argv)
