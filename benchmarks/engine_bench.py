"""Old-vs-new execution substrate benchmark -> BENCH_engine.json.

Compares the legacy per-level Python unroll ('xla_unrolled', the pre-refactor
program structure) against the unified leveled-CSR substrate ('xla' fallback,
plus 'pallas' when a TPU is attached) on a Zipfian read/write trace:

  * update (write) throughput, events/s
  * query (read) throughput, events/s
  * plan compile time (host) and first-batch jit time per path

The JSON is written to the repo root so successive PRs extend the perf
trajectory. Run:  PYTHONPATH=src python -m benchmarks.run --engine [--quick]
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import make_system
from repro.streams.traces import generate_trace

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")


def _measure(eng, bp, *, n_events, write_read_ratio, batch, seed=1):
    """Update and query throughput over one Zipfian trace, phase-separated:
    writes replay in full ``batch``-row batches, then reads — so the numbers
    measure the substrate, not the tiny homogeneous runs an interleaved
    replay produces (mean run length ~2 at a 1:1 ratio)."""
    readers = np.array(list(bp.reader_inputs))
    trace = generate_trace(bp.writers, readers, n_events,
                           write_read_ratio=write_read_ratio, seed=seed)
    from repro.streams.traces import WRITE
    wsel = trace.kind == WRITE
    w_ids, w_vals = trace.node[wsel], trace.value[wsel]
    r_ids = trace.node[~wsel]

    def chunks(a):
        return [a[i: i + batch] for i in range(0, len(a) - batch + 1, batch)]

    # warmup = compile both programs once
    t0 = time.perf_counter()
    eng.write_batch(w_ids[:batch], w_vals[:batch], batch_size=batch)
    eng.read_batch(r_ids[:batch], batch_size=batch)
    jax.block_until_ready(eng.state.pao)
    jit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_w = 0
    for ids, vals in zip(chunks(w_ids), chunks(w_vals)):
        eng.write_batch(ids, vals, batch_size=batch)
        n_w += len(ids)
    jax.block_until_ready(eng.state.pao)
    t_w = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_r = 0
    for ids in chunks(r_ids):
        eng.read_batch(ids, batch_size=batch)  # device_get syncs per batch
        n_r += len(ids)
    t_r = time.perf_counter() - t0
    return {
        "write_events_per_s": round(n_w / t_w) if t_w else None,
        "read_events_per_s": round(n_r / t_r) if t_r else None,
        "events_per_s": round((n_w + n_r) / (t_w + t_r)) if t_w + t_r else None,
        "first_batches_jit_s": round(jit_s, 3),
    }


def run_engine_bench(quick: bool = False, out_path: str = OUT_PATH) -> dict:
    graph = dict(n_nodes=4_000, n_edges=24_000) if quick else \
        dict(n_nodes=12_000, n_edges=72_000)
    n_events = 20_000 if quick else 60_000
    batch = 1024 if quick else 2048
    backends = ["xla_unrolled", "xla"]
    if jax.default_backend() == "tpu":
        backends.append("pallas")

    report = {
        "bench": "engine_substrate",
        "device": jax.default_backend(),
        "graph": graph,
        "n_events": n_events,
        "batch": batch,
        "trace": "zipf(alpha=1.0), write:read=1.0",
        "substrates": {},
    }
    for backend in backends:
        t0 = time.perf_counter()
        eng, bp, _, _ = make_system(algorithm="vnm_a", backend=backend, **graph)
        build_s = time.perf_counter() - t0
        from repro.core.engine import compile_plan
        t0 = time.perf_counter()
        compile_plan(eng.overlay, eng.plan.decision, backend=backend)
        compile_s = time.perf_counter() - t0
        res = _measure(eng, bp, n_events=n_events, write_read_ratio=1.0,
                       batch=batch)
        res["plan_compile_s"] = round(compile_s, 3)
        res["system_build_s"] = round(build_s, 3)  # graph+overlay+mincut+plan
        res["overlay_depth"] = eng.plan.depth
        res["padded_levels"] = eng.plan.meta.n_levels
        res["push_edges"] = eng.plan.n_push_edges
        res["pull_edges"] = eng.plan.n_pull_edges
        report["substrates"][backend] = res
        print(f"engine/{backend}: {res}", flush=True)

    old = report["substrates"].get("xla_unrolled", {})
    new = report["substrates"].get(
        "pallas" if "pallas" in report["substrates"] else "xla", {})
    if old.get("events_per_s") and new.get("events_per_s"):
        report["speedup_new_vs_old"] = round(
            new["events_per_s"] / old["events_per_s"], 3)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)
    return report


if __name__ == "__main__":
    import sys
    run_engine_bench(quick="--quick" in sys.argv)
