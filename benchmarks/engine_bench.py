"""Old-vs-new execution substrate benchmark -> BENCH_engine.json.

Compares the legacy per-level Python unroll ('xla_unrolled', the pre-refactor
program structure) against the unified leveled-CSR substrate ('xla' fallback,
plus 'pallas' when a TPU is attached) on a Zipfian read/write trace:

  * update (write) throughput, events/s
  * query (read) throughput, events/s
  * plan compile time (host) and first-batch jit time per path

The JSON is written to the repo root so successive PRs extend the perf
trajectory. Run:  PYTHONPATH=src python -m benchmarks.run --engine [--quick]
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import make_system
from repro.streams.traces import generate_trace

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")


def _measure(eng, bp, *, n_events, write_read_ratio, batch, seed=1):
    """Update and query throughput over one Zipfian trace, phase-separated:
    writes replay in full ``batch``-row batches, then reads — so the numbers
    measure the substrate, not the tiny homogeneous runs an interleaved
    replay produces (mean run length ~2 at a 1:1 ratio)."""
    readers = np.array(list(bp.reader_inputs))
    trace = generate_trace(bp.writers, readers, n_events,
                           write_read_ratio=write_read_ratio, seed=seed)
    from repro.streams.traces import WRITE
    wsel = trace.kind == WRITE
    w_ids, w_vals = trace.node[wsel], trace.value[wsel]
    r_ids = trace.node[~wsel]

    def chunks(a):
        return [a[i: i + batch] for i in range(0, len(a) - batch + 1, batch)]

    # warmup = compile both programs once
    t0 = time.perf_counter()
    eng.write_batch(w_ids[:batch], w_vals[:batch], batch_size=batch)
    eng.read_batch(r_ids[:batch], batch_size=batch)
    jax.block_until_ready(eng.state.pao)
    jit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_w = 0
    for ids, vals in zip(chunks(w_ids), chunks(w_vals)):
        eng.write_batch(ids, vals, batch_size=batch)
        n_w += len(ids)
    jax.block_until_ready(eng.state.pao)
    t_w = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_r = 0
    for ids in chunks(r_ids):
        eng.read_batch(ids, batch_size=batch)  # device_get syncs per batch
        n_r += len(ids)
    t_r = time.perf_counter() - t0
    return {
        "write_events_per_s": round(n_w / t_w) if t_w else None,
        "read_events_per_s": round(n_r / t_r) if t_r else None,
        "events_per_s": round((n_w + n_r) / (t_w + t_r)) if t_w + t_r else None,
        "first_batches_jit_s": round(jit_s, 3),
    }


TOPK_DOMAINS = (16, 32, 64, 128)


def _topk_lane_rows(quick: bool) -> dict:
    """F_BLK lane utilization for topk domains <= 128 (ROADMAP carry-over):
    the segment_agg kernel pads the PAO feature axis to F_BLK=128 lanes per
    tile, so a topk aggregate with ``domain`` lanes drives ``domain/128`` of
    each tile — the padded sweep costs the same regardless. Per-domain write
    throughput on the active substrate makes the overhead visible:
    events/s stays roughly flat across domains below F_BLK (the padded-lane
    ceiling), so effective per-lane throughput scales with utilization."""
    from repro.kernels.segment_agg.segment_agg import F_BLK

    from repro.core.aggregates import make_aggregate
    from repro.core.engine import EagrEngine
    from repro.core.window import WindowSpec

    rows: dict[str, dict] = {}
    n_events = 4_000 if quick else 12_000
    batch = 512
    base_eng, bp, _, _ = make_system(algorithm="vnm_a", aggregate="sum",
                                     n_nodes=2_000, n_edges=12_000)
    for domain in TOPK_DOMAINS:
        agg = make_aggregate("topk", k=3, domain=domain)
        eng = EagrEngine(base_eng.overlay, base_eng.plan.decision, agg,
                        WindowSpec("tuple", 8))
        writer_bases = np.flatnonzero(eng.plan.routes.writer_row >= 0)
        rng = np.random.default_rng(domain)
        ids = rng.choice(writer_bases, size=n_events).astype(np.int64)
        vals = rng.integers(0, domain, n_events).astype(np.float32)
        eng.write_batch(ids[:batch], vals[:batch], batch_size=batch)
        jax.block_until_ready(eng.state.pao)
        t0 = time.perf_counter()
        n = 0
        for i in range(0, n_events - batch + 1, batch):
            eng.write_batch(ids[i: i + batch], vals[i: i + batch],
                            batch_size=batch)
            n += batch
        jax.block_until_ready(eng.state.pao)
        dt = time.perf_counter() - t0
        f_pad = -(-domain // F_BLK) * F_BLK
        util = domain / f_pad
        ev_s = round(n / dt) if dt else None
        rows[str(domain)] = {
            "pao_dim": domain,
            "f_pad": f_pad,
            "lane_utilization": round(util, 4),
            "write_events_per_s": ev_s,
            "events_per_s_per_lane": round(ev_s / domain) if ev_s else None,
        }
        print(f"engine/topk_lanes[domain={domain}]: util {util:.2f} "
              f"{ev_s:,} ev/s", flush=True)
    return {"F_BLK": int(F_BLK), "domains": rows}


def run_engine_bench(quick: bool = False, out_path: str = OUT_PATH) -> dict:
    graph = dict(n_nodes=4_000, n_edges=24_000) if quick else \
        dict(n_nodes=12_000, n_edges=72_000)
    n_events = 20_000 if quick else 60_000
    batch = 1024 if quick else 2048
    backends = ["xla_unrolled", "xla"]
    if jax.default_backend() == "tpu":
        backends.append("pallas")

    report = {
        "bench": "engine_substrate",
        "device": jax.default_backend(),
        "graph": graph,
        "n_events": n_events,
        "batch": batch,
        "trace": "zipf(alpha=1.0), write:read=1.0",
        "substrates": {},
    }
    for backend in backends:
        t0 = time.perf_counter()
        eng, bp, _, _ = make_system(algorithm="vnm_a", backend=backend, **graph)
        build_s = time.perf_counter() - t0
        from repro.core.engine import compile_plan
        t0 = time.perf_counter()
        compile_plan(eng.overlay, eng.plan.decision, backend=backend)
        compile_s = time.perf_counter() - t0
        res = _measure(eng, bp, n_events=n_events, write_read_ratio=1.0,
                       batch=batch)
        res["plan_compile_s"] = round(compile_s, 3)
        res["system_build_s"] = round(build_s, 3)  # graph+overlay+mincut+plan
        res["overlay_depth"] = eng.plan.depth
        res["padded_levels"] = eng.plan.meta.n_levels
        res["push_edges"] = eng.plan.n_push_edges
        res["pull_edges"] = eng.plan.n_pull_edges
        report["substrates"][backend] = res
        print(f"engine/{backend}: {res}", flush=True)

    report["topk_lane_utilization"] = _topk_lane_rows(quick)

    old = report["substrates"].get("xla_unrolled", {})
    new = report["substrates"].get(
        "pallas" if "pallas" in report["substrates"] else "xla", {})
    if old.get("events_per_s") and new.get("events_per_s"):
        report["speedup_new_vs_old"] = round(
            new["events_per_s"] / old["events_per_s"], 3)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)
    return report


if __name__ == "__main__":
    import sys
    run_engine_bench(quick="--quick" in sys.argv)
