"""Benchmark harness (redisbench-admin style): small composable modules the
per-topic benches share instead of each growing its own copy.

  run_local   environment fingerprint, phase timers, latency percentiles,
              duration-based sustained loops
  compare     committed-baseline gates (tolerance bands + absolute floors)
              over dotted metric paths — the generalized `--check`
  export      per-PR trajectory export (BENCH_trajectory.jsonl, one line per
              run, keyed by git sha) for cross-PR throughput tracking
  watchdog    wall-clock budget guard + the optional `jax.profiler` trace
              hook (EAGR_PROFILE_DIR)
"""
from benchmarks.harness.compare import check_gates, load_baselines
from benchmarks.harness.export import export_trajectory
from benchmarks.harness.run_local import (
    Phases,
    env_fingerprint,
    frontier_summary,
    percentiles,
    sustained,
)
from benchmarks.harness.watchdog import Watchdog, profiler_trace

__all__ = [
    "check_gates",
    "load_baselines",
    "export_trajectory",
    "Phases",
    "env_fingerprint",
    "frontier_summary",
    "percentiles",
    "sustained",
    "Watchdog",
    "profiler_trace",
]
