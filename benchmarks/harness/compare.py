"""Committed-baseline gates: the generalized ``--check``.

A gate is (dotted metric path, direction, optional absolute floor). The
measured value must satisfy the floor AND stay inside the committed
baseline's tolerance band — the same two-sided discipline
``dynamic_bench._check_report`` established, factored out so every bench
shares one implementation.
"""
from __future__ import annotations

import json
import os
from typing import Any

BASELINES_PATH = os.path.join(
    os.path.dirname(os.path.dirname(__file__)), "..", "BENCH_baselines.json")


def load_baselines(path: str | None = None) -> dict:
    with open(path or os.path.abspath(BASELINES_PATH)) as fh:
        return json.load(fh)


def _lookup(report: dict, dotted: str) -> Any:
    cur: Any = report
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_gates(report: dict, gates: list[dict], *,
                baselines: dict | None = None,
                section: str | None = None,
                label: str = "bench") -> None:
    """Each gate: ``{"path": "modes.pipeline.events_per_s",
    "direction": "higher"|"lower", "floor": <abs min, optional>,
    "ceiling": <abs max, optional>, "baseline": <key, optional>}``.

    ``baseline`` names a key in ``baselines[section]``; when present the
    measured value must be >= base*(1-tol) for "higher" gates (<=
    base*(1+tol) for "lower"). Raises ``SystemExit`` listing every
    violation; prints one line per passing gate.
    """
    tol = float((baselines or {}).get("tolerance", 0.30))
    base_section = (baselines or {}).get(section or "", {}) \
        if baselines else {}
    failures: list[str] = []
    for g in gates:
        path = g["path"]
        val = _lookup(report, path)
        if val is None:
            failures.append(f"{path}: missing from report")
            continue
        val = float(val)
        higher = g.get("direction", "higher") == "higher"
        if "floor" in g and val < float(g["floor"]):
            failures.append(
                f"{path}: {val:.4g} below absolute floor {g['floor']:.4g}")
        if "ceiling" in g and val > float(g["ceiling"]):
            failures.append(
                f"{path}: {val:.4g} above absolute ceiling "
                f"{g['ceiling']:.4g}")
        base_key = g.get("baseline")
        if base_key is not None and base_key in base_section:
            base = float(base_section[base_key])
            if higher and val < base * (1.0 - tol):
                failures.append(
                    f"{path}: {val:.4g} regressed >"
                    f"{tol:.0%} below baseline {base:.4g}")
            elif not higher and val > base * (1.0 + tol):
                failures.append(
                    f"{path}: {val:.4g} regressed >"
                    f"{tol:.0%} above baseline {base:.4g}")
        if not failures or not failures[-1].startswith(path):
            print(f"  gate ok: {path} = {val:.4g}")
    if failures:
        for f in failures:
            print(f"  GATE FAIL [{label}]: {f}")
        raise SystemExit(f"{label}: {len(failures)} gate(s) failed")
    print(f"  {label}: all {len(gates)} gates passed")
