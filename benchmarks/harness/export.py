"""Per-PR trajectory export: one JSON line per bench run, keyed by git sha,
appended to ``BENCH_trajectory.jsonl``. Reading the file back gives the
throughput trajectory across the PR stack without re-running old commits."""
from __future__ import annotations

import json
import os
import time

from benchmarks.harness.run_local import _git

TRAJECTORY_PATH = os.path.join(
    os.path.dirname(os.path.dirname(__file__)), "..",
    "BENCH_trajectory.jsonl")


def export_trajectory(bench: str, metrics: dict,
                      path: str | None = None) -> str:
    """Append ``{ts, sha, branch, bench, metrics}`` to the trajectory file.
    ``metrics`` should be the small flat summary (headline numbers), not the
    whole report. Returns the path written."""
    path = os.path.abspath(path or TRAJECTORY_PATH)
    line = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "sha": _git("rev-parse", "--short", "HEAD"),
        "branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
        "bench": bench,
        "metrics": metrics,
    }
    with open(path, "a") as fh:
        fh.write(json.dumps(line, sort_keys=True) + "\n")
    return path
