"""Local-run primitives: fingerprint, phase timing, percentiles, sustained
duration loops. Everything here is measurement mechanics — benches supply
the workload, this module supplies the clock discipline."""
from __future__ import annotations

import contextlib
import os
import platform
import subprocess
import sys
import time


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ["git", *args], capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except OSError:
        return None


def env_fingerprint() -> dict:
    """What produced the numbers: versions, device inventory, git state.
    Committed next to every report so a regression can be attributed to
    code vs environment."""
    import jax
    import numpy as np

    return {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "numpy": np.__version__,
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "jax_device_count": jax.device_count(),
        "eagr_backend": os.environ.get("EAGR_BACKEND") or "(default)",
        "git_sha": _git("rev-parse", "--short", "HEAD"),
        "git_branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
    }


class Phases:
    """Named wall-clock phases of one bench run; serializes to the
    ``phase_seconds`` dict the construct bench popularized."""

    def __init__(self) -> None:
        self.seconds: dict[str, float] = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.seconds[name] = round(
                self.seconds.get(name, 0.0)
                + time.perf_counter() - t0, 3)


def percentiles(samples_s: list[float],
                pcts: tuple = (50.0, 99.0, 99.9)) -> dict:
    """Latency percentiles in milliseconds from a list of seconds samples.
    Keys look like ``p50_ms`` / ``p99_ms`` / ``p99_9_ms``."""
    out: dict[str, float | int] = {"n": len(samples_s)}
    if not samples_s:
        return out
    xs = sorted(samples_s)
    for p in pcts:
        idx = min(len(xs) - 1, max(0, round(p / 100.0 * (len(xs) - 1))))
        key = "p" + (f"{p:g}".replace(".", "_")) + "_ms"
        out[key] = round(xs[idx] * 1e3, 3)
    return out


def frontier_summary(counts: list[int]) -> dict:
    """Frontier-size distribution from an engine's ``frontier_log`` —
    re-exported from :mod:`repro.core.frontier` (the summary moved next to
    the index so ``EagrSession.stats()`` shares it)."""
    from repro.core.frontier import frontier_summary as impl

    return impl(counts)


def sustained(step, *, duration_s: float, barrier=None) -> dict:
    """Sustained-throughput loop: call ``step(i) -> events`` repeatedly for
    at least ``duration_s`` of wall clock, then run ``barrier()`` (e.g. a
    pipeline flush / ``block_until_ready``) INSIDE the timed region — what
    is measured is steady state including the final drain, not enqueue
    rate. Returns events, elapsed seconds and events/s."""
    t0 = time.perf_counter()
    events = steps = 0
    while time.perf_counter() - t0 < duration_s:
        events += int(step(steps))
        steps += 1
    if barrier is not None:
        barrier()
    elapsed = time.perf_counter() - t0
    return {
        "events": events,
        "steps": steps,
        "elapsed_s": round(elapsed, 3),
        "events_per_s": round(events / elapsed, 1) if elapsed else 0.0,
    }
