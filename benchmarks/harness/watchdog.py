"""Run guards: a wall-clock budget watchdog (so a hung sustained loop fails
loudly with stacks instead of eating the CI job timeout) and the optional
``jax.profiler`` trace hook gated on ``EAGR_PROFILE_DIR``."""
from __future__ import annotations

import contextlib
import faulthandler
import os
import sys
import threading


class Watchdog:
    """Context manager: if the body runs longer than ``budget_s``, dump all
    thread stacks to stderr and (by default) hard-exit. Benches wrap their
    sustained loops in one so a deadlocked ring barrier is diagnosable."""

    def __init__(self, budget_s: float, *, hard: bool = True,
                 label: str = "bench"):
        self.budget_s = float(budget_s)
        self.hard = hard
        self.label = label
        self._timer: threading.Timer | None = None

    def _fire(self) -> None:
        sys.stderr.write(
            f"\nWATCHDOG: {self.label} exceeded {self.budget_s:.0f}s "
            "wall-clock budget; dumping stacks\n")
        faulthandler.dump_traceback(file=sys.stderr)
        if self.hard:
            os._exit(2)

    def __enter__(self) -> "Watchdog":
        self._timer = threading.Timer(self.budget_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


@contextlib.contextmanager
def profiler_trace(name: str = "trace"):
    """Wrap a region in ``jax.profiler.trace`` when ``EAGR_PROFILE_DIR`` is
    set; otherwise a no-op. The trace lands in
    ``$EAGR_PROFILE_DIR/<name>`` for TensorBoard / Perfetto."""
    out = os.environ.get("EAGR_PROFILE_DIR")
    if not out:
        yield
        return
    import jax

    target = os.path.join(out, name)
    os.makedirs(target, exist_ok=True)
    with jax.profiler.trace(target):
        yield
