"""One benchmark per paper table/figure (EAGr, Mondal & Deshpande 2014).

  fig8   sharing index per construction algorithm (per iteration)
  fig9   VNM chunk-size sensitivity vs VNM_A
  fig10  construction running time + memory
  fig11a overlay depth distribution (VNM_A vs IOB)
  fig11b VNM_N: effect of allowed negative edges on SI
  fig12  pruning effectiveness before max-flow (by graph / by ratio)
  fig13b throughput: overlay+dataflow vs all-push vs all-pull (fixed ratio)
  fig13a adaptivity under workload shift
  fig13c read latency vs push:pull cost ratio
  fig14a end-to-end throughput across write:read ratios / aggregates
  fig14b node-splitting benefit
  fig14c 2-hop aggregates
"""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from benchmarks.common import (
    BenchResult,
    build_overlay,
    make_system,
    measure_throughput,
)
from repro.core import dataflow as D
from repro.core.bipartite import build_bipartite
from repro.core.vnm import construct_vnm
from repro.graphs.generators import rmat_graph
from repro.streams.traces import generate_trace, shift_workload

GRAPH = dict(n_nodes=12_000, n_edges=72_000)
SMALL = dict(n_nodes=5_000, n_edges=30_000)


def _bp(seed=0, **kw):
    g = rmat_graph(kw.get("n_nodes", GRAPH["n_nodes"]),
                   kw.get("n_edges", GRAPH["n_edges"]), seed=seed)
    return g, build_bipartite(g)


def fig8_sharing_index(out):
    """Two graph regimes, as in the paper: social-like (R-MAT; poor
    compression, paper's LiveJournal/G+) and web-like (copying model with
    out-neighborhood queries; high shared adjacency, paper's eu/uk graphs)."""
    from repro.graphs.generators import copying_graph

    g_soc, bp_soc = _bp()
    g_web = copying_graph(SMALL["n_nodes"], out_degree=8, copy_p=0.75, seed=0)
    bp_web = build_bipartite(
        g_web, neighborhood=lambda g, v: g.out_neighbors(v))
    for label, bp in (("social", bp_soc), ("web", bp_web)):
        for algo in ("vnm", "vnm_a", "vnm_n", "vnm_d", "iob"):
            ov, stats = build_overlay(bp, algo)
            si = ov.sharing_index(bp.n_edges)
            per_iter = getattr(stats, "si_per_iteration", [])
            out(BenchResult(f"fig8/SI/{label}/{algo}", 0, dict(
                si=round(si, 4),
                per_iter=[round(x, 3) for x in per_iter[:6]])))


def fig9_chunk_size(out):
    g, bp = _bp(**SMALL)
    for c in (25, 100, 400):
        ov, _ = construct_vnm(bp, variant="vnm", chunk_size=c, max_iterations=4)
        out(BenchResult(f"fig9/VNM/chunk={c}", 0,
                        dict(si=round(ov.sharing_index(bp.n_edges), 4))))
    ov, stats = construct_vnm(bp, variant="vnm_a", chunk_size=100, max_iterations=4)
    out(BenchResult("fig9/VNM_A/adaptive", 0, dict(
        si=round(ov.sharing_index(bp.n_edges), 4),
        chunk_schedule=stats.chunk_sizes)))


def fig10_time_memory(out):
    g, bp = _bp(**SMALL)
    for algo in ("vnm_a", "vnm_n", "vnm_d", "iob"):
        tracemalloc.start()
        t0 = time.perf_counter()
        ov, _ = build_overlay(bp, algo)
        dt = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        out(BenchResult(f"fig10/{algo}", 0, dict(
            seconds=round(dt, 2), peak_mb=round(peak / 1e6, 1),
            si=round(ov.sharing_index(bp.n_edges), 4))))


def fig11a_overlay_depth(out):
    g, bp = _bp(**SMALL)
    for algo in ("vnm_a", "iob"):
        ov, _ = build_overlay(bp, algo)
        depths = np.array(list(ov.depth_per_reader().values()))
        out(BenchResult(f"fig11a/depth/{algo}", 0, dict(
            mean=round(float(depths.mean()), 2), max=int(depths.max()))))


def fig11b_negative_edges(out):
    g, bp = _bp(**SMALL)
    for k1 in (1, 2, 3):
        ov, _ = construct_vnm(bp, variant="vnm_n", k1=k1, max_iterations=4)
        neg = sum(1 for ins in ov.in_edges for _, s in ins if s < 0)
        out(BenchResult(f"fig11b/VNM_N/k1={k1}", 0, dict(
            si=round(ov.sharing_index(bp.n_edges), 4), neg_edges=neg)))


def fig12_pruning(out):
    g, bp = _bp()
    ov, _ = build_overlay(bp, "vnm_a")
    for ratio in (0.1, 1.0, 10.0):
        tr = generate_trace(bp.writers, np.array(list(bp.reader_inputs)), 1,
                            write_read_ratio=ratio, n_base=g.n_nodes)
        _, st = D.decide_mincut(ov, tr.write_freq, tr.read_freq,
                                D.cost_model_for("sum"))
        out(BenchResult(f"fig12/pruning/ratio={ratio}", 0, dict(
            pruned=f"{st.pruned_fraction:.1%}",
            residual_nodes=st.maxflow_nodes,
            components=st.n_components,
            largest=st.largest_component)))


def fig13b_dataflow_baselines(out, budget=30_000):
    for dec in ("all_push", "all_pull", "mincut"):
        eng, bp, _, _ = make_system(decisions=dec, algorithm="vnm_a", **GRAPH)
        tput = measure_throughput(eng, bp, n_events=budget)
        out(BenchResult(f"fig13b/overlay+{dec}", tput,
                        dict(push=int((eng.plan.decision == 0).sum()),
                             pull=int((eng.plan.decision == 1).sum()))))


def fig13a_adaptivity(out, budget=20_000):
    eng, bp, g, _ = make_system(algorithm="vnm_a", **GRAPH)
    readers = np.array(list(bp.reader_inputs))
    trace = generate_trace(bp.writers, readers, budget, n_base=g.n_nodes)
    # mid-trace shift: boost reads of the highest-latency (deep pull) readers
    depths = eng.overlay.depth_per_reader()
    worst = sorted(depths, key=depths.get)[-200:]
    worst_base = np.array([eng.overlay.origin[v] for v in worst])
    shifted = shift_workload(trace, worst_base, factor=20.0)
    t_static = measure_throughput(eng, bp, n_events=budget, seed=3)
    # adapt the frontier to the observed (shifted) frequencies
    dec2, flips = D.adapt_decisions(
        eng.overlay, eng.plan.decision, shifted.write_freq, shifted.read_freq,
        D.cost_model_for("sum", window=8))
    from repro.core.engine import EagrEngine
    from repro.core.window import WindowSpec
    eng2 = EagrEngine(eng.overlay, dec2, eng.agg, eng.spec)
    t_adapted = measure_throughput(eng2, bp, n_events=budget, seed=3)
    out(BenchResult("fig13a/static-after-shift", t_static, dict()))
    out(BenchResult("fig13a/adapted", t_adapted, dict(flips=flips)))


def fig13c_latency(out):
    import jax
    eng, bp, _, _ = make_system(algorithm="vnm_a", **SMALL)
    readers = np.array(list(bp.reader_inputs))
    rng = np.random.default_rng(0)
    eng.write_batch(rng.choice(bp.writers, 1024),
                    rng.normal(size=1024).astype(np.float32))
    lats = []
    for _ in range(200):
        r = rng.choice(readers, 1)
        t0 = time.perf_counter()
        eng.read_batch(r, batch_size=1)
        lats.append((time.perf_counter() - t0) * 1e6)
    lats = np.array(lats[20:])
    out(BenchResult("fig13c/read-latency", 0, dict(
        p50_us=round(float(np.percentile(lats, 50)), 1),
        p95_us=round(float(np.percentile(lats, 95)), 1),
        worst_us=round(float(lats.max()), 1))))


def fig14a_throughput(out, budget=20_000):
    for agg in ("sum", "max", "topk"):
        for ratio in (0.1, 1.0, 10.0):
            algo = "vnm_d" if agg == "max" else "vnm_n"
            eng, bp, _, _ = make_system(aggregate=agg, algorithm=algo,
                                        write_read_ratio=ratio, **SMALL)
            tput = measure_throughput(eng, bp, n_events=budget,
                                      write_read_ratio=ratio)
            out(BenchResult(f"fig14a/{agg}/wr={ratio}", tput, dict()))


def fig14b_node_splitting(out, budget=30_000):
    for split in (False, True):
        eng, bp, _, _ = make_system(algorithm="vnm_a", split=split, **GRAPH)
        tput = measure_throughput(eng, bp, n_events=budget)
        out(BenchResult(f"fig14b/split={split}", tput,
                        dict(n_nodes=eng.overlay.n_nodes)))


def fig14c_two_hop(out, budget=20_000):
    for dec in ("all_pull", "all_push", "mincut"):
        eng, bp, _, _ = make_system(hops=2, decisions=dec, algorithm="vnm_a",
                                    **SMALL)
        tput = measure_throughput(eng, bp, n_events=budget)
        out(BenchResult(f"fig14c/2hop/{dec}", tput,
                        dict(bip_edges=bp.n_edges)))


ALL = [fig8_sharing_index, fig9_chunk_size, fig10_time_memory,
       fig11a_overlay_depth, fig11b_negative_edges, fig12_pruning,
       fig13b_dataflow_baselines, fig13a_adaptivity, fig13c_latency,
       fig14a_throughput, fig14b_node_splitting, fig14c_two_hop]
