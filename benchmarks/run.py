"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig14a --quick
  PYTHONPATH=src python -m benchmarks.run --engine   # substrate bench -> BENCH_engine.json
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="substring filter, e.g. fig12")
    p.add_argument("--quick", action="store_true",
                   help="smaller graphs/budgets (CI mode)")
    p.add_argument("--engine", action="store_true",
                   help="run the old-vs-new substrate benchmark and emit "
                        "BENCH_engine.json (skips the paper figures)")
    p.add_argument("--dynamic", action="store_true",
                   help="run the structural-churn benchmark (patch vs "
                        "recompile, §3.3) and emit BENCH_dynamic.json")
    p.add_argument("--construct", action="store_true",
                   help="run the overlay-construction scale benchmark "
                        "(12k/120k/1M graphs, per-phase breakdown) and emit "
                        "BENCH_construct.json")
    p.add_argument("--sharded", action="store_true",
                   help="run the stacked shard_map vs per-shard host loop "
                        "benchmark at 2/4/8 shards (forces 8 host devices) "
                        "and emit BENCH_sharded.json")
    p.add_argument("--streaming", action="store_true",
                   help="run the streaming-ingest benchmark (legacy vs "
                        "vectorized vs pipelined write path, reads under "
                        "write, per-backend rows) and emit "
                        "BENCH_streaming.json")
    p.add_argument("--alerts", action="store_true",
                   help="run the standing-alert benchmark (push-based "
                        "device predicates vs the poll-everything oracle, "
                        "fired-fraction sweep, detection latency under "
                        "ingest) and emit BENCH_alerts.json")
    p.add_argument("--checkpoint", action="store_true",
                   help="run the durable-session benchmark (cold build vs "
                        "save/restore time-to-first-answer, restore with "
                        "resharding) and emit BENCH_checkpoint.json")
    p.add_argument("--check", action="store_true",
                   help="with --dynamic/--sharded/--streaming/--checkpoint: "
                        "exit nonzero if the measured path regresses below "
                        "its floor")
    args = p.parse_args(argv)

    if args.engine:
        from benchmarks.engine_bench import run_engine_bench
        run_engine_bench(quick=args.quick)
        return
    if args.dynamic:
        from benchmarks.dynamic_bench import run_dynamic_bench
        run_dynamic_bench(quick=args.quick, check=args.check)
        return
    if args.construct:
        from benchmarks.construct_bench import run_construct_bench
        run_construct_bench(quick=args.quick, check=args.check)
        return
    if args.sharded:
        from benchmarks.sharded_bench import run_sharded_bench
        run_sharded_bench(quick=args.quick, check=args.check)
        return
    if args.streaming:
        from benchmarks.streaming_bench import run_streaming_bench
        run_streaming_bench(quick=args.quick, check=args.check)
        return
    if args.alerts:
        from benchmarks.alerts_bench import run_alerts_bench
        run_alerts_bench(quick=args.quick, check=args.check)
        return
    if args.checkpoint:
        from benchmarks.checkpoint_bench import run_checkpoint_bench
        run_checkpoint_bench(quick=args.quick, check=args.check)
        return

    import benchmarks.paper_figures as F

    if args.quick:
        F.GRAPH = dict(n_nodes=4_000, n_edges=24_000)
        F.SMALL = dict(n_nodes=2_000, n_edges=12_000)

    rows = []

    def out(res):
        rows.append(res)
        print(res.row(), flush=True)

    t0 = time.time()
    for fn in F.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        print(f"== {fn.__name__}", flush=True)
        try:
            fn(out)
        except Exception as e:  # keep the harness going; report at the end
            print(f"{fn.__name__},FAILED,{type(e).__name__}: {e}", flush=True)
    print(f"\n{len(rows)} rows in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
