"""Stacked shard_map execution vs the per-shard host loop -> BENCH_sharded.json.

Measures the PR-3 refactor end to end at 2/4/8 shards: one global write batch
plus one global read batch per step, through

  * host_loop — ``shard_write_batch`` / ``shard_read_batch`` host routing and
    n_shards sequential jitted per-shard dispatches (the pre-stacking path,
    kept as the parity baseline), and
  * stacked   — ``StackedShardedEngine``: one ``shard_map`` program over the
    device mesh (vmap fallback when devices < shards), batch routing
    on-device via all-gather + owner maps, reads gathered by one psum.

The process forces 8 host CPU devices (when jax is not yet initialized) so
the CPU CI smoke exercises the real collective path.

Run:  PYTHONPATH=src python -m benchmarks.run --sharded [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

if "jax" not in sys.modules:  # must precede first jax init
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = \
            (_flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.distributed.eagr_shard import (
    host_loop_read,
    host_loop_write,
    partition_overlay,
)
from repro.distributed.stacked import StackedShardedEngine
from repro.graphs.generators import rmat_graph

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sharded.json")

SHARD_COUNTS = (2, 4, 8)


def _host_loop_step(sharded, engines, ids, vals, readers):
    host_loop_write(sharded, engines, ids, vals)
    return host_loop_read(sharded, engines, readers)


def run_sharded_bench(quick: bool = False, out_path: str = OUT_PATH,
                      check: bool = False) -> dict:
    graph = dict(n_nodes=2_000, n_edges=12_000) if quick else \
        dict(n_nodes=6_000, n_edges=36_000)
    steps = 12 if quick else 30
    batch = 256
    g = rmat_graph(seed=0, **graph)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    rng = np.random.default_rng(1)
    wf = rng.zipf(1.6, graph["n_nodes"]).clip(1, 1000).astype(np.float64)
    rf = wf[rng.permutation(graph["n_nodes"])]
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    agg = make_aggregate("sum")
    spec = WindowSpec("tuple", 8)
    readers_all = np.array(list(bp.reader_input_sets()))

    report = {
        "bench": "sharded_stacked_vs_host_loop",
        "device": jax.default_backend(),
        "n_devices": jax.device_count(),
        "graph": graph,
        "batch": batch,
        "steps_per_config": steps,
        "shards": {},
    }
    for S in SHARD_COUNTS:
        sharded = partition_overlay(ov, dec, n_shards=S, seed=0)
        stacked = StackedShardedEngine(sharded, agg, spec)
        engines = [EagrEngine(s, d, agg, spec, plan=p)
                   for s, d, p in zip(sharded.shards,
                                      sharded.shard_decisions,
                                      sharded.shard_plans)]

        def make_batch():
            ids = rng.choice(bp.writers, batch)
            vals = rng.normal(size=batch).astype(np.float32)
            readers = rng.choice(readers_all, batch)
            return ids, vals, readers

        # warm both paths + parity check (bit-identical by construction)
        ids, vals, readers = make_batch()
        stacked.write_batch(ids, vals, batch_size=batch)
        want = _host_loop_step(sharded, engines, ids, vals, readers)
        got = stacked.read_batch(readers, batch_size=batch)
        np.testing.assert_array_equal(np.asarray(got), want)

        # interleave the two paths so scheduler drift (2-core CI runners with
        # 8 forced devices oversubscribe heavily) hits both medians alike
        batches = [make_batch() for _ in range(steps)]
        loop_s, stacked_s = [], []
        for ids, vals, readers in batches:
            t0 = time.perf_counter()
            _host_loop_step(sharded, engines, ids, vals, readers)
            jax.block_until_ready(engines[-1].state.pao)
            loop_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            stacked.write_batch(ids, vals, batch_size=batch)
            stacked.read_batch(readers, batch_size=batch)
            jax.block_until_ready(stacked.state.pao)
            stacked_s.append(time.perf_counter() - t0)

        loop_med = statistics.median(loop_s)
        stacked_med = statistics.median(stacked_s)
        row = {
            "mode": "shard_map" if stacked.mesh is not None else "vmap",
            "host_loop_s_median": round(loop_med, 5),
            "stacked_s_median": round(stacked_med, 5),
            "host_loop_steps_per_s": round(1.0 / loop_med, 1),
            "stacked_steps_per_s": round(1.0 / stacked_med, 1),
            "speedup_stacked_vs_loop": round(loop_med / stacked_med, 2),
        }
        report["shards"][str(S)] = row
        print(f"sharded/S={S}: {row}", flush=True)

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    if check:
        # the claim is scaling: at SOME shard count >= 4 the one-program path
        # must beat the sequential host loop (per-count medians are noisy on
        # oversubscribed CI cores, so gate on the best, not the worst)
        best = max(r["speedup_stacked_vs_loop"]
                   for s, r in report["shards"].items() if int(s) >= 4)
        if best < 1.0:
            raise SystemExit(
                f"stacked-path regression: best speedup {best:.2f}x < 1.0x "
                f"at >=4 shards — the one-program path must beat the host loop")
        print(f"check passed: stacked {best:.2f}x host loop at >=4 shards")
    return report


if __name__ == "__main__":
    run_sharded_bench(quick="--quick" in sys.argv, check="--check" in sys.argv)
