"""Streaming-ingest benchmark -> BENCH_streaming.json.

Measures sustained steady-state ingest (events/s) on one graph under three
write paths sharing one engine:

  legacy_sync      in-bench replica of the pre-PR-7 path: per-event Python
                   routing (dict lookups + keep-list) and one dense device
                   step per arrival batch (the legacy system predates the
                   frontier index) — the synchronous baseline the ISSUE
                   gates against
  vectorized_sync  ``write_batch`` (one BaseRoutes table lookup per batch),
                   still one device step per arrival batch
  pipeline         :class:`IngestPipeline` — vectorized routing plus ring
                   double-buffering and coalescing of arrival batches into
                   ``device_batch``-sized device steps

plus a ``sparse_vs_dense`` phase (PR 8): median write-step latency under the
frontier-sparse path vs the dense sweep at batch/overlay ratios of 0.01% /
0.1% / 1%, with the per-step frontier-size distribution; p50/p99/p99.9 read
latency sampled *during* the pipelined write load (reads-under-write); and a
per-backend (pallas / xla / xla_unrolled) ingest+read throughput section on
a small graph (ROADMAP carry-over).

Full mode runs the paper-scale 1M-node / 10M-edge power-law graph; quick mode
a 20k/120k R-MAT (CI). ``--check`` gates the pipeline-vs-legacy speedup
(absolute floor 1.5x), sustained pipeline events/s and the p99
read-under-write latency against ``BENCH_baselines.json``.

Run:  PYTHONPATH=src python -m benchmarks.run --streaming [--quick] [--check]
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.harness import (
    Phases,
    Watchdog,
    check_gates,
    env_fingerprint,
    export_trajectory,
    load_baselines,
    percentiles,
    profiler_trace,
    sustained,
)
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine, bucket_batch
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import powerlaw_graph, rmat_graph
from repro.streams.ingest import IngestPipeline
from repro.streams.traces import zipf_frequencies

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_streaming.json")

QUICK = dict(gen="rmat", n_nodes=20_000, n_edges=120_000,
             arrival=1_024, device_batch=8_192, duration_s=1.5,
             read_every=5, budget_s=900)
FULL = dict(gen="powerlaw", n_nodes=1_000_000, n_edges=10_000_000,
            arrival=2_048, device_batch=16_384, duration_s=10.0,
            read_every=5, budget_s=3_600)

WINDOW = 8
READ_BATCH = 256
N_ARRIVAL_BATCHES = 32


# ------------------------------------------------------------------- fixture
def _build(cfg: dict):
    """Graph -> bipartite -> overlay -> all-push engine (the continuous-query
    configuration: every result always fresh, no mincut at 1M nodes)."""
    if cfg["gen"] == "rmat":
        g = rmat_graph(cfg["n_nodes"], cfg["n_edges"], seed=0)
    else:
        g = powerlaw_graph(cfg["n_nodes"], cfg["n_edges"], sharing=0.5, seed=0)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dec = np.full(ov.n_nodes, D.PUSH, np.int64)
    eng = EagrEngine(ov, dec, make_aggregate("sum"),
                     WindowSpec("tuple", WINDOW))
    return eng, g, ov


def _arrival_batches(eng: EagrEngine, arrival: int, *, n_batches: int,
                     seed: int = 1) -> list:
    """Pre-generated Zipfian write batches (ids, scalar values) so the timed
    loops replay arrays instead of paying RNG cost per step."""
    writer_bases = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    freqs = zipf_frequencies(len(writer_bases), seed=seed)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        ids = rng.choice(writer_bases, size=arrival, p=freqs)
        vals = rng.integers(0, 64, arrival).astype(np.float32)
        out.append((ids.astype(np.int64), vals))
    return out


def _read_ids(eng: EagrEngine, *, seed: int = 2) -> np.ndarray:
    readers = np.flatnonzero(eng.plan.routes.reader_node >= 0)
    rng = np.random.default_rng(seed)
    take = min(READ_BATCH, len(readers))
    return rng.choice(readers, size=take, replace=False).astype(np.int64)


def _reset(eng: EagrEngine) -> None:
    """Fresh windows/PAOs/clock between modes, same compiled plan."""
    import jax

    jax.block_until_ready(eng.state.now)
    eng.state = eng.init_state()
    eng._now_host = 0.0
    eng._last_eval_now = 0.0
    eng._expiry = []


# --------------------------------------------------------------- write modes
def _legacy_writer(eng: EagrEngine, arrival: int):
    """The pre-PR-7 write path, reconstructed: keep-list comprehension over
    ``writer_row_of_base`` dict lookups (per-event Python), then one padded
    device step per arrival batch — pinned to the dense sweep
    (``active=None``), because the legacy system it replicates predates the
    frontier index; letting it ride the auto-sparse path would compare the
    pipeline against something that never existed."""
    wrb = dict(eng.plan.writer_row_of_base)

    def step(ids: np.ndarray, vals: np.ndarray) -> int:
        keep = [(wrb[b], v) for b, v in zip(ids.tolist(), vals.tolist())
                if b in wrb]
        n = len(keep)
        rows = np.zeros(arrival, np.int32)
        vmat = np.zeros(arrival, np.float32)
        mask = np.zeros(arrival, bool)
        if n:
            rows[:n] = [r for r, _ in keep]
            vmat[:n] = [v for _, v in keep]
            mask[:n] = True
        eng.write_rows(rows, vmat, mask, n_live=n, active=None)
        return len(ids)

    return step


def _run_mode(name: str, eng, batches, step_fn, *, duration_s: float,
              barrier, warmup: int) -> dict:
    import jax

    for i in range(warmup):  # compile + first dispatches, outside the clock
        ids, vals = batches[i % len(batches)]
        step_fn(ids, vals)
    barrier()
    jax.block_until_ready(eng.state.now)
    _reset(eng)
    res = sustained(
        lambda i: step_fn(*batches[i % len(batches)]),
        duration_s=duration_s, barrier=barrier)
    print(f"streaming/{name}: {res['events_per_s']:,.0f} ev/s "
          f"({res['events']} events, {res['steps']} steps, "
          f"{res['elapsed_s']}s)", flush=True)
    return res


def _reads_under_write(eng, batches, read_ids, *, depth, device_batch,
                       duration_s: float, every: int) -> dict:
    """p50/p99/p99.9 read latency while the pipeline sustains write load —
    the 'read under concurrent write' number the ISSUE asks for. Reads drain
    the partial slot first (session semantics: a read observes every
    submitted event) and block on the device answer."""
    pipe = IngestPipeline([eng], depth=depth, device_batch=device_batch)
    rb = bucket_batch(len(read_ids))
    eng.read_batch(read_ids, batch_size=rb)  # compile outside the clock
    samples: list[float] = []
    t0 = time.perf_counter()
    i = 0
    while time.perf_counter() - t0 < duration_s:
        pipe.submit(*batches[i % len(batches)])
        if i % every == 0:
            r0 = time.perf_counter()
            pipe.drain()
            eng.read_batch(read_ids, batch_size=rb)
            samples.append(time.perf_counter() - r0)
        i += 1
    pipe.flush()
    out = percentiles(samples)
    out["every"] = every
    out["read_batch"] = int(len(read_ids))
    out["write_events_per_s"] = round(
        pipe.stats.events_in / (time.perf_counter() - t0), 1)
    return out


# ------------------------------------------------------------ sparse writes
SPARSE_RATIOS = (0.0001, 0.001, 0.01)  # batch size as a fraction of n_nodes


def _sparse_vs_dense(eng: EagrEngine, cfg: dict, *, quick: bool) -> dict:
    """Median write-step latency, dense sweep (EAGR_SPARSE_WRITE=0) vs
    frontier-sparse (=1), at batch sizes that are a fixed fraction of the
    graph — the regime the block-reachability index exists for: the sparser
    the batch relative to the overlay, the larger the win. JSON keys are
    dot-free (``ratio_0_001``) because the gate engine splits paths on '.'"""
    import jax

    from benchmarks.harness import frontier_summary
    from repro.core import frontier as F

    writer_bases = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(9)
    reps = 12 if quick else 8
    out: dict = {}
    if eng.plan.frontier is None:  # charge the one-off index build visibly
        t0 = time.perf_counter()
        eng.plan.frontier = F.FrontierIndex.build(eng.plan)
        out["index_build_s"] = round(time.perf_counter() - t0, 3)
        print(f"streaming/sparse: frontier index built in "
              f"{out['index_build_s']}s", flush=True)
    prev = os.environ.get("EAGR_SPARSE_WRITE")
    try:
        for ratio in SPARSE_RATIOS:
            n = min(max(16, int(ratio * cfg["n_nodes"])), len(writer_bases))
            bs = bucket_batch(n)
            batches = [(rng.choice(writer_bases, size=n).astype(np.int64),
                        rng.integers(0, 64, n).astype(np.float32))
                       for _ in range(min(reps, 8))]
            key = "ratio_" + f"{ratio:g}".replace("0.", "0_")
            row: dict = {"batch": int(n)}
            for mode, label in (("0", "dense"), ("1", "sparse")):
                os.environ["EAGR_SPARSE_WRITE"] = mode
                _reset(eng)
                log0 = len(eng.frontier_log)
                for ids, vals in batches[:2]:  # compile outside the clock
                    eng.write_batch(ids, vals, batch_size=bs)
                jax.block_until_ready(eng.state.now)
                samples = []
                for i in range(reps):
                    ids, vals = batches[i % len(batches)]
                    t0 = time.perf_counter()
                    eng.write_batch(ids, vals, batch_size=bs)
                    jax.block_until_ready(eng.state.now)
                    samples.append(time.perf_counter() - t0)
                row[f"{label}_ms"] = round(
                    sorted(samples)[len(samples) // 2] * 1e3, 3)
                if mode == "1":
                    row["frontier"] = frontier_summary(eng.frontier_log[log0:])
            row["speedup"] = round(row["dense_ms"] / row["sparse_ms"], 2)
            out[key] = row
            print(f"streaming/sparse[{key}]: batch {n} dense "
                  f"{row['dense_ms']}ms sparse {row['sparse_ms']}ms = "
                  f"{row['speedup']}x {row['frontier']}", flush=True)
    finally:
        if prev is None:
            os.environ.pop("EAGR_SPARSE_WRITE", None)
        else:
            os.environ["EAGR_SPARSE_WRITE"] = prev
    return out


# ----------------------------------------------------------------- backends
def _backend_rows(quick: bool) -> dict:
    """Per-backend ingest/read throughput on a small shared fixture (the
    carried ROADMAP item): same overlay, three engine substrates."""
    from benchmarks.common import make_system

    rows: dict[str, dict] = {}
    dur = 0.6 if quick else 1.5
    for backend in ("pallas", "xla", "xla_unrolled"):
        try:
            eng, bp, _, _ = make_system(
                n_nodes=2_000, n_edges=12_000, decisions="all_push",
                backend=backend)
            batches = _arrival_batches(eng, 512, n_batches=8, seed=3)
            pipe = IngestPipeline([eng], depth=2, device_batch=2_048)
            ing = _run_mode(f"backend[{backend}]/ingest", eng, batches,
                            lambda ids, vals: (pipe.submit(ids, vals),
                                               len(ids))[1],
                            duration_s=dur, barrier=pipe.flush, warmup=8)
            read_ids = _read_ids(eng, seed=4)
            rb = bucket_batch(len(read_ids))
            rd = sustained(lambda i: len(
                eng.read_batch(read_ids, batch_size=rb)), duration_s=dur / 2)
            rows[backend] = {
                "ingest_events_per_s": ing["events_per_s"],
                "read_events_per_s": rd["events_per_s"],
            }
        except Exception as e:  # noqa: BLE001 — record, keep the bench going
            rows[backend] = {"error": f"{type(e).__name__}: {e}"}
        print(f"streaming/backends[{backend}]: {rows[backend]}", flush=True)
    return rows


# --------------------------------------------------------------------- main
def run_streaming_bench(quick: bool = False, check: bool = False,
                        out_path: str = OUT_PATH) -> dict:
    cfg = QUICK if quick else FULL
    phases = Phases()
    report: dict = {
        "bench": "streaming",
        "quick": quick,
        "fingerprint": env_fingerprint(),
        "graph": {k: cfg[k] for k in ("gen", "n_nodes", "n_edges")},
        "window": WINDOW,
        "arrival_batch": cfg["arrival"],
        "device_batch": cfg["device_batch"],
        "depth": 2,
        "modes": {},
    }
    with Watchdog(cfg["budget_s"], label="streaming_bench"):
        with phases.phase("build"):
            eng, g, ov = _build(cfg)
        report["graph"]["overlay_nodes"] = int(ov.n_nodes)
        report["graph"]["overlay_edges"] = int(ov.n_edges)
        print(f"streaming/build: {cfg['n_nodes']} nodes -> "
              f"{ov.n_nodes} overlay nodes", flush=True)
        batches = _arrival_batches(eng, cfg["arrival"],
                                   n_batches=N_ARRIVAL_BATCHES)

        import jax

        barrier = lambda: jax.block_until_ready(eng.state.now)  # noqa: E731
        with phases.phase("legacy_sync"):
            report["modes"]["legacy_sync"] = _run_mode(
                "legacy_sync", eng, batches, _legacy_writer(eng,
                                                            cfg["arrival"]),
                duration_s=cfg["duration_s"], barrier=barrier, warmup=2)
        with phases.phase("vectorized_sync"):
            report["modes"]["vectorized_sync"] = _run_mode(
                "vectorized_sync", eng, batches,
                lambda ids, vals: (eng.write_batch(
                    ids, vals, batch_size=cfg["arrival"]), len(ids))[1],
                duration_s=cfg["duration_s"], barrier=barrier, warmup=2)
        with phases.phase("pipeline"), profiler_trace("streaming_pipeline"):
            pipe = IngestPipeline([eng], depth=2,
                                  device_batch=cfg["device_batch"])
            res = _run_mode(
                "pipeline", eng, batches,
                lambda ids, vals: (pipe.submit(ids, vals), len(ids))[1],
                duration_s=cfg["duration_s"], barrier=pipe.flush,
                warmup=2 * cfg["device_batch"] // cfg["arrival"])
            res["ingest_stats"] = pipe.stats.as_dict()
            report["modes"]["pipeline"] = res

        legacy = report["modes"]["legacy_sync"]["events_per_s"]
        vect = report["modes"]["vectorized_sync"]["events_per_s"]
        pl = report["modes"]["pipeline"]["events_per_s"]
        report["speedup_pipeline_vs_legacy"] = round(pl / legacy, 2)
        report["speedup_pipeline_vs_vectorized"] = round(pl / vect, 2)
        print(f"streaming/speedup: pipeline {pl:,.0f} ev/s = "
              f"{report['speedup_pipeline_vs_legacy']}x legacy, "
              f"{report['speedup_pipeline_vs_vectorized']}x vectorized-sync",
              flush=True)

        with phases.phase("sparse_vs_dense"):
            _reset(eng)
            report["sparse_vs_dense"] = _sparse_vs_dense(eng, cfg,
                                                         quick=quick)

        with phases.phase("reads_under_write"):
            _reset(eng)
            report["reads_under_write"] = _reads_under_write(
                eng, batches, _read_ids(eng), depth=2,
                device_batch=cfg["device_batch"],
                duration_s=cfg["duration_s"], every=cfg["read_every"])
        print(f"streaming/reads_under_write: {report['reads_under_write']}",
              flush=True)

        with phases.phase("backends"):
            report["backends"] = _backend_rows(quick)

    report["phase_seconds"] = phases.seconds
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(out_path)}", flush=True)

    export_trajectory("streaming", {
        "quick": quick,
        "pipeline_events_per_s": pl,
        "legacy_events_per_s": legacy,
        "speedup_pipeline_vs_legacy":
            report["speedup_pipeline_vs_legacy"],
        "p99_read_under_write_ms":
            report["reads_under_write"].get("p99_ms"),
        "sparse_speedup_ratio_0_0001":
            report["sparse_vs_dense"]["ratio_0_0001"]["speedup"],
        "sparse_speedup_ratio_0_001":
            report["sparse_vs_dense"]["ratio_0_001"]["speedup"],
    })

    if check:
        all_b = load_baselines()
        view = {"tolerance": all_b.get("tolerance", 0.30),
                "streaming": all_b.get("streaming", {}).get(
                    "quick" if quick else "full", {})}
        check_gates(report, [
            {"path": "speedup_pipeline_vs_legacy", "floor": 1.5,
             "baseline": "speedup_pipeline_vs_legacy"},
            {"path": "modes.pipeline.events_per_s",
             "baseline": "pipeline_events_per_s"},
            {"path": "reads_under_write.p99_ms", "direction": "lower",
             "baseline": "p99_read_under_write_ms"},
            # ISSUE PR 8: sparse must beat dense >= 5x at the 0.1% ratio on
            # the full graph; the quick floors are conservative (small
            # graph — the dense sweep is already cheap there). The committed
            # baseline band sits on the sparsest ratio, where the win is
            # biggest and least noisy.
            {"path": "sparse_vs_dense.ratio_0_0001.speedup",
             "floor": 1.3 if quick else 5.0,
             "baseline": "sparse_speedup_ratio_0_0001"},
            {"path": "sparse_vs_dense.ratio_0_001.speedup",
             "floor": 1.3 if quick else 5.0},
        ], baselines=view, section="streaming", label="streaming")
    return report


if __name__ == "__main__":
    import sys

    run_streaming_bench(quick="--quick" in sys.argv,
                        check="--check" in sys.argv)
