"""Continuous anomaly detection on a communication network (paper §1): keep
every node's ego-centric COUNT of recent calls up to date as events stream
in, and flag neighborhoods whose activity exceeds a z-score threshold.

A *continuous* query needs always-fresh results, so the session pins it
all-push (``Query(continuous=True)``) instead of cost-optimized push/pull —
the paper's continuous class expressed as a query flag.

    PYTHONPATH=src python examples/anomaly_detection.py

``EAGR_EXAMPLE_FAST=1`` shrinks the graph for CI smoke runs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec

FAST = bool(os.environ.get("EAGR_EXAMPLE_FAST"))
N, E = (600, 4800) if FAST else (2000, 16000)
WINDOW = 32

from repro.graphs.generators import rmat_graph  # noqa: E402

session = EagrSession(rmat_graph(N, E, seed=3))
calls = session.register(Query(agg="count",
                               window=WindowSpec("tuple", WINDOW),
                               continuous=True))   # always fresh => all-push

rng = np.random.default_rng(0)
readers = np.array(session.readers)
writers = np.array(session.writers)

# ---- phase 1: normal traffic establishes each node's OWN baseline
# (ego-network sizes are power-law; a global z-score would be blind)
for _ in range(12):
    session.update(rng.choice(writers, 512))    # count streams need no values
base = np.ravel(session.read(calls, readers))
print(f"baseline ego-activity: mean={base.mean():.1f} max={base.max():.0f}")

# ---- phase 2: a hot cluster floods calls (their windows saturate at cap)
hot = rng.choice(writers, 12, replace=False)
for _ in range(12):
    session.update(np.concatenate([rng.choice(hot, 480),
                                   rng.choice(writers, 32)]))
act = np.ravel(session.read(calls, readers))
# per-node Poisson-style deviation score against its own baseline
score = (act - base) / np.sqrt(base + 1.0)
flagged = readers[score > 4.0]
ris = session.bipartite.reader_input_sets()
truly_hot = [r for r in flagged if set(map(int, hot)) & ris[int(r)]]
print(f"flagged {len(flagged)} anomalous neighborhoods "
      f"(score > 4); {len(truly_hot)} contain a flooding caller")
assert len(flagged) > 0 and len(truly_hot) / max(1, len(flagged)) > 0.9
print("PASS: anomaly neighborhoods localize the hot cluster")
