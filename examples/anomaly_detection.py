"""Continuous anomaly detection on a communication network (paper §1): keep
every node's ego-centric COUNT of recent calls up to date as events stream
in, and flag neighborhoods whose activity exceeds a z-score threshold.

A *continuous* query needs always-fresh results, so the session pins it
all-push (``Query(continuous=True)``) instead of cost-optimized push/pull —
the paper's continuous class expressed as a query flag. The anomaly
threshold itself is a *standing alert*: each reader's per-node z-score
cutoff is registered once (``QueryHandle.on_threshold``) and evaluated on
device inside every write step — flagged neighborhoods arrive as compact
fired sets (``drain_fired``), no per-round poll over all readers.

    PYTHONPATH=src python examples/anomaly_detection.py

``EAGR_EXAMPLE_FAST=1`` shrinks the graph for CI smoke runs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec

FAST = bool(os.environ.get("EAGR_EXAMPLE_FAST"))
N, E = (600, 4800) if FAST else (2000, 16000)
WINDOW = 32

from repro.graphs.generators import rmat_graph  # noqa: E402

session = EagrSession(rmat_graph(N, E, seed=3))
calls = session.register(Query(agg="count",
                               window=WindowSpec("tuple", WINDOW),
                               continuous=True))   # always fresh => all-push

rng = np.random.default_rng(0)
readers = np.sort(np.array(session.readers))
writers = np.array(session.writers)

# ---- phase 1: normal traffic establishes each node's OWN baseline
# (ego-network sizes are power-law; a global z-score would be blind)
for _ in range(12):
    session.update(rng.choice(writers, 512))    # count streams need no values
base = np.ravel(session.read(calls, readers))
print(f"baseline ego-activity: mean={base.mean():.1f} max={base.max():.0f}")

# ---- arm the standing alert: score > 4 <=> count > base + 4*sqrt(base+1),
# one per-reader threshold array, evaluated on device from here on
alert = calls.on_threshold(above=(base + 4.0 * np.sqrt(base + 1.0)),
                           readers=readers)

# ---- phase 2: a hot cluster floods calls (their windows saturate at cap)
hot = rng.choice(writers, 12, replace=False)
for _ in range(12):
    session.update(np.concatenate([rng.choice(hot, 480),
                                   rng.choice(writers, 32)]))
fired = sorted({int(b) for batch in alert.fired() for b in batch.base_ids})
print(f"standing alert fired on {len(fired)} neighborhoods "
      f"(pushed, not polled)")

# ---- polled ground truth: the same predicate by explicit readback
act = np.ravel(session.read(calls, readers))
score = (act - base) / np.sqrt(base + 1.0)
flagged = readers[score > 4.0]
ris = session.bipartite.reader_input_sets()
truly_hot = [r for r in flagged if set(map(int, hot)) & ris[int(r)]]
print(f"flagged {len(flagged)} anomalous neighborhoods "
      f"(score > 4); {len(truly_hot)} contain a flooding caller")
assert len(flagged) > 0 and len(truly_hot) / max(1, len(flagged)) > 0.9
# every neighborhood currently over its cutoff crossed it mid-stream, so the
# push path must have reported it (the converse can differ: a fired reader
# may have decayed back under its cutoff by the final read)
assert set(int(r) for r in flagged) <= set(fired), \
    "push-based fired set missed a polled anomaly"
print("PASS: anomaly neighborhoods localize the hot cluster")
