"""Continuous anomaly detection on a communication network (paper §1): keep
every node's ego-centric COUNT of recent calls up to date as events stream
in (a *continuous* query — all-push), and flag neighborhoods whose activity
exceeds a z-score threshold. Includes an adaptive-dataflow phase change.

    PYTHONPATH=src python examples/anomaly_detection.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph

WINDOW = 32

graph = rmat_graph(2000, 16000, seed=3)
bp = build_bipartite(graph)
overlay, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)

# continuous query => results must always be fresh => all-push decisions
dec = np.full(overlay.n_nodes, D.PUSH)
engine = EagrEngine(overlay, dec, make_aggregate("count"),
                    WindowSpec("tuple", WINDOW))

rng = np.random.default_rng(0)
readers = np.array(list(bp.reader_inputs))

# ---- phase 1: normal traffic establishes each node's OWN baseline
# (ego-network sizes are power-law; a global z-score would be blind)
for _ in range(12):
    ids = rng.choice(bp.writers, 512)
    engine.write_batch(ids, np.ones(512, np.float32))
base = np.ravel(engine.read_batch(readers))
print(f"baseline ego-activity: mean={base.mean():.1f} max={base.max():.0f}")

# ---- phase 2: a hot cluster floods calls (their windows saturate at cap)
hot = rng.choice(bp.writers, 12, replace=False)
for _ in range(12):
    ids = np.concatenate([rng.choice(hot, 480), rng.choice(bp.writers, 32)])
    engine.write_batch(ids, np.ones(512, np.float32))
act = np.ravel(engine.read_batch(readers))
# per-node Poisson-style deviation score against its own baseline
score = (act - base) / np.sqrt(base + 1.0)
flagged = readers[score > 4.0]
ris = bp.reader_input_sets()
truly_hot = [r for r in flagged if set(map(int, hot)) & ris[int(r)]]
print(f"flagged {len(flagged)} anomalous neighborhoods "
      f"(score > 4); {len(truly_hot)} contain a flooding caller")
assert len(flagged) > 0 and len(truly_hot) / max(1, len(flagged)) > 0.9
print("PASS: anomaly neighborhoods localize the hot cluster")
