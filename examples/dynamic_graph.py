"""Trend detection on a *growing* follower graph (paper §3.3 end to end):
value updates, structural churn, and reads interleave on one live engine.

New users join, follow edges appear and disappear, and accounts get deleted —
each burst is journaled by ``DynamicOverlay``, drained as an ``OverlayDelta``,
and applied to the running engine with ``apply_delta``: in-capacity bursts
patch the compiled plan's tables in place (no recompile, no retrace), only a
genuine capacity overflow falls back to ``compile_plan`` with growth headroom.

    PYTHONPATH=src python examples/dynamic_graph.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph

N_TOPICS, K, WINDOW = 32, 3, 16
N_USERS = 1500

# ---- seed social graph + 1-hop friend neighborhoods
graph = rmat_graph(N_USERS, 9000, seed=7, symmetric=True)
bp = build_bipartite(graph)
overlay, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
ris = bp.reader_input_sets()
dyn = DynamicOverlay.from_overlay(overlay, ris)
# the patch path lives in the unpruned id space: builder node ids stay stable
ov0 = dyn.to_overlay(prune=False)
rng = np.random.default_rng(1)
wf = rng.zipf(1.6, N_USERS).clip(1, 1000).astype(np.float64)
rf = wf[rng.permutation(N_USERS)]
dec, _ = D.decide_mincut(ov0, wf, rf, D.cost_model_for("topk", window=WINDOW),
                         window=WINDOW)
agg = make_aggregate("topk", k=K, domain=N_TOPICS)
engine = EagrEngine(ov0, dec, agg, WindowSpec("tuple", WINDOW), headroom=2.0)
print(f"{N_USERS} users, {bp.n_edges} feed edges; plan "
      f"levels={engine.plan.meta.n_levels} writers={engine.plan.meta.n_writers}")

# ---- stream: posts + churn + trend queries, all interleaved
readers = list(ris)
next_user = N_USERS
n_posts = n_queries = n_patches = n_recompiles = 0
for step in range(30):
    # value updates: a batch of posts (topic ids)
    ids = rng.choice(bp.writers, 256)
    topics = rng.integers(0, N_TOPICS, 256).astype(np.float32)
    engine.write_batch(ids, topics, batch_size=256)
    n_posts += len(ids)

    # structural churn: follows, unfollows, joins, account deletions
    for _ in range(4):
        kind = rng.random()
        if kind < 0.45:      # new follow edge
            dyn.add_edge(int(rng.integers(0, N_USERS)), int(rng.choice(readers)))
        elif kind < 0.70:    # unfollow
            r = int(rng.choice(readers))
            if dyn.reader_inputs.get(r):
                dyn.delete_edge(int(next(iter(dyn.reader_inputs[r]))), r)
        elif kind < 0.90:    # new user joins, following a few accounts
            dyn.add_node(next_user,
                         in_neighbors={int(x) for x in rng.integers(0, N_USERS, 5)},
                         out_readers={int(rng.choice(readers))})
            next_user += 1
        else:                # an account added this run gets deleted
            joined = [u for u in dyn.reader_inputs if u >= N_USERS]
            if joined:
                dyn.delete_node(int(rng.choice(joined)))
    res = engine.apply_delta(dyn.drain_delta())
    n_patches += 1
    n_recompiles += bool(res.recompiled)

    # trend queries against the live (possibly just-patched) plan
    q = rng.choice([r for r in dyn.reader_inputs
                    if dyn.reader_inputs[r]
                    and r in engine.plan.reader_node_of_base], 64)
    engine.read_batch(q, batch_size=64)
    n_queries += len(q)

print(f"processed {n_posts} posts, {n_queries} trend queries, "
      f"{n_patches} structural bursts ({n_recompiles} recompile fallbacks, "
      f"{engine.plan.patches_applied} in-place patches)")

# ---- verify a few users' trends against the window-level oracle
sample = [r for r in dyn.reader_inputs
          if dyn.reader_inputs[r] and r in engine.plan.reader_node_of_base][:5]
trends = engine.read_batch(np.array(sample))
from repro.core.window import window_pao  # noqa: E402

wp = np.asarray(window_pao(engine.state.windows, engine.spec, agg))
for u, t in zip(sample, np.asarray(trends)):
    counts = np.zeros(N_TOPICS)
    for w in dyn.reader_inputs[int(u)]:
        row = engine.plan.writer_row_of_base.get(int(w))
        if row is not None:
            counts += wp[row]
    assert counts[int(t[0])] == counts.max(), "top-1 mismatch vs oracle"
    print(f"user {int(u):5d}: trending topics {t.tolist()} "
          f"(counts {[int(counts[i]) for i in t]})")
print("PASS: trends stay exact under structural churn")
