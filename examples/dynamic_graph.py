"""Trend detection on a *growing* follower graph (paper §3.3 end to end):
value updates, structural churn, and reads interleave on one live session.

New users join, follow edges appear and disappear, and accounts get deleted —
each burst journals through the session (``add_edge``/``delete_edge``/
``add_node``/``delete_node``) and lands on the live plan at ``flush()``
through the device-resident patch path: in-capacity bursts rewrite the
compiled plan's tables in place (no recompile, no retrace, zero table
uploads); only a genuine capacity overflow falls back to a recompile with
growth headroom.

    PYTHONPATH=src python examples/dynamic_graph.py

``EAGR_EXAMPLE_FAST=1`` shrinks the graph/stream for CI smoke runs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec
from repro.graphs.generators import rmat_graph

FAST = bool(os.environ.get("EAGR_EXAMPLE_FAST"))
N_TOPICS, K, WINDOW = 32, 3, 16
N_USERS, N_EDGES, STEPS = (500, 3000, 12) if FAST else (1500, 9000, 30)

# ---- seed social graph; decisions tuned to zipf-skewed traffic
rng = np.random.default_rng(1)
wf = rng.zipf(1.6, N_USERS).clip(1, 1000).astype(np.float64)
rf = wf[rng.permutation(N_USERS)]
session = EagrSession(rmat_graph(N_USERS, N_EDGES, seed=7, symmetric=True),
                      write_freq=wf, read_freq=rf, headroom=2.0)
trends = session.register(Query(agg="topk",
                                agg_kwargs={"k": K, "domain": N_TOPICS},
                                window=WindowSpec("tuple", WINDOW)))
eng = trends.group.engine   # one level down, for plan stats only
print(f"{N_USERS} users, {session.bipartite.n_edges} feed edges; plan "
      f"levels={eng.plan.meta.n_levels} writers={eng.plan.meta.n_writers}")

# ---- stream: posts + churn + trend queries, all interleaved
writers = np.array(session.writers)
readers = list(session.readers)
next_user = N_USERS
n_posts = n_queries = n_patches = n_recompiles = 0
for step in range(STEPS):
    # value updates: a batch of posts (topic ids)
    ids = rng.choice(writers, 256)
    session.update(ids, rng.integers(0, N_TOPICS, 256).astype(np.float32))
    n_posts += len(ids)

    # structural churn: follows, unfollows, joins, account deletions
    for _ in range(4):
        kind = rng.random()
        if kind < 0.45:      # new follow edge
            session.add_edge(int(rng.integers(0, N_USERS)),
                             int(rng.choice(readers)))
        elif kind < 0.70:    # unfollow
            r = int(rng.choice(readers))
            ins = session.neighborhood(r)
            if ins:
                session.delete_edge(int(next(iter(ins))), r)
        elif kind < 0.90:    # new user joins, following a few accounts
            session.add_node(
                next_user,
                in_neighbors={int(x) for x in rng.integers(0, N_USERS, 5)},
                out_readers={int(rng.choice(readers))})
            next_user += 1
        else:                # an account added this run gets deleted
            joined = [u for u in session.readers if u >= N_USERS]
            if joined:
                session.delete_node(int(rng.choice(joined)))
    report = session.flush()   # typed FlushReport (still the result list)
    n_patches += 1
    n_recompiles += report.recompiled

    # trend queries against the live (possibly just-patched) plan
    q = rng.choice(session.readers, 64)
    session.read(trends, q)
    n_queries += len(q)

print(f"processed {n_posts} posts, {n_queries} trend queries, "
      f"{n_patches} structural bursts ({n_recompiles} recompile fallbacks, "
      f"{eng.plan.patches_applied} in-place patches)")

# ---- verify a few users' trends against the window-level oracle
from repro.core.window import window_pao  # noqa: E402

sample = session.readers[:5]
answers = session.read(trends, np.array(sample))
wp = np.asarray(window_pao(eng.state.windows, eng.spec, eng.agg))
for u, t in zip(sample, np.asarray(answers)):
    counts = np.zeros(N_TOPICS)
    for w in session.neighborhood(int(u)):
        row = eng.plan.writer_row_of_base.get(int(w))
        if row is not None:
            counts += wp[row]
    assert counts[int(t[0])] == counts.max(), "top-1 mismatch vs oracle"
    print(f"user {int(u):5d}: trending topics {t.tolist()} "
          f"(counts {[int(counts[i]) for i in t]})")
print("PASS: trends stay exact under structural churn")
