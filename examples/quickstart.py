"""Quickstart: the EAGr pipeline end to end on the paper's running example.

    PYTHONPATH=src python examples/quickstart.py

Builds Figure 1(a)'s data graph, compiles an aggregation overlay, makes
push/pull dataflow decisions with the max-flow algorithm, and streams
writes/reads through the vectorized engine — reproducing the SUM results in
Figure 1(b) exactly.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import small_example_graph

NAMES = "abcdefg"

# ---- 1. data graph + query ⟨SUM, c=1, N(x) = {y | y -> x}, pred=V⟩ (paper §2.1)
graph = small_example_graph()
bp = build_bipartite(graph)
print(f"data graph: {graph.n_nodes} nodes, bipartite A_G: {bp.n_edges} edges")

# ---- 2. compile the aggregation overlay (§3)
overlay, stats = construct_vnm(bp, variant="vnm_a", max_iterations=4, seed=0)
overlay.validate(bp.reader_input_sets())
print(f"overlay: {overlay.n_nodes} nodes, {overlay.n_edges} edges, "
      f"sharing index = {overlay.sharing_index(bp.n_edges):.3f}")

# ---- 3. dataflow decisions by min s-t cut (§4), uniform frequencies
wf = np.ones(graph.n_nodes)
rf = np.ones(graph.n_nodes)
decisions, dstats = D.decide_mincut(overlay, wf, rf, D.cost_model_for("sum"))
print(f"decisions: {int((decisions == D.PUSH).sum())} push / "
      f"{int((decisions == D.PULL).sum())} pull "
      f"({dstats.pruned_fraction:.0%} pruned before max-flow)")

# ---- 4. stream the paper's Figure 1 writes; window c=1 keeps the last value
engine = EagrEngine(overlay, decisions, make_aggregate("sum"),
                    WindowSpec("tuple", 1))
writes = {  # most recent write per node, per Figure 1(a)
    "a": 4.0, "b": 2.0, "c": 9.0, "d": 3.0, "e": 1.0, "f": 6.0, "g": 7.0}
ids = np.array([NAMES.index(k) for k in writes])
vals = np.array(list(writes.values()), dtype=np.float32)
engine.write_batch(ids, vals)

# ---- 5. read every node's ego-centric SUM; expect Figure 1(b)'s last column
expected = {"a": 19.0, "b": 19.0, "c": 16.0, "d": 15.0, "e": 18.0,
            "f": 19.0, "g": 25.0}
answers = engine.read_batch(np.arange(7))
print("\n  node  SUM(N(v))  expected")
ok = True
for v in range(7):
    got = float(np.ravel(answers[v])[0])
    want = expected[NAMES[v]]
    ok &= abs(got - want) < 1e-5
    print(f"     {NAMES[v]}   {got:8.1f}  {want:8.1f}")
print("\nPASS: engine reproduces Figure 1(b)" if ok else "FAIL")
