"""Quickstart: EAGr end to end on the paper's running example.

    PYTHONPATH=src python examples/quickstart.py

Part 1 drives the public session API: one ``EagrSession`` owns overlay
construction, cost-model decisions and engine assembly, and serves several
simultaneous queries over Figure 1(a)'s data graph — reproducing the SUM
results in Figure 1(b) exactly.

Part 2 keeps the low-level substrate walkthrough (what the session assembles
for you): ``build_bipartite -> construct_vnm -> decide_mincut -> EagrEngine``,
for substrate users who need direct control of each stage.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec
from repro.graphs.generators import small_example_graph

NAMES = "abcdefg"
EXPECTED = {"a": 19.0, "b": 19.0, "c": 16.0, "d": 15.0, "e": 18.0,
            "f": 19.0, "g": 25.0}

# ======================= Part 1: the session API ===========================
# query ⟨SUM, c=1, N(x) = {y | y -> x}, pred=V⟩ (paper §2.1) in five lines
graph = small_example_graph()
session = EagrSession(graph)                       # overlay compiled once
sums = session.register(Query(agg="sum", window=WindowSpec("tuple", 1)))
counts = session.register(Query(agg="count"))      # shares the same overlay

writes = {  # most recent write per node, per Figure 1(a)
    "a": 4.0, "b": 2.0, "c": 9.0, "d": 3.0, "e": 1.0, "f": 6.0, "g": 7.0}
session.update(np.array([NAMES.index(k) for k in writes]),
               np.array(list(writes.values()), dtype=np.float32))

answers = session.read(sums, np.arange(7))
degrees = session.read(counts, np.arange(7))
print("session API — two queries, one overlay "
      f"({session.n_engine_groups} engine groups):")
print("\n  node  SUM(N(v))  expected  COUNT(N(v))")
ok = True
for v in range(7):
    got = float(np.ravel(answers[v])[0])
    want = EXPECTED[NAMES[v]]
    ok &= abs(got - want) < 1e-5
    print(f"     {NAMES[v]}   {got:8.1f}  {want:8.1f}  {float(np.ravel(degrees[v])[0]):10.0f}")
assert ok, "session SUM mismatch vs Figure 1(b)"
print("\nPASS: session reproduces Figure 1(b)\n")

# =================== Part 2: the low-level substrate =======================
from repro.core import dataflow as D                       # noqa: E402
from repro.core.aggregates import make_aggregate           # noqa: E402
from repro.core.bipartite import build_bipartite           # noqa: E402
from repro.core.engine import EagrEngine                   # noqa: E402
from repro.core.vnm import construct_vnm                   # noqa: E402

# ---- 1. bipartite writer/reader graph A_G (§3.1)
bp = build_bipartite(graph)
print(f"data graph: {graph.n_nodes} nodes, bipartite A_G: {bp.n_edges} edges")

# ---- 2. compile the aggregation overlay (§3)
overlay, stats = construct_vnm(bp, variant="vnm_a", max_iterations=4, seed=0)
overlay.validate(bp.reader_input_sets())
print(f"overlay: {overlay.n_nodes} nodes, {overlay.n_edges} edges, "
      f"sharing index = {overlay.sharing_index(bp.n_edges):.3f}")

# ---- 3. dataflow decisions by min s-t cut (§4), uniform frequencies
wf = np.ones(graph.n_nodes)
rf = np.ones(graph.n_nodes)
decisions, dstats = D.decide_mincut(overlay, wf, rf, D.cost_model_for("sum"))
print(f"decisions: {int((decisions == D.PUSH).sum())} push / "
      f"{int((decisions == D.PULL).sum())} pull "
      f"({dstats.pruned_fraction:.0%} pruned before max-flow)")

# ---- 4. stream the paper's Figure 1 writes; window c=1 keeps the last value
engine = EagrEngine(overlay, decisions, make_aggregate("sum"),
                    WindowSpec("tuple", 1))
ids = np.array([NAMES.index(k) for k in writes])
vals = np.array(list(writes.values()), dtype=np.float32)
engine.write_batch(ids, vals)

# ---- 5. read every node's ego-centric SUM; expect Figure 1(b)'s last column
answers = engine.read_batch(np.arange(7))
ok = all(abs(float(np.ravel(answers[v])[0]) - EXPECTED[NAMES[v]]) < 1e-5
         for v in range(7))
assert ok, "low-level engine mismatch vs Figure 1(b)"
print("PASS: hand-assembled engine reproduces Figure 1(b) too")
