"""Streaming ingest (PR 7): sustain a high-rate write stream through the
session's async double-buffered pipeline while serving fresh reads.

``EagrSession(ingest_depth=2)`` (or ``EAGR_INGEST_DEPTH=2`` in the
environment) routes ``session.update`` through an
:class:`repro.streams.ingest.IngestPipeline`: arrival batches accumulate
into a ring of pre-allocated host buffers, each full ``ingest_batch`` slot
is routed in one vectorized table lookup and dispatched asynchronously, and
the host prepares the next slot while the device still runs the previous
step. Reads drain the ring (no barrier — the data dependency through the
engine state sequences them); graph churn flushes it (a full pipeline
barrier before patches land).

    PYTHONPATH=src python examples/streaming_ingest.py

``EAGR_EXAMPLE_FAST=1`` shrinks the graph/stream for CI smoke runs.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec
from repro.graphs.generators import rmat_graph
from repro.streams.traces import zipf_frequencies

FAST = bool(os.environ.get("EAGR_EXAMPLE_FAST"))
N_NODES, N_EDGES, N_BATCHES = (800, 6_400, 60) if FAST \
    else (20_000, 120_000, 400)
ARRIVAL, WINDOW = 512, 8

# ---- one pipelined session, one continuous (always-fresh) sum query
graph = rmat_graph(N_NODES, N_EDGES, seed=7)
session = EagrSession(graph, ingest_depth=2, ingest_batch=4 * ARRIVAL)
totals = session.register(Query(agg="sum", window=WindowSpec("tuple", WINDOW),
                                continuous=True))
writers = np.array(session.writers)
readers = np.array(session.readers)
print(f"{graph.n_nodes} nodes; ingest ring: depth {session.ingest_depth}, "
      f"device batch {session.ingest_batch} "
      f"({session.ingest_batch // ARRIVAL} arrival batches coalesced)")

# ---- pre-generated Zipfian arrival batches (hot keys, like real streams)
rng = np.random.default_rng(1)
freqs = zipf_frequencies(len(writers), seed=1)
batches = [(rng.choice(writers, size=ARRIVAL, p=freqs).astype(np.int64),
            rng.integers(0, 64, ARRIVAL).astype(np.float32))
           for _ in range(16)]

# ---- sustain the stream; interleave reads (always fresh: reads drain the
# ring) and a little graph churn (flushes it)
expected = np.zeros(graph.n_nodes)  # host mirror of the last-WINDOW sums
history: list = []
t0 = time.perf_counter()
for step in range(N_BATCHES):
    ids, vals = batches[step % len(batches)]
    session.update(ids, vals)
    history.append((ids, vals))
    if step % 10 == 5:
        sample = rng.choice(readers, size=8, replace=False)
        session.read(totals, sample)
session.flush()  # final pipeline barrier
dt = time.perf_counter() - t0
stats = session.stats().ingest  # SessionStats: one consolidated counter view
print(f"streamed {stats.events_in:,} events in {dt:.2f}s "
      f"({stats.events_in / dt:,.0f} ev/s): {stats.batches} device batches, "
      f"{stats.flushes} flushes, {stats.stall_s * 1e3:.0f}ms backpressure")

# ---- verify: replay the last WINDOW writes per writer on the host and
# compare one neighborhood sum against the pipelined answer
per_writer: dict = {}
for ids, vals in history:
    for b, v in zip(ids.tolist(), vals.tolist()):
        per_writer.setdefault(b, []).append(v)
probe = int(readers[int(np.argmax(
    [len(session.neighborhood(int(r)) & set(per_writer)) for r in
     readers[:64]]))])
want = sum(sum(per_writer[w][-WINDOW:])
           for w in session.neighborhood(probe) if w in per_writer)
got = float(np.asarray(session.read(totals, [probe])).reshape(-1)[0])
assert got == want, f"pipelined sum {got} != host replay {want}"
print(f"PASS: reader {probe} neighborhood sum {got:.0f} matches host replay")
