"""End-to-end driver: train a ~100M-parameter GQA transformer for a few
hundred steps with the full production stack (remat scan, fused CE, gradient
accumulation, AdamW, checkpoint/restart with an injected node failure).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import FaultTolerantRunner
from repro.models import transformer as T
from repro.models.common import init_from_specs
from repro.train.optimizer import adamw
from repro.train.trainer import make_train_step

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=300)
p.add_argument("--batch", type=int, default=4)
p.add_argument("--seq", type=int, default=128)
args = p.parse_args()

# ~103M params: 12L x d512 (8 heads, GQA kv=4, ffn 2048, 32k vocab)
cfg = T.TransformerConfig(
    name="lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=2048, vocab=32000, head_dim=64, compute_dtype=jnp.float32)
specs = T.param_specs(cfg)
n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
    specs, is_leaf=lambda x: hasattr(x, "shape")))
print(f"model: {n_params/1e6:.0f}M params")

params = init_from_specs(specs, jax.random.PRNGKey(0))
opt = adamw(weight_decay=0.01)
step = jax.jit(make_train_step(
    lambda p_, b: T.loss_fn(p_, b, cfg), opt, accum_steps=2))


def make_batch(i):
    """Synthetic language: next token = (3 * tok + noise) % vocab — gives the
    model a learnable structure so the loss visibly drops below ln(V)."""
    key = jax.random.PRNGKey(i)
    toks = [jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)]
    for t in range(args.seq):
        k = jax.random.fold_in(key, t)
        nxt = (3 * toks[-1] + jax.random.randint(k, toks[-1].shape, 0, 17)) % cfg.vocab
        toks.append(nxt)
    seq = jnp.concatenate(toks, axis=1)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def step_fn(state, batch):
    params_, opt_state_ = state
    params_, opt_state_, metrics = step(params_, opt_state_, batch,
                                        jnp.float32(3e-4))
    return (params_, opt_state_), metrics


ckpt = CheckpointManager("/tmp/repro_lm_ckpt", keep=2)
runner = FaultTolerantRunner(step_fn, make_batch, ckpt, ckpt_every=100)
t0 = time.time()
state, report = runner.run((params, opt.init(params)), args.steps,
                           fail_at={args.steps // 2})  # injected node failure
dt = time.time() - t0

losses = report.losses
k = max(1, len(losses) // 8)
curve = [round(float(np.mean(losses[i:i + k])), 3)
         for i in range(0, len(losses), k)]
print(f"{report.steps_run} steps in {dt:.0f}s "
      f"({report.steps_run / dt:.2f} steps/s), restarts={report.restarts}")
print(f"loss: {curve} (ln V = {np.log(cfg.vocab):.2f})")
assert report.restarts == 1, "the injected failure must trigger one restart"
if args.steps >= 100:   # shorter runs are for timing only
    assert losses[-1] < losses[0] - 0.5, "loss must decrease"
print("PASS: trained through a node failure with checkpoint/restart")
