"""Ego-centric trend detection (the paper's §1 motivating query): for every
user, maintain the TOP-K most frequent topics among their friends' recent
posts — a quasi-continuous query served from partial pre-computation.

    PYTHONPATH=src python examples/trend_detection.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.streams.traces import generate_trace, batched_playback

N_TOPICS, K, WINDOW = 32, 3, 16

# ---- social graph + per-user friend neighborhoods
graph = rmat_graph(3000, 24000, seed=7, symmetric=True)
bp = build_bipartite(graph)
print(f"{graph.n_nodes} users, {bp.n_edges} friendship-feed edges")

# ---- compile: overlay + dataflow decisions tuned to a read-light workload
overlay, _ = construct_vnm(bp, variant="vnm_n", max_iterations=3, seed=0)
overlay.validate(bp.reader_input_sets())
readers = np.array(list(bp.reader_inputs))
trace = generate_trace(bp.writers, readers, 60_000, write_read_ratio=5.0,
                       value_domain=N_TOPICS, seed=1, n_base=graph.n_nodes)
dec, _ = D.decide_mincut(overlay, trace.write_freq, trace.read_freq,
                         D.cost_model_for("topk", window=WINDOW), window=WINDOW)
print(f"overlay SI={overlay.sharing_index(bp.n_edges):.3f}; "
      f"{int((dec == D.PUSH).sum())} push / {int((dec == D.PULL).sum())} pull")

# ---- stream posts (topic ids) and serve trend queries
agg = make_aggregate("topk", k=K, domain=N_TOPICS)
engine = EagrEngine(overlay, dec, agg, WindowSpec("tuple", WINDOW))
n_writes = n_reads = 0
for kind, ids, vals in batched_playback(trace, 2048):
    if kind == "write":
        engine.write_batch(ids, vals, batch_size=2048)
        n_writes += len(ids)
    else:
        answers = engine.read_batch(ids, batch_size=2048)
        n_reads += len(ids)
print(f"processed {n_writes} posts, served {n_reads} trend queries")

# ---- show a few users' personalized trends + verify against the oracle
from repro.core.window import window_pao

sample = readers[:5]
trends = engine.read_batch(sample)
ris = bp.reader_input_sets()
wp = np.asarray(window_pao(engine.state.windows, engine.spec, agg))
for u, t in zip(sample, np.asarray(trends)):
    counts = np.zeros(N_TOPICS)
    for w in ris[int(u)]:
        counts += wp[engine.plan.writer_row_of_base[w]]
    assert counts[int(t[0])] == counts.max(), "top-1 mismatch vs oracle"
    print(f"user {int(u):5d}: trending topics {t.tolist()} "
          f"(counts {[int(counts[i]) for i in t]})")
print("PASS: trends match the window-level oracle")
