"""Ego-centric trend detection (the paper's §1 motivating query): for every
user, maintain the TOP-K most frequent topics among their friends' recent
posts — a quasi-continuous query served from partial pre-computation.

The session owns the pipeline: overlay construction over the friendship
graph, push/pull decisions tuned to the trace's write/read frequencies
(``write_freq=``/``read_freq=``), and the engine behind one register call.

    PYTHONPATH=src python examples/trend_detection.py

``EAGR_EXAMPLE_FAST=1`` shrinks the graph/trace for CI smoke runs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import EagrSession, Query, WindowSpec
from repro.graphs.generators import rmat_graph
from repro.streams.traces import batched_playback, generate_trace

FAST = bool(os.environ.get("EAGR_EXAMPLE_FAST"))
N_TOPICS, K, WINDOW = 32, 3, 16
N_USERS, N_EDGES, N_EVENTS = (800, 6400, 12_000) if FAST \
    else (3000, 24000, 60_000)

# ---- social graph + a posting/query trace to tune the dataflow against
from repro import build_bipartite  # noqa: E402

graph = rmat_graph(N_USERS, N_EDGES, seed=7, symmetric=True)
bp = build_bipartite(graph)
writers = np.array(bp.writers)
readers = np.array(list(bp.reader_inputs))
trace = generate_trace(writers, readers, N_EVENTS, write_read_ratio=5.0,
                       value_domain=N_TOPICS, seed=1, n_base=graph.n_nodes)

# ---- the session: overlay once, decisions from the trace frequencies
# (the session accepts the pre-built Bipartite, so A_G is built only once)
session = EagrSession(bp, variant="vnm_n",
                      write_freq=trace.write_freq, read_freq=trace.read_freq)
trends = session.register(Query(agg="topk",
                                agg_kwargs={"k": K, "domain": N_TOPICS},
                                window=WindowSpec("tuple", WINDOW)))
eng = trends.group.engine   # one level down, for stats + the oracle check
print(f"{graph.n_nodes} users, {session.bipartite.n_edges} feed edges; "
      f"overlay SI="
      f"{eng.overlay.sharing_index(session.bipartite.n_edges):.3f}")

# ---- stream posts (topic ids) and serve trend queries
n_writes = n_reads = 0
for kind, ids, vals in batched_playback(trace, 2048):
    if kind == "write":
        session.update(ids, vals)
        n_writes += len(ids)
    else:
        session.read(trends, ids)
        n_reads += len(ids)
print(f"processed {n_writes} posts, served {n_reads} trend queries")

# ---- show a few users' personalized trends + verify against the oracle
from repro.core.window import window_pao  # noqa: E402

sample = readers[:5]
answers = session.read(trends, sample)
ris = session.bipartite.reader_input_sets()
wp = np.asarray(window_pao(eng.state.windows, eng.spec, eng.agg))
for u, t in zip(sample, np.asarray(answers)):
    counts = np.zeros(N_TOPICS)
    for w in ris[int(u)]:
        counts += wp[eng.plan.writer_row_of_base[w]]
    assert counts[int(t[0])] == counts.max(), "top-1 mismatch vs oracle"
    print(f"user {int(u):5d}: trending topics {t.tolist()} "
          f"(counts {[int(counts[i]) for i in t]})")
print("PASS: trends match the window-level oracle")
