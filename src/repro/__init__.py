"""EAGr reproduction — continuous ego-centric aggregate queries over large
dynamic graphs, on a JAX/Pallas execution substrate.

Public surface:

  * :class:`EagrSession` / :class:`Query` / :class:`QueryHandle` — the
    declarative front door (``repro.session``): one session owns overlay
    construction, cost-model decisions, engine grouping and churn journaling
    for any number of simultaneous queries, single-machine or sharded.
  * :class:`WindowSpec`, :func:`make_aggregate` / :class:`Aggregate` — query
    building blocks.
  * The low-level tier stays public for substrate users: ``EagrEngine``,
    ``DynamicOverlay``, ``partition_overlay`` / ``StackedShardedEngine`` /
    ``ShardedDynamic``, ``build_bipartite``, ``construct_vnm``.
  * Standing alerts: ``EagrSession.register_alert`` /
    ``QueryHandle.on_threshold`` with :class:`AlertSpec`,
    :class:`AlertHandle` and :class:`FiredBatch` — device-evaluated
    predicate queries piggybacked on the write step, compact fired-set
    readback (``repro.streams.alerts``; :class:`PollOracle` is the
    poll-everything parity/bench reference).
  * Durable sessions: ``EagrSession.save`` / ``EagrSession.restore`` /
    ``EagrSession.stats`` with :class:`SessionStats`, :class:`FlushReport`,
    :class:`AdaptReport`, the :class:`CheckpointManager` substrate and the
    :class:`SessionRecoveryDriver` crash-recovery loop.

The session lifecycle end to end::

    import numpy as np
    from repro import EagrSession, Query, WindowSpec

    session = EagrSession(graph, ckpt_dir="/data/ckpt", ckpt_every=64)
    clicks = session.register(Query(agg="sum",
                                    window=WindowSpec("tuple", 8)))
    session.update(np.array([2, 5, 2]), np.array([1.0, 0.5, 2.0]))
    step = session.save()                 # async, atomic; also every 64th
                                          # update lands one automatically
    ...                                   # process dies / redeploys ...
    session = EagrSession.restore("/data/ckpt")       # bit-identical state
    (clicks,) = session.queries
    session.read(clicks, np.array([7]))   # answers exactly as before save
    session.stats()                       # SessionStats counter snapshot

Exports resolve lazily (PEP 562) so ``import repro`` stays cheap and config
subpackages avoid pulling the whole engine stack.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "EagrSession": "repro.session",
    "Query": "repro.session",
    "QueryHandle": "repro.session",
    "SessionStats": "repro.session",
    "FlushReport": "repro.session",
    "AdaptReport": "repro.session",
    "AlertHandle": "repro.session",
    "AlertSpec": "repro.streams.alerts",
    "AlertSet": "repro.streams.alerts",
    "FiredBatch": "repro.streams.alerts",
    "PollOracle": "repro.streams.alerts",
    "CheckpointManager": "repro.distributed.checkpoint",
    "SessionRecoveryDriver": "repro.distributed.fault",
    "WindowSpec": "repro.core.window",
    "Aggregate": "repro.core.aggregates",
    "make_aggregate": "repro.core.aggregates",
    "EagrEngine": "repro.core.engine",
    "compile_plan": "repro.core.engine",
    "DynamicOverlay": "repro.core.dynamic",
    "Overlay": "repro.core.overlay",
    "build_bipartite": "repro.core.bipartite",
    "Bipartite": "repro.core.bipartite",
    "construct_vnm": "repro.core.vnm",
    "decide_mincut": "repro.core.dataflow",
    "cost_model_for": "repro.core.dataflow",
    "partition_overlay": "repro.distributed.eagr_shard",
    "ShardedDynamic": "repro.distributed.eagr_shard",
    "StackedShardedEngine": "repro.distributed.stacked",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") \
            from None
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
