"""Architecture registry: ``get_arch(arch_id)`` -> ArchSpec.

One module per assigned architecture (exact public-literature config) plus the
paper's own EAGr system config. Every arch exposes the same CellPlan interface
consumed by launch/dryrun.py, launch/train.py and the smoke tests.
"""
from __future__ import annotations

import importlib

_MODULES = {
    "granite-3-2b": "repro.configs.granite_3_2b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "arctic-480b": "repro.configs.arctic_480b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "graphcast": "repro.configs.graphcast",
    "gat-cora": "repro.configs.gat_cora",
    "nequip": "repro.configs.nequip",
    "gatedgcn": "repro.configs.gatedgcn",
    "dien": "repro.configs.dien",
    "eagr": "repro.configs.eagr",
}

ARCH_IDS = [k for k in _MODULES if k != "eagr"]  # the 10 assigned archs


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch, shape) dry-run cells."""
    cells = []
    for a in ARCH_IDS:
        arch = get_arch(a)
        cells.extend((a, s) for s in arch.shapes)
    return cells
