"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: dense-MoE hybrid.
35L d_model=7168 56H (kv=8) d_ff=4864(dense residual) vocab=32000,
MoE 128 experts top-2 (expert d_ff=4864) + dense residual path;
head_dim = 7168/56 = 128.

480B params => bf16 params + Adafactor + 'sort' (dropless) MoE dispatch: the
GShard one-hot dispatch einsum would materialize a (B,S,E,C) tensor measured
in terabytes at this scale (DESIGN.md 'MoE dispatch' note).
"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, make_arch
from repro.models.transformer import TransformerConfig

ARCH = make_arch("arctic-480b", LMArch(
    cfg=TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=4864, vocab=32000, head_dim=128,
        n_experts=128, top_k=2, moe_dense_residual=True, moe_impl="sort",
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16),
    optimizer="adafactor", accum=8, lr=1e-4, train_rules="residual_sp"))
