"""CellPlan: the uniform (arch x shape) contract consumed by the dry-run,
launchers and smoke tests.

``ArchSpec.build(shape, mesh=...)`` returns a CellPlan whose ``fn`` is jitted
with the plan's shardings and lowered against ShapeDtypeStruct args — no
device allocation ever happens for the full configs. ``ArchSpec.build_smoke()``
returns a reduced-config plan with *real* (tiny) arrays for CPU execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    DEFAULT_RULES,
    param_shardings,
    replicated,
    sharding_for,
)
from repro.models.common import ParamSpec, spec_to_sds
from repro.train.optimizer import AdamState, FactorState, Optimizer


@dataclasses.dataclass
class CellPlan:
    arch_id: str
    shape: str
    fn: Callable                     # positional args
    args: tuple                      # SDS trees (dry-run) or arrays (smoke)
    in_shardings: tuple | None       # pytree matching args (None for smoke)
    out_shardings: Any = None
    donate: tuple[int, ...] = ()
    kind: str = "train"              # 'train' | 'serve'
    rules: Any = None                # logical->mesh rules for constrain()
    notes: str = ""

    def lower(self, mesh):
        from repro.distributed.sharding import activation_sharding
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate,
        )
        # tracing happens inside .lower(), so the activation-constraint context
        # must be active around it
        with mesh, activation_sharding(mesh, self.rules):
            return jitted.lower(*self.args)


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str
    shapes: tuple[str, ...]
    build: Callable[..., CellPlan]          # build(shape, mesh, rules=None)
    build_smoke: Callable[..., CellPlan]    # build_smoke(shape)
    describe: str = ""


# ------------------------------------------------------------------- helpers
def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def tree_sharding(axes_tree, sds_tree, mesh, rules=None):
    """axes tree (tuples of logical names, structure-matching sds tree) ->
    NamedSharding tree."""
    return jax.tree.map(
        lambda ax, s: sharding_for(s.shape, ax, mesh, rules),
        axes_tree, sds_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def opt_state_specs(opt: Optimizer, spec_tree) -> Any:
    """ParamSpec tree for the optimizer state (mirrors optimizer.init)."""
    is_spec = lambda x: isinstance(x, ParamSpec)
    if opt.name == "adamw":
        st = lambda s: ParamSpec(s.shape, s.axes, jnp.float32)
        return AdamState(
            mu=jax.tree.map(st, spec_tree, is_leaf=is_spec),
            nu=jax.tree.map(st, spec_tree, is_leaf=is_spec),
            count=ParamSpec((), (), jnp.int32),
        )
    if opt.name == "adafactor":
        def vr(s):
            if len(s.shape) >= 2:
                return ParamSpec(s.shape[:-1], s.axes[:-1], jnp.float32)
            return ParamSpec(s.shape, s.axes, jnp.float32)

        def vc(s):
            if len(s.shape) >= 2:
                return ParamSpec(s.shape[:-2] + s.shape[-1:],
                                 s.axes[:-2] + s.axes[-1:], jnp.float32)
            return ParamSpec((1,), (None,), jnp.float32)

        return FactorState(
            vr=jax.tree.map(vr, spec_tree, is_leaf=is_spec),
            vc=jax.tree.map(vc, spec_tree, is_leaf=is_spec),
            count=ParamSpec((), (), jnp.int32),
        )
    if opt.name == "sgd":
        return jax.tree.map(lambda s: ParamSpec(s.shape, s.axes, jnp.float32),
                            spec_tree, is_leaf=is_spec)
    raise ValueError(opt.name)


def state_and_shardings(opt: Optimizer, spec_tree, mesh, rules=None):
    """(params_sds, opt_sds, params_sh, opt_sh) for the dry-run."""
    o_specs = opt_state_specs(opt, spec_tree)
    return (
        spec_to_sds(spec_tree),
        spec_to_sds(o_specs),
        param_shardings(spec_tree, mesh, rules),
        param_shardings(o_specs, mesh, rules),
    )


def scalar_sharding(mesh):
    return replicated(mesh)
