"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01 family]: dense GQA
LM, no biases. 64L d_model=12288 96H (kv=8) d_ff=33792 vocab=256000;
head_dim = 12288/96 = 128.

104B params: Adafactor (factored second moment) + bf16 params + microbatched
gradient accumulation keep the per-chip HBM budget (see DESIGN.md memory
table); fp32 Adam states alone would need ~3.3 GB/chip more than fits
alongside activations on a 16 GB v5e chip.
"""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, make_arch
from repro.models.transformer import TransformerConfig

ARCH = make_arch("command-r-plus-104b", LMArch(
    cfg=TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv_heads=8, d_ff=33792, vocab=256000, head_dim=128,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16),
    optimizer="adafactor", accum=4, lr=1e-4, train_rules="residual_sp"))
