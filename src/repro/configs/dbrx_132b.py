"""dbrx-132b [hf:databricks/dbrx-base]: fine-grained MoE.
40L d_model=6144 48H (kv=8) d_ff=10752, 16 experts top-4 vocab=100352;
head_dim = 6144/48 = 128. bf16 + Adafactor + sort dispatch (see arctic)."""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, make_arch
from repro.models.transformer import TransformerConfig

ARCH = make_arch("dbrx-132b", LMArch(
    cfg=TransformerConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352, head_dim=128,
        n_experts=16, top_k=4, moe_impl="sort",
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16),
    optimizer="adafactor", accum=8, lr=1e-4))
