"""dien [arXiv:1809.03672]: embed_dim=18, seq_len=100, gru_dim=108,
MLP 200-80, AUGRU interaction. 1M-item / 1k-category embedding tables
(row-sharded over model — the EAGr reader-partitioning analogue), 100k-feature
multi-hot profile EmbeddingBag.

Shapes:
  train_batch     batch=65,536   train_step (CTR + DIEN auxiliary loss)
  serve_p99       batch=512      online CTR scoring
  serve_bulk      batch=262,144  offline scoring
  retrieval_cand  batch=1, n_candidates=1,000,000  two-tower retrieval scoring
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.cell import ArchSpec, CellPlan, sds, state_and_shardings
from repro.distributed.sharding import param_shardings, replicated, sharding_for
from repro.models.common import init_from_specs, spec_to_sds
from repro.models.recsys import dien as m
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

CFG = m.DIENConfig()
SMOKE_CFG = m.DIENConfig(n_items=1000, n_cats=20, n_profile_feats=100,
                         seq_len=12, profile_bag_size=8)

DIEN_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
SHAPE_DEFS = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_cand=1_000_000, kind="retrieval"),
}


def _rank_batch_sds(cfg, B, *, train):
    S, nb = cfg.seq_len, cfg.profile_bag_size
    i32, b_ = jnp.int32, jnp.bool_
    batch = dict(
        item_ids=sds((B, S), i32), cat_ids=sds((B, S), i32),
        mask=sds((B, S), b_),
        target_item=sds((B,), i32), target_cat=sds((B,), i32),
        profile_ids=sds((B, nb), i32), profile_mask=sds((B, nb), b_))
    if train:
        batch |= dict(labels=sds((B,), i32),
                      neg_item_ids=sds((B, S), i32), neg_cat_ids=sds((B, S), i32))
    return batch


def _batch_shardings(b_sds, mesh, rules):
    return {k: sharding_for(v.shape, ("batch",) + (None,) * (len(v.shape) - 1),
                            mesh, rules) for k, v in b_sds.items()}


def _build(shape, mesh, rules=None, unroll=False):
    d = SHAPE_DEFS[shape]
    cfg = dataclasses.replace(CFG, scan_unroll=CFG.seq_len) if unroll else CFG
    opt = get_optimizer("adamw")
    specs = m.param_specs(cfg)
    if d["kind"] == "train":
        p_sds, o_sds, p_sh, o_sh = state_and_shardings(opt, specs, mesh, rules)
        b_sds = _rank_batch_sds(cfg, d["batch"], train=True)
        b_sh = _batch_shardings(b_sds, mesh, rules)
        step = make_train_step(functools.partial(m.loss_fn, cfg=cfg), opt)
        return CellPlan("dien", shape, step,
                        args=(p_sds, o_sds, b_sds, sds((), jnp.float32)),
                        in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
                        out_shardings=(p_sh, o_sh, None),
                        donate=(0, 1), kind="train", rules=rules)
    p_sds = spec_to_sds(specs)
    p_sh = param_shardings(specs, mesh, rules)
    if d["kind"] == "serve":
        b_sds = _rank_batch_sds(cfg, d["batch"], train=False)
        b_sh = _batch_shardings(b_sds, mesh, rules)
        fn = functools.partial(_serve_fn, cfg=cfg)
        out_sh = sharding_for((d["batch"],), ("batch",), mesh, rules)
        return CellPlan("dien", shape, fn, args=(p_sds, b_sds),
                        in_shardings=(p_sh, b_sh), out_shardings=out_sh,
                        kind="serve", rules=rules)
    # retrieval: one user, 1M candidates sharded over every mesh axis
    b_sds = _rank_batch_sds(cfg, 1, train=False)
    b_sds.pop("target_item"), b_sds.pop("target_cat")
    b_sds |= dict(cand_items=sds((d["n_cand"],), jnp.int32),
                  cand_cats=sds((d["n_cand"],), jnp.int32))
    b_sh = {k: sharding_for(
        v.shape,
        (("candidates",) if k.startswith("cand") else
         ("batch",) + (None,) * (len(v.shape) - 1)), mesh, rules)
        for k, v in b_sds.items()}
    fn = functools.partial(_retrieval_fn, cfg=cfg)
    out_sh = sharding_for((d["n_cand"],), ("candidates",), mesh, rules)
    return CellPlan("dien", shape, fn, args=(p_sds, b_sds),
                    in_shardings=(p_sh, b_sh), out_shardings=out_sh,
                    kind="serve", rules=rules)


def _serve_fn(params, batch, cfg):
    return m.serve(params, batch, cfg)


def _retrieval_fn(params, batch, cfg):
    return m.retrieval_score(params, batch, cfg)


def _rand_rank_batch(key, cfg, B, *, train):
    S, nb = cfg.seq_len, cfg.profile_bag_size
    ks = jax.random.split(key, 10)
    batch = dict(
        item_ids=jax.random.randint(ks[0], (B, S), 0, cfg.n_items),
        cat_ids=jax.random.randint(ks[1], (B, S), 0, cfg.n_cats),
        mask=jax.random.bernoulli(ks[2], 0.9, (B, S)),
        target_item=jax.random.randint(ks[3], (B,), 0, cfg.n_items),
        target_cat=jax.random.randint(ks[4], (B,), 0, cfg.n_cats),
        profile_ids=jax.random.randint(ks[5], (B, nb), 0, cfg.n_profile_feats),
        profile_mask=jnp.ones((B, nb), jnp.bool_))
    if train:
        batch |= dict(labels=jax.random.randint(ks[6], (B,), 0, 2),
                      neg_item_ids=jax.random.randint(ks[7], (B, S), 0, cfg.n_items),
                      neg_cat_ids=jax.random.randint(ks[8], (B, S), 0, cfg.n_cats))
    return batch


def _build_smoke(shape):
    cfg = SMOKE_CFG
    d = SHAPE_DEFS[shape]
    opt = get_optimizer("adamw")
    params = init_from_specs(m.param_specs(cfg), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if d["kind"] == "train":
        batch = _rand_rank_batch(key, cfg, 8, train=True)
        step = make_train_step(functools.partial(m.loss_fn, cfg=cfg), opt)
        return CellPlan("dien", shape, step,
                        (params, opt.init(params), batch, jnp.float32(1e-3)),
                        None, kind="train")
    if d["kind"] == "serve":
        batch = _rand_rank_batch(key, cfg, 8, train=False)
        return CellPlan("dien", shape, functools.partial(_serve_fn, cfg=cfg),
                        (params, batch), None, kind="serve")
    batch = _rand_rank_batch(key, cfg, 1, train=False)
    batch.pop("target_item"), batch.pop("target_cat")
    batch |= dict(cand_items=jax.random.randint(key, (512,), 0, cfg.n_items),
                  cand_cats=jax.random.randint(key, (512,), 0, cfg.n_cats))
    return CellPlan("dien", shape, functools.partial(_retrieval_fn, cfg=cfg),
                    (params, batch), None, kind="serve")


ARCH = ArchSpec(arch_id="dien", family="recsys", shapes=DIEN_SHAPES,
                build=_build, build_smoke=_build_smoke)
