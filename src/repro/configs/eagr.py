"""The paper's own system config: EAGr continuous ego-centric aggregation.

Not one of the 40 assigned dry-run cells — this is the reference config used
by the paper-validation benchmarks, the examples, and a bonus dry-run cell
that lowers the vectorized write/read step of a compiled overlay on the
production mesh (batch dims sharded over (pod, data); the overlay plan is a
compile-time constant exactly as the paper's pre-compiled overlay is).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cell import ArchSpec, CellPlan, sds
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.engine import EagrEngine, _read_body, _write_body_sum
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec, init_windows
from repro.distributed.sharding import sharding_for
from repro.graphs.generators import rmat_graph


@dataclasses.dataclass(frozen=True)
class EagrSystemConfig:
    n_nodes: int = 100_000
    n_edges: int = 800_000
    aggregate: str = "sum"
    window: int = 8
    algorithm: str = "vnm_n"          # overlay construction algorithm
    write_batch: int = 4096
    read_batch: int = 4096
    write_read_ratio: float = 1.0
    zipf_a: float = 1.5
    seed: int = 0


CFG = EagrSystemConfig()
SMOKE_CFG = EagrSystemConfig(n_nodes=400, n_edges=2400, write_batch=128,
                             read_batch=128)

EAGR_SHAPES = ("stream_mixed",)


def build_engine(cfg: EagrSystemConfig):
    """Host compile phase: graph -> bipartite -> overlay -> dataflow -> engine."""
    g = rmat_graph(cfg.n_nodes, cfg.n_edges, seed=cfg.seed)
    bp = build_bipartite(g)
    ov, stats = construct_vnm(bp, variant=cfg.algorithm, max_iterations=4,
                              seed=cfg.seed)
    rng = np.random.default_rng(cfg.seed)
    wf = rng.zipf(cfg.zipf_a, g.n_nodes).clip(1, 10_000).astype(np.float64)
    rf = (wf * cfg.write_read_ratio)[rng.permutation(g.n_nodes)]
    cm = D.cost_model_for(cfg.aggregate, window=cfg.window)
    dec, dstats = D.decide_mincut(ov, wf, rf, cm, window=cfg.window)
    ov, dec, _ = D.split_nodes(ov, dec, wf, rf, cm, window=cfg.window)
    agg = make_aggregate(cfg.aggregate)
    eng = EagrEngine(ov, dec, agg, WindowSpec(kind="tuple", size=cfg.window))
    return eng, bp, (stats, dstats)


def _build(shape, mesh, rules=None, unroll=False):
    cfg = CFG
    eng, bp, _ = build_engine(cfg)
    B = cfg.write_batch

    # lower the raw step bodies with batch args sharded over (pod, data)
    write_fn = functools.partial(_write_body_sum, eng.plan, eng.agg, eng.spec)
    read_fn = functools.partial(_read_body, eng.plan, eng.agg)

    def mixed(state, rows, vals, wmask, rnodes, rmask):
        state = write_fn(state, rows, vals, wmask)
        ans, _ = read_fn(state, rnodes, rmask)
        return state, ans

    st = eng.state
    st_sds = jax.tree.map(lambda x: sds(x.shape, x.dtype), st)
    vec = lambda n, dt: sds((n,), dt)
    bsh = sharding_for((B,), ("batch",), mesh, rules)
    rep = sharding_for((), (), mesh, rules)
    st_sh = jax.tree.map(lambda x: rep, st_sds)  # PAO state replicated per pod
    return CellPlan(
        arch_id="eagr", shape=shape, fn=mixed,
        args=(st_sds, vec(B, jnp.int32), vec(B, jnp.float32), vec(B, jnp.bool_),
              vec(cfg.read_batch, jnp.int32), vec(cfg.read_batch, jnp.bool_)),
        in_shardings=(st_sh, bsh, bsh, bsh, bsh, bsh),
        out_shardings=None, kind="serve", rules=rules,
        notes="bonus cell: EAGr engine step (overlay = compile-time constant)")


def _build_smoke(shape):
    cfg = SMOKE_CFG
    eng, bp, _ = build_engine(cfg)
    rng = np.random.default_rng(1)
    writers = bp.writers
    readers = list(bp.reader_inputs.keys())
    ids = rng.choice(writers, cfg.write_batch)
    vals = rng.normal(size=cfg.write_batch).astype(np.float32)

    def run():
        eng.write_batch(ids, vals)
        q = rng.choice(readers, cfg.read_batch)
        return eng.read_batch(q)

    return CellPlan("eagr", shape, lambda: jnp.asarray(run()), (), None,
                    kind="serve")


ARCH = ArchSpec(arch_id="eagr", family="graph-streams", shapes=EAGR_SHAPES,
                build=_build, build_smoke=_build_smoke,
                describe="the paper's system (reference implementation)")
