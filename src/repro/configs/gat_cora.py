"""gat-cora [arXiv:1710.10903]: 2 layers, 8 hidden per head, 8 heads, attn
aggregator — the paper-exact Cora config, scaled to each assigned shape's
feature/class counts."""
import dataclasses

from repro.configs.gnn_common import make_gnn_arch
from repro.models.gnn import gat


def _mk(d, graph_task):
    return gat.GATConfig(
        name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
        d_in=d["d_feat"], n_classes=d["classes"],
        task="graph" if graph_task else "node")


ARCH = make_gnn_arch(
    "gat-cora",
    make_cfg=_mk, param_specs=gat.param_specs, loss_fn=gat.loss_fn,
    make_smoke_cfg=_mk)
