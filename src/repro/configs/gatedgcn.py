"""gatedgcn [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregator."""
from repro.configs.gnn_common import make_gnn_arch
from repro.models.gnn import gatedgcn as m


def _mk(d, graph_task):
    return m.GatedGCNConfig(
        name="gatedgcn", n_layers=16, d_hidden=70,
        d_in=d["d_feat"], n_classes=d["classes"],
        task="graph" if graph_task else "node")


def _mk_smoke(d, graph_task):
    cfg = _mk(d, graph_task)
    import dataclasses
    return dataclasses.replace(cfg, n_layers=3, d_hidden=24)


ARCH = make_gnn_arch(
    "gatedgcn",
    make_cfg=_mk, param_specs=m.param_specs, loss_fn=m.loss_fn,
    make_smoke_cfg=_mk_smoke)
