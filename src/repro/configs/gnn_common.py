"""Shared cell builders for the four GNN architectures.

Shapes (assigned):
  full_graph_sm   n_nodes=2,708 n_edges=10,556 d_feat=1,433   (Cora full-batch)
  minibatch_lg    n_nodes=232,965 n_edges=114,615,892,
                  batch_nodes=1,024, fanout 15-10             (Reddit sampled)
  ogb_products    n_nodes=2,449,029 n_edges=61,859,140 d_feat=100
  molecule        n_nodes=30 n_edges=64 batch=128             (small graphs)

minibatch_lg lowers the *sampled union subgraph* produced by
graphs/sampler.py (GraphSAINT-style: all fanout layers merged into one padded
subgraph so arbitrary-depth models train on it; the sampler itself is the
real neighbor sampler, exercised in tests and examples).

All cells lower a full train_step (fwd + bwd + optimizer). Node/edge arrays
shard over (pod, data); model params are small enough to replicate except
GraphCast's d=512 MLPs (mlp -> model).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cell import ArchSpec, CellPlan, sds, state_and_shardings
from repro.distributed.sharding import replicated, sharding_for
from repro.models.common import init_from_specs
from repro.models.gnn.common import GraphBatch
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def pad512(x: int) -> int:
    """Pad node/edge counts to a multiple of 512 so every mesh-axis
    combination divides evenly (2,449,029 nodes shards over nothing;
    2,449,408 shards over all of pod*data*model). Padded slots are masked."""
    return -(-x // 512) * 512

# minibatch union-subgraph sizes: seeds + 15 + 15*10 per seed
_MB_NODES = 1024 * (1 + 15 + 150)
_MB_EDGES = 1024 * (15 + 150)

SHAPE_DEFS = {
    "full_graph_sm": dict(n=2708, e=10556, d_feat=1433, classes=7, graphs=0),
    "minibatch_lg": dict(n=_MB_NODES, e=_MB_EDGES, d_feat=602, classes=41, graphs=0),
    "ogb_products": dict(n=2449029, e=61859140, d_feat=100, classes=47, graphs=0),
    "molecule": dict(n=30 * 128, e=64 * 128, d_feat=16, classes=2, graphs=128),
}

_BATCH_AXES = dict(
    x=("nodes", None), edge_src=("edges",), edge_dst=("edges",),
    edge_mask=("edges",), node_mask=("nodes",), labels=("nodes",),
    label_mask=("nodes",), graph_ids=("nodes",),
    positions=("nodes", None), species=("nodes",),
)


def graph_batch_sds(d: dict, *, geometric: bool = False,
                    graph_task: bool = False) -> GraphBatch:
    n, e, g = pad512(d["n"]), pad512(d["e"]), d["graphs"]
    lbl_n = g if graph_task and g else n
    return GraphBatch(
        x=sds((n, d["d_feat"])),
        edge_src=sds((e,), jnp.int32), edge_dst=sds((e,), jnp.int32),
        edge_mask=sds((e,), jnp.bool_), node_mask=sds((n,), jnp.bool_),
        labels=sds((lbl_n,), jnp.int32), label_mask=sds((lbl_n,), jnp.bool_),
        graph_ids=sds((n,), jnp.int32) if g else None,
        n_graphs=max(g, 1),
        positions=sds((n, 3)) if geometric else None,
        species=sds((n,), jnp.int32) if geometric else None,
    )


def graph_batch_shardings(b: GraphBatch, mesh, rules, *, graph_task=False):
    def shard(name, v):
        if v is None:
            return None
        axes = _BATCH_AXES[name]
        if name in ("labels", "label_mask") and graph_task:
            axes = ("batch",) + axes[1:]
        return sharding_for(v.shape, axes, mesh, rules)
    return GraphBatch(
        **{f.name: (shard(f.name, getattr(b, f.name))
                    if f.name != "n_graphs" else b.n_graphs)
           for f in dataclasses.fields(GraphBatch)})


def random_graph_batch(key, n, e, d_feat, classes, *, graphs=0,
                       geometric=False, graph_task=False) -> GraphBatch:
    ks = jax.random.split(key, 8)
    lbl_n = graphs if graph_task and graphs else n
    if graphs:
        per = n // graphs
        gid = jnp.repeat(jnp.arange(graphs, dtype=jnp.int32), per)
        # edges stay within their graph
        base = jax.random.randint(ks[0], (e,), 0, per)
        off = jnp.repeat(jnp.arange(graphs, dtype=jnp.int32), e // graphs) * per
        esrc = (base + off).astype(jnp.int32)
        edst = (jax.random.randint(ks[1], (e,), 0, per) + off).astype(jnp.int32)
    else:
        gid = None
        esrc = jax.random.randint(ks[0], (e,), 0, n).astype(jnp.int32)
        edst = jax.random.randint(ks[1], (e,), 0, n).astype(jnp.int32)
    return GraphBatch(
        x=jax.random.normal(ks[2], (n, d_feat)),
        edge_src=esrc, edge_dst=edst,
        edge_mask=jnp.ones((e,), jnp.bool_), node_mask=jnp.ones((n,), jnp.bool_),
        labels=jax.random.randint(ks[3], (lbl_n,), 0, classes),
        label_mask=jnp.ones((lbl_n,), jnp.bool_),
        graph_ids=gid, n_graphs=max(graphs, 1),
        positions=jax.random.normal(ks[4], (n, 3)) * 2.0 if geometric else None,
        species=jax.random.randint(ks[5], (n,), 0, 10) if geometric else None,
    )


def make_gnn_arch(arch_id: str, *, make_cfg, param_specs, loss_fn,
                  make_smoke_cfg, optimizer="adamw", lr=1e-3,
                  geometric=False) -> ArchSpec:
    """Generic ArchSpec factory for GraphBatch-based GNNs (gat, gatedgcn)."""

    def build(shape, mesh, rules=None, unroll=False):
        d = SHAPE_DEFS[shape]
        graph_task = shape == "molecule"
        cfg = make_cfg(d, graph_task)
        if unroll and hasattr(cfg, "scan_unroll"):
            cfg = dataclasses.replace(cfg, scan_unroll=cfg.n_layers)
        opt = get_optimizer(optimizer)
        specs = param_specs(cfg)
        p_sds, o_sds, p_sh, o_sh = state_and_shardings(opt, specs, mesh, rules)
        b_sds = graph_batch_sds(d, geometric=geometric, graph_task=graph_task)
        b_sh = graph_batch_shardings(b_sds, mesh, rules, graph_task=graph_task)
        step = make_train_step(functools.partial(loss_fn, cfg=cfg), opt)
        return CellPlan(
            arch_id=arch_id, shape=shape, fn=step,
            args=(p_sds, o_sds, b_sds, sds((), jnp.float32)),
            in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
            out_shardings=(p_sh, o_sh, None),
            donate=(0, 1), kind="train", rules=rules)

    def build_smoke(shape):
        d = dict(SHAPE_DEFS[shape])
        d.update(n=min(d["n"], 64), e=min(d["e"], 256),
                 d_feat=min(d["d_feat"], 24), graphs=min(d["graphs"], 4))
        graph_task = shape == "molecule"
        cfg = make_smoke_cfg(d, graph_task)
        opt = get_optimizer(optimizer)
        params = init_from_specs(param_specs(cfg), jax.random.PRNGKey(0))
        batch = random_graph_batch(
            jax.random.PRNGKey(1), d["n"], d["e"], d["d_feat"], d["classes"],
            graphs=d["graphs"], geometric=geometric, graph_task=graph_task)
        step = make_train_step(functools.partial(loss_fn, cfg=cfg), opt)
        return CellPlan(arch_id, shape, step,
                        (params, opt.init(params), batch, jnp.float32(lr)),
                        None, kind="train")

    return ArchSpec(arch_id=arch_id, family="gnn", shapes=GNN_SHAPES,
                    build=build, build_smoke=build_smoke)
