"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: dense GQA LM.
40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155; head_dim = 2048/32 = 64."""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, make_arch
from repro.models.transformer import TransformerConfig

ARCH = make_arch("granite-3-2b", LMArch(
    cfg=TransformerConfig(
        name="granite-3-2b", n_layers=40, d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab=49155, head_dim=64,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16),
    optimizer="adamw", accum=4))
