"""graphcast [arXiv:2212.12794]: 16-layer encoder-processor-decoder mesh GNN,
d_hidden=512, mesh_refinement=6 (40,962 mesh nodes / 327,660 directed
multimesh edges — static constants of the refinement), n_vars=227.

The assigned shape's n_nodes plays the grid; grid<->mesh edges are ~4 per
grid node (data arrays, ShapeDtypeStruct in the dry-run)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.cell import ArchSpec, CellPlan, sds, state_and_shardings
from repro.configs.gnn_common import GNN_SHAPES, SHAPE_DEFS, pad512
from repro.distributed.sharding import replicated, sharding_for
from repro.models.common import init_from_specs
from repro.models.gnn import graphcast as m
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

CFG = m.GraphCastConfig(name="graphcast", n_layers=16, d_hidden=512,
                        mesh_refinement=6, n_vars=227)
SMOKE_CFG = m.GraphCastConfig(name="graphcast", n_layers=2, d_hidden=32,
                              mesh_refinement=1, n_vars=8,
                              compute_dtype=jnp.float32)

_AXES = m.GraphCastBatch(
    grid_x=("nodes", None), g2m_src=("edges",), g2m_dst=("edges",),
    mesh_src=("edges",), mesh_dst=("edges",), m2g_src=("edges",),
    m2g_dst=("edges",), targets=("nodes", None), grid_mask=("nodes",),
    n_mesh=0)


def _batch_sds(cfg: m.GraphCastConfig, n_grid: int) -> m.GraphCastBatch:
    n_grid = pad512(n_grid)
    e_gm = pad512(4 * n_grid)
    e_mesh = pad512(cfg.n_mesh_edges)
    i32 = jnp.int32
    return m.GraphCastBatch(
        grid_x=sds((n_grid, cfg.n_vars)),
        g2m_src=sds((e_gm,), i32), g2m_dst=sds((e_gm,), i32),
        mesh_src=sds((e_mesh,), i32), mesh_dst=sds((e_mesh,), i32),
        m2g_src=sds((e_gm,), i32), m2g_dst=sds((e_gm,), i32),
        targets=sds((n_grid, cfg.n_vars)),
        grid_mask=sds((n_grid,), jnp.bool_))


def _batch_shardings(b, mesh, rules):
    return m.GraphCastBatch(**{
        f.name: (sharding_for(getattr(b, f.name).shape,
                              getattr(_AXES, f.name), mesh, rules)
                 if f.name != "n_mesh" else 0)
        for f in dataclasses.fields(m.GraphCastBatch)})


def _build(shape, mesh, rules=None, unroll=False):
    d = SHAPE_DEFS[shape]
    cfg = (dataclasses.replace(CFG, scan_unroll=CFG.n_layers)
           if unroll else CFG)
    opt = get_optimizer("adamw")
    specs = m.param_specs(cfg)
    p_sds, o_sds, p_sh, o_sh = state_and_shardings(opt, specs, mesh, rules)
    b_sds = _batch_sds(cfg, d["n"])
    b_sh = _batch_shardings(b_sds, mesh, rules)
    step = make_train_step(functools.partial(m.loss_fn, cfg=cfg), opt)
    return CellPlan(
        arch_id="graphcast", shape=shape, fn=step,
        args=(p_sds, o_sds, b_sds, sds((), jnp.float32)),
        in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
        out_shardings=(p_sh, o_sh, None),
        donate=(0, 1), kind="train", rules=rules)


def _build_smoke(shape):
    cfg = SMOKE_CFG
    n_grid = 48
    key = jax.random.PRNGKey(0)
    params = init_from_specs(m.param_specs(cfg), key)
    ks = jax.random.split(key, 8)
    e_gm, e_mesh, M = 4 * n_grid, cfg.n_mesh_edges, cfg.n_mesh
    batch = m.GraphCastBatch(
        grid_x=jax.random.normal(ks[0], (n_grid, cfg.n_vars)),
        g2m_src=jax.random.randint(ks[1], (e_gm,), 0, n_grid),
        g2m_dst=jax.random.randint(ks[2], (e_gm,), 0, M),
        mesh_src=jax.random.randint(ks[3], (e_mesh,), 0, M),
        mesh_dst=jax.random.randint(ks[4], (e_mesh,), 0, M),
        m2g_src=jax.random.randint(ks[5], (e_gm,), 0, M),
        m2g_dst=jax.random.randint(ks[6], (e_gm,), 0, n_grid),
        targets=jax.random.normal(ks[7], (n_grid, cfg.n_vars)),
        grid_mask=jnp.ones((n_grid,), jnp.bool_))
    opt = get_optimizer("adamw")
    step = make_train_step(functools.partial(m.loss_fn, cfg=cfg), opt)
    return CellPlan("graphcast", shape, step,
                    (params, opt.init(params), batch, jnp.float32(1e-3)),
                    None, kind="train")


ARCH = ArchSpec(arch_id="graphcast", family="gnn", shapes=GNN_SHAPES,
                build=_build, build_smoke=_build_smoke)
