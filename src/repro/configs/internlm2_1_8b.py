"""internlm2-1.8b [arXiv:2403.17297]: dense GQA LM.
24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92544; head_dim = 2048/16 = 128."""
import jax.numpy as jnp

from repro.configs.lm_common import LMArch, make_arch
from repro.models.transformer import TransformerConfig

ARCH = make_arch("internlm2-1.8b", LMArch(
    cfg=TransformerConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=8192, vocab=92544, head_dim=128,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16),
    optimizer="adamw", accum=2))
