"""Shared cell builders for the five LM-family architectures.

Shapes (assigned):
  train_4k     seq 4,096   global_batch 256   -> train_step (fwd+bwd+optimizer)
  prefill_32k  seq 32,768  global_batch 32    -> prefill (logits + KV cache)
  decode_32k   seq 32,768  global_batch 128   -> decode_step (1 token vs cache)
  long_500k    seq 524,288 global_batch 1     -> decode_step (linear in S; see
                                                DESIGN.md long_500k note)

Sharding: FSDP over (pod, data) on the d_model param dim, TP over model on
heads/mlp/vocab/experts, batch over (pod, data), decode KV cache sequence over
whatever axes the batch dim left free (handles the B=1 long-context cell).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.cell import (
    ArchSpec,
    CellPlan,
    sds,
    state_and_shardings,
)
from repro.distributed.sharding import replicated, sharding_for
from repro.models import transformer as T
from repro.models.common import init_from_specs, spec_to_sds
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class LMArch:
    cfg: T.TransformerConfig
    optimizer: str = "adamw"
    accum: int = 1
    lr: float = 3e-4
    # per-kind sharding-rule preset names (see distributed.sharding.RULE_SETS);
    # None -> DEFAULT_RULES. 'residual_sp' = Megatron sequence parallelism on
    # the residual stream (required where saved activations dominate HBM).
    train_rules: str | None = None
    prefill_rules: str | None = None


def _cache_axes(cfg):
    # (L, B, Hkv, S, hd); cache_seq picks up every mesh axis batch leaves free
    return ("layers", "batch", "kv_heads", "cache_seq", None)


def build_cell(lm: LMArch, shape: str, mesh, rules=None,
               unroll: bool = False) -> CellPlan:
    from repro.distributed.sharding import RULE_SETS
    cfg = lm.cfg
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=cfg.n_layers)
    d = SHAPE_DEFS[shape]
    B, S = d["batch"], d["seq"]
    opt = get_optimizer(lm.optimizer)
    specs = T.param_specs(cfg)
    if rules is None:
        preset = lm.train_rules if d["kind"] == "train" else (
            lm.prefill_rules if d["kind"] == "prefill" else None)
        rules = RULE_SETS[preset] if preset else None
    accum = lm.accum
    if unroll and d["kind"] == "train":
        # analysis variant: lower ONE microbatch with accum_steps=1 — the HLO
        # is exactly the accumulation-loop body (identical shapes every
        # iteration); roofline.py multiplies flops/bytes/collectives by the
        # step_multiplier recorded in notes. Keeps cost_analysis exact while
        # the unrolled-HLO stays compilable in minutes.
        B = B // accum
        accum = 1

    if d["kind"] == "train":
        p_sds, o_sds, p_sh, o_sh = state_and_shardings(opt, specs, mesh, rules)
        batch_sds = {"tokens": sds((B, S), jnp.int32),
                     "labels": sds((B, S), jnp.int32)}
        b_sh = {k: sharding_for(v.shape, ("batch", None), mesh, rules)
                for k, v in batch_sds.items()}
        step = make_train_step(
            functools.partial(_lm_loss, cfg=cfg), opt, accum_steps=accum)
        fn = lambda p, o, b, lr: step(p, o, b, lr)
        return CellPlan(
            arch_id=cfg.name, shape=shape, fn=fn,
            args=(p_sds, o_sds, batch_sds, sds((), jnp.float32)),
            in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
            out_shardings=(p_sh, o_sh, None),
            donate=(0, 1), kind="train",
            rules=rules,
            notes=f"accum={lm.accum} opt={lm.optimizer}"
                  + (f" step_multiplier={lm.accum}" if unroll else ""))

    p_sds = spec_to_sds(specs)
    from repro.distributed.sharding import param_shardings
    p_sh = param_shardings(specs, mesh, rules)

    if d["kind"] == "prefill":
        tok_sds = sds((B, S), jnp.int32)
        tok_sh = sharding_for((B, S), ("batch", "sequence"), mesh, rules)
        fn = functools.partial(_prefill_fn, cfg=cfg)
        cache_sh = _kv_sharding(cfg, B, S, mesh, rules)
        logits_sh = sharding_for((B, cfg.vocab), ("batch", "vocab"), mesh, rules)
        return CellPlan(
            arch_id=cfg.name, shape=shape, fn=fn,
            args=(p_sds, tok_sds),
            in_shardings=(p_sh, tok_sh),
            out_shardings=((logits_sh, (cache_sh, cache_sh))),
            kind="serve", rules=rules)

    # decode: one new token against a live cache of size S
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    kv_sds = sds((L, B, Hkv, S, hd), cfg.compute_dtype)
    cache_sh = _kv_sharding(cfg, B, S, mesh, rules)
    tok_sds, len_sds = sds((B,), jnp.int32), sds((B,), jnp.int32)
    vec_sh = sharding_for((B,), ("batch",), mesh, rules)
    logits_sh = sharding_for((B, cfg.vocab), ("batch", "vocab"), mesh, rules)
    fn = functools.partial(_decode_fn, cfg=cfg)
    return CellPlan(
        arch_id=cfg.name, shape=shape, fn=fn,
        args=(p_sds, (kv_sds, kv_sds), tok_sds, len_sds),
        in_shardings=(p_sh, (cache_sh, cache_sh), vec_sh, vec_sh),
        out_shardings=(logits_sh, (cache_sh, cache_sh), vec_sh),
        donate=(1,), kind="serve", rules=rules)


def _kv_sharding(cfg, B, S, mesh, rules):
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return sharding_for((L, B, Hkv, S, hd), _cache_axes(cfg), mesh, rules)


def _lm_loss(params, batch, cfg):
    return T.loss_fn(params, batch, cfg)


def _prefill_fn(params, tokens, cfg):
    return T.prefill(params, tokens, cfg)


def _decode_fn(params, cache, tokens, lengths, cfg):
    return T.decode_step(params, cache, tokens, lengths, cfg)


# -------------------------------------------------------------------- smoke
def smoke_config(cfg: T.TransformerConfig) -> T.TransformerConfig:
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, head_dim=16,
        n_experts=(4 if cfg.is_moe else 0), top_k=min(cfg.top_k, 2),
        compute_dtype=jnp.float32, param_dtype=jnp.float32)


def build_smoke(lm: LMArch, shape: str) -> CellPlan:
    cfg = smoke_config(lm.cfg)
    d = SHAPE_DEFS[shape]
    kind = d["kind"]
    B, S = (4, 64) if kind == "train" else ((2, 64) if kind == "prefill" else (2, 128))
    opt = get_optimizer(lm.optimizer)
    key = jax.random.PRNGKey(0)
    params = init_from_specs(T.param_specs(cfg), key)

    if kind == "train":
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        step = make_train_step(functools.partial(_lm_loss, cfg=cfg), opt,
                               accum_steps=min(lm.accum, 2))
        return CellPlan(cfg.name, shape, step,
                        (params, opt.init(params), batch, jnp.float32(1e-3)),
                        None, kind="train")
    if kind == "prefill":
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        return CellPlan(cfg.name, shape, functools.partial(_prefill_fn, cfg=cfg),
                        (params, tokens), None, kind="serve")
    kv = jnp.zeros((cfg.n_layers, B, cfg.n_kv_heads, S, cfg.head_dim),
                   cfg.compute_dtype)
    tokens = jax.random.randint(key, (B,), 0, cfg.vocab)
    lengths = jnp.full((B,), S // 2, jnp.int32)
    return CellPlan(cfg.name, shape, functools.partial(_decode_fn, cfg=cfg),
                    (params, (kv, kv), tokens, lengths), None, kind="serve")


def make_arch(arch_id: str, lm: LMArch) -> ArchSpec:
    return ArchSpec(
        arch_id=arch_id, family="lm", shapes=LM_SHAPES,
        build=lambda shape, mesh, rules=None, unroll=False: build_cell(
            lm, shape, mesh, rules, unroll),
        build_smoke=lambda shape: build_smoke(lm, shape),
        describe=f"{lm.cfg.n_layers}L d={lm.cfg.d_model} "
                 f"{'MoE' if lm.cfg.is_moe else 'dense'}")
