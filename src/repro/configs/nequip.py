"""nequip [arXiv:2101.03164]: 5 interaction layers, 32 channels/irrep,
l_max=2, 8 Bessel RBFs, cutoff 5 A, E(3) tensor-product messages.

molecule is the native shape (energies + forces); the giant graph shapes run
energy-only (no force supervision exists there, and force training is
grad-through-energy — double memory on 61.9M-edge graphs)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.cell import ArchSpec, CellPlan, sds, state_and_shardings
from repro.configs.gnn_common import (GNN_SHAPES, SHAPE_DEFS, pad512,
                                       random_graph_batch)
from repro.distributed.sharding import replicated, sharding_for
from repro.models.common import init_from_specs
from repro.models.gnn import nequip as m
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

CFG = m.NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                     n_rbf=8, cutoff=5.0, n_species=10)
# the 61.9M-edge shape runs the same architecture in bf16 (no force training
# there; fp32 equivariance is validated in tests on the molecule path)
CFG_BF16 = dataclasses.replace(CFG, compute_dtype=jnp.bfloat16)
SMOKE_CFG = m.NequIPConfig(name="nequip", n_layers=2, d_hidden=8, l_max=2,
                           n_rbf=4, cutoff=5.0, n_species=4)

_AXES = dict(
    positions=("nodes", None), species=("nodes",), edge_src=("edges",),
    edge_dst=("edges",), edge_mask=("edges",), node_mask=("nodes",),
    graph_ids=("nodes",), energy_targets=("batch",),
    force_targets=("nodes", None),
)


def _batch_sds(d):
    n, e, g = pad512(d["n"]), pad512(d["e"]), max(d["graphs"], 1)
    i32 = jnp.int32
    return dict(
        positions=sds((n, 3)), species=sds((n,), i32),
        edge_src=sds((e,), i32), edge_dst=sds((e,), i32),
        edge_mask=sds((e,), jnp.bool_), node_mask=sds((n,), jnp.bool_),
        graph_ids=sds((n,), i32), energy_targets=sds((g,)),
        force_targets=sds((n, 3)))


def _loss_shardmap(params, batch, cfg, mesh, axis_names):
    """Energy-only loss over the destination-partitioned shard_map forward
    (EAGr reader partitioning applied to message passing; §Perf I10)."""
    e = m.forward_energy_shardmap(
        params, batch["positions"], batch["species"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], batch["node_mask"],
        batch["graph_ids"], 1, cfg, mesh, axis_names)
    e_loss = jnp.mean((e - batch["energy_targets"].astype(jnp.float32)) ** 2)
    return e_loss, {"e_mse": e_loss}


# huge single-graph shapes route through the shard_map path; molecule keeps
# the fp32 pjit path (forces + equivariance tests run there)
_SHARDMAP_SHAPES = ("ogb_products", "minibatch_lg", "full_graph_sm")


def _build(shape, mesh, rules=None, unroll=False):  # model is python-unrolled
    d = SHAPE_DEFS[shape]
    use_forces = shape == "molecule"
    cfg = CFG if shape == "molecule" else CFG_BF16
    opt = get_optimizer("adamw")
    specs = m.param_specs(cfg)
    p_sds, o_sds, p_sh, o_sh = state_and_shardings(opt, specs, mesh, rules)
    b_sds = _batch_sds(d)
    b_sh = {k: sharding_for(v.shape, _AXES[k], mesh, rules)
            for k, v in b_sds.items()}
    if shape in _SHARDMAP_SHAPES:
        axis_names = tuple(a for a in ("pod", "data", "model")
                           if a in mesh.axis_names)
        # params must be replicated for the shard_map in_specs contract
        p_sh = jax.tree.map(lambda _: replicated(mesh), p_sh)
        o_sh = jax.tree.map(lambda _: replicated(mesh), o_sh)
        loss = functools.partial(_loss_shardmap, cfg=cfg, mesh=mesh,
                                 axis_names=axis_names)
        notes = "shard_map dst-partitioned MP (energy-only)"
    else:
        loss = functools.partial(m.loss_fn, cfg=cfg, use_forces=use_forces)
        notes = "" if use_forces else "energy-only"
    step = make_train_step(loss, opt)
    return CellPlan(
        arch_id="nequip", shape=shape, fn=step,
        args=(p_sds, o_sds, b_sds, sds((), jnp.float32)),
        in_shardings=(p_sh, o_sh, b_sh, replicated(mesh)),
        out_shardings=(p_sh, o_sh, None),
        donate=(0, 1), kind="train", rules=rules, notes=notes)


def _build_smoke(shape):
    d = dict(SHAPE_DEFS[shape])
    d.update(n=min(d["n"], 60), e=min(d["e"], 200), graphs=min(d["graphs"], 4))
    g = max(d["graphs"], 1)
    use_forces = shape == "molecule"
    cfg = SMOKE_CFG
    params = init_from_specs(m.param_specs(cfg), jax.random.PRNGKey(0))
    gb = random_graph_batch(jax.random.PRNGKey(1), d["n"], d["e"], 4, 2,
                            graphs=d["graphs"], geometric=True,
                            graph_task=bool(d["graphs"]))
    batch = dict(
        positions=gb.positions, species=jnp.clip(gb.species, 0, cfg.n_species - 1),
        edge_src=gb.edge_src, edge_dst=gb.edge_dst, edge_mask=gb.edge_mask,
        node_mask=gb.node_mask,
        graph_ids=gb.graph_ids if gb.graph_ids is not None
        else jnp.zeros((d["n"],), jnp.int32),
        energy_targets=jnp.zeros((g,)), force_targets=jnp.zeros((d["n"], 3)))
    opt = get_optimizer("adamw")
    step = make_train_step(
        functools.partial(m.loss_fn, cfg=cfg, use_forces=use_forces), opt)
    return CellPlan("nequip", shape, step,
                    (params, opt.init(params), batch, jnp.float32(1e-3)),
                    None, kind="train")


ARCH = ArchSpec(arch_id="nequip", family="gnn", shapes=GNN_SHAPES,
                build=_build, build_smoke=_build_smoke)
