"""Aggregate functions and the user-defined aggregate API (paper §2.2.3).

A *PAO* (partial aggregate object) is a dense fp32 vector of ``pao_dim``
entries; every overlay node owns one row of the global ``(n_nodes, pao_dim)``
PAO array. The engine only needs four vectorized operations from an aggregate:

  lift(raw)            raw write values -> PAO contributions
  segment_merge(x,seg) merge many PAO rows by segment id (the MERGE of the
                       classic INITIALIZE/UPDATE/FINALIZE API, batched)
  subtract(a, b)       remove contribution b from a (only if invertible)
  finalize(pao)        PAO -> user-facing answer

Duplicate-insensitive aggregates (MAX/MIN/UNIQUE) tolerate multiple overlay
paths per writer; subtractable aggregates (SUM/COUNT/AVG/TOP-K) tolerate
negative edges (§2.2.1). Holistic aggregates are supported through bounded-
domain PAOs (TOP-K below keeps a dense count vector over a topic domain —
exact for bounded domains, the standard streaming relaxation otherwise).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -3.0e38  # representable in fp32/bf16; used as the MAX identity


@dataclasses.dataclass(frozen=True, eq=False)
class Aggregate:
    """Vectorized user-defined aggregate (paper §2.2.3 API, batched).

    ``combine`` is either 'sum' (signed, supports negative edges) or 'max' /
    'min' (duplicate-insensitive, recompute-on-write in the engine).

    Aggregates are static jit arguments in the engine; ``cache_key`` (set by
    the built-in constructors to name + parameters) gives two equivalent
    instances value equality so separately-built engines share compiled
    programs. Custom aggregates leave it None -> identity semantics (safe,
    no sharing).
    """

    name: str
    pao_dim: int
    combine: str                      # 'sum' | 'max' | 'min'
    lift: Callable[[jnp.ndarray], jnp.ndarray]          # (B,) raw -> (B, pao_dim)
    finalize: Callable[[jnp.ndarray], jnp.ndarray]      # (..., pao_dim) -> answer
    dup_insensitive: bool = False
    supports_subtraction: bool = False
    value_dim: int = 1                # raw write arity lift consumes: scalar
                                      # streams = 1, vector payloads match
                                      # WindowSpec(value_dim=...)
    cache_key: tuple | None = None

    def __eq__(self, other):
        if (self.cache_key is None or not isinstance(other, Aggregate)
                or other.cache_key is None):
            return self is other
        return self.cache_key == other.cache_key

    def __hash__(self):
        return hash(self.cache_key) if self.cache_key is not None else id(self)

    # ------------------------------------------------------------- identities
    @property
    def identity(self) -> float:
        if self.combine == "sum":
            return 0.0
        return NEG_INF if self.combine == "max" else -NEG_INF

    def init_pao(self, n_rows: int) -> jnp.ndarray:
        return jnp.full((n_rows, self.pao_dim), self.identity, dtype=jnp.float32)

    # ------------------------------------------------------------- merge ops
    def segment_merge(self, x: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
        """MERGE many PAO rows grouped by segment id. x: (E, pao_dim)."""
        if self.combine == "sum":
            return jax.ops.segment_sum(x, seg, num_segments=num_segments)
        if self.combine == "max":
            return jax.ops.segment_max(
                x, seg, num_segments=num_segments, indices_are_sorted=False
            )
        return jax.ops.segment_min(x, seg, num_segments=num_segments)

    def merge(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        if self.combine == "sum":
            return a + b
        return jnp.maximum(a, b) if self.combine == "max" else jnp.minimum(a, b)

    def subtract(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        if not self.supports_subtraction:
            raise ValueError(f"{self.name} does not support subtraction")
        return a - b

    # ------------------------------------------------- scalar reference (UDF)
    # The classic per-event API, used by tests as an oracle and available for
    # user-defined aggregates that want event-at-a-time semantics.
    def INITIALIZE(self) -> np.ndarray:
        return np.full((self.pao_dim,), self.identity, dtype=np.float64)

    def UPDATE(self, pao: np.ndarray, old, new) -> np.ndarray:
        lifted_new = np.asarray(jax.device_get(self.lift(jnp.asarray([new]))))[0]
        if self.combine == "sum":
            out = pao + lifted_new
            if old is not None:
                lifted_old = np.asarray(jax.device_get(self.lift(jnp.asarray([old]))))[0]
                out = out - lifted_old
            return out
        fn = np.maximum if self.combine == "max" else np.minimum
        if old is not None:
            raise ValueError("non-invertible aggregate cannot UPDATE out an old value")
        return fn(pao, lifted_new)

    def FINALIZE(self, pao: np.ndarray):
        return np.asarray(jax.device_get(self.finalize(jnp.asarray(pao, dtype=jnp.float32))))


# --------------------------------------------------------------------- built-ins
def sum_aggregate(value_dim: int = 1) -> Aggregate:
    return Aggregate(
        name="sum", pao_dim=value_dim, combine="sum",
        cache_key=("sum", value_dim),
        lift=lambda v: v.reshape(v.shape[0], -1).astype(jnp.float32),
        finalize=lambda p: p,
        supports_subtraction=True,
        value_dim=value_dim,
    )


def count_aggregate() -> Aggregate:
    return Aggregate(
        name="count", pao_dim=1, combine="sum", cache_key=("count",),
        lift=lambda v: jnp.ones((v.shape[0], 1), dtype=jnp.float32),
        finalize=lambda p: p,
        supports_subtraction=True,
    )


def avg_aggregate() -> Aggregate:
    return Aggregate(
        name="avg", pao_dim=2, combine="sum", cache_key=("avg",),
        lift=lambda v: jnp.stack([v.reshape(-1).astype(jnp.float32),
                                  jnp.ones_like(v.reshape(-1), dtype=jnp.float32)], axis=-1),
        finalize=lambda p: p[..., 0] / jnp.maximum(p[..., 1], 1.0),
        supports_subtraction=True,
    )


def max_aggregate(value_dim: int = 1) -> Aggregate:
    return Aggregate(
        name="max", pao_dim=value_dim, combine="max",
        cache_key=("max", value_dim),
        lift=lambda v: v.reshape(v.shape[0], -1).astype(jnp.float32),
        finalize=lambda p: p,
        dup_insensitive=True,
        value_dim=value_dim,
    )


def min_aggregate(value_dim: int = 1) -> Aggregate:
    return Aggregate(
        name="min", pao_dim=value_dim, combine="min",
        cache_key=("min", value_dim),
        lift=lambda v: v.reshape(v.shape[0], -1).astype(jnp.float32),
        finalize=lambda p: p,
        dup_insensitive=True,
        value_dim=value_dim,
    )


def topk_aggregate(k: int = 3, domain: int = 64) -> Aggregate:
    """Paper's TOP-K: the k most *frequent* values (generalized mode, §5.1).
    PAO = dense count vector over a bounded topic-id domain; finalize returns
    the top-k topic ids (most-frequent first)."""

    def lift(v: jnp.ndarray) -> jnp.ndarray:
        ids = jnp.clip(v.reshape(-1).astype(jnp.int32), 0, domain - 1)
        return jax.nn.one_hot(ids, domain, dtype=jnp.float32)

    def finalize(p: jnp.ndarray) -> jnp.ndarray:
        _, idx = jax.lax.top_k(p, k)
        return idx

    return Aggregate(
        name="topk", pao_dim=domain, combine="sum", cache_key=("topk", k, domain),
        lift=lift, finalize=finalize, supports_subtraction=True,
    )


BUILTINS: dict[str, Callable[..., Aggregate]] = {
    "sum": sum_aggregate,
    "count": count_aggregate,
    "avg": avg_aggregate,
    "max": max_aggregate,
    "min": min_aggregate,
    "topk": topk_aggregate,
}


def make_aggregate(name: "str | Aggregate", **kwargs) -> Aggregate:
    """Resolve an aggregate by name (case/hyphen-insensitive: 'TOP-K' ->
    'topk'). An ``Aggregate`` instance passes through unchanged so APIs can
    accept either form. Unknown or non-string names raise a ``ValueError``
    naming the valid set; bad constructor kwargs raise a ``ValueError``
    naming the aggregate and its signature."""
    if isinstance(name, Aggregate):
        if kwargs:
            raise ValueError(
                f"aggregate {name.name!r} is already constructed; "
                f"constructor kwargs {sorted(kwargs)} cannot be applied")
        return name
    if not isinstance(name, str):
        raise ValueError(f"aggregate name must be a string or Aggregate, "
                         f"got {type(name).__name__}; "
                         f"built-ins: {sorted(BUILTINS)}")
    try:
        ctor = BUILTINS[name.strip().lower().replace("-", "").replace("_", "")]
    except KeyError:
        raise ValueError(f"unknown aggregate {name!r}; "
                         f"built-ins: {sorted(BUILTINS)}") from None
    try:
        return ctor(**kwargs)
    except TypeError as e:
        raise ValueError(f"bad arguments for aggregate {name!r}: {e}") from None
