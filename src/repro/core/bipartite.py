"""Bipartite writer/reader graph A_G construction (paper §3.1, Figure 1c).

Given the data graph G, a neighborhood selection function N(), and a predicate
over V, produce the directed bipartite graph: writer nodes -> reader nodes,
where reader v's inputs are N(v).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.graphs.csr import CSRGraph


@dataclasses.dataclass
class Bipartite:
    n_base: int
    reader_inputs: dict[int, np.ndarray]  # base reader id -> sorted base writer ids
    writers: np.ndarray                   # base ids of nodes that feed >=1 reader

    @property
    def n_edges(self) -> int:
        return sum(v.size for v in self.reader_inputs.values())

    @property
    def n_readers(self) -> int:
        return len(self.reader_inputs)

    def reader_input_sets(self) -> dict[int, set[int]]:
        return {r: set(map(int, ins)) for r, ins in self.reader_inputs.items()}

    def writer_out_degrees(self) -> dict[int, int]:
        deg: dict[int, int] = {}
        for ins in self.reader_inputs.values():
            for w in ins:
                deg[int(w)] = deg.get(int(w), 0) + 1
        return deg


def build_bipartite(
    graph: CSRGraph,
    *,
    hops: int = 1,
    pred: Callable[[int], bool] | None = None,
    neighborhood: Callable[[CSRGraph, int], np.ndarray] | None = None,
    two_hop_cap: int | None = None,
) -> Bipartite:
    """N(x) defaults to the in-neighborhood {y | y -> x} (paper's running example),
    extended to the 2-hop in-neighborhood for hops=2 (§5.4). A custom
    ``neighborhood(graph, v)`` callable supports filtered neighborhoods."""
    # in-neighbors as out-adjacency of the reversed graph
    rev = graph.reverse()
    if hops == 2:
        rev = rev.two_hop(cap_per_node=two_hop_cap)
    elif hops != 1:
        raise ValueError(f"hops must be 1 or 2, got {hops}")

    if pred is None and neighborhood is None:
        # bulk path: CSR rows are already deduplicated and sorted, so reader
        # lists are direct row views and the writer set is one np.unique
        reader_inputs = {
            int(v): rev.indices[rev.indptr[v]: rev.indptr[v + 1]]
            for v in np.flatnonzero(np.diff(rev.indptr) > 0)
        }
        return Bipartite(
            n_base=graph.n_nodes,
            reader_inputs=reader_inputs,
            writers=np.unique(rev.indices),
        )

    reader_inputs = {}
    writer_set: set[int] = set()
    for v in range(graph.n_nodes):
        if pred is not None and not pred(v):
            continue
        if neighborhood is not None:
            ins = np.asarray(neighborhood(graph, v), dtype=np.int64)
        else:
            ins = rev.out_neighbors(v)
        if ins.size == 0:
            continue
        ins = np.unique(ins)
        reader_inputs[v] = ins
        writer_set.update(map(int, ins))
    return Bipartite(
        n_base=graph.n_nodes,
        reader_inputs=reader_inputs,
        writers=np.array(sorted(writer_set), dtype=np.int64),
    )
