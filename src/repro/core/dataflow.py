"""Dataflow (push/pull pre-computation) decisions (paper §4).

Pipeline: read/write frequencies -> push/pull frequencies f_h/f_l (§4.1) ->
node weights w(v) = PULL(v) - PUSH(v) (§4.3) -> P1/P2 pruning (§4.5) ->
min s-t cut per connected component (§4.4, optimal) or greedy (§4.6) ->
optional node splitting for partial pre-computation (§4.7) and adaptive
re-decision at the push/pull frontier (§4.8).
"""
from __future__ import annotations

import dataclasses
import math
import sys
from typing import Callable

import numpy as np

from repro.core.maxflow import Dinic, INF
from repro.core.overlay import Overlay

PUSH, PULL = 0, 1


@dataclasses.dataclass
class CostModel:
    """H(k) = avg cost of one push into a k-input node; L(k) = one pull (§4.2)."""

    H: Callable[[int], float]
    L: Callable[[int], float]
    name: str = "custom"


def cost_model_for(aggregate: str, window: int = 1) -> CostModel:
    a = aggregate.lower()
    if a in ("sum", "count", "avg", "topk", "top-k"):
        # incremental update is O(1); on-demand merge is O(k)
        return CostModel(H=lambda k: 1.0, L=lambda k: float(max(1, k)), name=a)
    if a in ("max", "min"):
        # priority-queue style incremental update: H ∝ log2 k (§4.2)
        return CostModel(H=lambda k: math.log2(max(2, k)), L=lambda k: float(max(1, k)), name=a)
    raise ValueError(f"unknown aggregate {aggregate}")


def calibrate_cost_model(aggregate, pao_dim: int = 1, sizes=(1, 2, 4, 8, 16, 32)) -> CostModel:
    """Paper §4.2: learn H()/L() by timing the aggregate implementation.
    ``aggregate`` is a repro.core.aggregates.Aggregate. Fits L(k)=a*k+b, H const."""
    import time

    import jax
    import jax.numpy as jnp

    pulls = []
    for k in sizes:
        x = jnp.ones((k, pao_dim), dtype=jnp.float32)
        seg = jnp.zeros((k,), dtype=jnp.int32)
        f = jax.jit(lambda x, seg: aggregate.segment_merge(x, seg, 1))
        f(x, seg).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            f(x, seg).block_until_ready()
        pulls.append((k, (time.perf_counter() - t0) / 20))
    ks = np.array([k for k, _ in pulls], dtype=np.float64)
    ts = np.array([t for _, t in pulls], dtype=np.float64)
    a, b = np.polyfit(ks, ts, 1)
    h = float(ts[0])  # one-input update cost
    scale = max(h, 1e-12)
    return CostModel(H=lambda k: 1.0, L=lambda k: max(1.0, (a * k + b) / scale), name="calibrated")


# ---------------------------------------------------------------------- freqs
def compute_frequencies(
    overlay: Overlay, write_freq: np.ndarray, read_freq: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """f_h (push) and f_l (pull) frequencies per overlay node (§4.1).
    write_freq/read_freq are indexed by *base* node id."""
    n = overlay.n_nodes
    f_h = np.zeros(n, dtype=np.float64)
    f_l = np.zeros(n, dtype=np.float64)
    order = overlay.toposort()
    for v in order:
        if overlay.kinds[v] == "W":
            f_h[v] = float(write_freq[overlay.origin[v]])
        else:
            f_h[v] = sum(f_h[src] for src, _ in overlay.in_edges[v])
    out = overlay.out_edges()
    for v in reversed(order):
        if overlay.kinds[v] == "R":
            f_l[v] = float(read_freq[overlay.origin[v]])
        else:
            f_l[v] = sum(f_l[dst] for dst, _ in out[v])
    return f_h, f_l


def node_weights(
    overlay: Overlay,
    f_h: np.ndarray,
    f_l: np.ndarray,
    cost: CostModel,
    *,
    window: int = 1,
    writers_always_push: bool = True,
) -> np.ndarray:
    """w(v) = PULL(v) - PUSH(v); positive weight favors push (§4.3)."""
    n = overlay.n_nodes
    w = np.zeros(n, dtype=np.float64)
    for v in range(n):
        k = overlay.in_degree(v)
        if overlay.kinds[v] == "W":
            if writers_always_push:
                w[v] = INF  # §2.2.1: writer nodes are always annotated push
                continue
            k = window  # §4.2: writers implicitly aggregate their window
        w[v] = f_l[v] * cost.L(k) - f_h[v] * cost.H(k)
    return w


def push_pull_costs(overlay: Overlay, f_h, f_l, cost: CostModel, window: int = 1):
    n = overlay.n_nodes
    push = np.zeros(n)
    pull = np.zeros(n)
    for v in range(n):
        k = window if overlay.kinds[v] == "W" else overlay.in_degree(v)
        push[v] = f_h[v] * cost.H(max(1, k))
        pull[v] = f_l[v] * cost.L(max(1, k))
    return push, pull


def total_cost(overlay: Overlay, decisions: np.ndarray, f_h, f_l, cost: CostModel,
               window: int = 1) -> float:
    push, pull = push_pull_costs(overlay, f_h, f_l, cost, window)
    return float(np.where(decisions == PUSH, push, pull).sum())


# ---------------------------------------------------------------------- prune
@dataclasses.dataclass
class DecisionStats:
    n_nodes: int = 0
    n_pruned: int = 0
    n_components: int = 0
    largest_component: int = 0
    maxflow_nodes: int = 0

    @property
    def pruned_fraction(self) -> float:
        return self.n_pruned / max(1, self.n_nodes)


def _prune(overlay: Overlay, w: np.ndarray):
    """P1/P2 (§4.5): returns (decisions or -1, alive mask). Optimality-preserving
    (Theorem 4.2)."""
    n = overlay.n_nodes
    out = overlay.out_edges()
    indeg = np.array([overlay.in_degree(v) for v in range(n)], dtype=np.int64)
    outdeg = np.array([len(out[v]) for v in range(n)], dtype=np.int64)
    decided = np.full(n, -1, dtype=np.int64)
    alive = np.ones(n, dtype=bool)
    stack = list(range(n))
    while stack:
        v = stack.pop()
        if not alive[v]:
            continue
        if w[v] > 0 and indeg[v] == 0:
            decided[v] = PUSH
        elif w[v] < 0 and outdeg[v] == 0:
            decided[v] = PULL
        else:
            continue
        alive[v] = False
        for dst, _ in out[v]:
            if alive[dst]:
                indeg[dst] -= 1
                stack.append(dst)
        for src, _ in overlay.in_edges[v]:
            if alive[src]:
                outdeg[src] -= 1
                stack.append(src)
    return decided, alive


def _components(overlay: Overlay, alive: np.ndarray) -> list[list[int]]:
    n = overlay.n_nodes
    out = overlay.out_edges()
    seen = np.zeros(n, dtype=bool)
    comps = []
    for v in range(n):
        if not alive[v] or seen[v]:
            continue
        comp = []
        stack = [v]
        seen[v] = True
        while stack:
            u = stack.pop()
            comp.append(u)
            for x, _ in overlay.in_edges[u]:
                if alive[x] and not seen[x]:
                    seen[x] = True
                    stack.append(x)
            for x, _ in out[u]:
                if alive[x] and not seen[x]:
                    seen[x] = True
                    stack.append(x)
        comps.append(comp)
    return comps


def _mincut_component(overlay: Overlay, comp: list[int], w: np.ndarray) -> dict[int, int]:
    """Optimal (X, Y) partition of one component via s-t min cut (Theorem 4.1)."""
    idx = {v: i for i, v in enumerate(comp)}
    n = len(comp)
    d = Dinic(n + 2)
    s, t = n, n + 1
    for v in comp:
        if w[v] < 0:
            d.add_edge(s, idx[v], -w[v])
        elif w[v] > 0:
            d.add_edge(idx[v], t, w[v])
    for v in comp:
        for src, _ in overlay.in_edges[v]:
            if src in idx:
                d.add_edge(idx[src], idx[v], INF)
    d.max_flow(s, t)
    reach = d.reachable_from(s)
    return {v: (PULL if reach[idx[v]] else PUSH) for v in comp}


def decide_mincut(
    overlay: Overlay,
    write_freq: np.ndarray,
    read_freq: np.ndarray,
    cost: CostModel,
    *,
    window: int = 1,
    writers_always_push: bool = True,
) -> tuple[np.ndarray, DecisionStats]:
    """The paper's optimal polynomial-time algorithm: prune, then min-cut per
    remaining connected component."""
    sys.setrecursionlimit(max(sys.getrecursionlimit(), 10000))
    f_h, f_l = compute_frequencies(overlay, write_freq, read_freq)
    w = node_weights(overlay, f_h, f_l, cost, window=window,
                     writers_always_push=writers_always_push)
    decided, alive = _prune(overlay, w)
    stats = DecisionStats(n_nodes=overlay.n_nodes, n_pruned=int((~alive).sum()))
    comps = _components(overlay, alive)
    stats.n_components = len(comps)
    stats.largest_component = max((len(c) for c in comps), default=0)
    stats.maxflow_nodes = int(alive.sum())
    for comp in comps:
        for v, dec in _mincut_component(overlay, comp, w).items():
            decided[v] = dec
    # w == 0 nodes pruned neither way: either side is optimal; default push.
    decided[decided < 0] = PUSH
    return decided.astype(np.int64), stats


# ---------------------------------------------------------------------- greedy
def decide_greedy(
    overlay: Overlay,
    write_freq: np.ndarray,
    read_freq: np.ndarray,
    cost: CostModel,
    *,
    window: int = 1,
    writers_always_push: bool = True,
) -> np.ndarray:
    """Linear-time greedy alternative (§4.6). Valid but not always optimal."""
    TENT = 2
    f_h, f_l = compute_frequencies(overlay, write_freq, read_freq)
    push_c, pull_c = push_pull_costs(overlay, f_h, f_l, cost, window)
    dec = np.full(overlay.n_nodes, -1, dtype=np.int64)
    for v in overlay.toposort():
        if overlay.kinds[v] == "W":
            if writers_always_push or push_c[v] <= pull_c[v]:
                dec[v] = PUSH
            else:
                dec[v] = TENT
            continue
        ins = [src for src, _ in overlay.in_edges[v]]
        wants_pull = push_c[v] > pull_c[v]
        if any(dec[i] == PULL for i in ins):
            dec[v] = PULL
            for i in ins:
                if dec[i] == TENT:
                    dec[i] = PULL
        elif wants_pull and any(dec[i] == TENT for i in ins):
            dec[v] = PULL
            for i in ins:
                if dec[i] == TENT:
                    dec[i] = PULL
        elif wants_pull:
            dec[v] = TENT
        elif all(dec[i] == PUSH for i in ins):
            dec[v] = PUSH
        else:
            tent = [i for i in ins if dec[i] == TENT]
            cost_push = push_c[v] + sum(push_c[i] for i in tent)
            cost_pull = pull_c[v] + sum(pull_c[i] for i in tent)
            if cost_push <= cost_pull:
                dec[v] = PUSH
                for i in tent:
                    dec[i] = PUSH
            else:
                dec[v] = PULL
                for i in tent:
                    dec[i] = PULL
    dec[dec == TENT] = PULL
    return dec


# ---------------------------------------------------------------------- split
def split_nodes(
    overlay: Overlay,
    decisions: np.ndarray,
    write_freq: np.ndarray,
    read_freq: np.ndarray,
    cost: CostModel,
    *,
    window: int = 1,
) -> tuple[Overlay, np.ndarray, int]:
    """Partial pre-computation by splitting (§4.7): for each *pull* node v with
    pull frequency f and input push frequencies f_1<=...<=f_k, find l minimizing
        sum_{i<=l} f_i*H(l) + f*L(k-l+1)
    and split inputs 1..l into a pushed partial aggregate v'.

    (Documented deviation: the paper prints f*L(l) for the second term, under
    which l=0 is always optimal — a typo; the on-demand merge at v is over the
    k-l remaining inputs plus v', hence L(k-l+1).)
    """
    n0 = overlay.n_nodes
    f_h, f_l = compute_frequencies(overlay, write_freq, read_freq)
    new_dec = list(decisions)
    n_split = 0
    for v in range(n0):
        if decisions[v] != PULL or overlay.kinds[v] == "W":
            continue
        ins = list(overlay.in_edges[v])
        k = len(ins)
        if k < 3:
            continue
        # the pushed prefix may only contain inputs that are themselves push
        # (a push node's inputs must all be push, §2.2.1)
        pushable = sorted((e for e in ins if decisions[e[0]] == PUSH), key=lambda e: f_h[e[0]])
        others = [e for e in ins if decisions[e[0]] != PUSH]
        if len(pushable) < 2:
            continue
        freqs = [f_h[src] for src, _ in pushable]
        f = f_l[v]
        best_l, best_cost = 0, f * cost.L(k)
        prefix = 0.0
        for l in range(1, len(pushable)):
            prefix += freqs[l - 1]
            c = prefix * cost.H(l) + f * cost.L(k - l + 1)
            if c < best_cost:
                best_l, best_cost = l, c
        if best_l == 0:
            continue
        vp = overlay.add_node("I", -1)
        overlay.in_edges[vp] = pushable[:best_l]
        overlay.in_edges[v] = pushable[best_l:] + others + [(vp, 1)]
        new_dec.append(PUSH)
        n_split += 1
    return overlay, np.array(new_dec, dtype=np.int64), n_split


# ---------------------------------------------------------------------- adapt
def frontier_nodes(overlay: Overlay, decisions: np.ndarray) -> list[int]:
    """The push/pull frontier (§4.8): pull nodes whose inputs are all push, and
    push nodes whose consumers are all pull."""
    out = overlay.out_edges()
    res = []
    for v in range(overlay.n_nodes):
        ins = [s for s, _ in overlay.in_edges[v]]
        outs = [d for d, _ in out[v]]
        if decisions[v] == PULL and ins and all(decisions[i] == PUSH for i in ins):
            res.append(v)
        elif decisions[v] == PUSH and outs and all(decisions[o] == PULL for o in outs):
            if overlay.kinds[v] != "W":
                res.append(v)
    return res


def adapt_decisions(
    overlay: Overlay,
    decisions: np.ndarray,
    observed_write: np.ndarray,
    observed_read: np.ndarray,
    cost: CostModel,
    *,
    window: int = 1,
    rounds: int = 4,
) -> tuple[np.ndarray, int]:
    """Unilaterally flip frontier nodes whose observed-frequency costs favor the
    other decision (§4.8). Each flip may expose new frontier nodes."""
    dec = decisions.copy()
    f_h, f_l = compute_frequencies(overlay, observed_write, observed_read)
    push_c, pull_c = push_pull_costs(overlay, f_h, f_l, cost, window)
    n_flips = 0
    for _ in range(rounds):
        flipped = 0
        for v in frontier_nodes(overlay, dec):
            if dec[v] == PULL and push_c[v] < pull_c[v]:
                dec[v] = PUSH
                flipped += 1
            elif dec[v] == PUSH and pull_c[v] < push_c[v]:
                dec[v] = PULL
                flipped += 1
        n_flips += flipped
        if flipped == 0:
            break
    return dec, n_flips
