"""Incremental overlay maintenance under data-graph changes (paper §3.3).

Adopts any constructed overlay (VNM*/IOB) into an indexed, mutable form and
applies edge/node additions and deletions using the IOB machinery:

  add edge   — if |Δ(I(r))| > threshold, cover the delta with (possibly new)
               aggregation nodes; else add direct writer edges; a per-reader
               direct-edge counter triggers IOB restructuring past the threshold.
  delete edge— if few upstream nodes are affected, split them so the reader
               stops consuming the deleted writers; else drop the reader's
               inputs and re-cover with IOB.
  add node   — new writer node + IOB insertion of the new reader.
  delete node— remove v_w and v_r with all incident edges (sound for all
               downstream aggregates: a deleted node leaves every neighborhood).

Negative (subtraction) edges into readers are supported: adding a data-graph
edge whose writer already has a negative edge to the reader simply cancels the
negative edge; deletions never touch negative edges (they reference non-members).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.core.iob import IOBBuilder
from repro.core.overlay import Overlay


class NodePatch(NamedTuple):
    """Post-mutation snapshot of one overlay node, as the patcher consumes it."""

    kind: str                           # effective kind ('W'|'I'|'R'; emptied
                                        # readers already demoted to 'I')
    origin: int
    edges: tuple[tuple[int, int], ...]  # current in-edges (src overlay node, sign)


@dataclasses.dataclass
class OverlayDelta:
    """Structured mutation log of one churn burst (paper §3.3).

    ``nodes`` snapshots every node whose in-edge list (or kind) changed,
    including all newly created nodes — enough for ``plan_patch`` to diff
    against the live plan's host mirror and patch the level tables in place.
    Per-level edge adds/removes are *derived* there (levels are a global
    property of the DAG, not something the mutation site can know).
    """

    nodes: dict[int, NodePatch]
    n_nodes_before: int
    n_nodes_after: int
    new_writer_nodes: list[int]         # ALL W-kind nodes created this epoch,
                                        # id order — every one claims a window
                                        # row so patched and recompiled plans
                                        # agree on row positions (W-kind nodes
                                        # own rows in id order on both paths,
                                        # even if deleted within the epoch)
    new_writers: dict[int, int]         # base id -> new overlay writer node
    new_readers: dict[int, int]         # base id -> new overlay reader node
    retired_writers: set[int]           # base ids whose writer role ended
    retired_readers: set[int]           # base ids whose reader role ended
    touched_readers: set[int]           # base reader ids affected (shard routing)

    @property
    def empty(self) -> bool:
        return not (self.nodes or self.retired_writers or self.retired_readers)

    def merge(self, later: "OverlayDelta") -> "OverlayDelta":
        """Coalesce two consecutive deltas (later snapshots win; a later
        retirement cancels an earlier addition and vice versa)."""
        nodes = dict(self.nodes)
        nodes.update(later.nodes)
        new_writers = {**self.new_writers, **later.new_writers}
        new_readers = {**self.new_readers, **later.new_readers}
        for b in later.retired_writers - set(later.new_writers):
            new_writers.pop(b, None)
        for b in later.retired_readers - set(later.new_readers):
            new_readers.pop(b, None)
        return OverlayDelta(
            nodes=nodes,
            n_nodes_before=self.n_nodes_before,
            n_nodes_after=later.n_nodes_after,
            new_writer_nodes=self.new_writer_nodes + later.new_writer_nodes,
            new_writers=new_writers,
            new_readers=new_readers,
            retired_writers=(self.retired_writers - set(later.new_writers))
            | later.retired_writers,
            retired_readers=(self.retired_readers - set(later.new_readers))
            | later.retired_readers,
            touched_readers=self.touched_readers | later.touched_readers,
        )


class DynamicOverlay:
    def __init__(self, builder: IOBBuilder, reader_node: dict[int, int],
                 neg_edges: dict[int, list[int]], reader_inputs: dict[int, set[int]],
                 threshold: int = 4, split_limit: int = 5):
        self.b = builder
        self.reader_node = reader_node          # base reader id -> overlay node
        self.neg_edges = neg_edges              # reader overlay node -> [writer overlay nodes]
        self.reader_inputs = reader_inputs      # base reader id -> set of base writers
        self.threshold = threshold
        self.split_limit = split_limit
        self.direct_writer_count: dict[int, int] = {}
        self.dup_insensitive = False
        # ------------------------------------------------------ mutation log
        self._dirty: set[int] = set()           # nodes whose inputs changed
        builder.journal = self._dirty
        self._delta_base = len(builder.kinds)   # first node id of this burst
        self._retired_writers: set[int] = set()
        self._retired_readers: set[int] = set()
        self._touched_readers: set[int] = set()

    # ------------------------------------------------------------ adoption
    @staticmethod
    def from_overlay(ov: Overlay, reader_inputs: dict[int, set[int]],
                     threshold: int = 4, split_limit: int = 5) -> "DynamicOverlay":
        b = IOBBuilder()
        neg: dict[int, list[int]] = {}
        # nodes adopt 1:1 (same ids); members computed from positive closure
        sets = ov.input_writer_sets()
        for v in range(ov.n_nodes):
            b.kinds.append(ov.kinds[v])
            b.origin.append(ov.origin[v])
            b.inputs.append([s for s, sign in ov.in_edges[v] if sign > 0])
            members = set(sets[v]) if ov.kinds[v] != "W" else {ov.origin[v]}
            b.members.append(members)
            for w in members:
                b.rev.setdefault(w, set()).add(v)
            if ov.kinds[v] == "W":
                b.writer_node[ov.origin[v]] = v
            negs = [s for s, sign in ov.in_edges[v] if sign < 0]
            if negs:
                neg[v] = negs
        reader_node = {ov.origin[v]: v for v in range(ov.n_nodes) if ov.kinds[v] == "R"}
        dyn = DynamicOverlay(b, reader_node, neg, {r: set(s) for r, s in reader_inputs.items()},
                             threshold=threshold, split_limit=split_limit)
        dyn.dup_insensitive = ov.dup_insensitive
        return dyn

    def fork(self) -> "DynamicOverlay":
        """Independent deep copy with the same node ids and the same internal
        counters, starting with a clean mutation journal.

        Two forks fed the same mutation sequence evolve identically (ids,
        restructuring thresholds, cover order), so a session can keep one
        journaling ``DynamicOverlay`` per engine group over a single overlay
        construction: each group drains its own delta against its own plan
        while all groups stay structurally in lockstep."""
        b = IOBBuilder()
        b.kinds = list(self.b.kinds)
        b.origin = list(self.b.origin)
        b.inputs = [list(ins) for ins in self.b.inputs]
        b.members = [set(m) for m in self.b.members]
        b.rev = {w: set(ns) for w, ns in self.b.rev.items()}
        b.writer_node = dict(self.b.writer_node)
        dyn = DynamicOverlay(
            b, dict(self.reader_node),
            {r: list(ws) for r, ws in self.neg_edges.items()},
            {r: set(ws) for r, ws in self.reader_inputs.items()},
            threshold=self.threshold, split_limit=self.split_limit)
        dyn.dup_insensitive = self.dup_insensitive
        dyn.direct_writer_count = dict(self.direct_writer_count)
        return dyn

    # ------------------------------------------------------------ helpers
    def _upstream_nodes(self, node: int) -> set[int]:
        seen = set()
        stack = [node]
        while stack:
            v = stack.pop()
            for s in self.b.inputs[v]:
                if s not in seen:
                    seen.add(s)
                    stack.append(s)
        return seen

    def _ensure_reader(self, r: int) -> int:
        if r in self.reader_node:
            return self.reader_node[r]
        nid = self.b.add_node("R", r, set())
        self.reader_node[r] = nid
        self.reader_inputs.setdefault(r, set())
        return nid

    # ------------------------------------------------------------ additions
    def add_reader_inputs(self, r: int, delta: set[int]) -> None:
        """Reader r's neighborhood gained the writers in ``delta`` (§3.3)."""
        delta = set(delta) - self.reader_inputs.get(r, set())
        if not delta:
            return
        rid = self._ensure_reader(r)
        self._dirty.add(rid)
        self._touched_readers.add(r)
        self.reader_inputs[r] |= delta
        # members/rev for the reader reflect its I-set
        self.b.members[rid] |= delta
        for w in delta:
            self.b.rev.setdefault(w, set()).add(rid)
        # cancel matching negative edges first
        negs = self.neg_edges.get(rid, [])
        cancelled = set()
        for wn in list(negs):
            wbase = self.b.origin[wn]
            if wbase in delta:
                negs.remove(wn)
                cancelled.add(wbase)
        delta -= cancelled
        if not delta:
            return
        if len(delta) > self.threshold:
            # cover the delta with aggregation nodes (best case: reuse one)
            self.b.cover_reader(rid, delta)
        else:
            for w in sorted(delta):
                self.b.inputs[rid].append(self.b.add_writer(w))
            cnt = self.direct_writer_count.get(rid, 0) + len(delta)
            self.direct_writer_count[rid] = cnt
            if cnt > self.threshold:
                self._restructure_direct_edges(rid)
                self.direct_writer_count[rid] = 0

    def _restructure_direct_edges(self, rid: int) -> None:
        """Re-cover the reader's direct writer edges through IOB (§3.3)."""
        direct = [d for d in self.b.inputs[rid] if self.b.kinds[d] == "W"]
        if len(direct) < 2:
            return
        keep = [d for d in self.b.inputs[rid] if self.b.kinds[d] != "W"]
        self.b.inputs[rid] = keep
        self._dirty.add(rid)
        self.b.cover_reader(rid, {self.b.origin[d] for d in direct})

    def add_edge(self, u: int, v: int, affected: dict[int, set[int]] | None = None) -> None:
        """Data-graph edge u -> v added. For 1-hop in-neighborhoods the affected
        reader is v with delta {u}; callers with other N() pass ``affected``
        explicitly as {reader: delta_writers}."""
        affected = affected if affected is not None else {v: {u}}
        for r, delta in affected.items():
            self.add_reader_inputs(r, delta)

    def add_node(self, u: int, in_neighbors: set[int], out_readers: set[int]) -> None:
        """New base node u: a writer feeding ``out_readers`` and a reader over
        ``in_neighbors`` (§3.3)."""
        self.b.add_writer(u)
        for r in out_readers:
            self.add_reader_inputs(r, {u})
        if in_neighbors:
            self.add_reader_inputs(u, set(in_neighbors))

    # ------------------------------------------------------------ deletions
    def remove_reader_inputs(self, r: int, delta: set[int]) -> None:
        delta = set(delta) & self.reader_inputs.get(r, set())
        if not delta:
            return
        rid = self.reader_node[r]
        self._dirty.add(rid)
        self._touched_readers.add(r)
        self.reader_inputs[r] -= delta
        self.b.members[rid] -= delta
        for w in delta:
            self.b.rev.get(w, set()).discard(rid)
        if self.neg_edges.get(rid):
            # negative edges pair with specific positive paths; untangling them
            # under deletion is not worth the bookkeeping — rebuild this reader.
            self.b.inputs[rid] = []
            self.neg_edges.pop(rid, None)
            self.b.cover_reader(rid, set(self.reader_inputs[r]))
            return
        affected = [d for d in self.b.inputs[rid] if self.b.members[d] & delta]
        if len(affected) <= self.split_limit:
            new_inputs = [d for d in self.b.inputs[rid] if d not in set(affected)]
            for d in affected:
                useful = (self.b.members[d] - delta) & self.reader_inputs[r]
                if not useful:
                    continue
                if self.b.kinds[d] == "W":
                    continue  # direct writer edge to a deleted member: just drop
                sub = self.b._split(d, useful)
                if sub is not None:
                    new_inputs.append(sub)
                    useful -= self.b.members[sub]
                if useful:
                    self.b.inputs[rid] = new_inputs
                    self.b.cover_reader(rid, useful)
                    new_inputs = self.b.inputs[rid]
            self.b.inputs[rid] = new_inputs
        else:
            # heavy change: drop all inputs and re-insert via IOB
            self.b.inputs[rid] = []
            self.neg_edges.pop(rid, None)
            self.b.cover_reader(rid, set(self.reader_inputs[r]))

    def delete_edge(self, u: int, v: int, affected: dict[int, set[int]] | None = None) -> None:
        affected = affected if affected is not None else {v: {u}}
        for r, delta in affected.items():
            if r in self.reader_node:
                self.remove_reader_inputs(r, delta)

    def delete_node(self, u: int) -> None:
        """Remove u_w and u_r and all incident edges (§3.3)."""
        b = self.b
        wid = b.writer_node.pop(u, None)
        if wid is not None:
            self._retired_writers.add(u)
            consumers = [n for n in range(len(b.kinds)) if wid in b.inputs[n]]
            for n in consumers:
                b.inputs[n] = [d for d in b.inputs[n] if d != wid]
                self._dirty.add(n)
            # u leaves every I-set and every reader's tracked input set
            for n in b.rev.get(u, set()).copy():
                b.members[n].discard(u)
                if b.kinds[n] == "R":
                    self.reader_inputs.get(b.origin[n], set()).discard(u)
                    self._touched_readers.add(b.origin[n])
                    self._dirty.add(n)  # may demote to 'I' if now empty
            b.rev.pop(u, None)
            for rid_neg, negs in self.neg_edges.items():
                while wid in negs:
                    negs.remove(wid)
                    self._dirty.add(rid_neg)
        rid = self.reader_node.pop(u, None)
        if rid is not None:
            self._retired_readers.add(u)
            self._touched_readers.add(u)
            b.inputs[rid] = []
            self._dirty.add(rid)
            self.neg_edges.pop(rid, None)
            self.reader_inputs.pop(u, None)
            for w in list(b.members[rid]):
                b.rev.get(w, set()).discard(rid)
            b.members[rid] = set()

    # ------------------------------------------------------------ delta log
    def _effective_kind(self, nid: int) -> str:
        """Node kind as exported: emptied/superseded readers demote to 'I'."""
        kind = self.b.kinds[nid]
        if kind == "R" and (
            self.reader_node.get(self.b.origin[nid]) != nid
            or not self.reader_inputs.get(self.b.origin[nid])
        ):
            return "I"
        return kind

    def _node_edges(self, nid: int) -> tuple[tuple[int, int], ...]:
        edges = [(s, 1) for s in self.b.inputs[nid]]
        edges += [(wn, -1) for wn in self.neg_edges.get(nid, [])]
        return tuple(edges)

    @property
    def pending_nodes(self) -> int:
        """Journal size: overlay nodes the next :meth:`drain_delta` will
        snapshot (dirtied existing nodes plus nodes born this burst)."""
        return len(set(self._dirty)
                   | set(range(self._delta_base, len(self.b.kinds))))

    def drain_delta(self) -> OverlayDelta:
        """Return the structured mutation log since the last drain (or since
        construction) and reset it. Feed the result to
        ``EagrEngine.apply_delta`` / ``plan_patch.patch_plan`` to patch a live
        plan instead of recompiling; ``to_overlay()`` remains the
        full-rebuild path."""
        b = self.b
        dirty = set(self._dirty) | set(range(self._delta_base, len(b.kinds)))
        nodes = {nid: NodePatch(self._effective_kind(nid), b.origin[nid],
                                self._node_edges(nid))
                 for nid in sorted(dirty)}
        new_writers = {b.origin[nid]: nid
                       for nid in range(self._delta_base, len(b.kinds))
                       if b.kinds[nid] == "W"
                       and b.writer_node.get(b.origin[nid]) == nid}
        new_readers = {b.origin[nid]: nid
                       for nid in range(self._delta_base, len(b.kinds))
                       if b.kinds[nid] == "R"
                       and self.reader_node.get(b.origin[nid]) == nid}
        delta = OverlayDelta(
            nodes=nodes,
            n_nodes_before=self._delta_base,
            n_nodes_after=len(b.kinds),
            new_writer_nodes=[nid for nid in range(self._delta_base, len(b.kinds))
                              if b.kinds[nid] == "W"],
            new_writers=new_writers,
            new_readers=new_readers,
            retired_writers=set(self._retired_writers),
            retired_readers=set(self._retired_readers),
            touched_readers=set(self._touched_readers),
        )
        self._dirty.clear()
        self._delta_base = len(b.kinds)
        self._retired_writers.clear()
        self._retired_readers.clear()
        self._touched_readers.clear()
        return delta

    # ------------------------------------------------------------ export
    def to_overlay(self, prune: bool = True) -> Overlay:
        """Full-rebuild export. ``prune=False`` keeps builder node ids stable
        (dead nodes linger edgeless) — the id space the patch path lives in,
        so a plan compiled from the unpruned export can later be patched by
        ``drain_delta`` deltas."""
        ov = Overlay(kinds=list(self.b.kinds), origin=list(self.b.origin),
                     in_edges=[[(s, 1) for s in ins] for ins in self.b.inputs],
                     dup_insensitive=self.dup_insensitive)
        for rid, negs in self.neg_edges.items():
            for wn in negs:
                ov.in_edges[rid].append((wn, -1))
        # deleted/superseded/emptied reader nodes linger: only the current node
        # for each base reader with a non-empty neighborhood keeps the 'R' label
        for v in range(ov.n_nodes):
            if ov.kinds[v] == "R" and (
                self.reader_node.get(ov.origin[v]) != v
                or not self.reader_inputs.get(ov.origin[v])
            ):
                ov.kinds[v] = "I"
        return ov.pruned() if prune else ov
