"""Vectorized JAX execution engine over a compiled overlay (paper §2.2.2).

The paper's runtime is event-at-a-time Java with two thread pools; on TPU the
equivalent is *batched dataflow*: a batch of writes (or reads) is one jitted
program over dense arrays. The overlay is compiled (host-side, once) into a
leveled CSR ``ExecPlan``; at runtime the plan only reacts — no per-event
reasoning, which is exactly the paper's design goal.

Substrate layout. The plan is split into a hashable ``PlanMeta`` (static jit
argument: shapes + backend) and a ``PlanArrays`` pytree of *runtime* device
arrays — stacked, tile-padded per-level routing tables built through
``segment_agg.ops.make_leveled_plan``. The jitted bodies ``lax.fori_loop``
over the level axis, dynamically slicing one level's tables per iteration, so

  * program op count is constant in overlay depth, and
  * two overlays whose padded table shapes match (levels bucketed to 4,
    edge blocks to powers of two) reuse one compiled program — an overlay
    restructure (§3.3) is a table swap, not a retrace.

Per-level reduce-by-key runs on a pluggable backend chosen at plan-compile
time: ``pallas`` (the TPU segment_agg kernel; interpret mode off-TPU),
``xla`` (segment_sum/segment_max fallback), or ``xla_unrolled`` (the legacy
Python unroll over levels, kept as the benchmark baseline).

Write path (combine='sum', invertible aggregates):
    window append -> per-writer PAO delta -> per-level
    ``delta[dst] += segment_sum(sign * delta[src])`` restricted to *push* dsts.

Write path (combine='max'/'min', non-invertible):
    window append -> recompute written writers from their windows -> per-level
    recompute of push nodes (``segment_max`` over all in-edges; idempotent).

Read path (the *pull* sweep):
    demand up-sweep from requested pull readers through pull ancestors ->
    per-level masked compute down-sweep -> gather + FINALIZE at readers.

Push nodes are always current, so a read on a push reader is a single gather —
the paper's low-latency case. The per-batch work is O(|E_push|) for writes and
O(|E_pull demanded|) for reads, matching the paper's cost model amortized over
the batch.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate
from repro.core.dataflow import PULL, PUSH
from repro.core.overlay import Overlay
from repro.core.window import (
    WindowSpec,
    WindowState,
    apply_writes,
    init_windows,
    pad_window_rows,
    reset_window_rows,
    stale_rows,
    window_pao,
)
from repro.kernels.segment_agg.ops import (
    E_BLK,
    R_BLK,
    make_leveled_plan,
    segment_agg_active,
    segment_agg_level,
)

BACKENDS = ("pallas", "xla", "xla_unrolled")


def bucket_batch(n: int, floor: int = 16) -> int:
    """Power-of-two batch bucketing: varying user batch sizes land on a
    handful of padded shapes, so the jitted write/read programs retrace at
    most log2(max_batch) times per engine instead of once per distinct size."""
    return max(floor, 1 << (max(1, int(n)) - 1).bit_length())


class BaseRoutes:
    """Dense base-id -> (writer row, reader node) routing tables.

    The steady-state event routing path: :meth:`writer_rows` /
    :meth:`reader_nodes` are O(B) vectorized numpy (clip + gather + validity
    mask) with zero Python work per event, replacing the per-event dict
    lookups the write/read hot paths used to run. The ``ExecPlan`` dicts stay
    authoritative for churn bookkeeping (shard owner maps, retired-writer
    accounting, the test oracle); this table mirrors them — built in bulk by
    ``compile_plan`` and maintained incrementally by ``plan_patch`` under
    churn (one table edit per delta entry, never per event). Capacity grows
    in power-of-two buckets so a new high base id rarely reallocates; absent
    entries are ``-1``.
    """

    __slots__ = ("writer_row", "reader_node")

    def __init__(self, cap: int = 1):
        cap = self._bucket(cap)
        self.writer_row = np.full(cap, -1, np.int32)
        self.reader_node = np.full(cap, -1, np.int32)

    @staticmethod
    def _bucket(n: int) -> int:
        return max(256, 1 << (max(1, int(n)) - 1).bit_length())

    @property
    def cap(self) -> int:
        return len(self.writer_row)

    @classmethod
    def from_maps(cls, writer_row_of_base: dict, reader_node_of_base: dict
                  ) -> "BaseRoutes":
        top = max((max(m) for m in (writer_row_of_base, reader_node_of_base)
                   if m), default=0)
        routes = cls(top + 1)
        for table, m in ((routes.writer_row, writer_row_of_base),
                         (routes.reader_node, reader_node_of_base)):
            if m:
                table[np.fromiter(m.keys(), np.int64, len(m))] = \
                    np.fromiter(m.values(), np.int64, len(m))
        return routes

    def _grow(self, top: int) -> None:
        if top < self.cap:
            return
        cap = self._bucket(top + 1)
        for name in ("writer_row", "reader_node"):
            old = getattr(self, name)
            new = np.full(cap, -1, np.int32)
            new[: len(old)] = old
            setattr(self, name, new)

    # ------------------------------------------- churn maintenance (per delta)
    def set_writer(self, base: int, row: int) -> None:
        self._grow(int(base))
        self.writer_row[int(base)] = row

    def clear_writer(self, base: int) -> None:
        if 0 <= int(base) < self.cap:
            self.writer_row[int(base)] = -1

    def set_reader(self, base: int, node: int) -> None:
        self._grow(int(base))
        self.reader_node[int(base)] = node

    def clear_reader(self, base: int) -> None:
        if 0 <= int(base) < self.cap:
            self.reader_node[int(base)] = -1

    # ------------------------------------------------- hot path (per batch)
    def writer_rows(self, base_ids) -> tuple[np.ndarray, np.ndarray]:
        """Route one batch: ``(rows, mask)`` with masked lanes pinned to row
        0 — the padding pattern the masked write bodies drop."""
        ids = np.asarray(base_ids, np.int64).reshape(-1)
        rows = self.writer_row[np.clip(ids, 0, self.cap - 1)]
        mask = (ids >= 0) & (ids < self.cap) & (rows >= 0)
        return np.where(mask, rows, 0).astype(np.int32), mask

    def reader_nodes(self, base_ids) -> tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(base_ids, np.int64).reshape(-1)
        nodes = self.reader_node[np.clip(ids, 0, self.cap - 1)]
        mask = (ids >= 0) & (ids < self.cap) & (nodes >= 0)
        return np.where(mask, nodes, 0).astype(np.int32), mask


def default_backend() -> str:
    env = os.environ.get("EAGR_BACKEND", "").strip()
    if env:
        if env not in BACKENDS:
            raise ValueError(f"EAGR_BACKEND={env!r}; choose from {BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


class LevelTables(NamedTuple):
    """One edge set (push or pull) as stacked per-level kernel-layout tables.

    All tables are (L, e_pad) / (L, n_blocks) with padding slots ``seg == -1``
    (source 0, sign 0) so a padded slot contributes nothing on any backend.
    ``touched`` marks, per level, the destination rows the level recomputes.
    """

    seg: jnp.ndarray            # (L, e_pad) int32 destination rows, -1 pad
    src: jnp.ndarray            # (L, e_pad) int32 source rows, 0 pad
    sign: jnp.ndarray           # (L, e_pad) f32 edge signs, 0 pad
    tile_of_block: jnp.ndarray  # (L, n_blocks) int32
    first_of_tile: jnp.ndarray  # (L, n_blocks) int32
    touched: jnp.ndarray        # (L, n_nodes) bool


class PlanArrays(NamedTuple):
    """Runtime half of the plan: a pytree of device arrays (jit-traced, so
    plans with equal shapes share one compiled program)."""

    decision: jnp.ndarray       # (n_nodes,) int32 PUSH/PULL
    writer_node: jnp.ndarray    # (n_writers,) int32; padding rows -> n_nodes
    push: LevelTables
    pull: LevelTables
    demand_dst: jnp.ndarray     # (L, d_pad) int32 gather rows, pad -> n_nodes
    demand_src: jnp.ndarray     # (L, d_pad) int32 scatter rows, pad -> n_nodes


@dataclasses.dataclass(frozen=True)
class PlanMeta:
    """Static half of the plan: the shape/backing information a jitted body
    needs at trace time. Hashable; used as a static jit argument."""

    n_nodes: int
    n_writers: int
    n_levels: int        # padded level-loop trip count
    unroll_levels: int   # real depth iterated by 'xla_unrolled'; 0 for looped
                         # backends so restructures with equal padded shapes
                         # share one jit cache entry
    n_row_tiles: int
    backend: str
    interpret: bool
    bf16: bool = False   # EAGR_SEGAGG_BF16: edge values stream as bfloat16
                         # (2x VMEM headroom); accumulation stays fp32


@dataclasses.dataclass
class ExecPlan:
    """Host-compiled execution plan: the overlay as dense leveled-CSR tables.

    No Python-level per-level edge lists — the per-level structure lives in
    the stacked ``PlanArrays`` tables; only host-side id maps stay as dicts.
    """

    meta: PlanMeta
    arrays: PlanArrays
    depth: int                           # real overlay depth (levels)
    decision: np.ndarray                 # (n,) PUSH/PULL (host copy)
    level: np.ndarray                    # (n,)
    writer_node: np.ndarray              # (n_writers,) overlay node per row
    writer_row_of_base: dict[int, int]   # base id -> window row
    reader_node_of_base: dict[int, int]  # base id -> overlay node
    routes: "BaseRoutes | None" = None   # dense mirror of the two dicts —
                                         # the vectorized hot-path router
    n_push_edges: int = 0
    n_pull_edges: int = 0
    host: object | None = None           # plan_patch.PlanHost mirror (lazy);
                                         # owned by the incremental patch path
    patches_applied: int = 0
    frontier: object | None = None       # frontier.FrontierIndex — writer-row
                                         # -> per-level push blocks, built
                                         # lazily on first sparse write
    reader_frontier: object | None = None  # frontier.ReaderFrontierIndex —
                                           # read-path twin; invalidated by
                                           # any structural patch

    @property
    def n_nodes(self) -> int:
        return self.meta.n_nodes

    @property
    def n_levels(self) -> int:
        return self.depth

    @property
    def n_writers(self) -> int:
        return len(self.writer_node)


def _build_tables(per_level: list[list[tuple[int, int, int]]],
                  pad_levels: int | None, pad_blocks: int | None,
                  pad_nodes: int) -> LevelTables:
    """Stack one edge set's per-level (src, dst, sign) triples into padded
    kernel-layout tables via ``make_leveled_plan``."""
    segs, srcs, signs = [], [], []
    for tris in per_level:
        arr = np.asarray(tris, dtype=np.int64).reshape(-1, 3)
        segs.append(arr[:, 1])
        srcs.append(arr[:, 0])
        signs.append(arr[:, 2])
    lp = make_leveled_plan(segs, pad_nodes, pad_levels=pad_levels,
                           pad_blocks=pad_blocks)
    L, E = lp.n_levels, lp.e_pad
    src = np.zeros((L, E), np.int32)
    sign = np.zeros((L, E), np.float32)
    touched = np.zeros((L, pad_nodes), bool)
    for l in range(len(segs)):
        src[l] = lp.layout(l, srcs[l].astype(np.int32), fill=0)
        sign[l] = lp.layout(l, signs[l].astype(np.float32), fill=0.0)
        touched[l, segs[l]] = True
    return LevelTables(
        seg=jnp.asarray(lp.seg), src=jnp.asarray(src), sign=jnp.asarray(sign),
        tile_of_block=jnp.asarray(lp.tile_of_block),
        first_of_tile=jnp.asarray(lp.first_of_tile),
        touched=jnp.asarray(touched),
    )


@dataclasses.dataclass(frozen=True)
class PlanPad:
    """Explicit padding targets so several plans (e.g. sibling shards) share
    one compiled program shape. Any field left at 0 keeps the natural size."""

    n_nodes: int = 0
    n_writers: int = 0
    n_levels: int = 0
    push_blocks: int = 0
    pull_blocks: int = 0
    demand_edges: int = 0


def _collect_levels(overlay: Overlay, decision: np.ndarray, level: np.ndarray):
    """Split overlay edges into per-level push/pull/demand triples."""
    n_levels = int(level.max()) if overlay.n_nodes else 0
    per_level_push: list[list[tuple[int, int, int]]] = [[] for _ in range(n_levels)]
    per_level_pull: list[list[tuple[int, int, int]]] = [[] for _ in range(n_levels)]
    per_level_demand: list[list[tuple[int, int]]] = [[] for _ in range(n_levels)]
    for dst in range(overlay.n_nodes):
        l = int(level[dst]) - 1
        for src, sign in overlay.in_edges[dst]:
            if decision[dst] == PUSH:
                per_level_push[l].append((src, dst, sign))
            else:
                per_level_pull[l].append((src, dst, sign))
                if decision[src] == PULL:
                    per_level_demand[l].append((dst, src))
    return per_level_push, per_level_pull, per_level_demand, n_levels


def measure_plan(overlay: Overlay, decisions: np.ndarray) -> PlanPad:
    """The padded table dimensions ``compile_plan`` would produce, computed
    host-side without building or uploading any tables — equal to
    ``plan_dims(compile_plan(overlay, decisions))``. Used to align several
    plans (e.g. sibling shards) before compiling each exactly once."""
    from repro.kernels.segment_agg.ops import leveled_plan_blocks

    decision = np.asarray(decisions, dtype=np.int64)
    level = overlay.levels()
    push, pull, demand, n_levels = _collect_levels(overlay, decision, level)

    def bucket_blocks(per_level):
        nb = leveled_plan_blocks(
            [np.asarray(t, np.int64).reshape(-1, 3)[:, 1] for t in per_level])
        return 1 << (nb - 1).bit_length()

    d_real = max((len(p) for p in demand), default=0)
    return PlanPad(
        n_nodes=overlay.n_nodes,
        n_writers=len(overlay.writer_nodes()),
        n_levels=max(1, -(-n_levels // 4) * 4),
        push_blocks=bucket_blocks(push),
        pull_blocks=bucket_blocks(pull),
        demand_edges=max(1, -(-d_real // 256) * 256),
    )


def compile_plan(overlay: Overlay, decisions: np.ndarray, *,
                 backend: str | None = None,
                 pad: PlanPad | None = None) -> ExecPlan:
    backend = backend or default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    pad = pad or PlanPad()
    level = overlay.levels()
    decision = np.asarray(decisions, dtype=np.int64)
    n_nodes = max(overlay.n_nodes, pad.n_nodes)

    writers = overlay.writer_nodes()
    writer_node = np.array(writers, dtype=np.int64)
    writer_row_of_base = {overlay.origin[v]: i for i, v in enumerate(writers)}
    reader_node_of_base = {overlay.origin[v]: v for v in overlay.reader_nodes()}

    per_level_push, per_level_pull, per_level_demand, n_levels = \
        _collect_levels(overlay, decision, level)

    pad_levels = pad.n_levels or None
    push = _build_tables(per_level_push, pad_levels,
                         pad.push_blocks or None, n_nodes)
    pull = _build_tables(per_level_pull, pad_levels,
                         pad.pull_blocks or None, n_nodes)
    L = push.seg.shape[0]

    d_real = max((len(p) for p in per_level_demand), default=0)
    d_pad = max(pad.demand_edges, max(1, -(-d_real // 256) * 256))
    demand_dst = np.full((L, d_pad), n_nodes, np.int32)
    demand_src = np.full((L, d_pad), n_nodes, np.int32)
    for l, pairs in enumerate(per_level_demand):
        if pairs:
            arr = np.asarray(pairs, dtype=np.int64)
            demand_dst[l, : len(pairs)] = arr[:, 0]
            demand_src[l, : len(pairs)] = arr[:, 1]

    n_writers = max(len(writer_node), pad.n_writers)
    wnode = np.full(n_writers, n_nodes, np.int32)
    wnode[: len(writer_node)] = writer_node

    dec_pad = np.full(n_nodes, PULL, np.int64)
    dec_pad[: overlay.n_nodes] = decision

    meta = PlanMeta(
        n_nodes=n_nodes,
        n_writers=n_writers,
        n_levels=L,
        unroll_levels=n_levels if backend == "xla_unrolled" else 0,
        n_row_tiles=max(1, -(-n_nodes // R_BLK)),
        backend=backend,
        interpret=(backend == "pallas" and jax.default_backend() != "tpu"),
        bf16=os.environ.get("EAGR_SEGAGG_BF16", "0").strip() == "1",
    )
    arrays = PlanArrays(
        decision=jnp.asarray(dec_pad, jnp.int32),
        writer_node=jnp.asarray(wnode),
        push=push,
        pull=pull,
        demand_dst=jnp.asarray(demand_dst),
        demand_src=jnp.asarray(demand_src),
    )
    return ExecPlan(
        meta=meta,
        arrays=arrays,
        depth=n_levels,
        decision=decision,
        level=level,
        writer_node=writer_node,
        writer_row_of_base=writer_row_of_base,
        reader_node_of_base=reader_node_of_base,
        routes=BaseRoutes.from_maps(writer_row_of_base, reader_node_of_base),
        n_push_edges=sum(len(p) for p in per_level_push),
        n_pull_edges=sum(len(p) for p in per_level_pull),
    )


def plan_dims(plan: ExecPlan) -> PlanPad:
    """The plan's padded table dimensions, as alignment targets."""
    return PlanPad(
        n_nodes=plan.meta.n_nodes,
        n_writers=plan.meta.n_writers,
        n_levels=plan.meta.n_levels,
        push_blocks=plan.arrays.push.seg.shape[1] // E_BLK,
        pull_blocks=plan.arrays.pull.seg.shape[1] // E_BLK,
        demand_edges=plan.arrays.demand_dst.shape[1],
    )


def grow_pad(pad: PlanPad, growth: float = 2.0) -> PlanPad:
    """Scale padding targets by ``growth`` so a plan compiled now has slot /
    node / level headroom for structural churn (§3.3): in-capacity updates
    then patch the tables in place instead of recompiling."""
    g = max(1.0, float(growth))

    def up(x, mult):
        x = max(1, int(np.ceil(x * g)))
        return -(-x // mult) * mult

    blocks = lambda x: 1 << (max(1, int(np.ceil(x * g))) - 1).bit_length()
    return PlanPad(
        n_nodes=up(pad.n_nodes, R_BLK),
        n_writers=up(pad.n_writers, 8),
        n_levels=up(pad.n_levels, 4),
        push_blocks=blocks(pad.push_blocks),
        pull_blocks=blocks(pad.pull_blocks),
        demand_edges=up(pad.demand_edges, 256),
    )


class EngineState(NamedTuple):
    windows: WindowState
    pao: jnp.ndarray      # (n_nodes, pao_dim)
    now: jnp.ndarray      # scalar fp32 logical clock


# ------------------------------------------------------------ level execution
def _level_reduce(meta: PlanMeta, tables: LevelTables, l, val: jnp.ndarray,
                  op: str) -> jnp.ndarray:
    """Reduce-by-destination of one level's edge contributions gathered from
    ``val`` (n_nodes, F). ``l`` may be traced (fori_loop) or a Python int
    (xla_unrolled). Rows outside the level's touched set are undefined —
    callers mask. op: 'sum' (signed) | 'max' | 'min'."""
    seg, src, sign = tables.seg[l], tables.src[l], tables.sign[l]
    x = val[src]
    if op == "sum":
        x = x * sign[:, None]
    if meta.backend == "pallas":
        kern_op = "max" if op in ("max", "min") else "sum"
        xk = -x if op == "min" else x
        out = segment_agg_level(
            xk, seg, tables.tile_of_block[l], tables.first_of_tile[l],
            n_rows=meta.n_nodes, n_row_tiles=meta.n_row_tiles,
            op=kern_op, interpret=meta.interpret, bf16=meta.bf16)
        return -out if op == "min" else out
    if meta.bf16:
        # match the pallas bf16 semantics: edge values rounded to bfloat16,
        # the segment reduction itself in fp32
        x = x.astype(jnp.bfloat16).astype(jnp.float32)
    dst = jnp.where(seg >= 0, seg, meta.n_nodes)
    if op == "sum":
        out = jax.ops.segment_sum(x, dst, num_segments=meta.n_nodes + 1)
    elif op == "max":
        out = jax.ops.segment_max(x, dst, num_segments=meta.n_nodes + 1)
    else:
        out = jax.ops.segment_min(x, dst, num_segments=meta.n_nodes + 1)
    return out[: meta.n_nodes]


# --------------------------------------------------- frontier-sparse execution
def _gather_active(meta: PlanMeta, tables: LevelTables, l, active_l):
    """Compact one level's edge tables to its K active blocks. ``active_l``
    is (K,) int32 ascending block indices, padded with ``n_blocks`` —
    padding lanes gather a real block but are neutralized to the slot-padding
    pattern (seg -1, src 0, sign 0) every backend already drops."""
    nb = tables.tile_of_block.shape[1]
    ab = jnp.minimum(active_l, nb - 1)
    valid = (active_l < nb)[:, None]
    seg_c = jnp.where(valid, tables.seg[l].reshape(nb, E_BLK)[ab], -1)
    src_c = jnp.where(valid, tables.src[l].reshape(nb, E_BLK)[ab], 0)
    sign_c = jnp.where(valid, tables.sign[l].reshape(nb, E_BLK)[ab], 0.0)
    tob_c = tables.tile_of_block[l][ab]
    return (seg_c.reshape(-1), src_c.reshape(-1), sign_c.reshape(-1), tob_c)


def _level_reduce_active(meta: PlanMeta, seg_c, src_c, sign_c, tob_c,
                         val: jnp.ndarray, op: str) -> jnp.ndarray:
    """``_level_reduce`` over a compacted active-block edge subset: the
    gather, the kernel grid, and the segment reduction all shrink from the
    level's padded edge capacity to K*E_BLK."""
    x = val[src_c]
    if op == "sum":
        x = x * sign_c[:, None]
    if meta.backend == "pallas":
        kern_op = "max" if op in ("max", "min") else "sum"
        xk = -x if op == "min" else x
        out = segment_agg_active(
            xk, seg_c, tob_c, n_rows=meta.n_nodes,
            n_row_tiles=meta.n_row_tiles, op=kern_op,
            interpret=meta.interpret, bf16=meta.bf16)
        return -out if op == "min" else out
    if meta.bf16:
        x = x.astype(jnp.bfloat16).astype(jnp.float32)
    dst = jnp.where(seg_c >= 0, seg_c, meta.n_nodes)
    if op == "sum":
        out = jax.ops.segment_sum(x, dst, num_segments=meta.n_nodes + 1)
    elif op == "max":
        out = jax.ops.segment_max(x, dst, num_segments=meta.n_nodes + 1)
    else:
        out = jax.ops.segment_min(x, dst, num_segments=meta.n_nodes + 1)
    return out[: meta.n_nodes]


def _row_active(meta: PlanMeta, seg_c) -> jnp.ndarray:
    """(n_nodes,) bool: destinations with at least one active edge this
    level. The sparse twin of ``touched`` — the index guarantees any
    destination with a nonzero/changed contribution has its *whole* slot
    range active, so masking to these rows is exact, and rows sharing a
    block with the frontier but outside it see only zero contributions."""
    dst = jnp.where(seg_c >= 0, seg_c, meta.n_nodes)
    return jnp.zeros((meta.n_nodes + 1,), bool).at[dst].set(
        True, mode="promise_in_bounds")[: meta.n_nodes]


def _level_loop(meta: PlanMeta, body, init):
    """fori_loop over the padded level axis — or the legacy Python unroll over
    real levels for the 'xla_unrolled' baseline backend."""
    if meta.backend == "xla_unrolled":
        for l in range(meta.unroll_levels):
            init = body(l, init)
        return init
    return jax.lax.fori_loop(0, meta.n_levels, body, init)


# ---------------------------------------------------------------- step bodies
# The write/read/refresh bodies are *pure* functions of
# ``(meta, agg, spec, arrays, state, batch) -> state`` with no per-engine
# Python state: ``meta`` is static shape info, everything else is traced
# arrays. They are exposed unjitted (``write_step_sum`` etc.) so callers can
# embed them in larger programs — ``distributed/stacked.py`` vmaps/shard_maps
# them over a leading shard axis — while the jitted single-engine wrappers
# below keep their own cache entries.
def write_step_sum(meta: PlanMeta, agg: Aggregate, spec: WindowSpec,
                   arrays: PlanArrays, state: EngineState, rows, vals, mask):
    windows, evicted, evicted_valid = apply_writes(
        state.windows, spec, rows, vals,
        jnp.full(rows.shape, state.now, jnp.float32), mask)
    delta_w = agg.lift(vals) * mask[:, None].astype(jnp.float32)
    delta_w -= agg.lift(evicted) * evicted_valid[:, None].astype(jnp.float32)
    delta = jnp.zeros((meta.n_nodes, agg.pao_dim), dtype=jnp.float32)
    delta = delta.at[arrays.writer_node[rows]].add(delta_w, mode="drop")

    def level(l, delta):
        contrib = _level_reduce(meta, arrays.push, l, delta, "sum")
        # untouched rows are undefined kernel output (uninitialized tiles) —
        # only the level's destinations may accumulate
        return delta + jnp.where(arrays.push.touched[l][:, None], contrib, 0.0)

    delta = _level_loop(meta, level, delta)
    pao = state.pao + delta
    return EngineState(windows, pao, state.now + 1.0)


def write_step_extremal(meta: PlanMeta, agg: Aggregate, spec: WindowSpec,
                        arrays: PlanArrays, state: EngineState, rows, vals,
                        mask, prev_now):
    """Non-invertible write path, restricted to the *touched* writer set: the
    rows written this batch plus (time windows) the rows with an entry that
    expired since ``prev_now`` — the last instant writer PAOs were evaluated.
    Untouched rows keep their stored PAO (identical to recomputing them), and
    the level sweep only overwrites destinations downstream of a touched
    writer, so the recompute is confined to the changed closure instead of
    every writer and every push node per batch."""
    windows, _, _ = apply_writes(
        state.windows, spec, rows, vals,
        jnp.full(rows.shape, state.now, jnp.float32), mask)
    wp = window_pao(windows, spec, agg, now=state.now)
    written = jnp.zeros((meta.n_writers,), bool).at[rows].max(mask, mode="drop")
    if spec.kind == "time":
        touched_w = written | stale_rows(state.windows, spec, prev_now, state.now)
    else:
        touched_w = written  # tuple windows only evict on write
    old_w = state.pao[jnp.minimum(arrays.writer_node, meta.n_nodes - 1)]
    new_w = jnp.where(touched_w[:, None], wp, old_w)
    pao = state.pao.at[arrays.writer_node].set(new_w, mode="drop")
    changed = jnp.zeros((meta.n_nodes + 1,), bool)
    changed = changed.at[arrays.writer_node].max(touched_w, mode="promise_in_bounds")

    def level(l, carry):
        pao, changed = carry
        new = _level_reduce(meta, arrays.push, l, pao, agg.combine)
        seg = arrays.push.seg[l]
        dst = jnp.where(seg >= 0, seg, meta.n_nodes)
        ch = jax.ops.segment_max(
            changed[arrays.push.src[l]].astype(jnp.int32), dst,
            num_segments=meta.n_nodes + 1) > 0
        upd = arrays.push.touched[l] & ch[: meta.n_nodes]
        pao = jnp.where(upd[:, None], new, pao)
        changed = changed.at[: meta.n_nodes].max(upd)
        return pao, changed

    pao, _ = _level_loop(meta, level, (pao, changed))
    return EngineState(windows, pao, state.now + 1.0)


def refresh_pao_step(meta: PlanMeta, agg: Aggregate, spec: WindowSpec,
                     arrays: PlanArrays, windows, now) -> jnp.ndarray:
    """Recompute the full PAO array from the writer windows through the push
    tables — the state repair after a structural patch (``apply_delta``):
    rewired push nodes get exact values, retired rows fall back to the
    aggregate identity, pull rows are left for the read-path demand sweep.
    One cached program per plan shape, so in-capacity churn never retraces."""
    wp = window_pao(windows, spec, agg, now=now)
    pao = agg.init_pao(meta.n_nodes)
    pao = pao.at[arrays.writer_node].set(wp[: meta.n_writers], mode="drop")

    def level(l, pao):
        new = _level_reduce(meta, arrays.push, l, pao, agg.combine)
        return jnp.where(arrays.push.touched[l][:, None], new, pao)

    return _level_loop(meta, level, pao)


def read_step(meta: PlanMeta, agg: Aggregate, arrays: PlanArrays,
              state: EngineState, reader_nodes, mask):
    decision = arrays.decision
    demand = jnp.zeros((meta.n_nodes + 1,), dtype=jnp.bool_)
    is_pull_target = mask & (decision[reader_nodes] == PULL)
    demand = demand.at[reader_nodes].max(is_pull_target)

    def demand_level(i, demand):  # dst level descending
        l = meta.n_levels - 1 - i if meta.backend != "xla_unrolled" \
            else meta.unroll_levels - 1 - i
        return demand.at[arrays.demand_src[l]].max(demand[arrays.demand_dst[l]])

    demand = _level_loop(meta, demand_level, demand)
    take = (demand[: meta.n_nodes] & (decision == PULL))[:, None]
    val = state.pao

    def level(l, val):  # level ascending
        computed = _level_reduce(meta, arrays.pull, l, val, agg.combine)
        # only overwrite rows that this level actually computed
        return jnp.where(take & arrays.pull.touched[l][:, None], computed, val)

    val = _level_loop(meta, level, val)
    answers = val[reader_nodes]
    return agg.finalize(answers), answers


# Frontier-sparse twins of the step bodies: identical math, but each level
# gathers only the batch frontier's active edge blocks (``active`` is the
# (L, K) host-expanded block list — see ``core/frontier.py`` for why a
# superset of the reachable blocks is bit-identical to the dense sweep).
# One cached trace per (batch bucket, K bucket); callers fall back to the
# dense bodies when the frontier is too dense to pay for the gather.
def write_step_sum_sparse(meta: PlanMeta, agg: Aggregate, spec: WindowSpec,
                          arrays: PlanArrays, state: EngineState, rows, vals,
                          mask, active):
    windows, evicted, evicted_valid = apply_writes(
        state.windows, spec, rows, vals,
        jnp.full(rows.shape, state.now, jnp.float32), mask)
    delta_w = agg.lift(vals) * mask[:, None].astype(jnp.float32)
    delta_w -= agg.lift(evicted) * evicted_valid[:, None].astype(jnp.float32)
    delta = jnp.zeros((meta.n_nodes, agg.pao_dim), dtype=jnp.float32)
    delta = delta.at[arrays.writer_node[rows]].add(delta_w, mode="drop")

    # Python unroll, not fori_loop: the active tuple is ragged (one bucketed
    # width per level), and levels whose frontier is empty cost nothing
    for l in range(meta.n_levels):
        if active[l].shape[0] == 0:
            continue
        seg_c, src_c, sign_c, tob_c = _gather_active(
            meta, arrays.push, l, active[l])
        contrib = _level_reduce_active(
            meta, seg_c, src_c, sign_c, tob_c, delta, "sum")
        ra = arrays.push.touched[l] & _row_active(meta, seg_c)
        delta = delta + jnp.where(ra[:, None], contrib, 0.0)
    pao = state.pao + delta
    return EngineState(windows, pao, state.now + 1.0)


def write_step_extremal_sparse(meta: PlanMeta, agg: Aggregate,
                               spec: WindowSpec, arrays: PlanArrays,
                               state: EngineState, rows, vals, mask,
                               prev_now, active):
    windows, _, _ = apply_writes(
        state.windows, spec, rows, vals,
        jnp.full(rows.shape, state.now, jnp.float32), mask)
    wp = window_pao(windows, spec, agg, now=state.now)
    written = jnp.zeros((meta.n_writers,), bool).at[rows].max(mask, mode="drop")
    if spec.kind == "time":
        touched_w = written | stale_rows(state.windows, spec, prev_now, state.now)
    else:
        touched_w = written
    old_w = state.pao[jnp.minimum(arrays.writer_node, meta.n_nodes - 1)]
    new_w = jnp.where(touched_w[:, None], wp, old_w)
    pao = state.pao.at[arrays.writer_node].set(new_w, mode="drop")
    changed = jnp.zeros((meta.n_nodes + 1,), bool)
    changed = changed.at[arrays.writer_node].max(touched_w, mode="promise_in_bounds")

    for l in range(meta.n_levels):  # ragged active tuple: Python unroll
        if active[l].shape[0] == 0:
            continue
        seg_c, src_c, sign_c, tob_c = _gather_active(
            meta, arrays.push, l, active[l])
        new = _level_reduce_active(
            meta, seg_c, src_c, sign_c, tob_c, pao, agg.combine)
        dst = jnp.where(seg_c >= 0, seg_c, meta.n_nodes)
        ch = jax.ops.segment_max(
            changed[src_c].astype(jnp.int32), dst,
            num_segments=meta.n_nodes + 1) > 0
        upd = arrays.push.touched[l] & ch[: meta.n_nodes]
        pao = jnp.where(upd[:, None], new, pao)
        changed = changed.at[: meta.n_nodes].max(upd)
    return EngineState(windows, pao, state.now + 1.0)


DEM_CHUNK = 256  # demand slots per active chunk (d_pad is a multiple of 256)


def read_step_sparse(meta: PlanMeta, agg: Aggregate, arrays: PlanArrays,
                     state: EngineState, reader_nodes, mask, dem_active,
                     pull_active):
    """``read_step`` with the demand up-sweep restricted to active
    DEM_CHUNK-slot chunks and the pull down-sweep to active edge blocks —
    both expanded host-side from ``ReaderFrontierIndex``."""
    decision = arrays.decision
    nc = arrays.demand_dst.shape[1] // DEM_CHUNK
    demand = jnp.zeros((meta.n_nodes + 1,), dtype=jnp.bool_)
    is_pull_target = mask & (decision[reader_nodes] == PULL)
    demand = demand.at[reader_nodes].max(is_pull_target)

    # d_pad below one chunk means the plan has no real demand pairs at all
    # (compile_plan only leaves d_pad=1 when d_real == 0): the sweep is a
    # no-op, and reshaping to (0, DEM_CHUNK) chunks would be ill-formed.
    # Python unroll over the ragged active tuples, dst level descending;
    # levels with no active chunks cost nothing
    if nc:
        for l in range(meta.n_levels - 1, -1, -1):
            if dem_active[l].shape[0] == 0:
                continue
            ac = jnp.minimum(dem_active[l], nc - 1)
            validc = (dem_active[l] < nc)[:, None]
            dsts = jnp.where(
                validc, arrays.demand_dst[l].reshape(nc, DEM_CHUNK)[ac],
                meta.n_nodes).reshape(-1)
            srcs = jnp.where(
                validc, arrays.demand_src[l].reshape(nc, DEM_CHUNK)[ac],
                meta.n_nodes).reshape(-1)
            demand = demand.at[srcs].max(demand[dsts])
    take = (demand[: meta.n_nodes] & (decision == PULL))[:, None]
    val = state.pao

    for l in range(meta.n_levels):  # level ascending
        if pull_active[l].shape[0] == 0:
            continue
        seg_c, src_c, sign_c, tob_c = _gather_active(
            meta, arrays.pull, l, pull_active[l])
        computed = _level_reduce_active(
            meta, seg_c, src_c, sign_c, tob_c, val, agg.combine)
        ra = arrays.pull.touched[l] & _row_active(meta, seg_c)
        val = jnp.where(take & ra[:, None], computed, val)
    answers = val[reader_nodes]
    return agg.finalize(answers), answers


# Single-engine jitted entry points over the pure step bodies. The write
# bodies donate the engine state: the window/PAO buffers are rewritten in
# place (callers always rebind ``eng.state`` to the result — the consumed
# pytree must never be read again), which keeps steady-state ingest from
# allocating a fresh multi-MB state per batch.
_write_body_sum = functools.partial(
    jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4,))(write_step_sum)
_write_body_extremal = functools.partial(
    jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4,))(write_step_extremal)
_write_body_sum_sparse = functools.partial(
    jax.jit, static_argnums=(0, 1, 2),
    donate_argnums=(4,))(write_step_sum_sparse)
_write_body_extremal_sparse = functools.partial(
    jax.jit, static_argnums=(0, 1, 2),
    donate_argnums=(4,))(write_step_extremal_sparse)
_refresh_pao = functools.partial(
    jax.jit, static_argnums=(0, 1, 2))(refresh_pao_step)
_read_body = functools.partial(jax.jit, static_argnums=(0, 1))(read_step)
_read_body_sparse = functools.partial(
    jax.jit, static_argnums=(0, 1))(read_step_sparse)


# ------------------------------------------------------------- stacked pytrees
def stack_plan_arrays(arrays: list[PlanArrays]) -> PlanArrays:
    """Stack aligned per-shard ``PlanArrays`` along a new leading shard axis.
    All inputs must share one program shape (``align_shard_plans``)."""
    shapes = {jax.tree.map(jnp.shape, a) for a in arrays}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack misaligned plan arrays: {shapes}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *arrays)


def plan_arrays_shard(stacked: PlanArrays, s: int) -> PlanArrays:
    """One shard's slice of a stacked ``PlanArrays`` pytree."""
    return jax.tree.map(lambda x: x[s], stacked)


def place_plan_arrays(stacked: PlanArrays, s: int,
                      arrays: PlanArrays) -> PlanArrays:
    """Write one shard's (patched) tables back into the stacked pytree —
    shapes must match, so jitted consumers keep their compiled program."""
    return jax.tree.map(lambda st, x: st.at[s].set(x), stacked, arrays)


# ------------------------------------------------------------ serialization
def _plan_array_leaves(arrays: PlanArrays):
    """Deterministic (name, leaf) walk of a ``PlanArrays`` pytree with dotted
    names (``push.seg`` ...) — the checkpoint codec's stable key space."""
    for name, val in arrays._asdict().items():
        if isinstance(val, LevelTables):
            for f, sub in val._asdict().items():
                yield f"{name}.{f}", sub
        else:
            yield name, val


def _map_to_pairs(m: dict[int, int]) -> np.ndarray:
    """A host id map as one (2, len) int64 array (keys row, values row)."""
    out = np.empty((2, len(m)), np.int64)
    if m:
        out[0] = np.fromiter(m.keys(), np.int64, len(m))
        out[1] = np.fromiter(m.values(), np.int64, len(m))
    return out


def plan_snapshot(plan: ExecPlan) -> tuple[dict, dict]:
    """Serialize a live ``ExecPlan`` to ``(arrays, objs)``: a flat dict of
    host numpy arrays plus a JSON-safe object dict. Everything a bit-identical
    restore needs travels verbatim — device tables, host decision/level (from
    the patch bookkeeping when present, so in-capacity churn since compile is
    reflected), id maps — while derived caches (routes LUT, ``PlanHost``,
    frontier indexes) are rebuilt on the other side."""
    arrays = {f"pa.{name}": np.asarray(jax.device_get(leaf))
              for name, leaf in _plan_array_leaves(plan.arrays)}
    host = plan.host
    if host is not None:
        decision = np.asarray(host.decision[: host.n_real], np.int64)
        level = np.asarray(host.level[: host.n_real], np.int64)
    else:
        decision = np.asarray(plan.decision, np.int64)
        level = np.asarray(plan.level, np.int64)
    arrays.update({
        "decision": decision,
        "level": level,
        "writer_node": np.asarray(plan.writer_node, np.int64),
        "wrob": _map_to_pairs(plan.writer_row_of_base),
        "rnob": _map_to_pairs(plan.reader_node_of_base),
    })
    objs = {
        "meta": dataclasses.asdict(plan.meta),
        "depth": int(plan.depth),
        "n_push_edges": int(plan.n_push_edges),
        "n_pull_edges": int(plan.n_pull_edges),
        "patches_applied": int(plan.patches_applied),
    }
    return arrays, objs


def plan_from_snapshot(arrays: dict, objs: dict) -> ExecPlan:
    """Rebuild an ``ExecPlan`` from :func:`plan_snapshot` output without
    compiling anything. ``interpret`` is recomputed for the restoring host
    (a TPU save restores on CPU and vice versa); lazy derived state
    (``PlanHost``, frontier indexes) stays unmaterialized until first use."""
    meta = PlanMeta(**objs["meta"])
    if meta.backend == "pallas":
        meta = dataclasses.replace(
            meta, interpret=jax.default_backend() != "tpu")
    def put(name):
        return jax.device_put(arrays[f"pa.{name}"])

    pa = PlanArrays(
        decision=put("decision"), writer_node=put("writer_node"),
        push=LevelTables(**{f: put(f"push.{f}")
                            for f in LevelTables._fields}),
        pull=LevelTables(**{f: put(f"pull.{f}")
                            for f in LevelTables._fields}),
        demand_dst=put("demand_dst"), demand_src=put("demand_src"))
    wrob = {int(k): int(v) for k, v in zip(*arrays["wrob"])}
    rnob = {int(k): int(v) for k, v in zip(*arrays["rnob"])}
    return ExecPlan(
        meta=meta, arrays=pa, depth=int(objs["depth"]),
        decision=np.asarray(arrays["decision"], np.int64),
        level=np.asarray(arrays["level"], np.int64),
        writer_node=np.asarray(arrays["writer_node"], np.int64),
        writer_row_of_base=wrob, reader_node_of_base=rnob,
        routes=BaseRoutes.from_maps(wrob, rnob),
        n_push_edges=int(objs["n_push_edges"]),
        n_pull_edges=int(objs["n_pull_edges"]),
        patches_applied=int(objs["patches_applied"]))


# ----------------------------------------------------------------------- API
class EagrEngine:
    """Runtime for one compiled ego-centric aggregate query."""

    def __init__(self, overlay: Overlay, decisions: np.ndarray, aggregate: Aggregate,
                 window: WindowSpec | None = None, *, backend: str | None = None,
                 plan: ExecPlan | None = None, headroom: float | None = None):
        if aggregate.combine != "sum":
            neg = any(s < 0 for ins in overlay.in_edges for _, s in ins)
            if neg and not aggregate.supports_subtraction:
                raise ValueError("overlay has negative edges but aggregate is not subtractable")
        self.overlay = overlay
        self.agg = aggregate
        self.spec = window or WindowSpec(kind="tuple", size=1)
        if plan is None:
            pad = (grow_pad(measure_plan(overlay, decisions), headroom)
                   if headroom and headroom > 1.0 else None)
            plan = compile_plan(overlay, decisions, backend=backend, pad=pad)
        self.plan = plan
        # standing alerts (streams.alerts.AlertSet) — None for the common
        # case, so non-alert sessions keep the plain write bodies untouched
        self.alerts = None
        # continuous groups pin every churn-added node PUSH through patches
        # (always-fresh readers; alert evaluation depends on it)
        self.pin_push = False
        self._rebind()
        self.state = self.init_state()
        # host-side logical clock mirror + extremal-path eviction bookkeeping:
        # `_expiry` holds the eval times of batches whose entries are still
        # inside the time window; an all-dropped batch only needs the device
        # program when one of them crosses the expiry boundary.
        self._now_host = 0.0
        self._last_eval_now = 0.0
        self._expiry: list[float] = []
        # per write step: K (active-block capacity) for sparse steps, -1 for
        # dense — the frontier-size distribution the bench harness reports
        self.frontier_log: list[int] = []

    def _rebind(self) -> None:
        """(Re)bind the jitted bodies to the current plan arrays. Called at
        init and after ``apply_delta`` swaps the table pytree; as long as the
        plan's ``PlanMeta`` and array shapes are unchanged the bound bodies
        hit the existing jit cache entries."""
        body = (_write_body_sum if self.agg.combine == "sum"
                else _write_body_extremal)
        self._write = functools.partial(
            body, self.plan.meta, self.agg, self.spec, self.plan.arrays)
        body_sp = (_write_body_sum_sparse if self.agg.combine == "sum"
                   else _write_body_extremal_sparse)
        self._write_sparse = functools.partial(
            body_sp, self.plan.meta, self.agg, self.spec, self.plan.arrays)
        self._read = functools.partial(
            _read_body, self.plan.meta, self.agg, self.plan.arrays)
        self._read_sparse = functools.partial(
            _read_body_sparse, self.plan.meta, self.agg, self.plan.arrays)
        if self.alerts is not None:
            from repro.streams.alerts import _alert_write
            step = (write_step_sum if self.agg.combine == "sum"
                    else write_step_extremal)
            step_sp = (write_step_sum_sparse if self.agg.combine == "sum"
                       else write_step_extremal_sparse)
            cap = self.alerts.cap
            self._write_alert = functools.partial(
                _alert_write, step, self.plan.meta, self.agg, self.spec,
                cap, self.plan.arrays)
            self._write_alert_sparse = functools.partial(
                _alert_write, step_sp, self.plan.meta, self.agg, self.spec,
                cap, self.plan.arrays)

    def attach_alerts(self, alerts) -> None:
        """Attach an ``AlertSet``: resolves its reader rows against the live
        plan, binds the fused write+eval bodies, and from the next write on
        every batch carries its own compact fired-set evaluation."""
        self.alerts = alerts
        self._rebind()
        try:
            alerts.sync(self)
        except Exception:
            self.alerts = None
            raise

    def init_state(self) -> EngineState:
        windows = init_windows(self.plan.meta.n_writers, self.spec)
        pao = self.agg.init_pao(self.plan.meta.n_nodes)
        return EngineState(windows, pao, jnp.float32(0.0))

    def adopt_state(self, state: EngineState, *, now_host: float,
                    last_eval_now: float, expiry=()) -> None:
        """Adopt a restored ``EngineState`` plus the host-side clock mirror
        and extremal expiry bookkeeping (checkpoint restore seam). The state
        is taken verbatim — no PAO refresh, so restored answers stay
        bit-identical to the saved session's."""
        self.state = state
        self._now_host = float(now_host)
        self._last_eval_now = float(last_eval_now)
        self._expiry = sorted(float(t) for t in expiry)

    # ------------------------------------------------------------- execution
    def write_batch(self, base_ids: np.ndarray, values: np.ndarray,
                    batch_size: int | None = None) -> None:
        """Apply a batch of writes (base node ids + raw values). Values are
        (B,) scalars or (B, value_dim) vectors matching the window spec.
        Writes to nodes that feed no reader (e.g. node g in the paper's
        Figure 1) are masked out — nothing consumes them. Routing is one
        vectorized ``BaseRoutes`` table lookup; without an explicit
        ``batch_size`` the batch pads to the power-of-two ``bucket_batch``
        bucket, so varying arrival sizes stay on a handful of compiled
        shapes."""
        base_ids = np.asarray(base_ids)
        values = np.asarray(values, np.float32)
        rows, mask = self.plan.routes.writer_rows(base_ids)
        n_live = int(np.count_nonzero(mask))
        if n_live == 0 and batch_size is None:
            if self.agg.combine == "sum" or self.spec.kind == "tuple":
                # every write was dropped; skip the jit call but still advance
                # the logical clock, matching what the masked program does
                # (sum adds a zero delta; tuple-window extremal recomputes an
                # unchanged pao — neither depends on `now`)
                self.state = self.state._replace(now=self.state.now + 1.0)
                self._now_host += 1.0
                return
            if not (self._expiry
                    and self._expiry[0] < self._now_host - self.spec.size):
                # extremal + time window, but no live entry crosses the expiry
                # boundary at this instant: the masked program would recompute
                # an unchanged pao — skip it and just advance the clock
                self.state = self.state._replace(now=self.state.now + 1.0)
                self._now_host += 1.0
                return
            # an entry expires at this evaluation instant: the masked program
            # must run — one all-masked lane refreshes the touched writer
            # PAOs at the new `now`
            rows, mask = np.zeros(1, np.int32), np.zeros(1, bool)
            values = np.zeros((1,) + values.shape[1:], np.float32)
        B = batch_size or bucket_batch(len(rows))
        if B < len(rows):
            # legacy callers size the batch to the *kept* count — compact the
            # live lanes (vectorized) instead of rejecting the batch
            if n_live > B:
                raise ValueError(f"batch_size={B} < {n_live} routed writes")
            live = np.flatnonzero(mask)
            rows, values, mask = rows[live], values[live], mask[live]
        elif not mask.all():
            # dropped lanes must not contribute: their raw values are dead
            # under the mask, but zero them so non-finite garbage (inf * 0)
            # can't leak through the masked multiply
            m = mask.reshape((-1,) + (1,) * (values.ndim - 1))
            values = np.where(m, values, 0.0).astype(np.float32)
        pad = B - len(rows)
        if pad:
            mask = np.concatenate([mask, np.zeros(pad, bool)])
            rows = np.concatenate([rows, np.zeros(pad, np.int32)])
            values = np.concatenate(
                [values, np.zeros((pad,) + values.shape[1:], np.float32)])
        self.write_rows(rows, values, mask, n_live=n_live)

    def frontier_active(self, rows: np.ndarray, mask: np.ndarray,
                        n_live: int | None = None):
        """Decide + expand this batch's frontier: the ragged per-level
        active-block tuple for the sparse write bodies, or ``None`` for the
        dense sweep. ``None`` whenever sparseness can't be exact (the
        xla_unrolled baseline backend; an extremal time window with entries
        expiring outside the batch) or can't pay (EAGR_SPARSE_WRITE=0; auto
        mode with a large batch or a frontier past the density threshold).
        Builds the plan's ``FrontierIndex`` lazily on first use."""
        from repro.core import frontier as F

        mode = F.sparse_mode()
        meta = self.plan.meta
        if mode == "0" or meta.backend == "xla_unrolled":
            return None
        if self.agg.combine != "sum" and self.spec.kind == "time" and \
                self._expiry and \
                self._expiry[0] < self._now_host - self.spec.size:
            # rows expire at this eval instant: the touched set exceeds the
            # batch frontier, only the dense sweep sees all of it
            return None
        if n_live is None:
            n_live = int(np.count_nonzero(mask))
        if n_live == 0:
            return None
        density = None
        if mode == "auto":
            nb = self.plan.arrays.push.tile_of_block.shape[1]
            if nb < 8 or n_live > F.sparse_rowfrac() * meta.n_writers:
                return None  # frontier ~ overlay: expansion can't win
            density = F.sparse_density()
        exact = self.agg.combine == "sum"
        if self.plan.frontier is None or self.plan.frontier.exact != exact:
            self.plan.frontier = F.FrontierIndex.build(self.plan,
                                                       exact=exact)
        rows_u = np.unique(np.asarray(rows)[np.asarray(mask, bool)])
        return self.plan.frontier.expand(rows_u, density=density)

    def write_rows(self, rows: np.ndarray, vals: np.ndarray,
                   mask: np.ndarray, *, n_live: int | None = None,
                   active="auto") -> None:
        """Pre-routed write dispatch: ``rows`` are window rows (see
        ``ExecPlan.routes``), masked lanes carry row 0 / value 0 and the
        batch is already padded to its compiled shape. This is the ingest
        pipeline's entry point — one explicit ``device_put`` of the batch
        triple, then the async jitted step (no implicit transfers, no host
        sync: the call returns while the device step runs). ``n_live``
        (host-side count of live lanes) feeds the extremal expiry-heap
        bookkeeping; it defaults to a reduction of ``mask``. ``active``
        selects the step: ``"auto"`` asks :meth:`frontier_active`, ``None``
        forces the dense sweep, and a per-level active-block tuple runs the
        frontier-sparse bodies over exactly those edge blocks (the ingest
        pipeline passes its own pre-expanded tuple)."""
        if n_live is None:
            n_live = int(np.count_nonzero(mask))
        if isinstance(active, str):
            active = self.frontier_active(rows, mask, n_live=n_live)
        self.frontier_log.append(
            -1 if active is None else sum(a.shape[0] for a in active))
        if len(self.frontier_log) > (1 << 20):
            del self.frontier_log[: (1 << 19)]
        rows_d, vals_d, mask_d = jax.device_put(
            (np.ascontiguousarray(rows, np.int32),
             np.ascontiguousarray(vals, np.float32),
             np.ascontiguousarray(mask, bool)))
        if active is not None:
            act_d = jax.device_put(tuple(
                np.ascontiguousarray(a, np.int32) for a in active))
        if self.agg.combine == "sum":
            extra = () if active is None else (act_d,)
        else:
            if self.spec.kind == "time":
                if n_live:
                    heapq.heappush(self._expiry, self._now_host)
                boundary = self._now_host - self.spec.size
                while self._expiry and self._expiry[0] < boundary:
                    heapq.heappop(self._expiry)  # reflected by this refresh
            prev = self._last_eval_now
            self._last_eval_now = self._now_host
            prev_d = jax.device_put(np.float32(prev))
            extra = (prev_d,) if active is None else (prev_d, act_d)
        al = self.alerts
        if al is not None and al.enabled and al.n_placed:
            # fused write+eval: same step body plus the alert predicate
            # sweep, one program — fired sets stay on device until the
            # caller's readback boundary
            fn = self._write_alert if active is None \
                else self._write_alert_sparse
            now_eval = self._now_host
            self.state, al.state, count, idx, avals, fired, m = fn(
                self.state, al.state, rows_d, vals_d, mask_d, *extra)
            al.push_pending(now_eval, count, idx, avals, fired, m)
        elif active is None:
            self.state = self._write(self.state, rows_d, vals_d, mask_d,
                                     *extra)
        else:
            self.state = self._write_sparse(self.state, rows_d, vals_d,
                                            mask_d, *extra)
        self._now_host += 1.0

    # -------------------------------------------------- structural updates
    def apply_delta(self, delta, *, growth: float = 2.0):
        """Apply a ``DynamicOverlay.drain_delta()`` mutation log to the live
        plan (§3.3 end to end). In-capacity updates route through the
        device-resident patch program: the delta is lowered to a
        ``plan_patch.PatchProgram`` and one cached ``apply_patch_step`` call
        rewrites the donated ``PlanArrays`` pytree in place — zero table
        uploads, every compiled body keeps its program (the old pytree is
        consumed by the donation; ``_rebind`` below re-points the jitted
        partials at the patched arrays). A tile/level/capacity overflow
        falls back to ``compile_plan`` with ``growth`` headroom so the next
        churn burst patches cheaply. Engine state is migrated: new writer
        rows are live immediately, retired writer windows are zeroed, and
        all push PAOs are repaired by one (cached) refresh program.
        Returns the ``plan_patch.PatchResult``."""
        from repro.core.plan_patch import patch_plan

        res = patch_plan(self.plan, delta, overlay=self.overlay,
                         growth=growth, pin_push=self.pin_push)
        if res.reason == "empty delta":
            return res  # nothing changed: skip the state refresh entirely
        self.plan = res.plan
        if res.recompiled and res.overlay is not None:
            self.overlay = res.overlay
        windows = pad_window_rows(self.state.windows, self.plan.meta.n_writers)
        if res.retired_writer_rows:
            windows = reset_window_rows(windows, res.retired_writer_rows)
        pao = _refresh_pao(self.plan.meta, self.agg, self.spec,
                           self.plan.arrays, windows, self.state.now)
        self.state = EngineState(windows, pao, self.state.now)
        self._last_eval_now = self._now_host
        self._rebind()
        if self.alerts is not None:
            # carry alert rows through churn: retired readers drop, moved
            # readers follow their node, query-wide alerts adopt new readers
            self.alerts.sync(self, retired=res.retired_reader_bases)
        return res

    def adopt_decisions(self, decisions: np.ndarray) -> "ExecPlan":
        """Recompile the live plan with new push/pull decisions (§4.8
        adaptive re-decision) and migrate engine state in place: the overlay
        is unchanged, so writer rows keep their positions and the windows
        survive untouched; PAOs are refreshed for the new push set. Padded
        dims are floored at the current plan's, so when the new decisions fit
        the existing table budget every jitted body keeps its compiled
        program. Host patch bookkeeping (slot pools, retired-writer bases,
        parity mirror) is re-seeded so structural churn keeps patching in
        place afterwards. Returns the adopted plan."""
        from repro.core.plan_patch import carry_plan_bookkeeping

        host = self.plan.host
        ov = host.export_overlay() if host is not None else self.overlay
        new = compile_plan(ov, np.asarray(decisions, dtype=np.int64),
                           backend=self.plan.meta.backend,
                           pad=plan_dims(self.plan))
        carry_plan_bookkeeping(new, self.plan, ov)
        self.overlay = ov
        self.adopt_plan(new)
        return new

    def adopt_plan(self, plan: ExecPlan) -> None:
        """Swap in a structurally-equivalent recompiled plan (e.g. a shard
        realigned to a new shared program shape) and migrate engine state:
        windows resize to the new writer capacity, PAOs are refreshed."""
        self.plan = plan
        windows = pad_window_rows(self.state.windows, plan.meta.n_writers)
        pao = _refresh_pao(plan.meta, self.agg, self.spec, plan.arrays,
                           windows, self.state.now)
        self.state = EngineState(windows, pao, self.state.now)
        self._last_eval_now = self._now_host
        self._rebind()
        if self.alerts is not None:
            self.alerts.sync(self)

    def read_batch(self, base_ids: np.ndarray, batch_size: int | None = None):
        """Answer a batch of reads. Returns finalized answers (B, ...).
        Routing and the unknown-reader check are one vectorized table
        lookup; the batch pads to the ``bucket_batch`` bucket unless
        ``batch_size`` pins the shape."""
        base_ids = np.asarray(base_ids)
        nodes, known = self.plan.routes.reader_nodes(base_ids)
        if not known.all():
            bad = np.asarray(base_ids, np.int64).reshape(-1)[~known]
            raise ValueError(
                f"read_batch: base ids {sorted(set(map(int, bad)))[:8]} are "
                f"not readers of this overlay (no reader node registered)")
        B = batch_size or bucket_batch(len(nodes))
        if B < len(nodes):
            raise ValueError(f"batch_size={B} < batch of {len(nodes)}")
        act = self._reader_active(nodes)
        pad = B - len(nodes)
        mask = np.concatenate([np.ones(len(nodes), bool), np.zeros(pad, bool)])
        nodes = np.concatenate([nodes, np.zeros(pad, np.int32)])
        nodes_d, mask_d = jax.device_put((nodes, mask))
        if act is None:
            ans, _ = self._read(self.state, nodes_d, mask_d)
        else:
            dem_d, pull_d = jax.device_put(
                (tuple(np.ascontiguousarray(a, np.int32) for a in act[0]),
                 tuple(np.ascontiguousarray(a, np.int32) for a in act[1])))
            ans, _ = self._read_sparse(self.state, nodes_d, mask_d,
                                       dem_d, pull_d)
        return np.asarray(jax.device_get(ans))[: len(base_ids)]

    def _reader_active(self, nodes: np.ndarray):
        """Read-path twin of :meth:`frontier_active`: ``(dem_active,
        pull_active)`` chunk/block arrays for the sparse demand + pull
        sweeps, or ``None`` for the dense read. Auto mode only pays for the
        expansion on small reader batches."""
        from repro.core import frontier as F

        mode = F.sparse_mode()
        meta = self.plan.meta
        if mode == "0" or meta.backend == "xla_unrolled":
            return None
        density = None
        if mode == "auto":
            if len(nodes) > F.sparse_rowfrac() * meta.n_nodes:
                return None
            density = F.sparse_density()
        if self.plan.reader_frontier is None:
            self.plan.reader_frontier = F.ReaderFrontierIndex.build(self.plan)
        return self.plan.reader_frontier.expand(np.unique(nodes),
                                                density=density)

    # --------------------------------------------------------------- oracle
    def oracle_read(self, base_id: int, reader_inputs: dict[int, set[int]]):
        """Reference answer computed directly from the writer windows
        (independent of the overlay) — the ground truth for tests."""
        wp = np.asarray(jax.device_get(
            window_pao(self.state.windows, self.spec, self.agg, now=self.state.now)))
        acc = self.agg.INITIALIZE()
        count = np.asarray(jax.device_get(self.state.windows.count))
        for w in reader_inputs[base_id]:
            row = self.plan.writer_row_of_base[w]
            if count[row] == 0:
                continue
            if self.agg.combine == "sum":
                acc = acc + wp[row]
            elif self.agg.combine == "max":
                acc = np.maximum(acc, wp[row])
            else:
                acc = np.minimum(acc, wp[row])
        return self.agg.FINALIZE(acc)
