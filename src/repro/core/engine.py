"""Vectorized JAX execution engine over a compiled overlay (paper §2.2.2).

The paper's runtime is event-at-a-time Java with two thread pools; on TPU the
equivalent is *batched dataflow*: a batch of writes (or reads) is one jitted
program over dense arrays. The overlay is compiled (host-side, once) into a
leveled CSR ``ExecPlan``; at runtime the plan only reacts — no per-event
reasoning, which is exactly the paper's design goal.

Write path (combine='sum', invertible aggregates):
    window append -> per-writer PAO delta -> per-level
    ``delta[dst] += segment_sum(sign * delta[src])`` restricted to *push* dsts.

Write path (combine='max'/'min', non-invertible):
    window append -> recompute written writers from their windows -> per-level
    recompute of push nodes (``segment_max`` over all in-edges; idempotent).

Read path (the *pull* sweep):
    demand up-sweep from requested pull readers through pull ancestors ->
    per-level masked compute down-sweep -> gather + FINALIZE at readers.

Push nodes are always current, so a read on a push reader is a single gather —
the paper's low-latency case. The per-batch work is O(|E_push|) for writes and
O(|E_pull demanded|) for reads, matching the paper's cost model amortized over
the batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate
from repro.core.dataflow import PULL, PUSH
from repro.core.overlay import Overlay
from repro.core.window import (
    WindowSpec,
    WindowState,
    apply_writes,
    init_windows,
    window_pao,
)


class _LevelEdges(NamedTuple):
    src: np.ndarray
    dst: np.ndarray
    sign: np.ndarray


@dataclasses.dataclass
class ExecPlan:
    """Host-compiled execution plan: the overlay as leveled CSR arrays."""

    n_nodes: int
    n_levels: int
    decision: np.ndarray              # (n,) PUSH/PULL
    level: np.ndarray                 # (n,)
    writer_node: np.ndarray           # (n_writers,) overlay node per window row
    writer_row_of_base: dict[int, int]  # base id -> window row
    reader_node_of_base: dict[int, int]  # base id -> overlay node
    push_edges: list[_LevelEdges]     # per level (1..L): edges into PUSH dsts
    pull_edges: list[_LevelEdges]     # per level (1..L): edges into PULL dsts
    demand_edges: list[_LevelEdges]   # per *dst* level: (dst->src), src PULL
    n_push_edges: int = 0
    n_pull_edges: int = 0

    @property
    def n_writers(self) -> int:
        return len(self.writer_node)


def compile_plan(overlay: Overlay, decisions: np.ndarray) -> ExecPlan:
    level = overlay.levels()
    n_levels = int(level.max()) if overlay.n_nodes else 0
    decision = np.asarray(decisions, dtype=np.int64)

    writers = overlay.writer_nodes()
    writer_node = np.array(writers, dtype=np.int64)
    writer_row_of_base = {overlay.origin[v]: i for i, v in enumerate(writers)}
    reader_node_of_base = {overlay.origin[v]: v for v in overlay.reader_nodes()}

    per_level_push: list[list[tuple[int, int, int]]] = [[] for _ in range(n_levels + 1)]
    per_level_pull: list[list[tuple[int, int, int]]] = [[] for _ in range(n_levels + 1)]
    per_level_demand: list[list[tuple[int, int]]] = [[] for _ in range(n_levels + 1)]
    for dst in range(overlay.n_nodes):
        l = int(level[dst])
        for src, sign in overlay.in_edges[dst]:
            if decision[dst] == PUSH:
                per_level_push[l].append((src, dst, sign))
            else:
                per_level_pull[l].append((src, dst, sign))
                if decision[src] == PULL:
                    per_level_demand[l].append((dst, src))

    def pack(tris) -> _LevelEdges:
        if not tris:
            return _LevelEdges(np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.int64))
        arr = np.asarray(sorted(tris, key=lambda t: t[1]), dtype=np.int64)
        return _LevelEdges(arr[:, 0], arr[:, 1], arr[:, 2])

    def pack2(pairs) -> _LevelEdges:
        if not pairs:
            return _LevelEdges(np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.int64))
        arr = np.asarray(sorted(pairs, key=lambda t: t[1]), dtype=np.int64)
        return _LevelEdges(arr[:, 0], arr[:, 1], np.ones(len(pairs), np.int64))

    plan = ExecPlan(
        n_nodes=overlay.n_nodes,
        n_levels=n_levels,
        decision=decision,
        level=level,
        writer_node=writer_node,
        writer_row_of_base=writer_row_of_base,
        reader_node_of_base=reader_node_of_base,
        push_edges=[pack(per_level_push[l]) for l in range(1, n_levels + 1)],
        pull_edges=[pack(per_level_pull[l]) for l in range(1, n_levels + 1)],
        demand_edges=[pack2(per_level_demand[l]) for l in range(1, n_levels + 1)],
    )
    plan.n_push_edges = sum(e.src.size for e in plan.push_edges)
    plan.n_pull_edges = sum(e.src.size for e in plan.pull_edges)
    return plan


class EngineState(NamedTuple):
    windows: WindowState
    pao: jnp.ndarray      # (n_nodes, pao_dim)
    now: jnp.ndarray      # scalar fp32 logical clock


# ----------------------------------------------------------------- jit bodies
def _write_body_sum(plan: ExecPlan, agg: Aggregate, spec: WindowSpec,
                    state: EngineState, rows, vals, mask):
    windows, evicted, evicted_valid = apply_writes(
        state.windows, spec, rows, vals, jnp.full_like(vals, state.now), mask)
    delta_w = agg.lift(vals) * mask[:, None].astype(jnp.float32)
    delta_w -= agg.lift(evicted) * evicted_valid[:, None].astype(jnp.float32)
    delta = jnp.zeros((plan.n_nodes, agg.pao_dim), dtype=jnp.float32)
    wnode = jnp.asarray(plan.writer_node)
    delta = delta.at[wnode[rows]].add(delta_w)
    for e in plan.push_edges:  # static unroll over overlay levels
        if e.src.size == 0:
            continue
        src, dst, sign = jnp.asarray(e.src), jnp.asarray(e.dst), jnp.asarray(e.sign)
        contrib = jax.ops.segment_sum(
            delta[src] * sign[:, None].astype(jnp.float32), dst,
            num_segments=plan.n_nodes, indices_are_sorted=True)
        delta = delta + contrib
    pao = state.pao + delta
    return EngineState(windows, pao, state.now + 1.0)


def _write_body_extremal(plan: ExecPlan, agg: Aggregate, spec: WindowSpec,
                         state: EngineState, rows, vals, mask):
    windows, _, _ = apply_writes(
        state.windows, spec, rows, vals, jnp.full_like(vals, state.now), mask)
    # Recompute *all* writer PAOs from their windows (dense; written rows are
    # the only ones that changed, the rest recompute to their current value).
    wp = window_pao(windows, spec, agg, now=state.now)
    pao = state.pao.at[jnp.asarray(plan.writer_node)].set(wp)
    for e in plan.push_edges:
        if e.src.size == 0:
            continue
        src, dst = jnp.asarray(e.src), jnp.asarray(e.dst)
        new = agg.segment_merge(pao[src], dst, plan.n_nodes)
        touched = jnp.zeros((plan.n_nodes, 1), jnp.float32).at[dst].set(1.0)
        pao = jnp.where(touched > 0, new, pao)
    return EngineState(windows, pao, state.now + 1.0)


def _read_body(plan: ExecPlan, agg: Aggregate, state: EngineState,
               reader_nodes, mask):
    decision = jnp.asarray(plan.decision)
    demand = jnp.zeros((plan.n_nodes,), dtype=jnp.bool_)
    is_pull_target = mask & (decision[reader_nodes] == PULL)
    demand = demand.at[reader_nodes].max(is_pull_target)
    for e in reversed(plan.demand_edges):  # dst level descending
        if e.src.size == 0:
            continue
        dst, src = jnp.asarray(e.src), jnp.asarray(e.dst)  # packed as (dst, src)
        demand = demand.at[src].max(demand[dst])
    val = state.pao
    for e in plan.pull_edges:  # level ascending
        if e.src.size == 0:
            continue
        src, dst, sign = jnp.asarray(e.src), jnp.asarray(e.dst), jnp.asarray(e.sign)
        if agg.combine == "sum":
            computed = jax.ops.segment_sum(
                val[src] * sign[:, None].astype(jnp.float32), dst,
                num_segments=plan.n_nodes, indices_are_sorted=True)
        else:
            computed = agg.segment_merge(val[src], dst, plan.n_nodes)
        take = demand[:, None] & (decision == PULL)[:, None]
        # only overwrite rows that this level actually computed
        touched = jnp.zeros((plan.n_nodes, 1), jnp.bool_).at[dst].set(True)
        val = jnp.where(take & touched, computed, val)
    answers = val[reader_nodes]
    return agg.finalize(answers), answers


# ----------------------------------------------------------------------- API
class EagrEngine:
    """Runtime for one compiled ego-centric aggregate query."""

    def __init__(self, overlay: Overlay, decisions: np.ndarray, aggregate: Aggregate,
                 window: WindowSpec | None = None):
        if aggregate.combine != "sum":
            neg = any(s < 0 for ins in overlay.in_edges for _, s in ins)
            if neg and not aggregate.supports_subtraction:
                raise ValueError("overlay has negative edges but aggregate is not subtractable")
        self.overlay = overlay
        self.agg = aggregate
        self.spec = window or WindowSpec(kind="tuple", size=1)
        self.plan = compile_plan(overlay, decisions)
        self._write = jax.jit(functools.partial(
            _write_body_sum if aggregate.combine == "sum" else _write_body_extremal,
            self.plan, self.agg, self.spec))
        self._read = jax.jit(functools.partial(_read_body, self.plan, self.agg))
        self.state = self.init_state()

    def init_state(self) -> EngineState:
        windows = init_windows(self.plan.n_writers, self.spec)
        pao = self.agg.init_pao(self.plan.n_nodes)
        return EngineState(windows, pao, jnp.float32(0.0))

    # ------------------------------------------------------------- execution
    def write_batch(self, base_ids: np.ndarray, values: np.ndarray,
                    batch_size: int | None = None) -> None:
        """Apply a batch of writes (base node ids + raw values). Writes to
        nodes that feed no reader (e.g. node g in the paper's Figure 1) are
        dropped — nothing consumes them."""
        keep = [i for i, b in enumerate(base_ids)
                if int(b) in self.plan.writer_row_of_base]
        base_ids = np.asarray(base_ids)[keep]
        values = np.asarray(values)[keep]
        rows = np.array([self.plan.writer_row_of_base[int(b)] for b in base_ids], np.int32)
        B = batch_size or len(rows)
        pad = B - len(rows)
        mask = np.concatenate([np.ones(len(rows), bool), np.zeros(pad, bool)])
        rows = np.concatenate([rows, np.zeros(pad, np.int32)])
        vals = np.concatenate([np.asarray(values, np.float32), np.zeros(pad, np.float32)])
        self.state = self._write(self.state, jnp.asarray(rows), jnp.asarray(vals),
                                 jnp.asarray(mask))

    def read_batch(self, base_ids: np.ndarray, batch_size: int | None = None):
        """Answer a batch of reads. Returns finalized answers (B, ...)."""
        nodes = np.array([self.plan.reader_node_of_base[int(b)] for b in base_ids], np.int32)
        B = batch_size or len(nodes)
        pad = B - len(nodes)
        mask = np.concatenate([np.ones(len(nodes), bool), np.zeros(pad, bool)])
        nodes = np.concatenate([nodes, np.zeros(pad, np.int32)])
        ans, _ = self._read(self.state, jnp.asarray(nodes), jnp.asarray(mask))
        return np.asarray(jax.device_get(ans))[: len(base_ids)]

    # --------------------------------------------------------------- oracle
    def oracle_read(self, base_id: int, reader_inputs: dict[int, set[int]]):
        """Reference answer computed directly from the writer windows
        (independent of the overlay) — the ground truth for tests."""
        wp = np.asarray(jax.device_get(
            window_pao(self.state.windows, self.spec, self.agg, now=self.state.now)))
        acc = self.agg.INITIALIZE()
        count = np.asarray(jax.device_get(self.state.windows.count))
        for w in reader_inputs[base_id]:
            row = self.plan.writer_row_of_base[w]
            if count[row] == 0:
                continue
            if self.agg.combine == "sum":
                acc = acc + wp[row]
            elif self.agg.combine == "max":
                acc = np.maximum(acc, wp[row])
            else:
                acc = np.minimum(acc, wp[row])
        return self.agg.FINALIZE(acc)
