"""FP-Tree construction and biclique mining (paper §3.2.1–§3.2.4).

Items are generic integer ids: base writers or virtual (partial-aggregation)
nodes — virtual items from earlier iterations participate in later trees, which
is how multi-level overlays arise.

Modes:
  'basic' — plain VNM FP-tree (one path per reader),
  'neg'   — VNM_N: readers may be added along up to k1 paths, introducing up to
            k2 negative entries per path (quasi-bicliques, §3.2.3),
  'dup'   — VNM_D: previously-mined (item, reader) edges may be reused; reuse is
            penalized in the benefit (§3.2.4). Duplicate-insensitive aggregates only.

The tree is maintained *incrementally* across the bicliques of one mining
group: the item order is frozen when the group is built (newly created virtual
items are appended at the end via ``register_item``), and after a biclique is
applied only its consumer readers are ``detach``ed and ``reinsert``ed with
their shrunk lists.  For 'basic'/'dup' the trie is insertion-order independent,
so this is exactly equivalent to a full rebuild under the frozen order — which
is what the vectorized row miner (``core.rowminer``) computes in array form.

Tie-breaks are canonical so independent implementations agree bit-for-bit:
``mine_best`` maximizes benefit and resolves ties toward the lexicographically
smallest rank sequence; the 'neg' path-candidate scan orders by
(-gain, rank sequence).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass
class ReaderRecord:
    reader: int
    active: set[int]                      # minable positive items
    frozen: list[tuple[int, int]]         # (item, sign) direct edges, never re-mined
    mined: set[int]                       # 'dup' mode: items covered by an earlier biclique


@dataclasses.dataclass
class Biclique:
    items: list[int]                      # the path P (virtual node inputs)
    readers: list[int]
    neg_items: dict[int, list[int]]       # reader -> items of P to subtract
    reused: dict[int, list[int]]          # reader -> items of P that were already mined
    benefit: int


class _Node:
    __slots__ = ("item", "parent", "children", "support", "neg", "mined", "depth")

    def __init__(self, item: int, parent: "_Node | None"):
        self.item = item
        self.parent = parent
        self.children: dict[int, _Node] = {}
        self.support: set[int] = set()
        self.neg: set[int] = set()     # readers with a negative entry AT this node
        self.mined: set[int] = set()   # readers whose (item->reader) edge is reused
        self.depth = 0 if parent is None else parent.depth + 1

    def path_items(self) -> list[int]:
        out = []
        n: _Node | None = self
        while n is not None and n.parent is not None:
            out.append(n.item)
            n = n.parent
        out.reverse()
        return out


def item_order(records: Iterable[ReaderRecord], mode: str = "basic") -> dict[int, int]:
    """Descending frequency of occurrence across reader insert lists (ties by
    id). In 'dup' mode mined items are inserted too, so they count as well.

    NOTE: paper §3.2.1 says "increasing order" but its own worked example is not
    monotone under that reading; descending frequency (the standard FP-tree
    ordering, which maximizes prefix sharing) is used here.
    """
    freq: dict[int, int] = {}
    for rec in records:
        items = rec.active | rec.mined if mode == "dup" else rec.active
        for it in items:
            freq[it] = freq.get(it, 0) + 1
    order = sorted(freq.keys(), key=lambda it: (-freq[it], it))
    return {it: i for i, it in enumerate(order)}


class FPTree:
    def __init__(self, mode: str = "basic", k1: int = 2, k2: int = 5):
        assert mode in ("basic", "neg", "dup")
        self.mode = mode
        self.k1 = k1
        self.k2 = k2
        self.root = _Node(-1, None)
        self.order: dict[int, int] = {}
        # reader -> deepest node of each chain its insertion touched
        self._chains: dict[int, list[_Node]] = {}

    # ---------------------------------------------------------------- build
    def build(self, records: list[ReaderRecord]) -> None:
        self.root = _Node(-1, None)
        self.order = item_order(records, self.mode)
        self._chains = {}
        for rec in records:
            self._insert(rec)

    def register_item(self, item: int) -> None:
        """Append a newly created virtual item at the end of the frozen order."""
        self.order[item] = len(self.order)

    def _rank_path(self, node: _Node) -> tuple[int, ...]:
        return tuple(self.order.get(it, 1 << 60) for it in node.path_items())

    def _sorted_items(self, items: set[int]) -> list[int]:
        return sorted(items, key=lambda it: self.order.get(it, 1 << 60))

    def _insert_along(self, items: list[int], rec: ReaderRecord) -> None:
        node = self.root
        for it in items:
            child = node.children.get(it)
            if child is None:
                child = _Node(it, node)
                node.children[it] = child
            child.support.add(rec.reader)
            if self.mode == "dup" and it in rec.mined:
                child.mined.add(rec.reader)
            node = child
        if node is not self.root:
            self._chains.setdefault(rec.reader, []).append(node)

    def _insert(self, rec: ReaderRecord) -> None:
        if self.mode == "dup":
            items = self._sorted_items(rec.active | rec.mined)
            self._insert_along(items, rec)
            return
        if self.mode == "basic":
            self._insert_along(self._sorted_items(rec.active), rec)
            return
        # mode == 'neg': pick up to k1 existing paths with positive gain, then
        # insert the leftover items as a standard branch.
        candidates: list[tuple[int, tuple[int, ...], _Node, set[int]]] = []
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            p_items = set(n.path_items())
            neg = p_items - rec.active
            if len(neg) > self.k2:
                continue  # prune: negatives only grow deeper
            gain = n.depth - 1 - len(neg)
            covered = p_items & rec.active
            if gain > 0 and covered:
                candidates.append((gain, self._rank_path(n), n, p_items))
            stack.extend(n.children.values())
        candidates.sort(key=lambda t: (-t[0], t[1]))

        covered_total: set[int] = set()
        picked = 0
        for _, _, node, p_items in candidates:
            if picked >= self.k1:
                break
            remaining = rec.active - covered_total
            newly = p_items & remaining
            if not newly:
                continue
            # anything on the path not in the *remaining* set must be subtracted
            neg_eff = p_items - remaining
            if len(neg_eff) > self.k2 or node.depth - 1 - len(neg_eff) <= 0:
                continue
            n: _Node | None = node
            while n is not None and n.parent is not None:
                n.support.add(rec.reader)
                if n.item in neg_eff:
                    n.neg.add(rec.reader)
                n = n.parent
            self._chains.setdefault(rec.reader, []).append(node)
            covered_total |= newly
            picked += 1
        leftover = rec.active - covered_total
        if leftover:
            self._insert_along(self._sorted_items(leftover), rec)

    # ------------------------------------------------------------ maintenance
    def detach(self, rec: ReaderRecord) -> None:
        """Remove a reader from every chain it supports, pruning nodes whose
        support empties (child support is a subset of its parent's, so an
        emptied node has no supported descendants)."""
        for node in self._chains.pop(rec.reader, []):
            n: _Node | None = node
            while n is not None and n.parent is not None:
                n.support.discard(rec.reader)
                n.neg.discard(rec.reader)
                n.mined.discard(rec.reader)
                if not n.support and n.parent.children.get(n.item) is n:
                    del n.parent.children[n.item]
                n = n.parent

    def reinsert(self, rec: ReaderRecord) -> None:
        self._insert(rec)

    # ---------------------------------------------------------------- mine
    def _all_nodes(self) -> list[_Node]:
        out: list[_Node] = []
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def mine_best(self) -> Biclique | None:
        """Find the path maximizing
        benefit(P) = L|S| - L - |S| - #neg(P,S) - #reused(P,S)  (paper §3.2.1/3/4);
        ties resolve toward the lexicographically smallest rank sequence."""
        best: tuple[int, tuple[int, ...], _Node] | None = None
        for n in self._all_nodes():
            S = n.support
            if len(S) < 2 or n.depth < 2:
                continue  # benefit of a depth-1 path is always negative
            L = n.depth
            negs = 0
            reused = 0
            m: _Node | None = n
            while m is not None and m.parent is not None:
                if m.neg:
                    negs += len(m.neg & S)
                if m.mined:
                    reused += len(m.mined & S)
                m = m.parent
            benefit = L * len(S) - L - len(S) - negs - reused
            if benefit <= 0 or (best is not None and benefit < best[0]):
                continue
            rp = self._rank_path(n)
            if best is None or benefit > best[0] or rp < best[1]:
                best = (benefit, rp, n)
        if best is None:
            return None
        benefit, _, node = best
        S = sorted(node.support)
        items = node.path_items()
        neg_items: dict[int, list[int]] = {}
        reused_items: dict[int, list[int]] = {}
        m: _Node | None = node
        while m is not None and m.parent is not None:
            for r in m.neg & node.support:
                neg_items.setdefault(r, []).append(m.item)
            for r in m.mined & node.support:
                reused_items.setdefault(r, []).append(m.item)
            m = m.parent
        return Biclique(items=items, readers=S, neg_items=neg_items, reused=reused_items, benefit=benefit)
