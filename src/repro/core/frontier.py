"""Frontier indices: per-writer reachable push blocks, per-reader demand
chunks — the host side of frontier-sparse steps (paper §3).

The dense write step sweeps every padded push block of every level per batch
(O(overlay nodes) regardless of batch size). The paper's premise is that an
update only traverses the overlay subgraph reachable from the updated node;
this module compiles that reachability into a *block-granular* index so a
batch can be expanded — entirely host-side, before dispatch — into the
per-level set of E_BLK edge blocks the device step actually needs:

  ``FrontierIndex``        writer row -> per level, the push blocks holding
                           any in-edge of any push node reachable from that
                           writer (its *closure*).
  ``ReaderFrontierIndex``  reader node -> the demand chunks + pull blocks its
                           demand down-set touches (the read-path twin).

Why this is exact (bit-identical to the dense sweep, not approximate): the
sum path propagates a delta that is zero outside the batch closure, so any
edge whose source carries a nonzero delta lies in an indexed block of some
batch writer (an omitted edge contributes ``sign * (+0.0)``, and a zero
delta is always ``+0.0``: scatter-add and cancellation both round to
positive zero, so omission never even flips a zero's sign); the extremal
path only overwrites destinations with a changed in-edge, and every in-edge
of every closure member is indexed. Extra blocks that ride along (block
sharing between neighbouring destinations, post-churn over-approximation)
are harmless for the same reason — a *superset* of the required blocks
computes identical state. ``verify`` checks exactly that superset invariant
against an independent per-writer graph walk.

Two flavors, matched to the two write bodies (``build(exact=...)``):

  exact=True   (sum) per-writer **source-exact** block entries: only blocks
               holding slots whose source is in the writer's closure. The
               delta-incremental sum never needs an untouched source's
               edges, and on power-law graphs this keeps a hub destination
               reached through one edge from dragging its whole (huge) slot
               span into every batch.
  exact=False  (extremal) per-writer **destination-span** ranges: a changed
               extremal row recomputes from *all* of its inputs — including
               edges from sources the batch never touched, whose PAOs are
               live values, not zeros — so each reached destination
               contributes its full (lo, hi) block range (slots are
               contiguous at build time: ``make_plan`` sorts by
               destination). One entry per (destination, reaching writer)
               pair bounds the extremal index against hub slot blowup.

Churn moves patched writers to exact per-level block *lists* in
``overrides`` (maintained incrementally by ``plan_patch`` using the
flavor-matched closure oracle; a level relayout or recompile invalidates
the whole index, which rebuilds lazily on next use).

Expansion packs a *ragged* per-level tuple, each level's active count
bucketed to its own power of two (``bucket_active``, same discipline as
``bucket_batch``) so the sparse step bodies compile once per bucket tuple, a
quiet level never pays the busiest level's gather width, and an empty level
(shape ``(0,)``) drops out of the trace entirely; widths are sticky
high-water marks per index, so steady-state ingest converges on one
compiled shape; pad entries carry the block count ``nb`` and are
neutralized on device. A batch whose frontier exceeds the density
threshold returns ``None`` — the caller runs the dense step.

Env knobs (read per call so tests can flip them):
  EAGR_SPARSE_WRITE    auto (default) | 1 (force sparse) | 0 (force dense)
  EAGR_SPARSE_DENSITY  active-block fraction above which auto mode falls
                       back to the dense sweep (default 0.25)
  EAGR_SPARSE_ROWFRAC  touched-writer fraction above which auto mode skips
                       expansion entirely (default 0.05)
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.kernels.segment_agg.ops import E_BLK

__all__ = [
    "DEM_CHUNK",
    "FrontierIndex",
    "ReaderFrontierIndex",
    "bucket_active",
    "frontier_summary",
    "sparse_mode",
    "sparse_density",
    "sparse_rowfrac",
]

DEM_CHUNK = 256          # demand slots per active chunk (d_pad is a multiple)
ACTIVE_FLOOR = 8         # smallest active-block bucket
_READER_BUILD_CAP = 20_000_000  # down-set entry budget before dense-only


def sparse_mode() -> str:
    """'auto' | '1' | '0' — read per call, not captured at trace time."""
    v = os.environ.get("EAGR_SPARSE_WRITE", "auto").strip().lower()
    return v if v in ("auto", "1", "0") else "auto"


def sparse_density() -> float:
    try:
        return float(os.environ.get("EAGR_SPARSE_DENSITY", "0.25"))
    except ValueError:
        return 0.25


def sparse_rowfrac() -> float:
    try:
        return float(os.environ.get("EAGR_SPARSE_ROWFRAC", "0.05"))
    except ValueError:
        return 0.05


def frontier_summary(counts: list[int]) -> dict:
    """Frontier-size distribution from an engine's ``frontier_log``: each
    write step contributed its active-block capacity K (sparse) or ``-1``
    (dense fallback). Reports how sparse the write path actually ran plus
    p50/p99 of the active-block count over the sparse steps. Shared by the
    bench harness and ``EagrSession.stats()``."""
    sparse = sorted(k for k in counts if k >= 0)
    out = {
        "steps": len(counts),
        "dense_steps": sum(1 for k in counts if k < 0),
        "sparse_steps": len(sparse),
    }
    if sparse:
        out["p50_blocks"] = sparse[min(len(sparse) - 1,
                                       round(0.50 * (len(sparse) - 1)))]
        out["p99_blocks"] = sparse[min(len(sparse) - 1,
                                       round(0.99 * (len(sparse) - 1)))]
    return out


def bucket_active(n: int) -> int:
    """Power-of-two active-count bucketing (floor ACTIVE_FLOOR): one cached
    trace per bucket, same ladder discipline as ``bucket_batch``. A count of
    zero buckets to zero — the level is skipped at trace time, not padded."""
    if n <= 0:
        return 0
    return max(ACTIVE_FLOOR, 1 << (int(n) - 1).bit_length())


def _pack_active(keys: np.ndarray, n_levels: int, n_units: int,
                 density: float | None,
                 floors: np.ndarray | None = None) \
        -> tuple[np.ndarray, ...] | None:
    """Turn sorted composite keys ``level * n_units + unit`` into the ragged
    per-level active tuple the sparse bodies consume — one ascending
    ``(bucket_active(count_l),)`` int32 array per level — or ``None`` when
    the busiest level exceeds ``density * n_units`` (dense fallback).
    Per-level bucketing matters on skewed overlays: a quiet level no longer
    pays the busiest level's gather width, and an empty level packs to shape
    ``(0,)`` so the step bodies drop its sweep entirely. ``floors`` (the
    caller's per-level high-water marks, updated in place) makes the widths
    *sticky*: a level never shrinks below its past bucket, so successive
    batches converge on ONE shape tuple instead of retracing every time a
    level count wobbles across a bucket boundary — with L raggedly bucketed
    levels that wobble is L times as likely as it was for one shared width,
    and an XLA retrace costs more than the padding it would save. Pads carry
    ``n_units`` and sit at the END of each row, so the device-side gather
    order stays ascending — the kernel's revisit invariant."""
    l_arr = keys // n_units
    u_arr = keys % n_units
    counts = np.bincount(l_arr, minlength=n_levels)
    kmax = int(counts.max()) if counts.size else 0
    if density is not None and kmax > density * n_units:
        return None
    offs = np.cumsum(counts) - counts
    out = []
    for l in range(n_levels):
        c = int(counts[l])
        K = bucket_active(c)
        if floors is not None:
            K = max(K, int(floors[l]))
            floors[l] = K
        lvl = np.full(K, n_units, np.int32)
        lvl[:c] = u_arr[offs[l]: offs[l] + c]
        out.append(lvl)
    return tuple(out)


@dataclasses.dataclass
class FrontierIndex:
    """Writer-row -> per-level push-block reachability (see module doc)."""

    n_levels: int                 # padded level count (meta.n_levels)
    n_blocks: int                 # per-level padded push block count
    n_base_rows: int              # writer rows covered by the range CSR
    w_indptr: np.ndarray          # (n_base_rows + 1,) int64
    w_lvl: np.ndarray             # (N,) int32 range levels
    w_lo: np.ndarray              # (N,) int32 inclusive first block
    w_hi: np.ndarray              # (N,) int32 exclusive last block
    row_of_node: dict[int, int]   # overlay node -> writer row
    # churn-patched writers: exact per-level block lists supersede the ranges
    overrides: dict[int, dict[int, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    # source-exact entries (sum path) vs full destination spans (extremal)
    exact: bool = False
    # sticky per-level width high-water marks (see _pack_active)
    k_floor: np.ndarray | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(plan, *, exact: bool = False) -> "FrontierIndex":
        """Bulk-build from the plan's (current) push tables: one ascending
        pass propagates per-node writer reach-sets through the push levels,
        a second pass emits the block entries. All vectorized numpy; the only
        device read is the one-time pull of the routing tables.

        ``exact=False`` (extremal aggregates) records each reached
        destination's FULL block span: a changed extremal row recomputes
        from every in-edge, including edges whose sources the batch never
        touched, so all of its slots must be gathered. ``exact=True`` (sum)
        records only the blocks holding slots whose *source* is in the
        writer's closure: the sum step is delta-incremental and an
        untouched source's delta is exactly ``+0.0``, so its edges
        contribute nothing — on power-law graphs this shrinks a batch's
        frontier by the hub in-degree factor (a hub destination reached
        through one edge no longer drags in its whole span)."""
        seg = np.asarray(plan.arrays.push.seg)
        src = np.asarray(plan.arrays.push.src)
        L, e_pad = seg.shape
        nb = e_pad // E_BLK
        wn = np.asarray(plan.writer_node, np.int64)
        n_rows = len(wn)
        n_nodes = plan.meta.n_nodes

        # growing CSR of reach-sets (writer rows) per node; push destinations
        # are interior overlay nodes (never base writers), so each node's
        # entry is written at exactly one level and appends monotonically
        node_start = np.full(n_nodes, -1, np.int64)
        node_len = np.zeros(n_nodes, np.int64)
        real = np.flatnonzero((wn >= 0) & (wn < n_nodes))  # skip pad rows
        data = real.astype(np.int64)  # writers reach themselves
        node_start[wn[real]] = np.arange(len(real))
        node_len[wn[real]] = 1

        ent_w, ent_l, ent_lo, ent_hi = [], [], [], []
        depth = min(plan.depth, L)
        for l in range(depth):
            live = np.flatnonzero(seg[l] >= 0)
            if live.size == 0:
                continue
            d_s = seg[l][live].astype(np.int64)
            s_s = src[l][live].astype(np.int64)
            b_s = live // E_BLK
            lens = node_len[s_s]
            nz = lens > 0
            if not nz.any():
                continue
            # expand each slot into its source's reach-set members
            lens_nz = lens[nz]
            starts = node_start[s_s[nz]]
            total = int(lens_nz.sum())
            offs = np.repeat(starts - (np.cumsum(lens_nz) - lens_nz),
                             lens_nz) + np.arange(total, dtype=np.int64)
            w_flat = data[offs]
            d_flat = np.repeat(d_s[nz], lens_nz)
            key = np.unique(d_flat * n_rows + w_flat)
            d_u = key // n_rows
            w_u = key % n_rows
            if exact:
                # per (writer, slot-block): only blocks holding this
                # closure's own edge slots
                b_flat = np.repeat(b_s[nz], lens_nz)
                kb = np.unique(w_flat * np.int64(nb) + b_flat)
                lo_b = (kb % nb).astype(np.int32)
                ent_w.append(kb // nb)
                ent_l.append(np.full(len(kb), l, np.int32))
                ent_lo.append(lo_b)
                ent_hi.append(lo_b + 1)
            else:
                # per-destination block span at this level: slots are sorted
                # by destination, so first/last occurrence bound the span
                uniq_d, first = np.unique(d_s, return_index=True)
                last = np.concatenate([first[1:], [len(d_s)]]) - 1
                lo_of = b_s[first]
                hi_of = b_s[last] + 1
                pos = np.searchsorted(uniq_d, d_u)
                ent_w.append(w_u)
                ent_l.append(np.full(len(w_u), l, np.int32))
                ent_lo.append(lo_of[pos].astype(np.int32))
                ent_hi.append(hi_of[pos].astype(np.int32))
            # fold the new destinations into the reach CSR (key is sorted by
            # destination, then writer — already CSR order)
            d_new, d_first = np.unique(d_u, return_index=True)
            d_counts = np.concatenate([d_first[1:], [len(d_u)]]) - d_first
            node_start[d_new] = len(data) + d_first
            node_len[d_new] = d_counts
            data = np.concatenate([data, w_u])

        if ent_w:
            w_all = np.concatenate(ent_w)
            order = np.argsort(w_all, kind="stable")
            w_sorted = w_all[order]
            lvl = np.concatenate(ent_l)[order]
            lo = np.concatenate(ent_lo)[order]
            hi = np.concatenate(ent_hi)[order]
        else:
            w_sorted = np.zeros(0, np.int64)
            lvl = lo = hi = np.zeros(0, np.int32)
        indptr = np.zeros(n_rows + 1, np.int64)
        indptr[1:] = np.cumsum(np.bincount(w_sorted.astype(np.int64),
                                           minlength=n_rows))
        return FrontierIndex(
            n_levels=L, n_blocks=nb, n_base_rows=n_rows, w_indptr=indptr,
            w_lvl=lvl.astype(np.int32), w_lo=lo.astype(np.int32),
            w_hi=hi.astype(np.int32),
            row_of_node={int(wn[i]): int(i) for i in real}, exact=exact)

    # ----------------------------------------------------------------- expand
    def expand(self, rows: np.ndarray,
               density: float | None = 0.25) \
            -> tuple[np.ndarray, ...] | None:
        """Expand a batch's (unique, live) writer rows into the ragged
        per-level active-block tuple (see ``_pack_active``), or ``None`` for
        dense fallback (frontier too dense, or a row the index cannot
        bound)."""
        rows = np.asarray(rows, np.int64).reshape(-1)
        nb = self.n_blocks
        keys: list[np.ndarray] = []
        if self.overrides:
            ov_mask = np.fromiter((int(r) in self.overrides for r in rows),
                                  bool, len(rows))
        else:
            ov_mask = np.zeros(len(rows), bool)
        base_rows = rows[~ov_mask]
        if (base_rows >= self.n_base_rows).any():
            return None  # unindexed row (shouldn't happen; be safe)
        if base_rows.size:
            lens = self.w_indptr[base_rows + 1] - self.w_indptr[base_rows]
            total = int(lens.sum())
            if total:
                starts = self.w_indptr[base_rows]
                offs = np.repeat(starts - (np.cumsum(lens) - lens), lens) \
                    + np.arange(total, dtype=np.int64)
                lvl = self.w_lvl[offs].astype(np.int64)
                lo = self.w_lo[offs].astype(np.int64)
                hi = self.w_hi[offs].astype(np.int64)
                # dedupe ranges before expanding them to blocks — sibling
                # writers share destination ranges heavily
                rk = np.unique((lvl * (nb + 1) + lo) * (nb + 1) + hi)
                hi_u = rk % (nb + 1)
                lo_u = (rk // (nb + 1)) % (nb + 1)
                lvl_u = rk // ((nb + 1) * (nb + 1))
                spans = hi_u - lo_u
                tot_b = int(spans.sum())
                base = np.repeat(lvl_u * nb + lo_u, spans)
                step = np.arange(tot_b, dtype=np.int64) \
                    - np.repeat(np.cumsum(spans) - spans, spans)
                keys.append(base + step)
        for r in rows[ov_mask]:
            for l, blks in self.overrides[int(r)].items():
                if len(blks):
                    keys.append(l * nb + blks.astype(np.int64))
        all_keys = np.unique(np.concatenate(keys)) if keys \
            else np.zeros(0, np.int64)
        if self.k_floor is None:
            self.k_floor = np.zeros(self.n_levels, np.int64)
        return _pack_active(all_keys, self.n_levels, nb, density,
                            floors=self.k_floor)

    # ------------------------------------------------------------ maintenance
    def set_override(self, row: int,
                     blocks: dict[int, np.ndarray]) -> None:
        self.overrides[int(row)] = {int(l): np.asarray(b, np.int32)
                                    for l, b in blocks.items()}

    def blocks_of(self, row: int) -> dict[int, set[int]]:
        """Materialized per-level block sets of one writer row (ranges or
        override), for the parity oracle."""
        out: dict[int, set[int]] = {}
        if int(row) in self.overrides:
            for l, arr in self.overrides[int(row)].items():
                out.setdefault(int(l), set()).update(int(b) for b in arr)
            return out
        if 0 <= row < self.n_base_rows:
            for i in range(int(self.w_indptr[row]),
                           int(self.w_indptr[row + 1])):
                out.setdefault(int(self.w_lvl[i]), set()).update(
                    range(int(self.w_lo[i]), int(self.w_hi[i])))
        return out

    # ----------------------------------------------------------------- parity
    def verify(self, plan, host) -> None:
        """Superset oracle (``EAGR_PATCH_PARITY``): every writer's indexed
        blocks must cover the blocks an independent walk of the host graph
        says its closure occupies — the invariant that makes the sparse step
        bit-identical to the dense one."""
        oracle = closure_src_blocks if self.exact else closure_blocks
        bad = []
        for node, row in self.row_of_node.items():
            want = oracle(host, node)
            have = self.blocks_of(row)
            for l, blks in want.items():
                missing = blks - have.get(l, set())
                if missing:
                    bad.append((row, l, sorted(missing)[:4]))
        if bad:
            raise AssertionError(
                f"frontier index under-covers writer closures: {bad[:5]}")


def closure_blocks(host, node: int) -> dict[int, set[int]]:
    """Exact per-level push blocks of one writer node's closure, from the
    ``PlanHost`` bookkeeping graph: forward walk over consumers, descending
    only through push destinations (a pull consumer breaks the delta chain),
    collecting every slot block of every member. The independent oracle for
    ``FrontierIndex.verify`` and the recompute behind churn overrides."""
    th = host.push
    per_level: dict[int, set[int]] = {}
    seen = {node}
    stack = [node]
    while stack:
        v = stack.pop()
        for c in host.out[v]:
            if c in seen:
                continue
            lv = th.level_of.get(c)
            if lv is None:
                continue  # not a push destination: nothing propagates past it
            seen.add(c)
            stack.append(c)
            blks = per_level.setdefault(int(lv), set())
            for slot, _, _ in th.slots_of[c]:
                blks.add(slot // E_BLK)
    return per_level


def closure_src_blocks(host, node: int) -> dict[int, set[int]]:
    """Source-exact per-level push blocks of one writer node's closure: the
    same forward walk as :func:`closure_blocks`, but a destination slot is
    collected only when its *source* is itself a closure member — the blocks
    the sum path's delta can actually reach. The ``exact=True`` twin of the
    extremal oracle."""
    th = host.push
    per_level: dict[int, set[int]] = {}
    seen = {node}
    stack = [node]
    while stack:
        v = stack.pop()
        for c in host.out[v]:
            if c in seen:
                continue
            if th.level_of.get(c) is None:
                continue  # not a push destination: the delta chain stops
            seen.add(c)
            stack.append(c)
    for c in seen - {node}:
        lv = th.level_of.get(c)
        if lv is None:
            continue
        blks = per_level.setdefault(int(lv), set())
        for slot, s, _ in th.slots_of.get(c, ()):
            if s in seen:
                blks.add(slot // E_BLK)
    return per_level


def maintain_frontier(fi: FrontierIndex, plan, host, seeds: set[int],
                      old_in: dict[int, list]) -> None:
    """Incremental maintenance after an in-capacity slot patch: find the
    writers whose closure block-map may have moved (reverse walk from every
    re-homed node, over the union of old and new in-edges so removed-edge
    ancestors are reached too) and recompute exact overrides for them. Level
    relayouts / recompiles invalidate the whole index instead (caller)."""
    # register rows appended by this patch (skip capacity-padding rows)
    wn = np.asarray(plan.writer_node)
    for r in range(fi.n_base_rows, len(wn)):
        node = int(wn[r])
        if 0 <= node < plan.meta.n_nodes and fi.row_of_node.get(node) != r:
            fi.row_of_node[node] = r
            fi.overrides.setdefault(r, {})
    visited = set(seeds)
    stack = list(seeds)
    while stack:
        v = stack.pop()
        parents = {s for s, _ in host.in_edges[v]}
        if v in old_in:
            parents |= {s for s, _ in old_in[v]}
        for s in parents:
            if s not in visited:
                visited.add(s)
                stack.append(s)
    oracle = closure_src_blocks if fi.exact else closure_blocks
    for node in visited:
        row = fi.row_of_node.get(int(node))
        if row is None:
            continue
        fi.set_override(row, {
            l: np.fromiter(sorted(b), np.int32, len(b))
            for l, b in oracle(host, int(node)).items()})


# ---------------------------------------------------------------- read side
@dataclasses.dataclass
class ReaderFrontierIndex:
    """Reader node -> (demand chunks, pull blocks) of its demand down-set.

    Built by one full descending propagation of ``above``-sets (which
    potential readers demand each pull node) over the demand pairs, then an
    emission pass: a demand pair's chunk is needed by every reader demanding
    its destination; a pull destination's whole slot block range is needed by
    every reader demanding it. Push readers get (correctly) empty entries —
    their answer is a PAO gather. ``dense_only`` marks graphs whose down-sets
    exceeded the build budget."""

    n_levels: int
    n_chunks: int                  # d_pad // DEM_CHUNK
    n_blocks: int                  # per-level padded pull block count
    dem_keys: dict[int, np.ndarray]   # node -> sorted level*n_chunks+chunk
    pull_keys: dict[int, np.ndarray]  # node -> sorted level*n_blocks+block
    dense_only: bool = False
    # sticky per-level width high-water marks (see _pack_active)
    dem_floor: np.ndarray | None = dataclasses.field(default=None, repr=False)
    pull_floor: np.ndarray | None = dataclasses.field(default=None,
                                                      repr=False)

    @staticmethod
    def build(plan) -> "ReaderFrontierIndex":
        seg = np.asarray(plan.arrays.pull.seg)
        dd = np.asarray(plan.arrays.demand_dst)
        ds = np.asarray(plan.arrays.demand_src)
        L, e_pad = seg.shape
        nb = e_pad // E_BLK
        n_chunks = dd.shape[1] // DEM_CHUNK
        n = plan.meta.n_nodes
        from repro.core.dataflow import PULL
        dec = np.asarray(plan.decision)
        pull_nodes = np.flatnonzero(dec == PULL)

        above: dict[int, set[int]] = {int(p): {int(p)} for p in pull_nodes}
        total = len(above)
        depth = min(plan.depth, L)
        # full descending propagation first (a node's demand settles only
        # once every higher level ran), then emit
        for l in range(depth - 1, -1, -1):
            live = dd[l] < n
            for d, s in zip(dd[l][live], ds[l][live]):
                src_set = above.setdefault(int(s), set())
                add = above.get(int(d), set()) - src_set
                if add:
                    src_set |= add
                    total += len(add)
                    if total > _READER_BUILD_CAP:
                        return ReaderFrontierIndex(
                            L, n_chunks, nb, {}, {}, dense_only=True)
        dem: dict[int, set[int]] = {}
        pull: dict[int, set[int]] = {}
        for l in range(depth):
            live = np.flatnonzero(dd[l] < n)
            for i in live:
                d = int(dd[l, i])
                key = l * n_chunks + int(i) // DEM_CHUNK
                for v in above.get(d, ()):
                    dem.setdefault(v, set()).add(key)
            sl = np.flatnonzero(seg[l] >= 0)
            if sl.size == 0:
                continue
            d_s = seg[l][sl].astype(np.int64)
            b_s = sl // E_BLK
            uniq_d, first = np.unique(d_s, return_index=True)
            last = np.concatenate([first[1:], [len(d_s)]]) - 1
            for d, lo, hi in zip(uniq_d, b_s[first], b_s[last] + 1):
                for v in above.get(int(d), ()):
                    pull.setdefault(v, set()).update(
                        l * nb + b for b in range(int(lo), int(hi)))
        return ReaderFrontierIndex(
            n_levels=L, n_chunks=n_chunks, n_blocks=nb,
            dem_keys={v: np.fromiter(sorted(k), np.int64, len(k))
                      for v, k in dem.items()},
            pull_keys={v: np.fromiter(sorted(k), np.int64, len(k))
                       for v, k in pull.items()})

    def expand(self, nodes: np.ndarray, density: float | None = 0.25):
        """(dem_active, pull_active) for a batch of reader nodes, or ``None``
        for dense fallback."""
        if self.dense_only:
            return None
        dk = [self.dem_keys[int(v)] for v in nodes if int(v) in self.dem_keys]
        pk = [self.pull_keys[int(v)] for v in nodes
              if int(v) in self.pull_keys]
        dem_keys = np.unique(np.concatenate(dk)) if dk \
            else np.zeros(0, np.int64)
        pull_keys = np.unique(np.concatenate(pk)) if pk \
            else np.zeros(0, np.int64)
        if self.dem_floor is None:
            self.dem_floor = np.zeros(self.n_levels, np.int64)
            self.pull_floor = np.zeros(self.n_levels, np.int64)
        dem = _pack_active(dem_keys, self.n_levels, self.n_chunks, density,
                           floors=self.dem_floor)
        pull = _pack_active(pull_keys, self.n_levels, self.n_blocks, density,
                            floors=self.pull_floor)
        if dem is None or pull is None:
            return None
        return dem, pull
