"""IOB — Incremental Overlay Building (paper §3.2.5).

Readers are added one at a time (shingle order). For each reader we greedily
cover its input list with the partial aggregates already in the overlay
(minimum exact set cover heuristic), restructuring the overlay — splitting an
existing node v1 into (v1' -> v1) — when only part of v1's aggregate is useful.

Maintains the paper's two indexes:
  reverse index: writer -> overlay nodes whose I() contains it,
  forward index: node -> direct input nodes.

Restructuring note (documented deviation): the paper reroutes *writers* in
A∩I(v1) from v1 to v1'. When v1's inputs are nested aggregates this is not
well-defined at writer granularity, so we reroute at the granularity of v1's
*direct inputs whose I-sets lie fully inside A* — identical behavior whenever
v1's inputs are raw writers (the common case, incl. the paper's Fig 4 example),
and always correctness-preserving.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter

import numpy as np

from repro.core.bipartite import Bipartite
from repro.core.overlay import Overlay
from repro.core.shingles import shingle_order
from repro.core.vnm import ConstructionStats


class IOBBuilder:
    def __init__(self) -> None:
        self.kinds: list[str] = []
        self.origin: list[int] = []
        self.inputs: list[list[int]] = []    # forward index (direct inputs)
        self.members: list[set[int]] = []    # I(ovl): base writers aggregated
        self.rev: dict[int, set[int]] = {}   # reverse index
        self.writer_node: dict[int, int] = {}
        # Optional mutation journal: when set (by DynamicOverlay), every node
        # whose input list changes is recorded so structural churn can be
        # turned into an OverlayDelta instead of a full rebuild (§3.3).
        self.journal: set[int] | None = None

    # ---------------------------------------------------------------- nodes
    def add_node(self, kind: str, origin: int, members: set[int]) -> int:
        nid = len(self.kinds)
        self.kinds.append(kind)
        self.origin.append(origin)
        self.inputs.append([])
        self.members.append(members)
        for w in members:
            self.rev.setdefault(w, set()).add(nid)
        if self.journal is not None:
            self.journal.add(nid)
        return nid

    def add_writer(self, w: int) -> int:
        if w in self.writer_node:
            return self.writer_node[w]
        nid = self.add_node("W", w, {w})
        self.writer_node[w] = nid
        return nid

    def set_inputs(self, node: int, new_inputs: list[int]) -> None:
        self.inputs[node] = list(new_inputs)
        if self.journal is not None:
            self.journal.add(node)

    # ---------------------------------------------------------------- cover
    def _best_candidate(self, A: set[int], exclude: set[int]) -> int | None:
        score: Counter[int] = Counter()
        for w in A:
            for n in self.rev.get(w, ()):
                if n not in exclude:
                    score[n] += 1
        best = None
        best_key = None
        for n, s in score.items():
            if s < 2:
                continue
            key = (s, -len(self.members[n]))  # max overlap, then tightest I-set
            if best_key is None or key > best_key:
                best, best_key = n, key
        return best

    def _split(self, v1: int, A: set[int]) -> int | None:
        """Create v1' from v1's direct inputs whose I-sets lie inside A.
        Returns v1' (or None if no beneficial split exists)."""
        reroutable = [d for d in self.inputs[v1] if self.members[d] <= A]
        if len(reroutable) < 2:
            return None
        cov: set[int] = set()
        for d in reroutable:
            cov |= self.members[d]
        if len(cov) < 2:
            return None
        v1p = self.add_node("I", -1, cov)
        self.set_inputs(v1p, reroutable)
        remaining = [d for d in self.inputs[v1] if d not in set(reroutable)]
        self.set_inputs(v1, remaining + [v1p])
        return v1p

    def cover_reader(self, target: int, A: set[int], exclude: set[int] | None = None) -> list[int]:
        """Greedy exact-set-cover of A; returns the list of covering node ids and
        wires them as direct inputs of ``target``."""
        A = set(A)
        chosen: list[int] = []
        exclude = set(exclude or ())
        exclude.add(target)
        while A:
            cand = self._best_candidate(A, exclude)
            if cand is None:
                for w in sorted(A):
                    chosen.append(self.add_writer(w))
                A.clear()
                break
            B = self.members[cand]
            if B <= A and self.kinds[cand] != "R":
                chosen.append(cand)
                A -= B
            else:
                # partial overlap, or candidate is a reader (cannot feed anyone):
                # split out the useful part as a new shared aggregate node.
                v1p = self._split(cand, A)
                if v1p is None:
                    exclude.add(cand)
                    continue
                chosen.append(v1p)
                A -= self.members[v1p]
        self.set_inputs(target, self.inputs[target] + chosen)
        return chosen

    # ---------------------------------------------------------------- revisit
    def descendants(self, node: int) -> set[int]:
        out: dict[int, list[int]] = {}
        for n, ins in enumerate(self.inputs):
            for s in ins:
                out.setdefault(s, []).append(n)
        seen = {node}
        stack = [node]
        while stack:
            v = stack.pop()
            for d in out.get(v, ()):
                if d not in seen:
                    seen.add(d)
                    stack.append(d)
        return seen

    def revisit(self) -> int:
        """One improvement pass: re-cover each intermediate node's I-set with the
        (now larger) overlay; keep the new cover if it uses fewer edges."""
        improved = 0
        for n in range(len(self.kinds)):
            if self.kinds[n] != "I":
                continue
            old_inputs = self.inputs[n]
            if len(old_inputs) <= 2:
                continue
            exclude = self.descendants(n)
            exclude |= {m for m in range(len(self.kinds)) if self.kinds[m] == "R"}
            self.inputs[n] = []
            self.cover_reader(n, self.members[n], exclude=exclude)
            if len(self.inputs[n]) >= len(old_inputs):
                self.inputs[n] = old_inputs
            else:
                improved += 1
        return improved

    # ---------------------------------------------------------------- export
    def n_edges(self) -> int:
        return sum(len(i) for i in self.inputs)

    def to_overlay(self) -> Overlay:
        ov = Overlay(kinds=list(self.kinds), origin=list(self.origin),
                     in_edges=[[(s, 1) for s in ins] for ins in self.inputs])
        return ov


def construct_iob(
    bip: Bipartite,
    *,
    max_iterations: int = 3,
    seed: int = 0,
) -> tuple[Overlay, ConstructionStats]:
    stats = ConstructionStats(algorithm="iob")
    t0 = time.perf_counter()
    b = IOBBuilder()
    for w in bip.writers:
        b.add_writer(int(w))
    lists = {r: np.asarray(ins) for r, ins in bip.reader_inputs.items()}
    order = shingle_order(lists, seed=seed)
    for r in order:
        rid = b.add_node("R", int(r), set(map(int, bip.reader_inputs[r])))
        b.cover_reader(rid, set(map(int, bip.reader_inputs[r])))
    stats.iterations = 1
    stats.si_per_iteration.append(1.0 - b.n_edges() / max(1, bip.n_edges))
    for _ in range(max_iterations - 1):
        if b.revisit() == 0:
            break
        stats.iterations += 1
        stats.si_per_iteration.append(1.0 - b.n_edges() / max(1, bip.n_edges))
    stats.seconds = time.perf_counter() - t0
    stats.bicliques = sum(1 for k in b.kinds if k == "I")
    return b.to_overlay().pruned(), stats
