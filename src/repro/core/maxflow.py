"""Dinic max-flow / min-cut on small graphs (used per connected component of the
pruned overlay, paper §4.4–4.5). Capacities are floats; INF marks uncuttable
(original overlay) edges."""
from __future__ import annotations

INF = float("inf")


class Dinic:
    def __init__(self, n: int):
        self.n = n
        self.to: list[int] = []
        self.cap: list[float] = []
        self.head: list[list[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, cap: float) -> None:
        self.head[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(cap)
        self.head[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(0.0)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = [s]
        while q:
            nq = []
            for u in q:
                for eid in self.head[u]:
                    v = self.to[eid]
                    if self.cap[eid] > 1e-12 and self.level[v] < 0:
                        self.level[v] = self.level[u] + 1
                        nq.append(v)
            q = nq
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float) -> float:
        if u == t:
            return f
        while self.it[u] < len(self.head[u]):
            eid = self.head[u][self.it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]))
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            self.it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._dfs(s, t, INF)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def reachable_from(self, s: int) -> list[bool]:
        """Nodes reachable from s in the residual graph (defines the min cut)."""
        seen = [False] * self.n
        seen[s] = True
        q = [s]
        while q:
            u = q.pop()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not seen[v]:
                    seen[v] = True
                    q.append(v)
        return seen
