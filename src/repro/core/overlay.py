"""The aggregation overlay graph O_G (paper §2.2.1).

Three node kinds:
  'W' writer nodes (one per base node that produces consumed content),
  'I' partial aggregation (intermediate / virtual) nodes,
  'R' reader nodes (one per base node satisfying pred).

Edges carry a sign: +1 normal, -1 "negative" (subtraction) edges (§2.2.1).
For duplicate-sensitive aggregates, the *net signed path count* from any writer to
any reader it feeds must be exactly 1; duplicate-insensitive overlays only require
set-reachability to match the bipartite graph.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Overlay:
    kinds: list[str]                       # per overlay node: 'W' | 'I' | 'R'
    origin: list[int]                      # base node id for W/R nodes, -1 for I
    in_edges: list[list[tuple[int, int]]]  # per node: list of (src_node, sign)
    dup_insensitive: bool = False

    # ------------------------------------------------------------------ basics
    @property
    def n_nodes(self) -> int:
        return len(self.kinds)

    @property
    def n_edges(self) -> int:
        return sum(len(e) for e in self.in_edges)

    def add_node(self, kind: str, origin: int = -1) -> int:
        self.kinds.append(kind)
        self.origin.append(origin)
        self.in_edges.append([])
        return len(self.kinds) - 1

    def add_edge(self, src: int, dst: int, sign: int = 1) -> None:
        self.in_edges[dst].append((src, sign))

    def writer_nodes(self) -> list[int]:
        return [i for i, k in enumerate(self.kinds) if k == "W"]

    def reader_nodes(self) -> list[int]:
        return [i for i, k in enumerate(self.kinds) if k == "R"]

    def out_edges(self) -> list[list[tuple[int, int]]]:
        out: list[list[tuple[int, int]]] = [[] for _ in range(self.n_nodes)]
        for dst, ins in enumerate(self.in_edges):
            for src, sign in ins:
                out[src].append((dst, sign))
        return out

    def in_degree(self, v: int) -> int:
        return len(self.in_edges[v])

    # ------------------------------------------------------------------ metrics
    def sharing_index(self, bipartite_edges: int) -> float:
        """SI = 1 - |E_overlay| / |E_bipartite| (paper §3.1)."""
        if bipartite_edges == 0:
            return 0.0
        return 1.0 - self.n_edges / bipartite_edges

    def depth_per_reader(self) -> dict[int, int]:
        """Overlay depth of each reader = longest writer->reader path (§5.2)."""
        depth = [0] * self.n_nodes
        for v in self.toposort():
            for src, _ in self.in_edges[v]:
                depth[v] = max(depth[v], depth[src] + 1)
        return {v: depth[v] for v in self.reader_nodes()}

    # ------------------------------------------------------------------ order
    def toposort(self) -> list[int]:
        indeg = [len(e) for e in self.in_edges]
        out = self.out_edges()
        stack = [v for v in range(self.n_nodes) if indeg[v] == 0]
        order: list[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for dst, _ in out[v]:
                indeg[dst] -= 1
                if indeg[dst] == 0:
                    stack.append(dst)
        if len(order) != self.n_nodes:
            raise ValueError("overlay graph contains a cycle")
        return order

    def levels(self) -> np.ndarray:
        """level[v] = longest path from any source to v (writers are level 0)."""
        level = np.zeros(self.n_nodes, dtype=np.int64)
        for v in self.toposort():
            for src, _ in self.in_edges[v]:
                level[v] = max(level[v], level[src] + 1)
        return level

    # ------------------------------------------------------------------ validation
    def contributions(self) -> list[dict[int, int]]:
        """Net signed writer contributions per node (exponential-free DP; for
        tests / small overlays). contributions()[r] maps base writer id -> count."""
        contrib: list[dict[int, int]] = [dict() for _ in range(self.n_nodes)]
        for v in self.toposort():
            if self.kinds[v] == "W":
                contrib[v] = {self.origin[v]: 1}
                continue
            acc: dict[int, int] = {}
            for src, sign in self.in_edges[v]:
                for w, c in contrib[src].items():
                    acc[w] = acc.get(w, 0) + sign * c
            contrib[v] = {w: c for w, c in acc.items() if c != 0}
        return contrib

    def validate(self, reader_inputs: dict[int, set[int]]) -> None:
        """Check the overlay computes exactly the bipartite spec.

        reader_inputs: base reader id -> set of base writer ids (= N(reader)).
        Raises AssertionError on any violation.
        """
        contrib = self.contributions()
        for r in self.reader_nodes():
            base = self.origin[r]
            want = reader_inputs[base]
            got = contrib[r]
            assert set(got.keys()) == set(want), (
                f"reader {base}: writers {sorted(got.keys())} != expected {sorted(want)}"
            )
            if self.dup_insensitive:
                assert all(c >= 1 for c in got.values()), f"reader {base}: negative net path count"
            else:
                bad = {w: c for w, c in got.items() if c != 1}
                assert not bad, f"reader {base}: duplicate/cancelled contributions {bad}"

    # ------------------------------------------------------------------ pruning
    def pruned(self) -> "Overlay":
        """Drop W/I nodes with no path to any reader (e.g. orphaned splits)."""
        useful = [False] * self.n_nodes
        order = self.toposort()
        for v in reversed(order):
            if self.kinds[v] == "R":
                useful[v] = True
        out = self.out_edges()
        for v in reversed(order):
            if useful[v]:
                continue
            useful[v] = any(useful[d] for d, _ in out[v])
        remap = {}
        ov = Overlay(kinds=[], origin=[], in_edges=[], dup_insensitive=self.dup_insensitive)
        for v in range(self.n_nodes):
            if useful[v]:
                remap[v] = ov.add_node(self.kinds[v], self.origin[v])
        for v in range(self.n_nodes):
            if not useful[v]:
                continue
            for src, sign in self.in_edges[v]:
                ov.add_edge(remap[src], remap[v], sign)
        return ov

    # ------------------------------------------------------------------ I-sets
    def input_writer_sets(self) -> list[set[int]]:
        """I(ovl): set of base writers aggregated by each node (ignoring signs)."""
        sets: list[set[int]] = [set() for _ in range(self.n_nodes)]
        for v in self.toposort():
            if self.kinds[v] == "W":
                sets[v] = {self.origin[v]}
            else:
                s: set[int] = set()
                for src, sign in self.in_edges[v]:
                    if sign > 0:
                        s |= sets[src]
                    else:
                        s -= sets[src]
                sets[v] = s
        return sets


def overlay_from_flat(
    kinds: list[str],
    origin: list[int],
    src: list[int],
    indptr: np.ndarray,
    signs: list[int] | None = None,
    dup_insensitive: bool = False,
) -> Overlay:
    """Materialize an Overlay from flat per-destination-grouped edge arrays:
    node v's in-edge sources are ``src[indptr[v]:indptr[v+1]]`` (in in-edge
    order). ``signs=None`` means all edges are positive. This is the bulk
    constructor for the vectorized assembly path — per-node Python edge lists
    are built in one pass instead of via n_edges ``add_edge`` calls."""
    in_edges: list[list[tuple[int, int]]] = []
    if signs is None:
        for a, b in zip(indptr[:-1], indptr[1:]):
            in_edges.append([(s, 1) for s in src[a:b]])
    else:
        for a, b in zip(indptr[:-1], indptr[1:]):
            in_edges.append(list(zip(src[a:b], signs[a:b])))
    return Overlay(kinds=list(kinds), origin=[int(o) for o in origin],
                   in_edges=in_edges, dup_insensitive=dup_insensitive)


def all_pull_overlay(reader_inputs: dict[int, "np.ndarray"], writers: np.ndarray) -> Overlay:
    """Baseline: direct writer->reader edges, no sharing (the bipartite graph
    itself as an overlay). Used for the *all-pull* / *all-push* baselines."""
    ov = Overlay(kinds=[], origin=[], in_edges=[])
    wmap: dict[int, int] = {}
    for w in writers:
        wmap[int(w)] = ov.add_node("W", int(w))
    for r, ins in reader_inputs.items():
        rid = ov.add_node("R", int(r))
        for w in ins:
            ov.add_edge(wmap[int(w)], rid)
    return ov
