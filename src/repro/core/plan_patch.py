"""Incremental ExecPlan maintenance: patch ``PlanArrays`` in place (§3.3).

The full-rebuild path (``compile_plan``) re-derives every stacked level table
and usually retraces the jitted bodies — seconds of latency per structural
update. This module consumes the structured mutation log a ``DynamicOverlay``
journals (``OverlayDelta``) and patches the *live* plan instead, in three
escalating tiers:

  1. **slot patch** — a retired edge's slot is neutralized in place
     (``seg=-1, src=0, sign=0``: the padding pattern every backend drops);
     a new edge claims a free slot inside the owning row tile's block range.
     Host mirrors mutate slot-wise; the device copy syncs through jitted
     scatters whose index counts are bucketed to powers of two (see
     ``_sync_table`` — bounded jit cache, only changed slots travel;
     ``ops.patch_level`` remains the in-place primitive for jit-resident
     table updates). Milliseconds, zero shape changes.
  2. **level relayout** — when a tile has no free slot (or a destination
     moved into a previously-empty tile) the whole level row is rebuilt from
     the host mirror (`ops.relayout_level`) — still inside the plan's padded
     block budget, so shapes and therefore the jit cache are untouched.
  3. **recompile fallback** — a genuine capacity overflow (nodes, writers,
     levels, blocks, demand slots) falls back to ``compile_plan`` with a
     ``growth``-factor ``PlanPad`` so the *next* churn burst patches cheaply.

Node ids are kept stable by operating on the **unpruned** overlay export
(``DynamicOverlay.to_overlay(prune=False)``): dead nodes linger edgeless and
writer rows are append-only, which is what makes window state migration a
pad-and-zero instead of a reshuffle.

The patcher owns a host mirror of the plan (``PlanHost``): the overlay graph
(in-edges, kinds, decisions, levels), numpy copies of every level table, and
per-(level, tile) free-slot pools derived from the kernel's block routing.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import PULL, PUSH
from repro.core.dynamic import OverlayDelta
from repro.core.engine import (
    ExecPlan,
    LevelTables,
    PlanArrays,
    compile_plan,
    grow_pad,
    measure_plan,
)
from repro.core.overlay import Overlay
from repro.kernels.segment_agg.ops import (
    E_BLK,
    R_BLK,
    relayout_level,
    tile_slot_ranges,
)


class CapacityExceeded(Exception):
    """An in-place patch does not fit the plan's padded capacity."""


# --------------------------------------------------------------- host mirrors
@dataclasses.dataclass
class TableHost:
    """Numpy mirror of one ``LevelTables`` plus slot bookkeeping."""

    seg: np.ndarray               # (L, e_pad) int32
    src: np.ndarray               # (L, e_pad) int32
    sign: np.ndarray              # (L, e_pad) f32
    tob: np.ndarray               # (L, n_blocks) int32
    fot: np.ndarray               # (L, n_blocks) int32
    touched: np.ndarray           # (L, cap) bool
    tile_slots: np.ndarray        # (L, n_row_tiles, 2) [start, stop) per tile
    slots_of: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    level_of: dict[int, int] = dataclasses.field(default_factory=dict)
    free: dict[tuple[int, int], list[int]] = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_tables(t: LevelTables, n_row_tiles: int) -> "TableHost":
        seg = np.array(t.seg)
        L = seg.shape[0]
        tob = np.array(t.tile_of_block)
        th = TableHost(
            seg=seg, src=np.array(t.src), sign=np.array(t.sign),
            tob=tob, fot=np.array(t.first_of_tile), touched=np.array(t.touched),
            tile_slots=np.stack([tile_slot_ranges(tob[l], n_row_tiles)
                                 for l in range(L)]),
        )
        for l in range(L):
            th.index_level(l)
        return th

    def index_level(self, l: int) -> None:
        """Rebuild slot occupancy and the free pools of one level row."""
        for d in [d for d, lv in self.level_of.items() if lv == l]:
            self.slots_of.pop(d, None)
            self.level_of.pop(d, None)
        row = self.seg[l]
        occ_mask = row >= 0
        occupied = np.flatnonzero(occ_mask)
        # group occupied slots by destination (vectorized: sort-then-split)
        dsts = row[occupied]
        order = np.argsort(dsts, kind="stable")
        sorted_dsts = dsts[order]
        sorted_slots = occupied[order]
        uniq, starts = np.unique(sorted_dsts, return_index=True)
        bounds = np.append(starts, len(sorted_dsts))
        for i, d in enumerate(uniq):
            d = int(d)
            self.slots_of[d] = sorted_slots[bounds[i]: bounds[i + 1]].tolist()
            self.level_of[d] = l
        free_mask = ~occ_mask
        for t in range(self.tile_slots.shape[1]):
            a, b = int(self.tile_slots[l, t, 0]), int(self.tile_slots[l, t, 1])
            self.free[(l, t)] = [] if a == b else \
                (np.flatnonzero(free_mask[a:b])[::-1] + a).tolist()

    def n_edges(self) -> int:
        return sum(len(s) for s in self.slots_of.values())


@dataclasses.dataclass
class PlanHost:
    """Host-side authoritative mirror of a live plan: the (unpruned) overlay
    graph plus numpy copies of every routing table."""

    kinds: list[str]
    origin: list[int]
    in_edges: list[list[tuple[int, int]]]
    out: list[list[int]]          # src -> consumer nodes (multiset as list)
    decision: np.ndarray          # (>=n_real,) int64
    level: np.ndarray             # (>=n_real,) int64
    push: TableHost
    pull: TableHost
    demand: list[list[tuple[int, int]]]   # per padded level: (dst, src) pairs
    n_real: int
    dup_insensitive: bool = False
    retired_writer_bases: set[int] = dataclasses.field(default_factory=set)

    @staticmethod
    def from_plan(plan: ExecPlan, overlay: Overlay) -> "PlanHost":
        if overlay.n_nodes != len(plan.level):
            raise ValueError(
                f"overlay has {overlay.n_nodes} nodes but the plan was "
                f"compiled over {len(plan.level)} — pass the (unpruned) "
                f"overlay the plan was compiled from")
        meta = plan.meta
        cap = meta.n_nodes
        in_edges = [list(e) for e in overlay.in_edges]
        out: list[list[int]] = [[] for _ in range(cap)]
        for dst, ins in enumerate(in_edges):
            for s, _ in ins:
                out[s].append(dst)
        level = np.zeros(cap, np.int64)
        level[: overlay.n_nodes] = plan.level
        dd = np.array(plan.arrays.demand_dst)
        ds = np.array(plan.arrays.demand_src)
        demand = [[(int(a), int(b)) for a, b in zip(dd[l], ds[l]) if a < cap]
                  for l in range(dd.shape[0])]
        kinds = list(overlay.kinds) + ["I"] * (cap - overlay.n_nodes)
        origin = list(overlay.origin) + [-1] * (cap - overlay.n_nodes)
        in_edges += [[] for _ in range(cap - overlay.n_nodes)]
        return PlanHost(
            kinds=kinds, origin=origin, in_edges=in_edges, out=out,
            decision=np.array(plan.arrays.decision, dtype=np.int64),
            level=level,
            push=TableHost.from_tables(plan.arrays.push, meta.n_row_tiles),
            pull=TableHost.from_tables(plan.arrays.pull, meta.n_row_tiles),
            demand=demand, n_real=overlay.n_nodes,
            dup_insensitive=overlay.dup_insensitive,
        )

    def export_overlay(self) -> Overlay:
        return Overlay(kinds=list(self.kinds[: self.n_real]),
                       origin=list(self.origin[: self.n_real]),
                       in_edges=[list(e) for e in self.in_edges[: self.n_real]],
                       dup_insensitive=self.dup_insensitive)


# ------------------------------------------------------------------- results
@dataclasses.dataclass
class PatchResult:
    plan: ExecPlan
    recompiled: bool
    reason: str | None
    overlay: Overlay | None                  # fresh export iff recompiled
    retired_writer_rows: list[int]
    stats: dict


# ------------------------------------------------------------ graph updating
def _relax_levels(host: PlanHost, seeds: set[int]) -> set[int]:
    """Longest-path level relaxation from the nodes whose in-edges changed.
    Returns every node whose level moved (their edges must re-home)."""
    changed: set[int] = set()
    q = deque(sorted(seeds))
    inq = set(q)
    while q:
        v = q.popleft()
        inq.discard(v)
        nl = max((int(host.level[s]) + 1 for s, _ in host.in_edges[v]),
                 default=0)
        if nl != int(host.level[v]):
            host.level[v] = nl
            changed.add(v)
            for c in host.out[v]:
                if c not in inq:
                    q.append(c)
                    inq.add(c)
    return changed


def _update_decisions(host: PlanHost, delta: OverlayDelta) -> set[int]:
    """Default decisions for new nodes (writers PUSH; interiors PUSH iff all
    inputs are PUSH; readers PULL), then enforce the dataflow invariant —
    no PULL upstream of a PUSH — by flipping violators PULL and cascading
    downstream. Returns every node whose decision changed."""
    changed: set[int] = set()
    for nid in range(delta.n_nodes_before, delta.n_nodes_after):
        k = host.kinds[nid]
        if k == "W":
            d = PUSH
        elif k == "R":
            d = PULL
        else:
            ins = host.in_edges[nid]
            d = PUSH if ins and all(host.decision[s] == PUSH for s, _ in ins) \
                else PULL
        if int(host.decision[nid]) != d:
            host.decision[nid] = d
            changed.add(nid)
    q = deque(sorted(set(delta.nodes) | changed,
                     key=lambda v: int(host.level[v])))
    while q:
        v = q.popleft()
        if host.decision[v] == PUSH and any(
                host.decision[s] == PULL for s, _ in host.in_edges[v]):
            host.decision[v] = PULL
            changed.add(v)
            q.extend(host.out[v])
    return changed


# ------------------------------------------------------------- table patching
def _table_of(host: PlanHost, d: int) -> str | None:
    if not host.in_edges[d]:
        return None
    return "push" if host.decision[d] == PUSH else "pull"


def _slot_tile(th: TableHost, l: int, slot: int) -> int:
    return int(th.tob[l, slot // E_BLK])


def _free_slots(th: TableHost, d: int, pend: dict, stats: dict) -> None:
    slots = th.slots_of.pop(d, None)
    if slots is None:
        return
    l = th.level_of.pop(d)
    for s in slots:
        th.seg[l, s] = -1
        th.src[l, s] = 0
        th.sign[l, s] = 0.0
        th.free[(l, _slot_tile(th, l, s))].append(s)
        pend.setdefault(l, set()).add(s)
    stats["edges_removed"] += len(slots)


def _claim_slots(th: TableHost, d: int, edges, l: int, pend: dict,
                 rebuild: set, stats: dict) -> None:
    """Place ``edges`` (src, sign) of destination ``d`` into free slots of its
    owning tile at level ``l``; escalate the level to a relayout when the
    tile's pool runs dry."""
    if l in rebuild:
        return  # the level row is being rebuilt from the graph mirror anyway
    pool = th.free.get((l, d // R_BLK), [])
    if len(pool) < len(edges):
        rebuild.add(l)
        return
    for s_, sg in edges:
        slot = pool.pop()
        th.seg[l, slot] = d
        th.src[l, slot] = s_
        th.sign[l, slot] = sg
        th.slots_of.setdefault(d, []).append(slot)
        th.level_of[d] = l
        pend.setdefault(l, set()).add(slot)
    stats["edges_added"] += len(edges)


def _diff_in_place(th: TableHost, d: int, new_edges, l: int, pend: dict,
                   rebuild: set, stats: dict) -> None:
    """Destination stays in the same table and level: free only the removed
    edges' slots and claim slots only for the added ones."""
    slots = th.slots_of.get(d, [])
    need = Counter((int(s), float(g)) for s, g in new_edges)
    keep, freed = [], []
    for s in slots:
        e = (int(th.src[l, s]), float(th.sign[l, s]))
        if need[e] > 0:
            need[e] -= 1
            keep.append(s)
        else:
            freed.append(s)
    for s in freed:
        th.seg[l, s] = -1
        th.src[l, s] = 0
        th.sign[l, s] = 0.0
        th.free[(l, _slot_tile(th, l, s))].append(s)
        pend.setdefault(l, set()).add(s)
    stats["edges_removed"] += len(freed)
    th.slots_of[d] = keep
    if not keep:
        th.slots_of.pop(d, None)
        th.level_of.pop(d, None)
    missing = [e for e, c in need.items() for _ in range(c)]
    if missing:
        _claim_slots(th, d, missing, l, pend, rebuild, stats)


def _rebuild_level(host: PlanHost, th: TableHost, table: str, l: int,
                   cap: int, n_row_tiles: int) -> None:
    dsts = [int(d) for d in np.flatnonzero(host.level[: host.n_real] == l + 1)
            if _table_of(host, d) == table]
    dst_l, src_l, sign_l = [], [], []
    for d in dsts:
        for s, sg in host.in_edges[d]:
            dst_l.append(d)
            src_l.append(s)
            sign_l.append(sg)
    rl = relayout_level(np.asarray(dst_l, np.int64), np.asarray(src_l, np.int64),
                        np.asarray(sign_l, np.float64), cap,
                        th.tob.shape[1], th.seg.shape[1])
    if rl is None:
        raise CapacityExceeded(f"{table} level {l} exceeds the block budget")
    th.seg[l], th.src[l], th.sign[l], th.tob[l], th.fot[l] = rl
    th.tile_slots[l] = tile_slot_ranges(th.tob[l], n_row_tiles)
    th.index_level(l)


_SLOT_BUCKET = 64  # scatter index-count floor; buckets grow by powers of 4


def _bucket_count(n: int) -> int:
    """Bucket scatter index counts to ``64 * 4**k``: the jitted scatters
    below are cache-keyed by their index shape, so distinct slot counts would
    otherwise each compile their own executable (~45ms on CPU). A coarse
    geometric ladder keeps the whole cache at a handful of executables —
    padding entries are idempotent duplicate writes, and scattering 4x more
    indices than needed is noise next to the table copy itself."""
    b = _SLOT_BUCKET
    while b < n:
        b *= 4
    return b


@jax.jit
def _scatter_slot_patch(seg, src, sign, lvl, slot, seg_v, src_v, sign_v):
    """Rewrite individual (level, slot) entries of the stacked edge tables
    (the device-side twin of ``ops.patch_level``, batched across levels)."""
    return (seg.at[lvl, slot].set(seg_v),
            src.at[lvl, slot].set(src_v),
            sign.at[lvl, slot].set(sign_v))


@jax.jit
def _scatter_level_rows(seg, src, sign, tob, fot, lvls,
                        seg_r, src_r, sign_r, tob_r, fot_r):
    """Replace whole level rows (the relayout path)."""
    return (seg.at[lvls].set(seg_r), src.at[lvls].set(src_r),
            sign.at[lvls].set(sign_r), tob.at[lvls].set(tob_r),
            fot.at[lvls].set(fot_r))


@jax.jit
def _scatter_touched(touched, lvls, rows):
    return touched.at[lvls].set(rows)


def _sync_table(t: LevelTables, th: TableHost, pend: dict, rebuilds: set,
                cap: int) -> LevelTables:
    """Push the host mirror's changed slots/rows to the device tables without
    changing any padded dim (so jitted consumers keep their programs).

    Slot-level changes go through a jitted scatter whose index count is
    bucketed (``_bucket_count`` — padding repeats the last entry, an
    idempotent duplicate write), so the jit cache holds a handful of
    executables per table shape instead of one per distinct slot count, and
    only the changed slots/rows travel to the device. Heavy churn — changed
    slots plus rebuilt rows approaching the table itself — falls back to the
    wholesale re-upload, which is one plain transfer with no scatter at all."""
    if not (pend or rebuilds):
        return t
    changed_levels = sorted(set(pend) | rebuilds)
    for l in changed_levels:
        row = np.zeros(cap, bool)
        segl = th.seg[l]
        row[segl[segl >= 0]] = True
        th.touched[l] = row

    L, e_pad = th.seg.shape
    entries = [(l, s) for l in sorted(set(pend) - rebuilds)
               for s in sorted(pend[l])]
    if len(entries) + len(rebuilds) * e_pad >= (L * e_pad) // 4:
        return LevelTables(seg=jnp.asarray(th.seg), src=jnp.asarray(th.src),
                           sign=jnp.asarray(th.sign),
                           tile_of_block=jnp.asarray(th.tob),
                           first_of_tile=jnp.asarray(th.fot),
                           touched=jnp.asarray(th.touched))

    seg, src, sign = t.seg, t.src, t.sign
    tob, fot = t.tile_of_block, t.first_of_tile
    if entries:
        k = _bucket_count(len(entries))
        entries += [entries[-1]] * (k - len(entries))
        lvl = np.asarray([e[0] for e in entries], np.int32)
        slot = np.asarray([e[1] for e in entries], np.int32)
        seg, src, sign = _scatter_slot_patch(
            seg, src, sign, jnp.asarray(lvl), jnp.asarray(slot),
            jnp.asarray(th.seg[lvl, slot]), jnp.asarray(th.src[lvl, slot]),
            jnp.asarray(th.sign[lvl, slot]))

    if rebuilds:
        lv = sorted(rebuilds)
        k = min(_bucket_count(len(lv)), L)  # never pad past the level count
        lv = np.asarray(lv + [lv[-1]] * (k - len(lv)), np.int32)
        seg, src, sign, tob, fot = _scatter_level_rows(
            seg, src, sign, tob, fot, jnp.asarray(lv),
            jnp.asarray(th.seg[lv]), jnp.asarray(th.src[lv]),
            jnp.asarray(th.sign[lv]), jnp.asarray(th.tob[lv]),
            jnp.asarray(th.fot[lv]))

    k = min(_bucket_count(len(changed_levels)), L)
    lv = np.asarray(changed_levels
                    + [changed_levels[-1]] * (k - len(changed_levels)),
                    np.int32)
    touched = _scatter_touched(t.touched, jnp.asarray(lv),
                               jnp.asarray(th.touched[lv]))
    return LevelTables(seg=seg, src=src, sign=sign, tile_of_block=tob,
                       first_of_tile=fot, touched=touched)


# --------------------------------------------------------------------- patch
def patch_plan(plan: ExecPlan, delta: OverlayDelta, *,
               overlay: Overlay | None = None,
               growth: float = 2.0) -> PatchResult:
    """Apply one ``OverlayDelta`` to a live plan.

    In-capacity updates mutate ``plan`` in place (new ``PlanArrays`` pytree,
    same ``PlanMeta`` — so every jitted body keeps its compiled program);
    overflows recompile with ``growth`` headroom. ``overlay`` is only needed
    on the first patch of a plan, to seed the host mirror; it must be the
    (unpruned) overlay the plan was compiled from."""
    if delta.empty:
        return PatchResult(plan, False, "empty delta", None, [], {})
    host: PlanHost = plan.host  # type: ignore[assignment]
    if host is None:
        if overlay is None:
            raise ValueError("first patch_plan call needs overlay= to seed "
                             "the host mirror")
        host = PlanHost.from_plan(plan, overlay)
        plan.host = host
    meta = plan.meta
    cap = meta.n_nodes
    stats = {"edges_added": 0, "edges_removed": 0, "levels_rebuilt": 0,
             "demand_levels": 0, "slot_levels": 0}

    # ---------------------------------------------- phase A: graph mirror
    for _ in range(delta.n_nodes_after - len(host.kinds)):
        host.kinds.append("I")
        host.origin.append(-1)
        host.in_edges.append([])
        host.out.append([])
    if delta.n_nodes_after > len(host.decision):
        extra = delta.n_nodes_after - len(host.decision)
        host.decision = np.concatenate(
            [host.decision, np.full(extra, PULL, np.int64)])
        host.level = np.concatenate([host.level, np.zeros(extra, np.int64)])
    for nid, patch in delta.nodes.items():
        for s, _ in host.in_edges[nid]:
            host.out[s].remove(nid)
        host.in_edges[nid] = list(patch.edges)
        for s, _ in patch.edges:
            host.out[s].append(nid)
        host.kinds[nid] = patch.kind
        host.origin[nid] = patch.origin
    host.n_real = max(host.n_real, delta.n_nodes_after)
    host.retired_writer_bases |= delta.retired_writers
    host.retired_writer_bases -= set(delta.new_writers)

    changed_level = _relax_levels(host, set(delta.nodes))
    changed_dec = _update_decisions(host, delta)
    depth = int(host.level[: host.n_real].max()) if host.n_real else 0

    retired_rows = [plan.writer_row_of_base[b] for b in delta.retired_writers
                    if b in plan.writer_row_of_base]

    # ---------------------------------------------- phase B: capacity gates
    def fallback(reason: str) -> PatchResult:
        new_plan, new_overlay = _recompile(plan, host, growth)
        _apply_base_maps(new_plan, host, delta)
        stats["reason"] = reason
        return PatchResult(new_plan, True, reason, new_overlay,
                           retired_rows, stats)

    if host.n_real > cap:
        return fallback("node capacity")
    if len(plan.writer_node) + len(delta.new_writer_nodes) > meta.n_writers:
        return fallback("writer capacity")
    if depth > meta.n_levels:
        return fallback("level capacity")
    if meta.backend == "xla_unrolled" and depth != plan.depth:
        return fallback("unrolled depth changed")

    # ---------------------------------------------- phase C: table patching
    rehome = set(delta.nodes) | changed_level | changed_dec
    pend = {"push": {}, "pull": {}}
    rebuild = {"push": set(), "pull": set()}
    demand_levels: set[int] = set()
    try:
        for d in sorted(rehome):
            new_table = _table_of(host, d)
            new_l = int(host.level[d]) - 1 if new_table else -1
            old = None
            for name in ("push", "pull"):
                th = getattr(host, name)
                if d in th.level_of:
                    old = (name, th.level_of[d])
                    break
            if old and old[0] == "pull":
                demand_levels.add(old[1])
            if new_table == "pull":
                demand_levels.add(new_l)
            if old == (new_table, new_l):
                _diff_in_place(getattr(host, new_table), d,
                               host.in_edges[d], new_l,
                               pend[new_table], rebuild[new_table], stats)
            else:
                if old:
                    _free_slots(getattr(host, old[0]), d, pend[old[0]], stats)
                if new_table:
                    _claim_slots(getattr(host, new_table), d,
                                 host.in_edges[d], new_l,
                                 pend[new_table], rebuild[new_table], stats)
        for v in changed_dec:
            for c in host.out[v]:
                if host.level[c] >= 1 and host.decision[c] == PULL:
                    demand_levels.add(int(host.level[c]) - 1)
        for name in ("push", "pull"):
            th = getattr(host, name)
            for l in sorted(rebuild[name]):
                _rebuild_level(host, th, name, l, cap, meta.n_row_tiles)
                stats["levels_rebuilt"] += 1
        # demand rows
        d_pad = plan.arrays.demand_dst.shape[1]
        new_demand_rows = {}
        for l in sorted(demand_levels):
            pairs = []
            for d in np.flatnonzero(host.level[: host.n_real] == l + 1):
                if host.decision[d] != PULL:
                    continue
                for s, _ in host.in_edges[int(d)]:
                    if host.decision[s] == PULL:
                        pairs.append((int(d), int(s)))
            if len(pairs) > d_pad:
                raise CapacityExceeded(f"demand level {l} needs {len(pairs)} "
                                       f"> {d_pad} slots")
            new_demand_rows[l] = pairs
    except CapacityExceeded as e:
        return fallback(str(e))

    # ---------------------------------------------- phase D: device sync
    arrays = plan.arrays
    push_t = _sync_table(arrays.push, host.push, pend["push"],
                         rebuild["push"], cap)
    pull_t = _sync_table(arrays.pull, host.pull, pend["pull"],
                         rebuild["pull"], cap)
    dd, ds = arrays.demand_dst, arrays.demand_src
    if new_demand_rows:
        dd_h, ds_h = np.array(dd), np.array(ds)
        for l, pairs in sorted(new_demand_rows.items()):
            host.demand[l] = pairs
            dd_h[l] = cap
            ds_h[l] = cap
            if pairs:
                arr = np.asarray(pairs, np.int64)
                dd_h[l, : len(pairs)] = arr[:, 0]
                ds_h[l, : len(pairs)] = arr[:, 1]
        dd, ds = jnp.asarray(dd_h), jnp.asarray(ds_h)
    decision = arrays.decision
    if changed_dec:
        decision = jnp.asarray(host.decision[:cap].astype(np.int32))
    writer_node = arrays.writer_node
    # every new W-kind node claims a row (id order), even if it was deleted
    # within this epoch — keeps row positions identical to what a recompile
    # over the unpruned overlay would assign, so window state migrates by
    # position safely
    for nid in sorted(delta.new_writer_nodes):
        plan.writer_node = np.append(plan.writer_node, nid)
    if delta.new_writer_nodes:
        wnode = np.full(meta.n_writers, cap, np.int32)
        wnode[: len(plan.writer_node)] = plan.writer_node
        writer_node = jnp.asarray(wnode)
    plan.arrays = PlanArrays(decision=decision, writer_node=writer_node,
                             push=push_t, pull=pull_t,
                             demand_dst=dd, demand_src=ds)

    # ---------------------------------------------- phase E: plan metadata
    plan.depth = depth
    plan.level = host.level[: host.n_real].copy()
    plan.decision = host.decision[: host.n_real].copy()
    plan.n_push_edges = host.push.n_edges()
    plan.n_pull_edges = host.pull.n_edges()
    plan.patches_applied += 1
    _apply_base_maps(plan, host, delta)
    stats["slot_levels"] = len(set(pend["push"]) | set(pend["pull"]))
    stats["demand_levels"] = len(new_demand_rows)
    return PatchResult(plan, False, None, None, retired_rows, stats)


def _apply_base_maps(plan: ExecPlan, host: PlanHost,
                     delta: OverlayDelta) -> None:
    """Reconcile base-id -> row/node maps with the delta (both patch and
    recompile paths)."""
    for b in delta.retired_writers:
        if b not in delta.new_writers:
            plan.writer_row_of_base.pop(b, None)
    for b, nid in delta.new_writers.items():
        row = int(np.flatnonzero(plan.writer_node == nid)[0]) \
            if (plan.writer_node == nid).any() else None
        if row is not None:
            plan.writer_row_of_base[b] = row
    for b in delta.retired_readers:
        if b not in delta.new_readers:
            plan.reader_node_of_base.pop(b, None)
    for nid, patch in delta.nodes.items():
        o = patch.origin
        if patch.kind == "R":
            plan.reader_node_of_base[o] = nid
        elif o >= 0 and plan.reader_node_of_base.get(o) == nid:
            plan.reader_node_of_base.pop(o, None)
    for b in host.retired_writer_bases:
        plan.writer_row_of_base.pop(b, None)


def _recompile(plan: ExecPlan, host: PlanHost,
               growth: float) -> tuple[ExecPlan, Overlay]:
    """Capacity-overflow fallback: a fresh ``compile_plan`` over the host
    mirror's (unpruned) overlay with ``growth`` headroom on every padded
    dimension, so the following churn burst patches in place again."""
    ov = host.export_overlay()
    dec = host.decision[: host.n_real].copy()
    pad = grow_pad(measure_plan(ov, dec), growth)
    new = compile_plan(ov, dec, backend=plan.meta.backend, pad=pad)
    new.patches_applied = plan.patches_applied
    new.host = PlanHost.from_plan(new, ov)
    new.host.retired_writer_bases = set(host.retired_writer_bases)
    return new, ov
