"""Incremental ExecPlan maintenance: patch ``PlanArrays`` on device (§3.3).

The full-rebuild path (``compile_plan``) re-derives every stacked level table
and usually retraces the jitted bodies — seconds of latency per structural
update. This module consumes the structured mutation log a ``DynamicOverlay``
journals (``OverlayDelta``) and patches the *live* plan instead, in three
escalating tiers:

  1. **slot patch** — a retired edge's slot is neutralized in place
     (``seg=-1, src=0, sign=0``: the padding pattern every backend drops);
     a new edge claims a free slot inside the owning row tile's block range.
  2. **level relayout** — when a tile's occupancy counter overflows its slot
     range (or a destination moved into a previously-empty tile) the whole
     level row is rebuilt from the graph mirror (``ops.relayout_level``) —
     still inside the plan's padded block budget, so shapes and therefore the
     jit cache are untouched.
  3. **recompile fallback** — a genuine capacity overflow (nodes, writers,
     levels, blocks, demand slots) falls back to ``compile_plan`` with a
     ``growth``-factor ``PlanPad`` so the *next* churn burst patches cheaply.

Tiers 1 and 2 are **device-resident**: the delta is lowered to a fixed-shape
``PatchProgram`` — shape-bucketed arrays of (level, slot) edits, touched-mask
point edits, whole-row relayouts, and decision / writer-row / demand-row
updates — and applied by ONE cached jitted ``apply_patch_step`` that donates
the ``PlanArrays`` pytree and scatters every table in place. Only the edits
travel to the device (explicit ``jax.device_put``); the tables themselves
never leave device memory. All edit fields share one bucket class
(``_bucket_class``), so a plan compiles at most ladder-depth patch
executables over its whole life.

The host side (``PlanHost``) is a *bookkeeping index*, not a table mirror:
the overlay graph (in-edges, kinds, decisions, levels), per-(level, tile)
free-slot pools and occupancy counters (the host twin of
``ops.tile_occupancy``), and per-destination slot assignments. Full numpy
table mirrors exist only as a parity oracle behind the ``EAGR_PATCH_PARITY``
debug flag (or ``PlanHost.enable_mirror``), which replays every edit host-side
and asserts the device tables bit-identical after each patch.

Node ids are kept stable by operating on the **unpruned** overlay export
(``DynamicOverlay.to_overlay(prune=False)``): dead nodes linger edgeless and
writer rows are append-only, which is what makes window state migration a
pad-and-zero instead of a reshuffle.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import Counter, deque
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import PULL, PUSH
from repro.core.dynamic import OverlayDelta
from repro.core.engine import (
    ExecPlan,
    LevelTables,
    PlanArrays,
    PlanMeta,
    compile_plan,
    grow_pad,
    measure_plan,
)
from repro.core.overlay import Overlay
from repro.kernels.segment_agg.ops import (
    E_BLK,
    R_BLK,
    relayout_level,
    scatter_rows,
    scatter_slots,
    tile_slot_ranges,
)


class CapacityExceeded(Exception):
    """An in-place patch does not fit the plan's padded capacity."""


# ------------------------------------------------------------- patch program
# Edit-array index value marking shape-bucket padding: out of every table's
# bounds, so the device scatters drop it (mode="drop") without masking.
_OOB = np.int32(2 ** 30)
_SLOT_BUCKET = 64  # slot-edit count floor; buckets grow by powers of 4


def _bucket(n: int, floor: int) -> int:
    """Bucket edit counts to ``floor * 4**k``: ``apply_patch_step`` is
    cache-keyed by the program's array shapes, so distinct edit counts would
    otherwise each compile their own executable. A coarse geometric ladder
    keeps the cache at a handful of executables — padding entries carry an
    out-of-bounds index and are dropped by the scatters."""
    b = floor
    while b < n:
        b *= 4
    return b


def _bucket_class(counts_floors) -> int:
    """One shared ladder rung for a GROUP of edit fields: every field in the
    group is padded to ``floor * 4**class``. Independent per-field ladders
    would make the program's shape signature a product of ladders (a compile
    per combination — the measured 10%-churn compile storm); a shared class
    caps the signature count at the ladder depth."""
    c = 0
    for n, floor in counts_floors:
        k, b = 0, floor
        while b < n:
            b *= 4
            k += 1
        c = max(c, k)
    return c


class TablePatch(NamedTuple):
    """One edge set's device edits, shape-bucketed (see ``_bucket``)."""

    lvl: jnp.ndarray        # (P,) i32 slot-edit levels, _OOB padding
    slot: jnp.ndarray       # (P,) i32 slot-edit positions
    seg: jnp.ndarray        # (P,) i32 new destinations (-1 retires)
    src: jnp.ndarray        # (P,) i32 new sources
    sign: jnp.ndarray       # (P,) f32 new signs
    t_lvl: jnp.ndarray      # (T,) i32 touched-mask point edits, _OOB padding
    t_node: jnp.ndarray     # (T,) i32 destination whose touched bit flips
    t_val: jnp.ndarray      # (T,) bool new touched bit
    row_lvl: jnp.ndarray    # (R,) i32 relayout levels, _OOB padding
    row_seg: jnp.ndarray    # (R, e_pad) i32 replacement rows
    row_src: jnp.ndarray    # (R, e_pad) i32
    row_sign: jnp.ndarray   # (R, e_pad) f32
    row_tob: jnp.ndarray    # (R, n_blocks) i32
    row_fot: jnp.ndarray    # (R, n_blocks) i32
    row_touched: jnp.ndarray  # (R, cap) bool replacement touched rows


class PatchProgram(NamedTuple):
    """A lowered ``OverlayDelta``: every device-side effect of one in-capacity
    patch as fixed-shape arrays, applied by ``apply_patch_step`` in one jitted
    call. Only these (bucketed, edit-sized) arrays travel host->device."""

    push: TablePatch
    pull: TablePatch
    dec_idx: jnp.ndarray    # (C,) i32 nodes whose PUSH/PULL decision flipped
    dec_val: jnp.ndarray    # (C,) i32
    w_row: jnp.ndarray      # (W,) i32 newly claimed writer rows
    w_node: jnp.ndarray     # (W,) i32 their overlay nodes
    d_lvl: jnp.ndarray      # (D,) i32 demand levels rebuilt, _OOB padding
    d_dst: jnp.ndarray      # (D, d_pad) i32 replacement demand rows
    d_src: jnp.ndarray      # (D, d_pad) i32


def _apply_table(t: LevelTables, p: TablePatch) -> LevelTables:
    seg = scatter_slots(t.seg, p.lvl, p.slot, p.seg)
    src = scatter_slots(t.src, p.lvl, p.slot, p.src)
    sign = scatter_slots(t.sign, p.lvl, p.slot, p.sign)
    touched = scatter_slots(t.touched, p.t_lvl, p.t_node, p.t_val)
    seg = scatter_rows(seg, p.row_lvl, p.row_seg)
    src = scatter_rows(src, p.row_lvl, p.row_src)
    sign = scatter_rows(sign, p.row_lvl, p.row_sign)
    tob = scatter_rows(t.tile_of_block, p.row_lvl, p.row_tob)
    fot = scatter_rows(t.first_of_tile, p.row_lvl, p.row_fot)
    touched = scatter_rows(touched, p.row_lvl, p.row_touched)
    return LevelTables(seg=seg, src=src, sign=sign, tile_of_block=tob,
                       first_of_tile=fot, touched=touched)


def apply_patch_program(arrays: PlanArrays, prog: PatchProgram) -> PlanArrays:
    """Pure patch body — embeddable in larger programs; ``distributed/
    stacked.py`` runs it masked under ``shard_map``/``vmap`` to patch one
    slice of a stacked plan pytree without leaving the device."""
    push = _apply_table(arrays.push, prog.push)
    pull = _apply_table(arrays.pull, prog.pull)
    decision = arrays.decision.at[prog.dec_idx].set(prog.dec_val, mode="drop")
    writer_node = arrays.writer_node.at[prog.w_row].set(prog.w_node,
                                                        mode="drop")
    demand_dst = arrays.demand_dst.at[prog.d_lvl].set(prog.d_dst, mode="drop")
    demand_src = arrays.demand_src.at[prog.d_lvl].set(prog.d_src, mode="drop")
    return PlanArrays(decision=decision, writer_node=writer_node, push=push,
                      pull=pull, demand_dst=demand_dst, demand_src=demand_src)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def apply_patch_step(meta: PlanMeta, arrays: PlanArrays,
                     prog: PatchProgram) -> PlanArrays:
    """The device-resident table update: donates the live ``PlanArrays``
    pytree (tables are rewritten in place, never copied through the host) and
    applies one lowered delta. One cache entry per (meta, program-bucket)
    pair — in-capacity churn stays on a single compiled step."""
    del meta  # shapes key the cache; meta pins the entry to its plan
    return apply_patch_program(arrays, prog)


# --------------------------------------------------------------- host mirrors
@dataclasses.dataclass
class TableMirror:
    """Full numpy twin of one ``LevelTables`` — the parity oracle. Maintained
    only under ``EAGR_PATCH_PARITY`` / ``PlanHost.enable_mirror``; the hot
    path never reads or uploads it."""

    seg: np.ndarray
    src: np.ndarray
    sign: np.ndarray
    touched: np.ndarray

    @staticmethod
    def from_tables(t: LevelTables) -> "TableMirror":
        return TableMirror(seg=np.array(t.seg), src=np.array(t.src),
                           sign=np.array(t.sign), touched=np.array(t.touched))


@dataclasses.dataclass
class TableHost:
    """Bookkeeping index of one ``LevelTables``: block routing, free-slot
    pools, per-tile occupancy counters (host twin of ``ops.tile_occupancy``)
    and per-destination slot assignments. Holds no authoritative copy of the
    device tables — mutations accumulate as edits for the patch program."""

    tob: np.ndarray               # (L, n_blocks) int32
    fot: np.ndarray               # (L, n_blocks) int32
    tile_slots: np.ndarray        # (L, n_row_tiles, 2) [start, stop) per tile
    occ: np.ndarray               # (L, n_row_tiles) int32 live slots per tile
    e_pad: int
    # d -> [(slot, src, sign)], and d -> level
    slots_of: dict[int, list[tuple[int, int, float]]] = \
        dataclasses.field(default_factory=dict)
    level_of: dict[int, int] = dataclasses.field(default_factory=dict)
    free: dict[tuple[int, int], list[int]] = dataclasses.field(default_factory=dict)
    mirror: TableMirror | None = None
    # edits of the in-flight patch, drained into a TablePatch
    edits: dict[tuple[int, int], tuple[int, int, float]] = \
        dataclasses.field(default_factory=dict)
    touched_edits: dict[tuple[int, int], bool] = \
        dataclasses.field(default_factory=dict)
    row_edits: dict[int, tuple] = dataclasses.field(default_factory=dict)

    @staticmethod
    def from_tables(t: LevelTables, n_row_tiles: int,
                    track_mirror: bool = False) -> "TableHost":
        seg = np.asarray(t.seg)
        src = np.asarray(t.src)
        sign = np.asarray(t.sign)
        tob = np.array(t.tile_of_block)
        L = seg.shape[0]
        th = TableHost(
            tob=tob, fot=np.array(t.first_of_tile),
            tile_slots=np.stack([tile_slot_ranges(tob[l], n_row_tiles)
                                 for l in range(L)]),
            occ=np.zeros((L, n_row_tiles), np.int32),
            e_pad=seg.shape[1],
        )
        for l in range(L):
            th.index_level(l, seg[l], src[l], sign[l])
        if track_mirror:
            th.mirror = TableMirror.from_tables(t)
        return th

    def index_level(self, l: int, seg_row: np.ndarray, src_row: np.ndarray,
                    sign_row: np.ndarray) -> None:
        """Rebuild slot occupancy and the free pools of one level row."""
        for d in [d for d, lv in self.level_of.items() if lv == l]:
            self.slots_of.pop(d, None)
            self.level_of.pop(d, None)
        occ_mask = seg_row >= 0
        occupied = np.flatnonzero(occ_mask)
        # group occupied slots by destination (vectorized: sort-then-split)
        dsts = seg_row[occupied]
        order = np.argsort(dsts, kind="stable")
        sorted_dsts = dsts[order]
        sorted_slots = occupied[order]
        uniq, starts = np.unique(sorted_dsts, return_index=True)
        bounds = np.append(starts, len(sorted_dsts))
        for i, d in enumerate(uniq):
            d = int(d)
            self.slots_of[d] = [(int(s), int(src_row[s]), float(sign_row[s]))
                                for s in sorted_slots[bounds[i]: bounds[i + 1]]]
            self.level_of[d] = l
        free_mask = ~occ_mask
        for t in range(self.tile_slots.shape[1]):
            a, b = int(self.tile_slots[l, t, 0]), int(self.tile_slots[l, t, 1])
            pool = [] if a == b else \
                (np.flatnonzero(free_mask[a:b])[::-1] + a).tolist()
            self.free[(l, t)] = pool
            self.occ[l, t] = (b - a) - len(pool)

    def record(self, l: int, slot: int, seg_v: int, src_v: int,
               sign_v: float) -> None:
        """Log one slot edit for the patch program (last write wins); replay
        it on the parity mirror when tracking."""
        self.edits[(l, slot)] = (seg_v, src_v, sign_v)
        if self.mirror is not None:
            self.mirror.seg[l, slot] = seg_v
            self.mirror.src[l, slot] = src_v
            self.mirror.sign[l, slot] = sign_v

    def n_edges(self) -> int:
        return sum(len(s) for s in self.slots_of.values())

    def drain_patch(self, cap: int, cls_idx: int, cls_row: int) -> TablePatch:
        """Drain the accumulated edits into numpy program arrays, padded to
        the shared bucket classes (see ``_bucket_class``), and replay touched
        changes on the parity mirror when tracking."""
        items = sorted(self.edits.items())
        k = _SLOT_BUCKET * 4 ** cls_idx
        lvl = _OOB + np.arange(k, dtype=np.int32)  # distinct OOB padding
        # (scatters promise unique_indices; dropped entries stay unique)
        slot = np.zeros(k, np.int32)
        seg_v = np.zeros(k, np.int32)
        src_v = np.zeros(k, np.int32)
        sign_v = np.zeros(k, np.float32)
        for i, ((l, s), (sv, rv, gv)) in enumerate(items):
            lvl[i], slot[i] = l, s
            seg_v[i], src_v[i], sign_v[i] = sv, rv, gv
        touches = sorted(self.touched_edits.items())
        tk = _SLOT_BUCKET * 4 ** cls_idx
        t_lvl = _OOB + np.arange(tk, dtype=np.int32)
        t_node = np.zeros(tk, np.int32)
        t_val = np.zeros(tk, bool)
        for i, ((l, d), v) in enumerate(touches):
            t_lvl[i], t_node[i], t_val[i] = l, d, v
        rows = sorted(self.row_edits.items())
        # never pad the relayout group past the level count: a slot-heavy
        # burst (high shared class) must not upload megabytes of all-padding
        # (R, e_pad) rows. L is a per-plan constant, so the jit-cache
        # signature count stays ladder-bounded.
        rk = min(4 ** cls_row, self.tob.shape[0])
        nb = self.tob.shape[1]
        row_lvl = _OOB + np.arange(rk, dtype=np.int32)
        row_seg = np.zeros((rk, self.e_pad), np.int32)
        row_src = np.zeros((rk, self.e_pad), np.int32)
        row_sign = np.zeros((rk, self.e_pad), np.float32)
        row_tob = np.zeros((rk, nb), np.int32)
        row_fot = np.zeros((rk, nb), np.int32)
        row_touched = np.zeros((rk, cap), bool)
        for i, (l, (sr, rr, gr, tr, fr, trow)) in enumerate(rows):
            row_lvl[i] = l
            row_seg[i], row_src[i], row_sign[i] = sr, rr, gr
            row_tob[i], row_fot[i] = tr, fr
            row_touched[i] = trow
        if self.mirror is not None:
            for (l, d), v in touches:
                self.mirror.touched[l, d] = v
            for l, (*_, trow) in rows:
                self.mirror.touched[l] = trow
        self.edits.clear()
        self.touched_edits.clear()
        self.row_edits.clear()
        return TablePatch(lvl=lvl, slot=slot, seg=seg_v, src=src_v,
                          sign=sign_v, t_lvl=t_lvl, t_node=t_node,
                          t_val=t_val, row_lvl=row_lvl, row_seg=row_seg,
                          row_src=row_src, row_sign=row_sign, row_tob=row_tob,
                          row_fot=row_fot, row_touched=row_touched)


@dataclasses.dataclass
class PlanHost:
    """Host-side bookkeeping index of a live plan: the (unpruned) overlay
    graph plus slot-pool state — NOT a table mirror (see module docstring)."""

    kinds: list[str]
    origin: list[int]
    in_edges: list[list[tuple[int, int]]]
    out: list[list[int]]          # src -> consumer nodes (multiset as list)
    decision: np.ndarray          # (>=n_real,) int64
    level: np.ndarray             # (>=n_real,) int64
    push: TableHost
    pull: TableHost
    demand: list[list[tuple[int, int]]]   # per padded level: (dst, src) pairs
    n_real: int
    dup_insensitive: bool = False
    retired_writer_bases: set[int] = dataclasses.field(default_factory=set)
    track_mirror: bool = False
    auto_verify: bool = False

    @staticmethod
    def from_plan(plan: ExecPlan, overlay: Overlay, *,
                  mirror: bool | None = None) -> "PlanHost":
        if overlay.n_nodes != len(plan.level):
            raise ValueError(
                f"overlay has {overlay.n_nodes} nodes but the plan was "
                f"compiled over {len(plan.level)} — pass the (unpruned) "
                f"overlay the plan was compiled from")
        parity_env = os.environ.get("EAGR_PATCH_PARITY", "") not in ("", "0")
        track = parity_env if mirror is None else mirror
        meta = plan.meta
        cap = meta.n_nodes
        in_edges = [list(e) for e in overlay.in_edges]
        out: list[list[int]] = [[] for _ in range(cap)]
        for dst, ins in enumerate(in_edges):
            for s, _ in ins:
                out[s].append(dst)
        level = np.zeros(cap, np.int64)
        level[: overlay.n_nodes] = plan.level
        dd = np.array(plan.arrays.demand_dst)
        ds = np.array(plan.arrays.demand_src)
        demand = [[(int(a), int(b)) for a, b in zip(dd[l], ds[l]) if a < cap]
                  for l in range(dd.shape[0])]
        kinds = list(overlay.kinds) + ["I"] * (cap - overlay.n_nodes)
        origin = list(overlay.origin) + [-1] * (cap - overlay.n_nodes)
        in_edges += [[] for _ in range(cap - overlay.n_nodes)]
        return PlanHost(
            kinds=kinds, origin=origin, in_edges=in_edges, out=out,
            decision=np.array(plan.arrays.decision, dtype=np.int64),
            level=level,
            push=TableHost.from_tables(plan.arrays.push, meta.n_row_tiles,
                                       track),
            pull=TableHost.from_tables(plan.arrays.pull, meta.n_row_tiles,
                                       track),
            demand=demand, n_real=overlay.n_nodes,
            dup_insensitive=overlay.dup_insensitive,
            track_mirror=track, auto_verify=parity_env if mirror is None
            else False,
        )

    def export_overlay(self) -> Overlay:
        return Overlay(kinds=list(self.kinds[: self.n_real]),
                       origin=list(self.origin[: self.n_real]),
                       in_edges=[list(e) for e in self.in_edges[: self.n_real]],
                       dup_insensitive=self.dup_insensitive)

    def enable_mirror(self, plan: ExecPlan) -> None:
        """Start parity tracking mid-life: seed the table mirrors from the
        current device arrays (one device->host pull)."""
        self.push.mirror = TableMirror.from_tables(plan.arrays.push)
        self.pull.mirror = TableMirror.from_tables(plan.arrays.pull)
        self.track_mirror = True

    def verify_device(self, plan: ExecPlan) -> None:
        """Parity oracle: assert the device ``PlanArrays`` are bit-identical
        to the host-side expectation (mirrored tables + bookkeeping). Needs
        mirror tracking (``EAGR_PATCH_PARITY`` / ``enable_mirror``)."""
        if self.push.mirror is None or self.pull.mirror is None:
            raise RuntimeError("parity check needs mirror tracking — set "
                               "EAGR_PATCH_PARITY=1 or call enable_mirror()")
        a = plan.arrays
        cap = plan.meta.n_nodes
        bad = []
        for name, th in (("push", self.push), ("pull", self.pull)):
            t = getattr(a, name)
            m = th.mirror
            for f, dev, want in (("seg", t.seg, m.seg), ("src", t.src, m.src),
                                 ("sign", t.sign, m.sign),
                                 ("touched", t.touched, m.touched),
                                 ("tile_of_block", t.tile_of_block, th.tob),
                                 ("first_of_tile", t.first_of_tile, th.fot)):
                if not np.array_equal(np.asarray(dev), want):
                    bad.append(f"{name}.{f}")
        if not np.array_equal(np.asarray(a.decision),
                              self.decision[:cap].astype(np.int32)):
            bad.append("decision")
        wn = np.full(plan.meta.n_writers, cap, np.int32)
        wn[: len(plan.writer_node)] = plan.writer_node
        if not np.array_equal(np.asarray(a.writer_node), wn):
            bad.append("writer_node")
        L, d_pad = np.asarray(a.demand_dst).shape
        dd = np.full((L, d_pad), cap, np.int32)
        ds = np.full((L, d_pad), cap, np.int32)
        for l, pairs in enumerate(self.demand):
            if pairs:
                arr = np.asarray(pairs, np.int64)
                dd[l, : len(pairs)] = arr[:, 0]
                ds[l, : len(pairs)] = arr[:, 1]
        if not np.array_equal(np.asarray(a.demand_dst), dd):
            bad.append("demand_dst")
        if not np.array_equal(np.asarray(a.demand_src), ds):
            bad.append("demand_src")
        routes = plan.routes
        for name, m in (("writer", plan.writer_row_of_base),
                        ("reader", plan.reader_node_of_base)):
            table = getattr(routes, f"{name}_row" if name == "writer"
                            else "reader_node")
            if m and max(m) >= len(table):
                bad.append(f"routes.{name}")
                continue
            want = np.full(len(table), -1, np.int32)
            if m:
                want[np.fromiter(m.keys(), np.int64, len(m))] = \
                    np.fromiter(m.values(), np.int64, len(m))
            if not np.array_equal(table, want):
                bad.append(f"routes.{name}")
        if bad:
            raise AssertionError(
                f"device/host parity broken after patch: {bad}")


# ------------------------------------------------------------------- results
@dataclasses.dataclass
class PatchResult:
    plan: ExecPlan
    recompiled: bool
    reason: str | None
    overlay: Overlay | None                  # fresh export iff recompiled
    retired_writer_rows: list[int]
    stats: dict
    program: PatchProgram | None = None      # device program (in-capacity
                                             # patches) — reusable by stacked
                                             # deployments for slice patching
    retired_reader_bases: list[int] = dataclasses.field(default_factory=list)
                                             # reader bases this delta removed
                                             # (standing alerts on them drop)

    @property
    def kind(self) -> str:
        """How this delta landed: ``'recompiled'`` (capacity overflow fell
        back to compile_plan), ``'relayout'`` (in-capacity but at least one
        level was rebuilt wholesale), or ``'patched'`` (slot/point edits
        only) — the categories ``FlushReport`` counts per flush."""
        if self.recompiled:
            return "recompiled"
        if self.stats.get("levels_rebuilt"):
            return "relayout"
        return "patched"


# ------------------------------------------------------------ graph updating
def _relax_levels(host: PlanHost, seeds: set[int]) -> set[int]:
    """Longest-path level relaxation from the nodes whose in-edges changed.
    Returns every node whose level moved (their edges must re-home)."""
    changed: set[int] = set()
    q = deque(sorted(seeds))
    inq = set(q)
    while q:
        v = q.popleft()
        inq.discard(v)
        nl = max((int(host.level[s]) + 1 for s, _ in host.in_edges[v]),
                 default=0)
        if nl != int(host.level[v]):
            host.level[v] = nl
            changed.add(v)
            for c in host.out[v]:
                if c not in inq:
                    q.append(c)
                    inq.add(c)
    return changed


def _update_decisions(host: PlanHost, delta: OverlayDelta, *,
                      pin_push: bool = False) -> set[int]:
    """Default decisions for new nodes (writers PUSH; interiors PUSH iff all
    inputs are PUSH; readers PULL), then enforce the dataflow invariant —
    no PULL upstream of a PUSH — by flipping violators PULL and cascading
    downstream. ``pin_push`` pins every new node PUSH — the continuous-query
    class (always-fresh readers; what standing alerts predicate on), where
    churn-added readers must stay push-maintained like their compile-time
    peers. Returns every node whose decision changed."""
    changed: set[int] = set()
    for nid in range(delta.n_nodes_before, delta.n_nodes_after):
        k = host.kinds[nid]
        if pin_push or k == "W":
            d = PUSH
        elif k == "R":
            d = PULL
        else:
            ins = host.in_edges[nid]
            d = PUSH if ins and all(host.decision[s] == PUSH for s, _ in ins) \
                else PULL
        if int(host.decision[nid]) != d:
            host.decision[nid] = d
            changed.add(nid)
    q = deque(sorted(set(delta.nodes) | changed,
                     key=lambda v: int(host.level[v])))
    while q:
        v = q.popleft()
        if host.decision[v] == PUSH and any(
                host.decision[s] == PULL for s, _ in host.in_edges[v]):
            host.decision[v] = PULL
            changed.add(v)
            q.extend(host.out[v])
    return changed


# ------------------------------------------------------------- table patching
def _table_of(host: PlanHost, d: int) -> str | None:
    if not host.in_edges[d]:
        return None
    return "push" if host.decision[d] == PUSH else "pull"


def _slot_tile(th: TableHost, l: int, slot: int) -> int:
    return int(th.tob[l, slot // E_BLK])


def _free_slots(th: TableHost, d: int, stats: dict) -> None:
    entries = th.slots_of.pop(d, None)
    if entries is None:
        return
    l = th.level_of.pop(d)
    for slot, _, _ in entries:
        t = _slot_tile(th, l, slot)
        th.free[(l, t)].append(slot)
        th.occ[l, t] -= 1
        th.record(l, slot, -1, 0, 0.0)
    th.touched_edits[(l, d)] = False  # d left the level entirely
    stats["edges_removed"] += len(entries)


def _claim_slots(th: TableHost, d: int, edges, l: int, rebuild: set,
                 stats: dict) -> None:
    """Place ``edges`` (src, sign) of destination ``d`` into free slots of its
    owning tile at level ``l``; escalate the level to a relayout when the
    tile's occupancy counter overflows its slot range."""
    if l in rebuild:
        return  # the level row is being rebuilt from the graph mirror anyway
    t = d // R_BLK
    a, b = int(th.tile_slots[l, t, 0]), int(th.tile_slots[l, t, 1])
    if int(th.occ[l, t]) + len(edges) > b - a:
        rebuild.add(l)
        return
    pool = th.free[(l, t)]
    for s_, sg in edges:
        slot = pool.pop()
        th.occ[l, t] += 1
        th.record(l, slot, d, int(s_), float(sg))
        th.slots_of.setdefault(d, []).append((slot, int(s_), float(sg)))
        th.level_of[d] = l
    if edges:
        th.touched_edits[(l, d)] = True
    stats["edges_added"] += len(edges)


def _diff_in_place(th: TableHost, d: int, new_edges, l: int,
                   rebuild: set, stats: dict) -> None:
    """Destination stays in the same table and level: free only the removed
    edges' slots and claim slots only for the added ones."""
    entries = th.slots_of.get(d, [])
    need = Counter((int(s), float(g)) for s, g in new_edges)
    keep, freed = [], []
    for slot, s, g in entries:
        if need[(s, g)] > 0:
            need[(s, g)] -= 1
            keep.append((slot, s, g))
        else:
            freed.append(slot)
    for slot in freed:
        t = _slot_tile(th, l, slot)
        th.free[(l, t)].append(slot)
        th.occ[l, t] -= 1
        th.record(l, slot, -1, 0, 0.0)
    stats["edges_removed"] += len(freed)
    th.slots_of[d] = keep
    if not keep:
        th.slots_of.pop(d, None)
        th.level_of.pop(d, None)
        th.touched_edits[(l, d)] = False
    missing = [e for e, c in need.items() for _ in range(c)]
    if missing:
        _claim_slots(th, d, missing, l, rebuild, stats)


def _rebuild_level(host: PlanHost, th: TableHost, table: str, l: int,
                   cap: int, n_row_tiles: int) -> None:
    dsts = [int(d) for d in np.flatnonzero(host.level[: host.n_real] == l + 1)
            if _table_of(host, d) == table]
    dst_l, src_l, sign_l = [], [], []
    for d in dsts:
        for s, sg in host.in_edges[d]:
            dst_l.append(d)
            src_l.append(s)
            sign_l.append(sg)
    rl = relayout_level(np.asarray(dst_l, np.int64), np.asarray(src_l, np.int64),
                        np.asarray(sign_l, np.float64), cap,
                        th.tob.shape[1], th.e_pad)
    if rl is None:
        raise CapacityExceeded(f"{table} level {l} exceeds the block budget")
    seg_row, src_row, sign_row, tob_row, fot_row = rl
    th.tob[l] = tob_row
    th.fot[l] = fot_row
    th.tile_slots[l] = tile_slot_ranges(tob_row, n_row_tiles)
    for key in [k for k in th.edits if k[0] == l]:
        del th.edits[key]  # superseded by the whole-row rewrite
    for key in [k for k in th.touched_edits if k[0] == l]:
        del th.touched_edits[key]
    trow = np.zeros(cap, bool)
    trow[seg_row[seg_row >= 0]] = True
    th.row_edits[l] = (seg_row, src_row, sign_row, tob_row, fot_row, trow)
    th.index_level(l, seg_row, src_row, sign_row)
    if th.mirror is not None:
        th.mirror.seg[l] = seg_row
        th.mirror.src[l] = src_row
        th.mirror.sign[l] = sign_row


# --------------------------------------------------------------------- patch
def patch_plan(plan: ExecPlan, delta: OverlayDelta, *,
               overlay: Overlay | None = None,
               growth: float = 2.0,
               pin_push: bool = False) -> PatchResult:
    """Apply one ``OverlayDelta`` to a live plan.

    In-capacity updates lower the delta to a ``PatchProgram`` and rewrite the
    donated ``PlanArrays`` pytree with one cached ``apply_patch_step`` call
    (same ``PlanMeta``, zero table uploads — so every jitted body keeps its
    compiled program); overflows recompile with ``growth`` headroom.
    ``overlay`` is only needed on the first patch of a plan, to seed the host
    bookkeeping; it must be the (unpruned) overlay the plan was compiled
    from. ``pin_push`` keeps churn-added nodes PUSH-decided (continuous
    groups)."""
    if delta.empty:
        return PatchResult(plan, False, "empty delta", None, [], {})
    host: PlanHost = plan.host  # type: ignore[assignment]
    if host is None:
        if overlay is None:
            raise ValueError("first patch_plan call needs overlay= to seed "
                             "the host mirror")
        host = PlanHost.from_plan(plan, overlay)
        plan.host = host
    meta = plan.meta
    cap = meta.n_nodes
    stats = {"edges_added": 0, "edges_removed": 0, "levels_rebuilt": 0,
             "demand_levels": 0, "slot_levels": 0}

    # ---------------------------------------------- phase A: graph mirror
    for _ in range(delta.n_nodes_after - len(host.kinds)):
        host.kinds.append("I")
        host.origin.append(-1)
        host.in_edges.append([])
        host.out.append([])
    if delta.n_nodes_after > len(host.decision):
        extra = delta.n_nodes_after - len(host.decision)
        host.decision = np.concatenate(
            [host.decision, np.full(extra, PULL, np.int64)])
        host.level = np.concatenate([host.level, np.zeros(extra, np.int64)])
    # pre-patch in-edges of the re-homed nodes: frontier maintenance walks
    # *up* through both the old and new parents so writers that lost a path
    # to a destination are re-indexed too
    old_in = {nid: list(host.in_edges[nid]) for nid in delta.nodes}
    for nid, patch in delta.nodes.items():
        for s, _ in host.in_edges[nid]:
            host.out[s].remove(nid)
        host.in_edges[nid] = list(patch.edges)
        for s, _ in patch.edges:
            host.out[s].append(nid)
        host.kinds[nid] = patch.kind
        host.origin[nid] = patch.origin
    host.n_real = max(host.n_real, delta.n_nodes_after)
    host.retired_writer_bases |= delta.retired_writers
    host.retired_writer_bases -= set(delta.new_writers)

    changed_level = _relax_levels(host, set(delta.nodes))
    changed_dec = _update_decisions(host, delta, pin_push=pin_push)
    depth = int(host.level[: host.n_real].max()) if host.n_real else 0

    retired_rows = [plan.writer_row_of_base[b] for b in delta.retired_writers
                    if b in plan.writer_row_of_base]
    retired_bases = sorted(
        set(delta.retired_readers) - set(delta.new_readers))

    # ---------------------------------------------- phase B: capacity gates
    def fallback(reason: str) -> PatchResult:
        new_plan, new_overlay = _recompile(plan, host, growth)
        _apply_base_maps(new_plan, host, delta)
        stats["reason"] = reason
        return PatchResult(new_plan, True, reason, new_overlay,
                           retired_rows, stats,
                           retired_reader_bases=retired_bases)

    if host.n_real > cap:
        return fallback("node capacity")
    if len(plan.writer_node) + len(delta.new_writer_nodes) > meta.n_writers:
        return fallback("writer capacity")
    if depth > meta.n_levels:
        return fallback("level capacity")
    if meta.backend == "xla_unrolled" and depth != plan.depth:
        return fallback("unrolled depth changed")

    # ---------------------------------------------- phase C: table patching
    rehome = set(delta.nodes) | changed_level | changed_dec
    rebuild = {"push": set(), "pull": set()}
    demand_levels: set[int] = set()
    try:
        for d in sorted(rehome):
            new_table = _table_of(host, d)
            new_l = int(host.level[d]) - 1 if new_table else -1
            old = None
            for name in ("push", "pull"):
                th = getattr(host, name)
                if d in th.level_of:
                    old = (name, th.level_of[d])
                    break
            if old and old[0] == "pull":
                demand_levels.add(old[1])
            if new_table == "pull":
                demand_levels.add(new_l)
            if old == (new_table, new_l):
                _diff_in_place(getattr(host, new_table), d,
                               host.in_edges[d], new_l,
                               rebuild[new_table], stats)
            else:
                if old:
                    _free_slots(getattr(host, old[0]), d, stats)
                if new_table:
                    _claim_slots(getattr(host, new_table), d,
                                 host.in_edges[d], new_l,
                                 rebuild[new_table], stats)
        for v in changed_dec:
            for c in host.out[v]:
                if host.level[c] >= 1 and host.decision[c] == PULL:
                    demand_levels.add(int(host.level[c]) - 1)
        for name in ("push", "pull"):
            th = getattr(host, name)
            for l in sorted(rebuild[name]):
                _rebuild_level(host, th, name, l, cap, meta.n_row_tiles)
                stats["levels_rebuilt"] += 1
        # demand rows
        d_pad = plan.arrays.demand_dst.shape[1]
        new_demand_rows = {}
        for l in sorted(demand_levels):
            pairs = []
            for d in np.flatnonzero(host.level[: host.n_real] == l + 1):
                if host.decision[d] != PULL:
                    continue
                for s, _ in host.in_edges[int(d)]:
                    if host.decision[s] == PULL:
                        pairs.append((int(d), int(s)))
            if len(pairs) > d_pad:
                raise CapacityExceeded(f"demand level {l} needs {len(pairs)} "
                                       f"> {d_pad} slots")
            new_demand_rows[l] = pairs
    except CapacityExceeded as e:
        return fallback(str(e))

    # -------------------------------- phase D: lower + run the patch program
    stats["slot_levels"] = len({l for l, _ in host.push.edits}
                               | {l for l, _ in host.pull.edits})
    stats["demand_levels"] = len(new_demand_rows)
    # every new W-kind node claims a row (id order), even if it was deleted
    # within this epoch — keeps row positions identical to what a recompile
    # over the unpruned overlay would assign, so window state migrates by
    # position safely
    first_new_row = len(plan.writer_node)
    for nid in sorted(delta.new_writer_nodes):
        plan.writer_node = np.append(plan.writer_node, nid)
    n_new = len(plan.writer_node) - first_new_row
    decs = sorted(int(v) for v in changed_dec)
    # ONE shared class for every edit field: the program's shape signature
    # moves along a single ladder, so a plan compiles at most ladder-depth
    # apply_patch_step executables over its whole life (compile storms at
    # high churn ratios were the dominant patch cost)
    cls = _bucket_class([
        (len(host.push.edits), _SLOT_BUCKET),
        (len(host.pull.edits), _SLOT_BUCKET),
        (len(host.push.touched_edits), _SLOT_BUCKET),
        (len(host.pull.touched_edits), _SLOT_BUCKET),
        (len(decs), 32), (n_new, 8),
        (len(host.push.row_edits), 1), (len(host.pull.row_edits), 1),
        (len(new_demand_rows), 4)])
    cls_idx = cls_row = cls
    # like the relayout group, demand rows never pad past the level count
    dk = min(4 * 4 ** cls_row, int(plan.arrays.demand_dst.shape[0]))
    d_lvl = _OOB + np.arange(dk, dtype=np.int32)  # distinct OOB padding
    d_dst = np.zeros((dk, d_pad), np.int32)
    d_src = np.zeros((dk, d_pad), np.int32)
    for i, (l, pairs) in enumerate(sorted(new_demand_rows.items())):
        host.demand[l] = pairs
        d_lvl[i] = l
        d_dst[i] = cap
        d_src[i] = cap
        if pairs:
            arr = np.asarray(pairs, np.int64)
            d_dst[i, : len(pairs)] = arr[:, 0]
            d_src[i, : len(pairs)] = arr[:, 1]
    ck = 32 * 4 ** cls_idx
    dec_idx = _OOB + np.arange(ck, dtype=np.int32)
    dec_val = np.zeros(ck, np.int32)
    dec_idx[: len(decs)] = decs
    if decs:
        dec_val[: len(decs)] = host.decision[decs].astype(np.int32)
    wk = 8 * 4 ** cls_idx
    w_row = _OOB + np.arange(wk, dtype=np.int32)
    w_node = np.zeros(wk, np.int32)
    w_row[:n_new] = np.arange(first_new_row, len(plan.writer_node))
    w_node[:n_new] = plan.writer_node[first_new_row:]
    prog: PatchProgram = jax.device_put(PatchProgram(
        push=host.push.drain_patch(cap, cls_idx, cls_row),
        pull=host.pull.drain_patch(cap, cls_idx, cls_row),
        dec_idx=dec_idx, dec_val=dec_val, w_row=w_row, w_node=w_node,
        d_lvl=d_lvl, d_dst=d_dst, d_src=d_src))
    plan.arrays = apply_patch_step(meta, plan.arrays, prog)

    # ---------------------------------------------- phase E: plan metadata
    plan.depth = depth
    plan.level = host.level[: host.n_real].copy()
    plan.decision = host.decision[: host.n_real].copy()
    plan.n_push_edges = host.push.n_edges()
    plan.n_pull_edges = host.pull.n_edges()
    plan.patches_applied += 1
    _apply_base_maps(plan, host, delta)
    # frontier bookkeeping: the reader index is cheap to rebuild and hard to
    # maintain (demand chunk positions move) — drop it on any patch. The
    # write index survives slot-level patches via exact per-writer overrides;
    # a level relayout moves every slot of that level wholesale, so it
    # invalidates the whole index (rebuilt lazily on the next sparse write).
    plan.reader_frontier = None
    if plan.frontier is not None:
        if rebuild["push"]:
            plan.frontier = None
        else:
            from repro.core.frontier import maintain_frontier
            maintain_frontier(plan.frontier, plan, host, rehome, old_in)
            if host.auto_verify:
                plan.frontier.verify(plan, host)
    if host.auto_verify:
        host.verify_device(plan)
    return PatchResult(plan, False, None, None, retired_rows, stats,
                       program=prog, retired_reader_bases=retired_bases)


def _apply_base_maps(plan: ExecPlan, host: PlanHost,
                     delta: OverlayDelta) -> None:
    """Reconcile base-id -> row/node maps with the delta (both patch and
    recompile paths). The dense ``plan.routes`` tables — the vectorized
    hot-path router — mirror every dict edit, so steady-state writes/reads
    never consult the dicts."""
    routes = plan.routes
    for b in delta.retired_writers:
        if b not in delta.new_writers:
            plan.writer_row_of_base.pop(b, None)
            routes.clear_writer(b)
    for b, nid in delta.new_writers.items():
        row = int(np.flatnonzero(plan.writer_node == nid)[0]) \
            if (plan.writer_node == nid).any() else None
        if row is not None:
            plan.writer_row_of_base[b] = row
            routes.set_writer(b, row)
    for b in delta.retired_readers:
        if b not in delta.new_readers:
            plan.reader_node_of_base.pop(b, None)
            routes.clear_reader(b)
    for nid, patch in delta.nodes.items():
        o = patch.origin
        if patch.kind == "R":
            plan.reader_node_of_base[o] = nid
            routes.set_reader(o, nid)
        elif o >= 0 and plan.reader_node_of_base.get(o) == nid:
            plan.reader_node_of_base.pop(o, None)
            routes.clear_reader(o)
    for b in host.retired_writer_bases:
        plan.writer_row_of_base.pop(b, None)
        routes.clear_writer(b)


def carry_plan_bookkeeping(new: ExecPlan, old: ExecPlan,
                           overlay: Overlay) -> ExecPlan:
    """Carry patch bookkeeping across a recompile of the same live plan (the
    growth fallback, a shard realign, or a decision re-adoption): the patch
    counter survives, retired writer rows stay retired (the unpruned overlay
    keeps their lingering W nodes, so ``compile_plan`` re-registers them),
    and — when the old plan had host state — the new plan gets a fresh
    ``PlanHost`` with the parity mirror/verify flags preserved."""
    new.patches_applied = old.patches_applied
    host: PlanHost | None = old.host  # type: ignore[assignment]
    if host is not None:
        for b in host.retired_writer_bases:
            new.writer_row_of_base.pop(b, None)
            new.routes.clear_writer(b)
        new.host = PlanHost.from_plan(new, overlay, mirror=host.track_mirror)
        new.host.auto_verify = host.auto_verify
        new.host.retired_writer_bases = set(host.retired_writer_bases)
    return new


def _recompile(plan: ExecPlan, host: PlanHost,
               growth: float) -> tuple[ExecPlan, Overlay]:
    """Capacity-overflow fallback: a fresh ``compile_plan`` over the host
    mirror's (unpruned) overlay with ``growth`` headroom on every padded
    dimension, so the following churn burst patches in place again."""
    ov = host.export_overlay()
    dec = host.decision[: host.n_real].copy()
    pad = grow_pad(measure_plan(ov, dec), growth)
    new = compile_plan(ov, dec, backend=plan.meta.backend, pad=pad)
    carry_plan_bookkeeping(new, plan, ov)
    return new, ov
