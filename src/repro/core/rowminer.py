"""Vectorized FP-tree biclique mining over rank-sorted rows ('basic'/'dup').

With the item order frozen for a mining group, each reader's transaction is an
ascending *rank sequence* (its row), and the group's FP-tree is exactly the
trie of those rows. That gives an array representation of everything
``FPTree.mine_best`` computes:

  * every trie node is a prefix P shared by >= 1 rows, and the rows sharing P
    form one lexicographically contiguous block — so sorting the rows once
    (bytes memcmp == tuple order for equal-width big-endian ranks) and taking
    longest-common-prefix lengths between neighbours enumerates all candidate
    (prefix, support) pairs without building a single node object;
  * a mined path is always a full prefix of its supporting rows, so applying a
    biclique is a shift-and-append on those rows: ``row[d:] + [vid_rank]``
    ('basic') or flag-prefix-as-mined-and-append ('dup'). New virtual items
    take the next rank, so rows stay rank-ascending with no re-sort.

Tie-breaks mirror ``FPTree.mine_best``: maximum benefit, then the
lexicographically smallest rank sequence. 'neg' mode stays on the object tree
(path picking is inherently sequential per reader).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RowBiclique:
    path: np.ndarray       # rank sequence; ranks >= the initial count are group-local vids
    support: int           # |S| — all rows sharing the prefix
    consumers: np.ndarray  # row indices whose rows were rewritten
    benefit: int


def _lcp(a: np.ndarray, b: np.ndarray) -> int:
    m = min(a.size, b.size)
    if m == 0:
        return 0
    neq = a[:m] != b[:m]
    i = int(neq.argmax())
    return m if not neq[i] else i


def _key(row: np.ndarray) -> bytes:
    # big-endian u4 bytes: memcmp order == tuple order for non-negative ranks,
    # including prefix < extension.
    return row.astype(">u4").tobytes()


def mine_rows(rows: list[np.ndarray], flags: list[np.ndarray] | None,
              dup: bool, n_ranks: int,
              max_bicliques: int = 64) -> list[RowBiclique]:
    """Mine up to ``max_bicliques`` positive-benefit bicliques from rank rows.

    ``rows`` (and ``flags`` when ``dup``) are mutated in place to their
    post-mining state. Returns the applied bicliques in application order;
    the j-th biclique's virtual item has rank ``n_ranks + j``.
    """
    n = len(rows)
    out: list[RowBiclique] = []
    if n < 2:
        return out
    keys = [_key(r) for r in rows]
    cums = [np.cumsum(f, dtype=np.int64) for f in flags] if dup else None
    next_rank = n_ranks

    while len(out) < max_bicliques:
        perm = sorted(range(n), key=keys.__getitem__)
        srows = [rows[i] for i in perm]
        lcp = np.fromiter((_lcp(srows[i], srows[i + 1]) for i in range(n - 1)),
                          dtype=np.int64, count=n - 1)
        maxd = int(lcp.max()) if lcp.size else 0
        if maxd < 2:
            break
        if dup:
            depths = range(2, maxd + 1)  # reuse penalty is not monotone in d
        else:
            # benefit strictly grows with d at fixed support, so only the
            # largest d yielding each support partition can win
            depths = [int(v) for v in np.unique(lcp) if v >= 2]

        best = None  # (benefit, path_tuple, d, sorted_start, support)
        for d in depths:
            idx = np.flatnonzero(lcp >= d)
            if idx.size == 0:
                continue
            splits = np.flatnonzero(np.diff(idx) > 1)
            starts = np.concatenate([[0], splits + 1])
            ends = np.concatenate([splits, [idx.size - 1]])
            for a, b in zip(starts, ends):
                lo = int(idx[a])
                s = int(idx[b]) - lo + 2
                benefit = d * s - d - s
                if dup:
                    benefit -= sum(int(cums[perm[i]][d - 1])
                                   for i in range(lo, lo + s))
                if benefit <= 0 or (best is not None and benefit < best[0]):
                    continue
                pt = tuple(int(x) for x in srows[lo][:d])
                if best is None or benefit > best[0] or pt < best[1]:
                    best = (benefit, pt, d, lo, s)
        if best is None:
            break

        benefit, _, d, lo, s = best
        members = [perm[i] for i in range(lo, lo + s)]
        if dup:
            # a supporter consumes only if the prefix still covers >= 1 of its
            # active (unmined) items; all-mined supporters keep their edges
            consumers = [i for i in members if d - int(cums[i][d - 1]) >= 1]
        else:
            consumers = members
        if len(consumers) < 2:
            break  # matches _apply_biclique: < 2 consumers -> no rewrite

        path = rows[members[0]][:d].copy()
        vid_rank = next_rank
        next_rank += 1
        for i in consumers:
            if dup:
                flags[i][:d] = True
                rows[i] = np.append(rows[i], vid_rank)
                flags[i] = np.append(flags[i], False)
                cums[i] = np.cumsum(flags[i], dtype=np.int64)
            else:
                rows[i] = np.append(rows[i][d:], vid_rank)
            keys[i] = _key(rows[i])
        out.append(RowBiclique(path=path, support=s,
                               consumers=np.array(sorted(consumers),
                                                  dtype=np.int64),
                               benefit=benefit))
    return out
