"""Min-hash shingle ordering of readers (paper §3.2.1, after Buehrer et al. /
Chierichetti et al.). Readers with similar input lists get similar shingle
tuples, so a lexicographic sort clusters biclique candidates together."""
from __future__ import annotations

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + _MIX).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def shingle_value(items: np.ndarray, seed: int) -> int:
    """min-hash of an item set under hash seed ``seed``."""
    if items.size == 0:
        return 0
    h = _splitmix64(items.astype(np.uint64) ^ _splitmix64(np.uint64(seed) * np.ones(1, np.uint64)))
    return int(h.min())


def shingle_order(input_lists: dict[int, np.ndarray], n_hashes: int = 2, seed: int = 0) -> list[int]:
    """Return reader ids sorted lexicographically by their shingle tuples."""
    keys = {}
    for r, items in input_lists.items():
        keys[r] = tuple(shingle_value(np.asarray(items), seed + i) for i in range(n_hashes))
    return sorted(input_lists.keys(), key=lambda r: (keys[r], r))
