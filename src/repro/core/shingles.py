"""Min-hash shingle ordering of readers (paper §3.2.1, after Buehrer et al. /
Chierichetti et al.). Readers with similar input lists get similar shingle
tuples, so a lexicographic sort clusters biclique candidates together.

Two entry points:
  * ``shingle_order`` — the historical dict API (reader -> item array),
  * ``shingle_order_csr`` — the batched path: one ``np.minimum.reduceat`` per
    hash over a CSR view of *all* reader lists, no per-reader Python work.
Both produce identical orderings (readers sorted by shingle tuple, ties by id).
"""
from __future__ import annotations

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)
_MASK64 = (1 << 64) - 1


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + _MIX).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def seed_mix(seed: int) -> int:
    """splitmix64 of the seed as a plain int — the per-hash constant that
    ``shingle_value`` used to recompute (with a fresh 1-element array) on
    every call. Python-int arithmetic: numpy uint64 *scalars* warn on
    wraparound, arrays don't."""
    x = (int(seed) + 0x9E3779B97F4A7C15) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    return x


def hash_items(items: np.ndarray, premix: int) -> np.ndarray:
    """Element-wise splitmix64 of ``items`` under a premixed seed constant."""
    return _splitmix64(items.astype(np.uint64) ^ np.uint64(premix))


def shingle_value(items: np.ndarray, seed: int) -> int:
    """min-hash of an item set under hash seed ``seed``."""
    if items.size == 0:
        return 0
    return int(hash_items(np.asarray(items), seed_mix(seed)).min())


def min_hashes_csr(indptr: np.ndarray, values: np.ndarray, n_hashes: int,
                   seed: int) -> np.ndarray:
    """(n_rows, n_hashes) min-hash matrix over a CSR item array: one
    vectorized hash + one ``np.minimum.reduceat`` per hash function.
    Empty rows hash to 0 (matching ``shingle_value`` on an empty array)."""
    n_rows = indptr.size - 1
    out = np.zeros((n_rows, n_hashes), dtype=np.uint64)
    if values.size == 0:
        return out
    sizes = np.diff(indptr)
    nonempty = sizes > 0
    # reduceat over the non-empty rows only: their start offsets are exactly
    # the segment boundaries (empty rows contribute no values in between);
    # empty rows keep the 0 fill.
    starts = indptr[:-1][nonempty].astype(np.int64)
    vals = np.asarray(values)
    for i in range(n_hashes):
        h = hash_items(vals, seed_mix(seed + i))
        out[nonempty, i] = np.minimum.reduceat(h, starts)
    return out


def shingle_order_csr(row_ids: np.ndarray, indptr: np.ndarray,
                      values: np.ndarray, n_hashes: int = 2,
                      seed: int = 0) -> np.ndarray:
    """Row ids sorted lexicographically by shingle tuple, ties by id."""
    mh = min_hashes_csr(indptr, values, n_hashes, seed)
    keys = tuple(mh[:, i] for i in reversed(range(n_hashes))) + ()
    order = np.lexsort((row_ids,) + keys)
    return np.asarray(row_ids)[order]


def shingle_order(input_lists: dict[int, np.ndarray], n_hashes: int = 2,
                  seed: int = 0) -> list[int]:
    """Return reader ids sorted lexicographically by their shingle tuples."""
    if not input_lists:
        return []
    rids = np.fromiter(input_lists.keys(), dtype=np.int64,
                       count=len(input_lists))
    arrays = [np.asarray(input_lists[int(r)]) for r in rids]
    sizes = np.array([a.size for a in arrays], dtype=np.int64)
    indptr = np.zeros(rids.size + 1, dtype=np.int64)
    np.cumsum(sizes, out=indptr[1:])
    values = (np.concatenate(arrays) if indptr[-1]
              else np.zeros(0, dtype=np.int64))
    return [int(r) for r in shingle_order_csr(rids, indptr, values,
                                              n_hashes=n_hashes, seed=seed)]
