"""VNM-family overlay construction (paper §3.2.2–§3.2.4).

All four variants share one loop: shingle-order the readers, chunk them into
groups, FP-tree-mine each group for positive-benefit bicliques, replace each
biclique with a virtual (partial aggregation) node, and iterate on the rewritten
bipartite graph until no more benefit is found.

  vnm    — fixed chunk size (Buehrer & Chellapilla's algorithm, the baseline)
  vnm_a  — adaptive chunk-size schedule (§3.2.2)
  vnm_n  — negative / subtraction edges, quasi-bicliques (§3.2.3)
  vnm_d  — duplicate-insensitive overlays, overlapping groups + edge reuse (§3.2.4)
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.bipartite import Bipartite
from repro.core.fptree import FPTree, ReaderRecord
from repro.core.overlay import Overlay
from repro.core.shingles import shingle_order


@dataclasses.dataclass
class ConstructionStats:
    algorithm: str
    iterations: int = 0
    bicliques: int = 0
    seconds: float = 0.0
    si_per_iteration: list[float] = dataclasses.field(default_factory=list)
    chunk_sizes: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _State:
    records: dict[int, ReaderRecord]
    virtual_members: dict[int, list[tuple[int, int]]]  # virtual item -> [(item, +1)]
    next_item: int

    def current_edges(self) -> int:
        e = sum(len(m) for m in self.virtual_members.values())
        for rec in self.records.values():
            e += len(rec.active) + len(rec.frozen)
        return e


def _init_state(bip: Bipartite) -> _State:
    records = {
        r: ReaderRecord(reader=r, active=set(map(int, ins)), frozen=[], mined=set())
        for r, ins in bip.reader_inputs.items()
    }
    return _State(records=records, virtual_members={}, next_item=bip.n_base)


def _apply_biclique(state: _State, bic, group: list[ReaderRecord], mode: str) -> int:
    """Replace the mined biclique with a virtual node. Returns the number of
    readers that actually consume it (readers whose individual edge saving
    would be negative — possible with negative edges — are left untouched)."""
    items = set(bic.items)
    plan: list[tuple[ReaderRecord, set[int], list[int]]] = []
    for r in bic.readers:
        rec = state.records[r]
        covered = items & rec.active
        # Negatives for items the reader still held directly are duplicate-
        # compensation markers: this biclique covers them, so no subtraction
        # edge is needed; the rest are true subtraction edges.
        true_negs = [it for it in bic.neg_items.get(r, []) if it not in covered]
        if len(covered) - 1 - len(true_negs) < 0:
            continue  # this reader would lose edges; keep its direct edges
        plan.append((rec, covered, true_negs))
    if len(plan) < 2:
        return 0
    vid = state.next_item
    state.next_item += 1
    state.virtual_members[vid] = [(it, 1) for it in bic.items]
    for rec, covered, true_negs in plan:
        rec.active -= covered
        if mode == "dup":
            rec.mined |= covered
        for it in true_negs:
            rec.frozen.append((it, -1))
        rec.active.add(vid)
    return len(plan)


def _mine_group(state: _State, group: list[ReaderRecord], mode: str, k1: int, k2: int,
                benefit_hist: dict[int, int], max_bicliques: int = 64) -> int:
    found = 0
    for _ in range(max_bicliques):
        tree = FPTree(mode=mode, k1=k1, k2=k2)
        tree.build(group)
        bic = tree.mine_best()
        if bic is None:
            break
        consumers = _apply_biclique(state, bic, group, mode)
        if consumers == 0:
            break  # nothing changed; rebuilding would re-find the same biclique
        benefit_hist[len(bic.readers)] = benefit_hist.get(len(bic.readers), 0) + bic.benefit
        found += 1
    return found


def _chunk(readers: list[int], chunk_size: int, overlap_pct: float) -> list[list[int]]:
    if not readers:
        return []
    step = max(1, int(round(chunk_size * (1.0 - overlap_pct / 100.0))))
    groups = []
    i = 0
    while i < len(readers):
        g = readers[i : i + chunk_size]
        if len(g) >= 2:
            groups.append(g)
        if i + chunk_size >= len(readers):
            break
        i += step
    return groups or [readers]


def _adaptive_next_chunk(benefit_hist: dict[int, int], c_i: int, frac: float = 0.9,
                         c_min: int = 8) -> int:
    """c_{i+1} = smallest c <= c_i with sum_{s<=c} B_s > frac * sum_{s<=c_i} B_s (§3.2.2)."""
    total = sum(b for s, b in benefit_hist.items() if s <= c_i)
    if total <= 0:
        return c_i
    acc = 0
    for c in sorted(benefit_hist.keys()):
        acc += benefit_hist[c]
        if acc > frac * total:
            return max(c_min, min(c, c_i))
    return c_i


def _assemble(state: _State, bip: Bipartite, dup_insensitive: bool) -> Overlay:
    ov = Overlay(kinds=[], origin=[], in_edges=[], dup_insensitive=dup_insensitive)
    item_to_node: dict[int, int] = {}
    for w in bip.writers:
        item_to_node[int(w)] = ov.add_node("W", int(w))
    # virtual items were created in increasing id order; members only reference
    # earlier items, so a single ordered pass suffices.
    for vid in sorted(state.virtual_members.keys()):
        node = ov.add_node("I", -1)
        item_to_node[vid] = node
        for it, sign in state.virtual_members[vid]:
            ov.add_edge(item_to_node[it], node, sign)
    for r, rec in state.records.items():
        node = ov.add_node("R", int(r))
        for it in sorted(rec.active):
            ov.add_edge(item_to_node[it], node, 1)
        for it, sign in rec.frozen:
            ov.add_edge(item_to_node[it], node, sign)
    return ov


def construct_vnm(
    bip: Bipartite,
    *,
    variant: str = "vnm_a",
    chunk_size: int = 100,
    max_iterations: int = 10,
    k1: int = 2,
    k2: int = 5,
    overlap_pct: float = 25.0,
    adapt_frac: float = 0.9,
    seed: int = 0,
) -> tuple[Overlay, ConstructionStats]:
    assert variant in ("vnm", "vnm_a", "vnm_n", "vnm_d")
    mode = {"vnm": "basic", "vnm_a": "basic", "vnm_n": "neg", "vnm_d": "dup"}[variant]
    overlap = overlap_pct if variant == "vnm_d" else 0.0
    state = _init_state(bip)
    stats = ConstructionStats(algorithm=variant)
    base_edges = bip.n_edges
    t0 = time.perf_counter()
    c = chunk_size
    for it in range(max_iterations):
        active_lists = {
            r: np.array(sorted(rec.active), dtype=np.int64)
            for r, rec in state.records.items()
            if len(rec.active) >= 2
        }
        if not active_lists:
            break
        order = shingle_order(active_lists, seed=seed + it)
        groups = _chunk(order, c, overlap)
        benefit_hist: dict[int, int] = {}
        found = 0
        for g in groups:
            group_records = [state.records[r] for r in g]
            found += _mine_group(state, group_records, mode, k1, k2, benefit_hist)
        stats.iterations += 1
        stats.bicliques += found
        stats.chunk_sizes.append(c)
        stats.si_per_iteration.append(1.0 - state.current_edges() / max(1, base_edges))
        if found == 0:
            break
        if variant in ("vnm_a", "vnm_n", "vnm_d"):
            c = _adaptive_next_chunk(benefit_hist, c, frac=adapt_frac)
    stats.seconds = time.perf_counter() - t0
    overlay = _assemble(state, bip, dup_insensitive=(variant == "vnm_d")).pruned()
    return overlay, stats
