"""VNM-family overlay construction (paper §3.2.2–§3.2.4).

All four variants share one loop: shingle-order the readers, chunk them into
groups, FP-tree-mine each group for positive-benefit bicliques, replace each
biclique with a virtual (partial aggregation) node, and iterate on the rewritten
bipartite graph until no more benefit is found.

  vnm    — fixed chunk size (Buehrer & Chellapilla's algorithm, the baseline)
  vnm_a  — adaptive chunk-size schedule (§3.2.2)
  vnm_n  — negative / subtraction edges, quasi-bicliques (§3.2.3)
  vnm_d  — duplicate-insensitive overlays, overlapping groups + edge reuse (§3.2.4)

Two interchangeable engines drive the group mining:

  * the *vectorized* engine (default): per-reader item lists live in flat
    arrays, groups are mined by ``core.rowminer`` (rank-sorted rows, one
    lexicographic sort + LCP scan per round instead of a Python object tree),
    and the overlay is assembled from flat edge arrays. 'neg' mode keeps the
    object tree (per-reader path picking is sequential by nature) but still
    maintains it incrementally instead of rebuilding it per biclique.
  * the *reference* engine (``EAGR_CONSTRUCT_REFERENCE=1`` or
    ``reference=True``): the original object pipeline, kept as the parity
    oracle. Both engines implement identical semantics — frozen per-group item
    order, incremental detach/reinsert, canonical tie-breaks — and must
    produce bit-identical overlays (see tests/test_construct_vectorized.py).

Groups within an iteration share no state (for the non-overlapping variants),
so they could be fanned out to a process pool; the batched single-process loop
is used here because the group work is already array code and the dev/CI boxes
are single-core.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.bipartite import Bipartite
from repro.core.fptree import FPTree, ReaderRecord
from repro.core.overlay import Overlay, overlay_from_flat
from repro.core.rowminer import mine_rows
from repro.core.shingles import shingle_order_csr

PHASES = ("shingle", "chunk", "build", "mine", "apply", "assemble")


@dataclasses.dataclass
class ConstructionStats:
    algorithm: str
    iterations: int = 0
    bicliques: int = 0
    seconds: float = 0.0
    si_per_iteration: list[float] = dataclasses.field(default_factory=list)
    chunk_sizes: list[int] = dataclasses.field(default_factory=list)
    # wall-clock per construction phase (shingle/chunk/build/mine/apply/assemble)
    phase_seconds: dict[str, float] = dataclasses.field(default_factory=dict)


# =====================================================================
# shared helpers (both engines)
# =====================================================================

def _chunk(readers: list[int], chunk_size: int, overlap_pct: float) -> list[list[int]]:
    if not readers:
        return []
    step = max(1, int(round(chunk_size * (1.0 - overlap_pct / 100.0))))
    groups = []
    i = 0
    while i < len(readers):
        g = readers[i : i + chunk_size]
        if len(g) >= 2:
            groups.append(g)
        if i + chunk_size >= len(readers):
            break
        i += step
    return groups or [readers]


def _adaptive_next_chunk(benefit_hist: dict[int, int], c_i: int, frac: float = 0.9,
                         c_min: int = 8) -> int:
    """c_{i+1} = smallest c <= c_i with sum_{s<=c} B_s > frac * sum_{s<=c_i} B_s (§3.2.2)."""
    total = sum(b for s, b in benefit_hist.items() if s <= c_i)
    if total <= 0:
        return c_i
    acc = 0
    for c in sorted(benefit_hist.keys()):
        acc += benefit_hist[c]
        if acc > frac * total:
            return max(c_min, min(c, c_i))
    return c_i


def _shingle_order_of(lists: dict[int, np.ndarray], seed: int) -> list[int]:
    """Batched shingle ordering over a CSR view of the eligible readers."""
    rids = np.fromiter(lists.keys(), dtype=np.int64, count=len(lists))
    sizes = np.fromiter((lists[int(r)].size for r in rids), dtype=np.int64,
                        count=rids.size)
    indptr = np.zeros(rids.size + 1, dtype=np.int64)
    np.cumsum(sizes, out=indptr[1:])
    values = (np.concatenate([lists[int(r)] for r in rids]) if rids.size
              else np.zeros(0, dtype=np.int64))
    return [int(r) for r in shingle_order_csr(rids, indptr, values, seed=seed)]


# =====================================================================
# reference engine (object records + incremental FP-tree)
# =====================================================================

@dataclasses.dataclass
class _State:
    records: dict[int, ReaderRecord]
    virtual_members: dict[int, list[tuple[int, int]]]  # virtual item -> [(item, +1)]
    next_item: int
    n_active_edges: int = 0
    n_frozen_edges: int = 0
    n_virtual_edges: int = 0

    def current_edges(self) -> int:
        return self.n_active_edges + self.n_frozen_edges + self.n_virtual_edges


def _init_state(bip: Bipartite) -> _State:
    records = {
        r: ReaderRecord(reader=r, active=set(map(int, ins)), frozen=[], mined=set())
        for r, ins in bip.reader_inputs.items()
    }
    return _State(records=records, virtual_members={}, next_item=bip.n_base,
                  n_active_edges=sum(len(rec.active) for rec in records.values()))


def _apply_biclique(state: _State, bic, mode: str):
    """Replace the mined biclique with a virtual node. Returns the consumer
    records and the virtual item id, or ``([], None)`` when fewer than two
    readers would actually benefit (readers whose individual edge saving
    would be negative — possible with negative edges — are left untouched)."""
    items = set(bic.items)
    plan: list[tuple[ReaderRecord, set[int], list[int]]] = []
    for r in bic.readers:
        rec = state.records[r]
        covered = items & rec.active
        # Negatives for items the reader still held directly are duplicate-
        # compensation markers: this biclique covers them, so no subtraction
        # edge is needed; the rest are true subtraction edges.
        true_negs = [it for it in bic.neg_items.get(r, []) if it not in covered]
        if len(covered) - 1 - len(true_negs) < 0:
            continue  # this reader would lose edges; keep its direct edges
        plan.append((rec, covered, true_negs))
    if len(plan) < 2:
        return [], None
    vid = state.next_item
    state.next_item += 1
    state.virtual_members[vid] = [(it, 1) for it in bic.items]
    state.n_virtual_edges += len(bic.items)
    for rec, covered, true_negs in plan:
        rec.active -= covered
        if mode == "dup":
            rec.mined |= covered
        for it in true_negs:
            rec.frozen.append((it, -1))
        rec.active.add(vid)
        state.n_active_edges += 1 - len(covered)
        state.n_frozen_edges += len(true_negs)
    return [rec for rec, _, _ in plan], vid


def _mine_group_ref(state: _State, group: list[ReaderRecord], mode: str, k1: int,
                    k2: int, benefit_hist: dict[int, int],
                    phase: dict[str, float], max_bicliques: int = 64) -> int:
    t = time.perf_counter()
    tree = FPTree(mode=mode, k1=k1, k2=k2)
    tree.build(group)
    phase["build"] += time.perf_counter() - t
    found = 0
    while found < max_bicliques:
        t = time.perf_counter()
        bic = tree.mine_best()
        phase["mine"] += time.perf_counter() - t
        if bic is None:
            break
        t = time.perf_counter()
        touched, vid = _apply_biclique(state, bic, mode)
        if vid is None:
            phase["apply"] += time.perf_counter() - t
            break  # nothing changed; mining again would re-find the same biclique
        tree.register_item(vid)
        for rec in touched:
            tree.detach(rec)
        for rec in touched:
            tree.reinsert(rec)
        phase["apply"] += time.perf_counter() - t
        benefit_hist[len(bic.readers)] = benefit_hist.get(len(bic.readers), 0) + bic.benefit
        found += 1
    return found


def _assemble(state: _State, bip: Bipartite, dup_insensitive: bool) -> Overlay:
    ov = Overlay(kinds=[], origin=[], in_edges=[], dup_insensitive=dup_insensitive)
    item_to_node: dict[int, int] = {}
    for w in bip.writers:
        item_to_node[int(w)] = ov.add_node("W", int(w))
    # virtual items were created in increasing id order; members only reference
    # earlier items, so a single ordered pass suffices.
    for vid in sorted(state.virtual_members.keys()):
        node = ov.add_node("I", -1)
        item_to_node[vid] = node
        for it, sign in state.virtual_members[vid]:
            ov.add_edge(item_to_node[it], node, sign)
    for r, rec in state.records.items():
        node = ov.add_node("R", int(r))
        for it in sorted(rec.active):
            ov.add_edge(item_to_node[it], node, 1)
        for it, sign in rec.frozen:
            ov.add_edge(item_to_node[it], node, sign)
    return ov


def _construct_ref(bip: Bipartite, variant: str, mode: str, chunk_size: int,
                   max_iterations: int, k1: int, k2: int, overlap: float,
                   adapt_frac: float, seed: int,
                   stats: ConstructionStats) -> Overlay:
    state = _init_state(bip)
    base_edges = bip.n_edges
    phase = stats.phase_seconds
    c = chunk_size
    for it in range(max_iterations):
        t = time.perf_counter()
        active_lists = {
            r: np.array(sorted(rec.active), dtype=np.int64)
            for r, rec in state.records.items()
            if len(rec.active) >= 2
        }
        if not active_lists:
            break
        order = _shingle_order_of(active_lists, seed + it)
        phase["shingle"] += time.perf_counter() - t
        t = time.perf_counter()
        groups = _chunk(order, c, overlap)
        phase["chunk"] += time.perf_counter() - t
        benefit_hist: dict[int, int] = {}
        found = 0
        for g in groups:
            group_records = [state.records[r] for r in g]
            found += _mine_group_ref(state, group_records, mode, k1, k2,
                                     benefit_hist, phase)
        stats.iterations += 1
        stats.bicliques += found
        stats.chunk_sizes.append(c)
        stats.si_per_iteration.append(1.0 - state.current_edges() / max(1, base_edges))
        if found == 0:
            break
        if variant in ("vnm_a", "vnm_n", "vnm_d"):
            c = _adaptive_next_chunk(benefit_hist, c, frac=adapt_frac)
    t = time.perf_counter()
    overlay = _assemble(state, bip, dup_insensitive=(variant == "vnm_d")).pruned()
    phase["assemble"] += time.perf_counter() - t
    return overlay


# =====================================================================
# vectorized engine ('basic'/'dup' modes)
# =====================================================================

@dataclasses.dataclass
class _ArrayState:
    active: dict[int, np.ndarray]           # reader -> sorted item ids
    mined: dict[int, np.ndarray]            # 'dup' only; disjoint from active
    virtual_members: dict[int, np.ndarray]  # vid -> item ids in path order
    next_item: int
    n_active_edges: int = 0
    n_virtual_edges: int = 0

    def current_edges(self) -> int:
        return self.n_active_edges + self.n_virtual_edges


_EMPTY = np.zeros(0, dtype=np.int64)


def _init_array_state(bip: Bipartite) -> _ArrayState:
    active = {int(r): np.array(ins, dtype=np.int64)
              for r, ins in bip.reader_inputs.items()}
    return _ArrayState(active=active,
                       mined={r: _EMPTY for r in active},
                       virtual_members={}, next_item=bip.n_base,
                       n_active_edges=sum(a.size for a in active.values()))


def _mine_group_fast(st: _ArrayState, group: list[int], dup: bool,
                     benefit_hist: dict[int, int], phase: dict[str, float],
                     max_bicliques: int = 64) -> int:
    t = time.perf_counter()
    # frozen group item order: rank by (-frequency, item id) over insert lists
    if dup:
        per_reader = [np.concatenate([st.active[r], st.mined[r]]) for r in group]
    else:
        per_reader = [st.active[r] for r in group]
    uniq, counts = np.unique(np.concatenate(per_reader), return_counts=True)
    by_freq = np.argsort(-counts, kind="stable")  # uniq ascending -> ties by id
    rank_of = np.empty(uniq.size, dtype=np.int64)
    rank_of[by_freq] = np.arange(uniq.size)
    item_of = uniq[by_freq]

    rows: list[np.ndarray] = []
    flags: list[np.ndarray] | None = [] if dup else None
    for i, r in enumerate(group):
        ranks = rank_of[np.searchsorted(uniq, per_reader[i])]
        if dup:
            fl = np.zeros(ranks.size, dtype=bool)
            fl[st.active[r].size:] = True
            p = np.argsort(ranks, kind="stable")
            rows.append(ranks[p])
            flags.append(fl[p])
        else:
            rows.append(np.sort(ranks))
    phase["build"] += time.perf_counter() - t

    t = time.perf_counter()
    bics = mine_rows(rows, flags, dup, n_ranks=uniq.size,
                     max_bicliques=max_bicliques)
    phase["mine"] += time.perf_counter() - t

    t = time.perf_counter()
    changed: set[int] = set()
    new_vids = []
    for b in bics:
        new_vids.append(st.next_item)
        st.next_item += 1
        benefit_hist[b.support] = benefit_hist.get(b.support, 0) + b.benefit
        changed.update(int(i) for i in b.consumers)
    item_of_ext = np.concatenate([item_of, np.array(new_vids, dtype=np.int64)]) \
        if new_vids else item_of
    for vid, b in zip(new_vids, bics):
        members = item_of_ext[b.path]
        st.virtual_members[vid] = members
        st.n_virtual_edges += members.size
    for i in changed:
        r = group[i]
        ids = item_of_ext[rows[i]]
        if dup:
            fl = flags[i]
            n_act = int(rows[i].size - fl.sum())
            st.n_active_edges += n_act - st.active[r].size
            st.active[r] = np.sort(ids[~fl])
            st.mined[r] = np.sort(ids[fl])
        else:
            st.n_active_edges += rows[i].size - st.active[r].size
            st.active[r] = np.sort(ids)
    phase["apply"] += time.perf_counter() - t
    return len(bics)


def _assemble_fast(st: _ArrayState, bip: Bipartite, dup_insensitive: bool) -> Overlay:
    """Flat-array assembly + pruning: node order and per-node edge order are
    identical to ``_assemble(...).pruned()``."""
    writers = np.asarray(bip.writers, dtype=np.int64)
    n_w = writers.size
    vids = np.array(sorted(st.virtual_members), dtype=np.int64)
    n_v = vids.size
    readers = np.fromiter(st.active.keys(), dtype=np.int64, count=len(st.active))
    n_r = readers.size
    n_nodes = n_w + n_v + n_r

    def node_of(items: np.ndarray) -> np.ndarray:
        is_w = items < bip.n_base
        out = np.empty(items.size, dtype=np.int64)
        out[is_w] = np.searchsorted(writers, items[is_w])
        out[~is_w] = n_w + np.searchsorted(vids, items[~is_w])
        return out

    member_lists = [st.virtual_members[int(v)] for v in vids]
    active_lists = [st.active[int(r)] for r in readers]
    v_counts = np.array([m.size for m in member_lists], dtype=np.int64)
    r_counts = np.array([a.size for a in active_lists], dtype=np.int64)
    # edges generated grouped by destination node in ascending order, matching
    # the add_edge order of the object assembler
    dst = np.repeat(np.arange(n_nodes, dtype=np.int64)[n_w:],
                    np.concatenate([v_counts, r_counts]))
    src_items = (np.concatenate(member_lists + active_lists)
                 if member_lists or active_lists else _EMPTY)
    src = node_of(src_items)

    kinds = np.concatenate([np.full(n_w, "W"), np.full(n_v, "I"),
                            np.full(n_r, "R")])
    origin = np.concatenate([writers, np.full(n_v, -1, dtype=np.int64), readers])

    # prune: drop W/I nodes with no path to any reader (reverse reachability,
    # one pass per overlay level)
    useful = np.zeros(n_nodes, dtype=bool)
    useful[n_w + n_v:] = True
    while True:
        grow = src[useful[dst] & ~useful[src]]
        if grow.size == 0:
            break
        useful[grow] = True

    remap = np.cumsum(useful) - 1
    keep = useful[dst]  # src of a useful dst is useful by propagation
    src_k = remap[src[keep]].tolist()
    dst_k = remap[dst[keep]]
    n_new = int(useful.sum())
    counts = np.bincount(dst_k, minlength=n_new)
    indptr = np.zeros(n_new + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return overlay_from_flat(
        kinds=kinds[useful].tolist(),
        origin=origin[useful].tolist(),
        src=src_k,
        signs=None,  # 'basic'/'dup' never emit negative edges
        indptr=indptr,
        dup_insensitive=dup_insensitive,
    )


def _construct_fast(bip: Bipartite, variant: str, mode: str, chunk_size: int,
                    max_iterations: int, overlap: float, adapt_frac: float,
                    seed: int, stats: ConstructionStats) -> Overlay:
    dup = mode == "dup"
    st = _init_array_state(bip)
    base_edges = bip.n_edges
    phase = stats.phase_seconds
    c = chunk_size
    for it in range(max_iterations):
        t = time.perf_counter()
        active_lists = {r: a for r, a in st.active.items() if a.size >= 2}
        if not active_lists:
            break
        order = _shingle_order_of(active_lists, seed + it)
        phase["shingle"] += time.perf_counter() - t
        t = time.perf_counter()
        groups = _chunk(order, c, overlap)
        phase["chunk"] += time.perf_counter() - t
        benefit_hist: dict[int, int] = {}
        found = 0
        for g in groups:
            found += _mine_group_fast(st, g, dup, benefit_hist, phase)
        stats.iterations += 1
        stats.bicliques += found
        stats.chunk_sizes.append(c)
        stats.si_per_iteration.append(1.0 - st.current_edges() / max(1, base_edges))
        if found == 0:
            break
        if variant in ("vnm_a", "vnm_n", "vnm_d"):
            c = _adaptive_next_chunk(benefit_hist, c, frac=adapt_frac)
    t = time.perf_counter()
    overlay = _assemble_fast(st, bip, dup_insensitive=(variant == "vnm_d"))
    phase["assemble"] += time.perf_counter() - t
    return overlay


# =====================================================================
# front door
# =====================================================================

def construct_vnm(
    bip: Bipartite,
    *,
    variant: str = "vnm_a",
    chunk_size: int = 100,
    max_iterations: int = 10,
    k1: int = 2,
    k2: int = 5,
    overlap_pct: float = 25.0,
    adapt_frac: float = 0.9,
    seed: int = 0,
    reference: bool | None = None,
) -> tuple[Overlay, ConstructionStats]:
    """Construct a VNM-family overlay.

    ``reference=True`` (or ``EAGR_CONSTRUCT_REFERENCE=1``) forces the original
    object-based pipeline; the default vectorized engine produces a
    bit-identical overlay. 'neg' mode always runs on the (incrementally
    maintained) object tree — see the module docstring.
    """
    assert variant in ("vnm", "vnm_a", "vnm_n", "vnm_d")
    if reference is None:
        reference = os.environ.get("EAGR_CONSTRUCT_REFERENCE", "") not in ("", "0")
    mode = {"vnm": "basic", "vnm_a": "basic", "vnm_n": "neg", "vnm_d": "dup"}[variant]
    overlap = overlap_pct if variant == "vnm_d" else 0.0
    stats = ConstructionStats(algorithm=variant,
                              phase_seconds={p: 0.0 for p in PHASES})
    t0 = time.perf_counter()
    if reference or mode == "neg":
        overlay = _construct_ref(bip, variant, mode, chunk_size, max_iterations,
                                 k1, k2, overlap, adapt_frac, seed, stats)
    else:
        overlay = _construct_fast(bip, variant, mode, chunk_size, max_iterations,
                                  overlap, adapt_frac, seed, stats)
    stats.seconds = time.perf_counter() - t0
    return overlay, stats
