"""Sliding windows over per-writer content streams (paper §2.1).

Tuple-based (last ``c`` updates) and time-based (last ``T`` time units)
windows, stored as fixed-capacity ring buffers so the whole writer state is
three dense arrays — jit-able and shardable:

  values (n_writers, cap)   raw written values (NaN-free; ``count`` masks)
  stamps (n_writers, cap)   arrival timestamps (time windows only)
  head   (n_writers,)       next write slot
  count  (n_writers,)       number of live entries (<= cap)

``window_pao`` evaluates the aggregate over each writer's current window —
used to (re)compute writer PAOs; ``push_writes`` returns the per-writer PAO
*delta* for invertible aggregates (new lift minus evicted lift).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate


class WindowState(NamedTuple):
    values: jnp.ndarray   # (n_writers, cap) fp32
    stamps: jnp.ndarray   # (n_writers, cap) fp32
    head: jnp.ndarray     # (n_writers,) int32
    count: jnp.ndarray    # (n_writers,) int32


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    kind: str = "tuple"      # 'tuple' | 'time'
    size: float = 1          # c for tuple windows, T for time windows
    capacity: int = 0        # ring capacity; defaults to c (tuple) / provided (time)
    value_dim: int = 1       # raw values per write: scalar (1) or vector (>1)

    @property
    def cap(self) -> int:
        if self.capacity:
            return int(self.capacity)
        if self.kind == "tuple":
            return max(1, int(self.size))
        raise ValueError("time windows need an explicit ring capacity")


def _vshape(cond: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (B,)-shaped condition against values with trailing dims."""
    return cond.reshape(cond.shape + (1,) * (like.ndim - cond.ndim))


def init_windows(n_writers: int, spec: WindowSpec) -> WindowState:
    cap = spec.cap
    vshape = (n_writers, cap) if spec.value_dim == 1 else (
        n_writers, cap, spec.value_dim)
    return WindowState(
        values=jnp.zeros(vshape, dtype=jnp.float32),
        stamps=jnp.full((n_writers, cap), -jnp.inf, dtype=jnp.float32),
        head=jnp.zeros((n_writers,), dtype=jnp.int32),
        count=jnp.zeros((n_writers,), dtype=jnp.int32),
    )


def apply_writes_scan(
    state: WindowState,
    spec: WindowSpec,
    writer_rows: jnp.ndarray,   # (B,) int32 rows into the window arrays
    values: jnp.ndarray,        # (B,) fp32
    stamps: jnp.ndarray,        # (B,) fp32
    mask: jnp.ndarray,          # (B,) bool — padding lanes are False
) -> tuple[WindowState, jnp.ndarray, jnp.ndarray]:
    """Event-at-a-time reference implementation (a scan over the batch).
    Semantics oracle for apply_writes; O(batch) sequential steps."""
    cap = spec.cap

    def step(carry, inp):
        vals, stms, head, cnt = carry
        row, v, t, m = inp
        slot = head[row]
        evicted = vals[row, slot]
        evicted_valid = m & (cnt[row] >= cap)
        vals = vals.at[row, slot].set(jnp.where(m, v, vals[row, slot]))
        stms = stms.at[row, slot].set(jnp.where(m, t, stms[row, slot]))
        head = head.at[row].set(jnp.where(m, (slot + 1) % cap, slot))
        cnt = cnt.at[row].set(jnp.where(m, jnp.minimum(cnt[row] + 1, cap), cnt[row]))
        return (vals, stms, head, cnt), (jnp.where(evicted_valid, evicted, 0.0), evicted_valid)

    (vals, stms, head, cnt), (evicted, evicted_valid) = jax.lax.scan(
        step,
        (state.values, state.stamps, state.head, state.count),
        (writer_rows.astype(jnp.int32), values.astype(jnp.float32),
         stamps.astype(jnp.float32), mask),
    )
    return WindowState(vals, stms, head, cnt), evicted, evicted_valid


def apply_writes(
    state: WindowState,
    spec: WindowSpec,
    writer_rows: jnp.ndarray,
    values: jnp.ndarray,
    stamps: jnp.ndarray,
    mask: jnp.ndarray,
) -> tuple[WindowState, jnp.ndarray, jnp.ndarray]:
    """Vectorized batch append with event-at-a-time semantics.

    The naive implementation scans the batch (duplicate writers must append
    in order) — measured 138 ev/s end-to-end because every event is a
    sequential dependency. This version sorts the batch by row, computes each
    write's rank within its row group, and derives ring slots and evictions
    in closed form (no sequential dependency):

      slot_i     = (head[row] + rank_i) % cap
      evicted_i  = ring[row, slot_i]          if rank_i <  cap
                   in-batch value rank_i-cap  if rank_i >= cap  (wrapped)
      valid_i    = count[row] + rank_i >= cap
      final ring = last-wins scatter of lanes with rank >= k_row - cap

    Verified equivalent to apply_writes_scan by hypothesis property tests.
    """
    cap = spec.cap
    B = writer_rows.shape[0]
    n_rows = state.values.shape[0]
    rows = writer_rows.astype(jnp.int32)
    vals_in = values.astype(jnp.float32)
    stamps_in = stamps.astype(jnp.float32)

    key = jnp.where(mask, rows, n_rows)            # masked lanes sort last
    order = jnp.argsort(key, stable=True)
    r_s = key[order]
    v_s = vals_in[order]
    t_s = stamps_in[order]
    m_s = mask[order]

    start = jnp.searchsorted(r_s, r_s, side="left")
    rank = jnp.arange(B, dtype=jnp.int32) - start.astype(jnp.int32)

    r_safe = jnp.where(m_s, r_s, 0)
    head_r = state.head[r_safe]
    count_r = state.count[r_safe]
    slot = (head_r + rank) % cap

    # ------------------------------------------------------------ evictions
    ring_evict = state.values[r_safe, slot]
    wrapped = rank >= cap
    # in-batch predecessor (same row, rank - cap); index i - cap is in range
    prev_idx = jnp.maximum(jnp.arange(B) - cap, 0)
    batch_evict = v_s[prev_idx]
    evicted_s = jnp.where(_vshape(wrapped, ring_evict), batch_evict, ring_evict)
    evicted_valid_s = m_s & (count_r + rank >= cap)
    evicted_s = jnp.where(_vshape(evicted_valid_s, evicted_s), evicted_s, 0.0)
    # back to original batch order
    inv = jnp.zeros(B, jnp.int32).at[order].set(jnp.arange(B, dtype=jnp.int32))
    evicted = evicted_s[inv]
    evicted_valid = evicted_valid_s[inv]

    # ------------------------------------------------------- final ring state
    k_row = jnp.zeros(n_rows + 1, jnp.int32).at[r_safe].max(
        jnp.where(m_s, rank + 1, 0))
    keep = m_s & (rank >= k_row[r_safe] - cap)      # last cap writes per row
    scatter_row = jnp.where(keep, r_safe, n_rows)   # sentinel row absorbs rest
    pad_vals = jnp.concatenate([state.values,
                                jnp.zeros((1,) + state.values.shape[1:],
                                          jnp.float32)])
    pad_stms = jnp.concatenate([state.stamps,
                                jnp.full((1, cap), -jnp.inf, jnp.float32)])
    new_vals = pad_vals.at[scatter_row, slot].set(v_s, mode="drop")[:n_rows]
    new_stms = pad_stms.at[scatter_row, slot].set(t_s, mode="drop")[:n_rows]
    new_head = (state.head + k_row[:n_rows]) % cap
    new_count = jnp.minimum(state.count + k_row[:n_rows], cap)
    return (WindowState(new_vals, new_stms, new_head, new_count),
            evicted, evicted_valid)


def stale_rows(state: WindowState, spec: WindowSpec,
               prev_now: jnp.ndarray | float,
               now: jnp.ndarray | float) -> jnp.ndarray:
    """(n_writers,) bool — rows holding an entry that was inside the time
    window at ``prev_now`` but has expired by ``now``. The union of these
    rows with the written rows is exactly the set whose window aggregate can
    have changed between two evaluations — the non-invertible write path
    restricts its recompute to that set instead of every writer."""
    if spec.kind != "time":
        return jnp.zeros((state.stamps.shape[0],), bool)
    lo = jnp.asarray(prev_now, jnp.float32) - spec.size
    hi = jnp.asarray(now, jnp.float32) - spec.size
    return ((state.stamps >= lo) & (state.stamps < hi)).any(axis=1)


def pad_window_rows(state: WindowState, n_rows: int) -> WindowState:
    """Resize the window arrays to ``n_rows`` writer rows (state migration
    when a plan recompile changes writer capacity). Existing rows keep their
    ids — writer rows are append-only under churn — new rows start empty, and
    shrinking only ever drops never-written padding rows."""
    cur = state.values.shape[0]
    if cur == n_rows:
        return state
    if cur > n_rows:
        return WindowState(values=state.values[:n_rows],
                           stamps=state.stamps[:n_rows],
                           head=state.head[:n_rows],
                           count=state.count[:n_rows])
    pad = n_rows - cur
    return WindowState(
        values=jnp.concatenate(
            [state.values,
             jnp.zeros((pad,) + state.values.shape[1:], jnp.float32)]),
        stamps=jnp.concatenate(
            [state.stamps,
             jnp.full((pad,) + state.stamps.shape[1:], -jnp.inf, jnp.float32)]),
        head=jnp.concatenate([state.head, jnp.zeros((pad,), jnp.int32)]),
        count=jnp.concatenate([state.count, jnp.zeros((pad,), jnp.int32)]),
    )


def reset_window_rows(state: WindowState, rows) -> WindowState:
    """Zero the given writer rows (retired writers: their content leaves every
    window immediately, per §3.3 node deletion)."""
    # explicit placement: the structural-patch path asserts zero *implicit*
    # host->device transfers (jax.transfer_guard) during in-capacity churn
    rows = jax.device_put(np.asarray(rows, dtype=np.int32))
    return WindowState(
        values=state.values.at[rows].set(0.0),
        stamps=state.stamps.at[rows].set(-jnp.inf),
        head=state.head.at[rows].set(0),
        count=state.count.at[rows].set(0),
    )


# ----------------------------------------------------------------- shard axis
# Stacked (SPMD) execution keeps every shard's window state in one pytree
# with a leading shard axis: values (S, n_writers, cap[, value_dim]), etc.
# The per-shard helpers above all operate on axis 0 = writer rows, so a
# stacked state is just the same NamedTuple vmapped/shard_mapped over axis 0.
def window_state_to_host(state: WindowState) -> dict:
    """One writer window ring as a ``{field: numpy}`` dict (checkpoint
    codec). Values travel verbatim — restore is bit-identical, including
    ring head positions and partial occupancy."""
    return {f: np.asarray(jax.device_get(x))
            for f, x in zip(WindowState._fields, state)}


def window_state_from_host(arrs: dict) -> WindowState:
    return WindowState(*(jax.device_put(np.ascontiguousarray(arrs[f]))
                         for f in WindowState._fields))


def take_window_rows(arrs: dict, rows) -> dict:
    """Host-side row gather of a window snapshot: output row i is input row
    ``rows[i]`` (or an all-zero ring for ``rows[i] < 0`` — a padding/fresh
    writer row). This is the reshard redistribution primitive: write
    replication keeps a writer's ring identical across every shard that owns
    it, so any N-shard layout reassembles into any M-shard layout by base
    id."""
    idx = np.asarray(rows, np.int64).reshape(-1)
    live = idx >= 0
    out = {}
    for f in WindowState._fields:
        src = np.asarray(arrs[f])
        # fresh rows match init_windows: empty slots carry stamp -inf, so a
        # gathered dead row is indistinguishable from a never-written one
        fill = -np.inf if f == "stamps" else 0
        dst = np.full((len(idx),) + src.shape[1:], fill, src.dtype)
        dst[live] = src[idx[live]]
        out[f] = dst
    return out


def stack_windows(states: list[WindowState]) -> WindowState:
    """Stack aligned per-shard window states along a new leading shard axis."""
    shapes = {tuple(x.shape for x in s) for s in states}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack misaligned window states: {shapes}")
    return WindowState(*[jnp.stack(xs) for xs in zip(*states)])


def window_shard(state: WindowState, s: int) -> WindowState:
    """One shard's slice of a stacked window state."""
    return WindowState(*[x[s] for x in state])


def place_window_shard(state: WindowState, s: int,
                       sub: WindowState) -> WindowState:
    """Write one shard's (migrated) window state back into the stack."""
    return WindowState(*[st.at[s].set(x) for st, x in zip(state, sub)])


def live_mask(state: WindowState, spec: WindowSpec, now: jnp.ndarray | float) -> jnp.ndarray:
    """(n_writers, cap) bool — which ring slots are inside the window."""
    cap = spec.cap
    slot = jnp.arange(cap, dtype=jnp.int32)[None, :]
    # slot age: 0 = most recent. head points at the *next* slot to write.
    age = (state.head[:, None] - 1 - slot) % cap
    occupied = age < state.count[:, None]
    if spec.kind == "tuple":
        return occupied & (age < int(spec.size))
    return occupied & (state.stamps >= (jnp.asarray(now, jnp.float32) - spec.size))


def window_pao(state: WindowState, spec: WindowSpec, agg: Aggregate,
               now: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Evaluate ``agg`` over every writer's current window -> (n_writers, pao_dim)."""
    m = live_mask(state, spec, now)
    n, cap = state.values.shape[:2]
    raw = state.values.reshape(n * cap, -1)
    if raw.shape[1] == 1:
        raw = raw[:, 0]  # scalar aggregates keep their (B,) lift contract
    lifted = agg.lift(raw).reshape(n, cap, agg.pao_dim)
    neutral = jnp.full_like(lifted, agg.identity)
    lifted = jnp.where(m[:, :, None], lifted, neutral)
    if agg.combine == "sum":
        return lifted.sum(axis=1)
    return lifted.max(axis=1) if agg.combine == "max" else lifted.min(axis=1)
