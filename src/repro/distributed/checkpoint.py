"""Sharded checkpointing with atomic manifests and restore-time resharding.

Design (what a 1000-node deployment needs):
  * every host writes only its addressable shards (here: the single-process
    case degenerates to one writer, but the layout is per-shard files keyed
    by (param path, shard index) so multi-host writes never collide),
  * a two-phase commit: shards land in ``step_NNN.tmp/``, the manifest (tree
    structure, shapes, dtypes, mesh, sharding specs, step) is written last
    and the directory atomically renamed — a crash mid-write never corrupts
    the latest checkpoint,
  * async save: the host-side serialization runs on a background thread over
    a snapshot (jax.device_get) taken synchronously — training continues,
  * restore-with-resharding: the target mesh/sharding may differ from the
    save-time one (elastic scaling); shards are reassembled to full arrays
    host-side and re-dispatched with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, *, blocking: bool = True,
             extra: dict | None = None) -> None:
        """Snapshot synchronously, serialize (a)synchronously, commit atomically."""
        flat, _ = _flat_with_paths(state)
        snapshot = [(p, np.asarray(jax.device_get(x))) for p, x in flat]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "time": time.time(),
                        "extra": extra or {}, "arrays": {}}
            for i, (p, arr) in enumerate(snapshot):
                fname = f"arr_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["arrays"][p] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``state_like``. ``shardings``
        (optional pytree of NamedSharding) re-shards onto the CURRENT mesh —
        which may differ from the save-time mesh (elastic restart)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flat_with_paths(state_like)
        arrays = []
        sh_flat = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat))
        for (p, like), sh in zip(flat, sh_flat):
            meta = manifest["arrays"].get(p)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing array {p}")
            arr = np.load(os.path.join(d, meta["file"]))
            want = tuple(getattr(like, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{p}: checkpoint shape {arr.shape} != {want}")
            if sh is not None:
                arrays.append(jax.device_put(arr, sh))
            else:
                arrays.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, [a for a in arrays]), manifest
