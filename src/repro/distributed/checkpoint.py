"""Sharded checkpointing with atomic manifests and restore-time resharding.

Design (what a 1000-node deployment needs):
  * every host writes only its addressable shards (here: the single-process
    case degenerates to one writer, but the layout is per-shard files keyed
    by (param path, shard index) so multi-host writes never collide),
  * a two-phase commit: shards land in ``step_NNN.tmp/``, the manifest (tree
    structure, shapes, dtypes, mesh, sharding specs, step) is written last
    and the directory atomically renamed — a crash mid-write never corrupts
    the latest checkpoint,
  * async save: the host-side serialization runs on a background thread over
    a snapshot (jax.device_get) taken synchronously — training continues,
  * restore-with-resharding: the target mesh/sharding may differ from the
    save-time one (elastic scaling); shards are reassembled to full arrays
    host-side and re-dispatched with the new sharding.

Durable sessions (PR 9) build the EAGr-specific codec on the same writer:
:func:`snapshot_session` flattens a live ``EagrSession`` — per-group
``PlanMeta``/``PlanArrays``, window rings, PAOs, ``BaseRoutes`` id maps, the
master ``DynamicOverlay``'s structural state and the event-stream sequence
number — into a named-array payload, and :func:`restore_session` rebuilds a
session whose reads are bit-identical to the saved one without re-running
construction or plan compilation. Restore may also *reshard*: the payload
keeps the master overlay and the global push/pull decisions, so an N-shard
save restacks into any M-shard (or single-engine) layout by base id — write
replication keeps a writer's window ring identical on every owning shard,
which is exactly what makes the rings reassemblable.

Crash injection for the fault tests: ``EAGR_CKPT_CRASH=arrays`` kills the
process (``os._exit``) after the array files are written but before the
manifest; ``EAGR_CKPT_CRASH=manifest`` kills it after the manifest lands in
the ``.tmp`` directory but before the atomic rename. Either way the latest
*committed* checkpoint stays restorable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat], treedef


def _crash_point(stage: str) -> None:
    """Fault-injection seam: die *here* when EAGR_CKPT_CRASH names this
    write-path stage. ``os._exit`` (not an exception) — the recovery claim
    is about a process that vanished mid-write, not one that unwound."""
    if os.environ.get("EAGR_CKPT_CRASH") == stage:
        os._exit(17)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, *, blocking: bool = True,
             extra: dict | None = None) -> None:
        """Snapshot synchronously, serialize (a)synchronously, commit atomically."""
        flat, _ = _flat_with_paths(state)
        snapshot = [(p, np.asarray(jax.device_get(x))) for p, x in flat]
        self._launch(step, snapshot, extra or {}, blocking)

    def save_payload(self, step: int, arrays: dict, objs: dict | None = None,
                     *, blocking: bool = False) -> None:
        """Commit a named-array payload (``{key: numpy array}``) plus a
        JSON-safe object dict (rides in the manifest's ``extra``) through
        the same two-phase writer. Arrays are expected host-side already —
        the caller took its ``device_get`` snapshot — so the async thread
        only does file IO."""
        snapshot = [(k, np.asarray(v)) for k, v in arrays.items()]
        self._launch(step, snapshot, objs or {}, blocking)

    def _launch(self, step: int, snapshot: list, extra: dict,
                blocking: bool) -> None:
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "time": time.time(),
                        "extra": extra, "arrays": {}}
            for i, (p, arr) in enumerate(snapshot):
                fname = f"arr_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["arrays"][p] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            _crash_point("arrays")
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            _crash_point("manifest")
            os.replace(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``state_like``. ``shardings``
        (optional pytree of NamedSharding) re-shards onto the CURRENT mesh —
        which may differ from the save-time mesh (elastic restart)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flat_with_paths(state_like)
        arrays = []
        sh_flat = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat))
        for (p, like), sh in zip(flat, sh_flat):
            meta = manifest["arrays"].get(p)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing array {p}")
            arr = np.load(os.path.join(d, meta["file"]))
            want = tuple(getattr(like, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{p}: checkpoint shape {arr.shape} != {want}")
            if sh is not None:
                arrays.append(jax.device_put(arr, sh))
            else:
                arrays.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, [a for a in arrays]), manifest

    def restore_payload(self, step: int | None = None
                        ) -> tuple[dict, dict, int]:
        """Load a :meth:`save_payload` checkpoint back as
        ``(arrays, objs, step)``."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {p: np.load(os.path.join(d, meta["file"]))
                  for p, meta in manifest["arrays"].items()}
        return arrays, manifest.get("extra", {}), int(manifest["step"])


# ======================================================================
# EagrSession codec
# ======================================================================
_KIND_U8 = {"W": 0, "I": 1, "R": 2}
_U8_KIND = np.array(["W", "I", "R"])


def _overlay_to_arrays(ov, prefix: str) -> dict:
    """One overlay as four flat arrays: kinds (uint8), origin, and the
    in-edge CSR with signs. Node ids are positional — exactly the id space
    the compiled plan and the patch path live in."""
    n = ov.n_nodes
    counts = np.fromiter((len(e) for e in ov.in_edges), np.int64, n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    src = np.empty(int(indptr[-1]), np.int64)
    sign = np.empty(int(indptr[-1]), np.int8)
    k = 0
    for edges in ov.in_edges:
        for s, sg in edges:
            src[k] = s
            sign[k] = sg
            k += 1
    return {
        f"{prefix}kinds": np.fromiter(
            (_KIND_U8[x] for x in ov.kinds), np.uint8, n),
        f"{prefix}origin": np.asarray(ov.origin, np.int64),
        f"{prefix}indptr": indptr,
        f"{prefix}src": src,
        f"{prefix}sign": sign,
    }


def _overlay_from_arrays(arrays: dict, prefix: str, dup: bool):
    from repro.core.overlay import Overlay

    kinds = _U8_KIND[arrays[f"{prefix}kinds"]].tolist()
    origin = arrays[f"{prefix}origin"].tolist()
    indptr = arrays[f"{prefix}indptr"]
    pairs = np.stack([arrays[f"{prefix}src"].astype(np.int64),
                      arrays[f"{prefix}sign"].astype(np.int64)],
                     axis=1).tolist() if len(arrays[f"{prefix}src"]) else []
    in_edges = [[tuple(p) for p in pairs[indptr[v]: indptr[v + 1]]]
                for v in range(len(kinds))]
    return Overlay(kinds=kinds, origin=origin, in_edges=in_edges,
                   dup_insensitive=bool(dup))


def _sets_to_arrays(d: dict, prefix: str) -> dict:
    """A ``{base id: set of base ids}`` map as a keyed CSR (keys sorted,
    values sorted within each key — deterministic bytes for equal state)."""
    keys = np.array(sorted(d), np.int64)
    counts = np.fromiter((len(d[int(k)]) for k in keys), np.int64, len(keys))
    indptr = np.zeros(len(keys) + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    vals = np.empty(int(indptr[-1]), np.int64)
    for i, k in enumerate(keys):
        vals[indptr[i]: indptr[i + 1]] = sorted(d[int(k)])
    return {f"{prefix}keys": keys, f"{prefix}indptr": indptr,
            f"{prefix}vals": vals}


def _sets_from_arrays(arrays: dict, prefix: str) -> dict:
    keys = arrays[f"{prefix}keys"]
    indptr = arrays[f"{prefix}indptr"]
    vals = arrays[f"{prefix}vals"]
    return {int(k): set(vals[indptr[i]: indptr[i + 1]].tolist())
            for i, k in enumerate(keys)}


def scrub_dead_writers(dyn, live_writers: set) -> None:
    """Repair a ``DynamicOverlay`` re-adopted from an unpruned export.

    ``to_overlay(prune=False)`` keeps deleted/superseded writer nodes with
    their 'W' label (stable ids for the patch path), and ``from_overlay``
    then re-registers every one of them — last id wins in ``writer_node``
    and each gets members/rev entries the IOB cover could wrongly reuse.
    Drop every W node that is not its base's current live writer, and
    unregister bases whose writer was deleted outright, so the rebuilt
    journal behaves like the live one it replaces."""
    b = dyn.b
    for v in range(len(b.kinds)):
        if b.kinds[v] != "W":
            continue
        base = b.origin[v]
        if base in live_writers and b.writer_node.get(base) == v:
            continue
        b.members[v] = set()
        ns = b.rev.get(base)
        if ns is not None:
            ns.discard(v)
            if not ns:
                del b.rev[base]
        if base not in live_writers:
            b.writer_node.pop(base, None)


def master_arrays(master) -> dict:
    """The session master ``DynamicOverlay`` as a named-array payload:
    unpruned overlay export (stable node ids), the live writer set (the
    export alone cannot distinguish a deleted writer from a live one — both
    keep the 'W' label), reader input sets and the direct-edge counters."""
    ov = master.to_overlay(prune=False)
    out = _overlay_to_arrays(ov, "m.")
    out["m.writers"] = np.array(sorted(master.b.writer_node), np.int64)
    out["m.dwc"] = _map_to_pairs(master.direct_writer_count)
    out.update(_sets_to_arrays(master.reader_inputs, "ri."))
    return out


def master_from_arrays(arrays: dict, *, threshold: int, split_limit: int,
                       dup: bool):
    from repro.core.dynamic import DynamicOverlay

    ov = _overlay_from_arrays(arrays, "m.", dup)
    ri = _sets_from_arrays(arrays, "ri.")
    dyn = DynamicOverlay.from_overlay(ov, ri, threshold=threshold,
                                      split_limit=split_limit)
    scrub_dead_writers(dyn, set(arrays["m.writers"].tolist()))
    dyn.direct_writer_count = {int(k): int(v)
                               for k, v in zip(*arrays["m.dwc"])}
    return dyn


def _map_to_pairs(m: dict) -> np.ndarray:
    from repro.core.engine import _map_to_pairs as impl

    return impl(m)


def _agg_payload(agg) -> dict:
    """(name, constructor params) recovered from the aggregate's cache key —
    the same identity the engine groups hash on. Custom aggregates carry
    Python callables and are not serializable."""
    ck = agg.cache_key
    if ck is None:
        raise ValueError(
            f"aggregate {agg.name!r} has no cache_key — custom aggregates "
            f"are not checkpointable (register built-ins, or rebuild the "
            f"session and replay)")
    name = ck[0]
    if name in ("sum", "max", "min"):
        params = {"value_dim": int(ck[1])}
    elif name == "topk":
        params = {"k": int(ck[1]), "domain": int(ck[2])}
    else:
        params = {}
    return {"name": name, "params": params}


def _extend_decisions(ov, dec: np.ndarray) -> np.ndarray:
    """Extend a creation-time global decision vector over an overlay that
    has since grown: new writers PUSH, new readers PULL, new interiors PUSH,
    then one toposort pass re-establishes the frontier invariant (a PUSH
    node never consumes a PULL node). Only the reshard path needs this —
    same-layout restores carry each plan's live decisions verbatim."""
    from repro.core.dataflow import PULL, PUSH

    out = np.empty(ov.n_nodes, np.int64)
    n0 = min(len(dec), ov.n_nodes)
    out[:n0] = np.asarray(dec[:n0], np.int64)
    for v in range(n0, ov.n_nodes):
        out[v] = PULL if ov.kinds[v] == "R" else PUSH
    for v in ov.toposort():
        if out[v] == PUSH and any(out[s] == PULL for s, _ in ov.in_edges[v]):
            out[v] = PULL
    return out


# ------------------------------------------------------------------ snapshot
def snapshot_session(session) -> tuple[dict, dict]:
    """Flatten a quiesced ``EagrSession`` to ``(arrays, objs)``.

    The caller (``EagrSession.save``) is responsible for quiescing — ingest
    ring drained, mutation journals flushed — before calling; this function
    takes the synchronous ``device_get`` snapshot and returns pure host
    data, so serialization can continue on the checkpoint thread while the
    session resumes."""
    from repro.core.engine import plan_snapshot
    from repro.core.window import window_state_to_host

    if session._pending:
        raise RuntimeError("snapshot_session on a session with un-flushed "
                           "mutations — flush() first")
    arrays: dict = {
        "wcount": np.asarray(session._wcount, np.float64),
        "rcount": np.asarray(session._rcount, np.float64),
    }
    if session.write_freq is not None:
        arrays["wfreq"] = np.asarray(session.write_freq, np.float64)
    if session.read_freq is not None:
        arrays["rfreq"] = np.asarray(session.read_freq, np.float64)
    # master overlay: if the lazy master was never materialized since the
    # last restore, its payload is still exactly the one we restored from
    if session._master_obj is None and session._master_src is not None:
        arrays.update(session._master_src)
    else:
        arrays.update(master_arrays(session._master))

    groups = list(session._groups.values())
    gobjs = []
    for i, g in enumerate(groups):
        eng = g.engine
        gobj = {
            "agg": _agg_payload(g.agg),
            "spec": dataclasses.asdict(g.spec),
            "continuous": bool(g.continuous),
            "now": float(eng._now_host),
        }
        if session.n_shards:
            S = session.n_shards
            # after churn the authoritative per-shard overlays live in the
            # journal's DynamicOverlays; `sharded.shards` is the construction
            # snapshot and goes stale
            if g.sdyn is not None:
                exports = [g.sdyn.dynamics[s].to_overlay(prune=False)
                           for s in range(S)]
            else:
                exports = list(g.sharded.shards)
            pobjs = []
            for s in range(S):
                pa, po = plan_snapshot(g.sharded.shard_plans[s])
                arrays.update({f"g{i}.s{s}.plan.{k}": v
                               for k, v in pa.items()})
                arrays.update(_overlay_to_arrays(exports[s], f"g{i}.s{s}."))
                pobjs.append(po)
            gobj["plans"] = pobjs
            win = window_state_to_host(eng.state.windows)
            arrays.update({f"g{i}.win.{f}": v for f, v in win.items()})
            arrays[f"g{i}.pao"] = np.asarray(jax.device_get(eng.state.pao))
            arrays[f"g{i}.now"] = np.asarray(jax.device_get(eng.state.now))
            arrays[f"g{i}.leval"] = np.asarray(eng._last_eval_now, np.float32)
            arrays[f"g{i}.rs"] = _map_to_pairs(g.sharded.reader_shard)
            arrays[f"g{i}.dec"] = np.asarray(g.dec_global, np.int64)
        else:
            pa, po = plan_snapshot(eng.plan)
            arrays.update({f"g{i}.plan.{k}": v for k, v in pa.items()})
            gobj["plan"] = po
            gobj["leval"] = float(eng._last_eval_now)
            win = window_state_to_host(eng.state.windows)
            arrays.update({f"g{i}.win.{f}": v for f, v in win.items()})
            arrays[f"g{i}.pao"] = np.asarray(jax.device_get(eng.state.pao))
            arrays[f"g{i}.now"] = np.asarray(jax.device_get(eng.state.now))
            arrays[f"g{i}.expiry"] = np.asarray(eng._expiry, np.float64)
            arrays[f"g{i}.flog"] = np.asarray(eng.frontier_log, np.int64)
        al = getattr(eng, "alerts", None)
        if al is not None and al.n_alerts:
            # standing alerts: packed per-base columns (armed/debounce/ref
            # state rides the dynamic fields) + JSON spec descriptors. The
            # snapshot is placement-free (base ids, not rows), so it
            # restores onto any shard layout without re-firing.
            aarr, aspecs = al.snapshot()
            arrays.update({f"g{i}.alert.{k}": v for k, v in aarr.items()})
            gobj["alerts"] = aspecs
            gobj["alert_cap"] = int(al.cap)
            gobj["alert_handles"] = [
                {"aid": int(aid), "qid": int(h.query.qid)}
                for aid, h in sorted(session._alerts.items())
                if h.query.group is g]
        gobjs.append(gobj)

    gi_of = {id(g): i for i, g in enumerate(groups)}
    handles = []
    for qid in sorted(session._handles):
        h = session._handles[qid]
        handles.append({
            "qid": int(qid),
            "group": gi_of[id(h.group)],
            "readers": (sorted(int(r) for r in h.query.readers)
                        if h.query.readers is not None else None),
        })

    ing = session._ingest_stats()
    objs = {
        "format": 1,
        "config": {
            "n_base": session.n_base,
            "n_shards": session.n_shards,
            "backend": session.backend,
            "headroom": session.headroom,
            "growth": session.growth,
            "seed": session.seed,
            "threshold": session.threshold,
            "split_limit": session.split_limit,
            "calibrate": session.calibrate,
            "adapt_every": session.adapt_every,
            "ingest_depth": session.ingest_depth,
            "ingest_batch": session.ingest_batch,
            "value_dim": session._value_dim,
            "dup": bool(session._master_dup),
            "seq": session._seq,
            "next_qid": session._next_qid,
            "next_aid": session._next_aid,
            "ops_since_adapt": session._ops_since_adapt,
            "ckpt_every": session.ckpt_every,
            "ckpt_keep": session.ckpt_keep,
        },
        "construction": (dataclasses.asdict(session.overlay_stats)
                         if session.overlay_stats is not None else None),
        "ingest": ing.as_dict() if ing is not None else None,
        "groups": gobjs,
        "handles": handles,
    }
    return arrays, objs


# ------------------------------------------------------------------- restore
def _slice(arrays: dict, prefix: str) -> dict:
    n = len(prefix)
    return {k[n:]: v for k, v in arrays.items() if k.startswith(prefix)}


def _restore_group_same(session, i: int, gobj: dict, arrays: dict,
                        master_ov, agg, spec):
    """Rebuild one engine group in its saved shard layout — no compilation,
    no PAO refresh: plans, windows, PAOs and clocks are adopted verbatim, so
    the first read off the restored group is bit-identical to the saved
    session's answer."""
    from repro.core.engine import EagrEngine, EngineState, plan_from_snapshot
    from repro.core.window import WindowState, window_state_from_host
    from repro.session import _EngineGroup

    g = object.__new__(_EngineGroup)
    g.session = session
    g.agg = agg
    g.spec = spec
    g.continuous = bool(gobj["continuous"])
    g.key = (agg, spec, g.continuous)
    g.handles = []
    g.window_int = int(max(1, spec.capacity or spec.size))
    g.cost = session._cost_model(agg, g.window_int)
    g.dyn = None   # journals rebuild lazily on the first post-restore churn
    g.sdyn = None
    win = window_state_from_host(
        {f: arrays[f"g{i}.win.{f}"] for f in WindowState._fields})
    pao = jax.device_put(arrays[f"g{i}.pao"])
    now = jax.device_put(arrays[f"g{i}.now"])
    if session.n_shards:
        from repro.distributed.eagr_shard import ShardedOverlay
        from repro.distributed.stacked import StackedShardedEngine

        S = session.n_shards
        plans = [plan_from_snapshot(_slice(arrays, f"g{i}.s{s}.plan."),
                                    gobj["plans"][s]) for s in range(S)]
        shards = [_overlay_from_arrays(arrays, f"g{i}.s{s}.",
                                       session._master_dup)
                  for s in range(S)]
        g.sharded = ShardedOverlay(
            shards=shards,
            shard_decisions=[np.asarray(p.decision, np.int64)
                             for p in plans],
            reader_shard={int(k): int(v)
                          for k, v in zip(*arrays[f"g{i}.rs"])},
            shard_plans=plans,
            writer_rows=[p.writer_row_of_base for p in plans])
        g.dec_global = np.asarray(arrays[f"g{i}.dec"], np.int64)
        g.engine = StackedShardedEngine(g.sharded, agg, spec,
                                        base_capacity=session.n_base)
        g.engine.pin_push = g.continuous
        g.engine.adopt_state(EngineState(win, pao, now),
                             now_host=gobj["now"],
                             last_eval_now=arrays[f"g{i}.leval"])
    else:
        plan = plan_from_snapshot(_slice(arrays, f"g{i}.plan."),
                                  gobj["plan"])
        g.engine = EagrEngine(master_ov, plan.decision, agg, spec, plan=plan)
        g.engine.pin_push = g.continuous
        g.engine.adopt_state(EngineState(win, pao, now),
                             now_host=gobj["now"],
                             last_eval_now=gobj["leval"],
                             expiry=arrays[f"g{i}.expiry"].tolist())
        g.engine.frontier_log = arrays[f"g{i}.flog"].tolist()
    return g


def _restore_group_reshard(session, i: int, gobj: dict, arrays: dict,
                           basis, old_shards: int, agg, spec):
    """Rebuild one engine group into a DIFFERENT shard layout (N -> M, or to
    a single engine). Plans recompile against the master basis, window rings
    redistribute by base id (write replication keeps a writer's ring
    identical across its owning shards, so any old owner is a valid source)
    and PAOs recompute from the migrated windows at the saved clock."""
    from repro.core.engine import (
        EagrEngine,
        EngineState,
        _refresh_pao,
    )
    from repro.core.window import (
        WindowState,
        stack_windows,
        take_window_rows,
        window_state_from_host,
    )
    from repro.session import _EngineGroup

    g = object.__new__(_EngineGroup)
    g.session = session
    g.agg = agg
    g.spec = spec
    g.continuous = bool(gobj["continuous"])
    g.key = (agg, spec, g.continuous)
    g.handles = []
    g.window_int = int(max(1, spec.capacity or spec.size))
    g.cost = session._cost_model(agg, g.window_int)
    g.dyn = None
    g.sdyn = None
    now = float(gobj["now"])

    from repro.core import dataflow as D
    if g.continuous:
        dec = np.full(basis.n_nodes, D.PUSH, np.int64)
    else:
        saved = (arrays[f"g{i}.dec"] if old_shards
                 else arrays[f"g{i}.plan.decision"])
        dec = _extend_decisions(basis, np.asarray(saved, np.int64))

    # gather every saved window ring, keyed by base writer id
    if old_shards:
        hosts = [{f: arrays[f"g{i}.win.{f}"][s]
                  for f in WindowState._fields} for s in range(old_shards)]
        maps = [{int(k): int(v)
                 for k, v in zip(*arrays[f"g{i}.s{s}.plan.wrob"])}
                for s in range(old_shards)]
    else:
        hosts = [{f: arrays[f"g{i}.win.{f}"]
                  for f in WindowState._fields}]
        maps = [{int(k): int(v) for k, v in zip(*arrays[f"g{i}.plan.wrob"])}]
    big = {f: np.concatenate([h[f] for h in hosts])
           for f in WindowState._fields}
    src_of_base: dict[int, int] = {}
    off = 0
    for h, m in zip(hosts, maps):
        for b, r in m.items():
            src_of_base.setdefault(b, off + r)
        off += len(h["head"])

    def rows_for(plan) -> np.ndarray:
        rows = np.full(plan.meta.n_writers, -1, np.int64)
        for b, r in plan.writer_row_of_base.items():
            rows[r] = src_of_base.get(b, -1)
        return rows

    if session.n_shards:
        from repro.distributed.eagr_shard import partition_overlay
        from repro.distributed.stacked import StackedShardedEngine

        M = session.n_shards
        g.sharded = partition_overlay(
            basis, dec, n_shards=M, seed=session.seed,
            backend=session.backend, headroom=session.headroom)
        g.dec_global = dec
        g.engine = StackedShardedEngine(g.sharded, agg, spec,
                                        base_capacity=session.n_base)
        g.engine.pin_push = g.continuous
        wins, paos = [], []
        for plan in g.sharded.shard_plans:
            w = window_state_from_host(take_window_rows(big, rows_for(plan)))
            wins.append(w)
            paos.append(_refresh_pao(plan.meta, agg, spec, plan.arrays, w,
                                     jnp.float32(now)))
        state = EngineState(stack_windows(wins), jnp.stack(paos),
                            jnp.full((M,), now, jnp.float32))
        g.engine.adopt_state(state, now_host=now,
                             last_eval_now=np.full(M, now, np.float32))
    else:
        g.engine = EagrEngine(basis, dec, agg, spec,
                              backend=session.backend,
                              headroom=session.headroom)
        g.engine.pin_push = g.continuous
        plan = g.engine.plan
        host_win = take_window_rows(big, rows_for(plan))
        w = window_state_from_host(host_win)
        pao = _refresh_pao(plan.meta, agg, spec, plan.arrays, w,
                           jnp.float32(now))
        expiry = ()
        if agg.combine != "sum" and spec.kind == "time":
            stamps = host_win["stamps"]
            expiry = np.unique(stamps[np.isfinite(stamps)]).tolist()
        g.engine.adopt_state(
            EngineState(w, pao, jax.device_put(np.float32(now))),
            now_host=now, last_eval_now=now, expiry=expiry)
    return g


def restore_session(directory: str, *, step: int | None = None,
                    graph=None, shards: "int | None" = None):
    """Rebuild an ``EagrSession`` from a checkpoint directory.

    ``shards=None`` restores the saved deployment shape bit-identically —
    compiled plans, window rings, PAOs and clocks are adopted verbatim, so
    the restored session answers every read exactly as the saved one would,
    without re-running construction or compilation. An explicit ``shards=M``
    (``M >= 1``, or ``0`` for a single engine) *reshards*: plans recompile
    over the saved master overlay and window state redistributes by base id.
    ``graph`` optionally re-attaches the data graph (only ``.bipartite``
    depends on it — registration and mutation run off the restored master).
    """
    from repro.core.bipartite import Bipartite, build_bipartite
    from repro.core.vnm import ConstructionStats
    from repro.core.window import WindowSpec
    from repro.session import EagrSession, Query, QueryHandle

    mgr = CheckpointManager(directory)
    arrays, objs, step = mgr.restore_payload(step)
    if objs.get("format") != 1:
        raise ValueError(f"checkpoint at {directory} step {step} is not an "
                         f"EagrSession payload (format={objs.get('format')})")
    cfg = objs["config"]
    old_shards = int(cfg["n_shards"])
    target = old_shards if shards is None else int(shards)
    if target < 0:
        raise ValueError(f"shards must be >= 0, got {shards}")

    sess = object.__new__(EagrSession)
    sess.bipartite = None if graph is None else (
        graph if isinstance(graph, Bipartite) else build_bipartite(graph))
    sess.n_base = int(cfg["n_base"])
    sess.n_shards = target
    sess.backend = cfg["backend"]
    sess.headroom = cfg["headroom"]
    sess.growth = cfg["growth"]
    sess.seed = cfg["seed"]
    sess.calibrate = bool(cfg["calibrate"])
    sess.adapt_every = int(cfg["adapt_every"])
    sess.threshold = int(cfg["threshold"])
    sess.split_limit = int(cfg["split_limit"])
    sess.write_freq = arrays.get("wfreq")
    sess.read_freq = arrays.get("rfreq")
    sess.overlay_stats = (ConstructionStats(**objs["construction"])
                          if objs.get("construction") else None)
    sess._master_obj = None
    sess._master_src = {k: v for k, v in arrays.items()
                        if k.startswith(("m.", "ri."))}
    sess._master_dup = bool(cfg["dup"])
    sess._groups = {}
    sess._handles = {}
    sess._next_qid = int(cfg["next_qid"])
    sess._alerts = {}
    sess._next_aid = int(cfg.get("next_aid") or 0)
    sess._value_dim = cfg["value_dim"]
    sess._wcount = np.asarray(arrays["wcount"], np.float64).copy()
    sess._rcount = np.asarray(arrays["rcount"], np.float64).copy()
    sess._ops_since_adapt = int(cfg["ops_since_adapt"])
    sess._pending = False
    sess.ingest_depth = int(cfg["ingest_depth"])
    sess.ingest_batch = int(cfg["ingest_batch"])
    sess._pipeline = None
    sess._carry_ingest = None
    if objs.get("ingest"):
        from repro.streams.ingest import IngestStats
        sess._carry_ingest = IngestStats(**objs["ingest"])
    sess._seq = int(cfg["seq"])
    sess.ckpt_dir = directory
    sess.ckpt_every = int(cfg.get("ckpt_every") or 0)
    sess.ckpt_keep = int(cfg.get("ckpt_keep") or 3)
    sess._ckpt_mgrs = {}
    sess._last_ckpt_step = step

    same = target == old_shards
    basis = None
    if not same or target == 0:
        # single engines keep the master export as their overlay mirror (the
        # patch path seeds its host bookkeeping from it); resharding needs
        # it as the repartition basis
        basis = _overlay_from_arrays(arrays, "m.", sess._master_dup)
    for i, gobj in enumerate(objs["groups"]):
        agg_p = gobj["agg"]
        from repro.core.aggregates import make_aggregate
        agg = make_aggregate(agg_p["name"], **agg_p["params"])
        spec = WindowSpec(**gobj["spec"])
        if same:
            g = _restore_group_same(sess, i, gobj, arrays, basis, agg, spec)
        else:
            g = _restore_group_reshard(sess, i, gobj, arrays, basis,
                                       old_shards, agg, spec)
        sess._groups[g.key] = g

    groups = list(sess._groups.values())
    for h in objs["handles"]:
        group = groups[h["group"]]
        agg_p = objs["groups"][h["group"]]["agg"]
        query = Query(agg=agg_p["name"],
                      window=group.spec,
                      readers=h["readers"],
                      continuous=group.continuous,
                      agg_kwargs=agg_p["params"] or None)
        handle = QueryHandle(qid=int(h["qid"]), query=query, agg=group.agg,
                             spec=group.spec, session=sess, group=group)
        group.handles.append(handle.qid)
        sess._handles[handle.qid] = handle

    # standing alerts: rebuild each group's AlertSet from the packed columns
    # (armed/debounce/last-measure state restored verbatim — restored
    # sessions never re-fire alerts the saved one already delivered) and
    # re-attach, which re-places rows against the restored (or resharded)
    # plans and recompiles the fused write+eval step on first write
    for i, gobj in enumerate(objs["groups"]):
        aspecs = gobj.get("alerts")
        if not aspecs:
            continue
        from repro.session import AlertHandle
        from repro.streams.alerts import AlertSet, AlertSpec
        g = groups[i]
        alerts = AlertSet.from_snapshot(
            _slice(arrays, f"g{i}.alert."), aspecs,
            cap=int(gobj.get("alert_cap") or 0) or None)
        g.engine.attach_alerts(alerts)
        qid_of = {int(e["aid"]): int(e["qid"])
                  for e in gobj.get("alert_handles", ())}
        for e in aspecs:
            aid = int(e["aid"])
            qh = sess._handles.get(qid_of.get(aid, -1))
            if qh is None:
                continue
            sess._alerts[aid] = AlertHandle(
                aid=aid, spec=AlertSpec.from_json(e["spec"]),
                query=qh, session=sess)
    return sess
