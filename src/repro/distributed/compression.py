"""int8 gradient compression with error feedback.

For cross-pod data parallelism the gradient all-reduce over the (slow) pod
interconnect dominates; int8 quantization cuts those bytes 4x (vs fp32).
Error feedback (Seide et al.; Karimireddy et al., arXiv:1901.09847) keeps the
residual of each quantization locally and adds it back next step, restoring
convergence to near-uncompressed quality.

``compressed_psum(g, axis)`` is the shard_map building block: quantize ->
psum int32 (wide accumulator; the wire format is the int8 payload) ->
dequantize. ``make_error_feedback`` wraps a train step's gradients for the
pjit path, where the quantize/dequantize pair around the (XLA-inserted)
all-reduce expresses the same wire compression and XLA keeps the reduce in
low precision where the platform supports it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-payload psum for use inside shard_map."""
    q, scale = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per shard: psum of the dequantized contribution requires
    # a per-shard scale; use max-scale quantization so one scale serves all
    smax = jax.lax.pmax(scale, axis_name)
    q2 = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax), -127, 127)
    total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, err_state):
    """Quantize grads to int8 (+ carried error), return (dequantized grads,
    new error state). The dequantized grads are what the optimizer consumes;
    the int8 payload is what crosses the wire."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq, g32 - deq

    out = jax.tree.map(one, grads, err_state)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
