"""Reader-partitioned distributed EAGr execution (paper §7's parallelization).

The paper sketches the distribution strategy: "readers can be partitioned in
a disjoint fashion over a set of machines, and for each machine, an overlay
can be constructed for the readers assigned to that machine; the writes for
each writer would be sent to all the machines where they are needed."

Mapping to JAX/TPU:
  * readers are hash-partitioned over the (pod, data) mesh axes,
  * each shard holds the *sub-overlay closure* of its readers (writers +
    partial aggregation nodes reachable backwards from its readers) as a
    leveled CSR plan — plans differ per shard, so execution uses shard_map
    with per-shard constants baked into one jitted program via a stacked,
    padded plan representation,
  * a write batch is relevant to every shard that consumes the writer: the
    batch is replicated (= the all-gather the paper describes; on TPU this is
    one small all-gather of the write ids/values, overlapped by XLA with the
    level-0 segment ops),
  * reads are shard-local (each reader lives on exactly one shard).

For realistic deployments the write batch (ids + values) is tiny compared to
the partial-aggregate state, exactly as the paper argues.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate
from repro.core.dataflow import PULL, PUSH
from repro.core.engine import ExecPlan, PlanPad, compile_plan, measure_plan
from repro.core.overlay import Overlay


@dataclasses.dataclass
class ShardedOverlay:
    """Host-side partition of an overlay into per-shard closures."""

    shards: list[Overlay]
    shard_decisions: list[np.ndarray]
    reader_shard: dict[int, int]          # base reader id -> shard
    shard_plans: list[ExecPlan]
    writer_rows: list[dict[int, int]]     # per shard: base writer -> local row

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def replication_factor(self) -> float:
        """Avg #shards a writer is replicated to (paper's write fan-out)."""
        from collections import Counter
        c = Counter()
        for rows in self.writer_rows:
            for w in rows:
                c[w] += 1
        return float(np.mean(list(c.values()))) if c else 0.0


def align_shard_plans(shards: list[Overlay], decisions: list[np.ndarray],
                      *, backend: str | None = None) -> list[ExecPlan]:
    """Compile every shard's plan padded to the element-wise maximum of all
    shard dimensions (nodes, writers, levels, edge blocks, demand slots).

    Aligned plans share one ``PlanMeta`` and identical array shapes, so the
    per-shard write/read bodies hit a single jitted program — the shard axis
    can then be a stacked leading dimension under ``shard_map`` instead of
    n_shards separately-compiled programs. Dims come from the host-side
    ``measure_plan`` pass, so each plan's tables are built exactly once."""
    dims = [measure_plan(s, d) for s, d in zip(shards, decisions)]
    pad = PlanPad(**{f: max(getattr(d, f) for d in dims)
                     for f in PlanPad.__dataclass_fields__})
    return [compile_plan(s, d, backend=backend, pad=pad)
            for s, d in zip(shards, decisions)]


def partition_overlay(overlay: Overlay, decisions: np.ndarray,
                      n_shards: int, seed: int = 0, *,
                      backend: str | None = None) -> ShardedOverlay:
    """Hash-partition readers; carve each shard's backward closure."""
    rng = np.random.default_rng(seed)
    readers = overlay.reader_nodes()
    shard_of_reader = {r: int(h) for r, h in zip(
        readers, rng.integers(0, n_shards, len(readers)))}

    out_edges = overlay.out_edges()  # noqa: F841  (kept for clarity)
    shards, shard_decs, plans, writer_rows = [], [], [], []
    reader_shard = {}
    for s in range(n_shards):
        keep = np.zeros(overlay.n_nodes, dtype=bool)
        stack = [r for r in readers if shard_of_reader[r] == s]
        for r in stack:
            keep[r] = True
            reader_shard[overlay.origin[r]] = s
        while stack:
            v = stack.pop()
            for src, _ in overlay.in_edges[v]:
                if not keep[src]:
                    keep[src] = True
                    stack.append(src)
        remap = {}
        sub = Overlay(kinds=[], origin=[], in_edges=[],
                      dup_insensitive=overlay.dup_insensitive)
        for v in range(overlay.n_nodes):
            if keep[v]:
                kind = overlay.kinds[v]
                if kind == "R" and shard_of_reader.get(v, -1) != s:
                    kind = "I"  # another shard's reader pulled in as interior
                remap[v] = sub.add_node(kind, overlay.origin[v])
        dec = []
        for v in range(overlay.n_nodes):
            if keep[v]:
                for src, sign in overlay.in_edges[v]:
                    sub.add_edge(remap[src], remap[v], sign)
                dec.append(decisions[v])
        sub = sub.pruned()
        # pruning may drop nodes; recompute decisions on the pruned overlay by
        # rebuilding the remap through origin/kind alignment: simplest is to
        # re-run partitioning without pruning; instead keep unpruned sub.
        shards.append(sub)
        # align decisions with pruned overlay via greedy re-derivation:
        # push nodes whose all-inputs-push invariants must hold; reuse the
        # original decision for surviving nodes by matching origins where
        # possible, defaulting interior nodes to PUSH.
        shard_decs.append(_project_decisions(overlay, decisions, sub))
    # One padded plan shape for all shards: execution shares a single
    # compiled program over the unified substrate (paper §7 on one machine).
    plans = align_shard_plans(shards, shard_decs, backend=backend)
    writer_rows = [plan.writer_row_of_base for plan in plans]
    return ShardedOverlay(shards=shards, shard_decisions=shard_decs,
                          reader_shard=reader_shard, shard_plans=plans,
                          writer_rows=writer_rows)


def _project_decisions(full: Overlay, decisions: np.ndarray,
                       sub: Overlay) -> np.ndarray:
    """Project dataflow decisions onto a shard's sub-overlay.

    Writers stay PUSH. For interior/reader nodes we match by the node's
    input-writer set signature (unique within one overlay construction)."""
    full_sets = full.input_writer_sets()
    sig_dec: dict[frozenset, int] = {}
    for v in range(full.n_nodes):
        if full.kinds[v] != "W":
            sig_dec.setdefault(frozenset(full_sets[v]), int(decisions[v]))
    dec = np.zeros(sub.n_nodes, dtype=np.int64)
    sub_sets = sub.input_writer_sets()
    for v in range(sub.n_nodes):
        if sub.kinds[v] == "W":
            dec[v] = PUSH
        else:
            dec[v] = sig_dec.get(frozenset(sub_sets[v]), PULL)
    # enforce the push/pull frontier invariant (no pull upstream of a push)
    order = sub.toposort()
    for v in order:
        if dec[v] == PUSH and any(dec[s] == PULL for s, _ in sub.in_edges[v]):
            dec[v] = PULL
    return dec


def shard_write_batch(sharded: ShardedOverlay, base_ids: np.ndarray,
                      values: np.ndarray):
    """Route one global write batch to every shard that consumes the writer
    (host-side; the device-side equivalent is the all-gather of the batch).
    Returns per-shard (rows, vals, mask) padded to the global batch size."""
    B = len(base_ids)
    out = []
    for s in range(sharded.n_shards):
        rows = np.zeros(B, np.int32)
        vals = np.zeros(B, np.float32)
        mask = np.zeros(B, bool)
        wr = sharded.writer_rows[s]
        j = 0
        for b, v in zip(base_ids, values):
            row = wr.get(int(b))
            if row is not None:
                rows[j], vals[j], mask[j] = row, v, True
                j += 1
        out.append((rows, vals, mask))
    return out


def shard_read_batch(sharded: ShardedOverlay, base_ids: np.ndarray):
    """Route reads to their unique owner shard (padded per shard)."""
    B = len(base_ids)
    out = []
    for s in range(sharded.n_shards):
        nodes = np.zeros(B, np.int32)
        mask = np.zeros(B, bool)
        plan = sharded.shard_plans[s]
        j = 0
        for b in base_ids:
            if sharded.reader_shard.get(int(b)) == s:
                nodes[j] = plan.reader_node_of_base[int(b)]
                mask[j] = True
                j += 1
        out.append((nodes, mask))
    return out
