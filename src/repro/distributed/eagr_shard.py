"""Reader-partitioned distributed EAGr execution (paper §7's parallelization).

The paper sketches the distribution strategy: "readers can be partitioned in
a disjoint fashion over a set of machines, and for each machine, an overlay
can be constructed for the readers assigned to that machine; the writes for
each writer would be sent to all the machines where they are needed."

Mapping to JAX/TPU:
  * readers are hash-partitioned over the shard mesh axis,
  * each shard holds the *sub-overlay closure* of its readers (writers +
    partial aggregation nodes reachable backwards from its readers) as a
    leveled CSR plan, padded to one shared program shape
    (``align_shard_plans``),
  * a write batch is relevant to every shard that consumes the writer: the
    batch is replicated (= the all-gather the paper describes; on TPU this is
    one small all-gather of the write ids/values, overlapped by XLA with the
    level-0 segment ops),
  * reads are shard-local (each reader lives on exactly one shard).

This module owns the host-side machinery: partitioning, plan alignment,
delta routing (``ShardedDynamic``), and the per-shard host loop helpers
(``shard_write_batch`` / ``shard_read_batch``) kept as the parity and
benchmark baseline. The production execution path is
``distributed.stacked.StackedShardedEngine``: all shards stacked along a
leading axis, one ``shard_map`` program, batch routing on-device.

For realistic deployments the write batch (ids + values) is tiny compared to
the partial-aggregate state, exactly as the paper argues.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate
from repro.core.dataflow import PULL, PUSH
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import (
    ExecPlan,
    PlanPad,
    compile_plan,
    grow_pad,
    measure_plan,
    plan_dims,
)
from repro.core.overlay import Overlay


@dataclasses.dataclass
class ShardedOverlay:
    """Host-side partition of an overlay into per-shard closures."""

    shards: list[Overlay]
    shard_decisions: list[np.ndarray]
    reader_shard: dict[int, int]          # base reader id -> shard
    shard_plans: list[ExecPlan]
    writer_rows: list[dict[int, int]]     # per shard: base writer -> local row

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def replication_factor(self) -> float:
        """Avg #shards a writer is replicated to (paper's write fan-out)."""
        from collections import Counter
        c = Counter()
        for rows in self.writer_rows:
            for w in rows:
                c[w] += 1
        return float(np.mean(list(c.values()))) if c else 0.0


def align_shard_plans(shards: list[Overlay], decisions: list[np.ndarray],
                      *, backend: str | None = None,
                      headroom: float | None = None) -> list[ExecPlan]:
    """Compile every shard's plan padded to the element-wise maximum of all
    shard dimensions (nodes, writers, levels, edge blocks, demand slots).

    Aligned plans share one ``PlanMeta`` and identical array shapes, so the
    per-shard write/read bodies hit a single jitted program — the shard axis
    can then be a stacked leading dimension under ``shard_map`` instead of
    n_shards separately-compiled programs. Dims come from the host-side
    ``measure_plan`` pass, so each plan's tables are built exactly once.
    ``headroom`` grows the shared pad (as ``EagrEngine(headroom=...)``) so
    structural churn patches every shard in place instead of forcing a
    stack-wide realignment on the first slot overflow."""
    dims = [measure_plan(s, d) for s, d in zip(shards, decisions)]
    pad = PlanPad(**{f: max(getattr(d, f) for d in dims)
                     for f in PlanPad.__dataclass_fields__})
    if headroom and headroom > 1.0:
        pad = grow_pad(pad, headroom)
    return [compile_plan(s, d, backend=backend, pad=pad)
            for s, d in zip(shards, decisions)]


def partition_overlay(overlay: Overlay, decisions: np.ndarray,
                      n_shards: int, seed: int = 0, *,
                      backend: str | None = None,
                      headroom: float | None = None) -> ShardedOverlay:
    """Hash-partition readers; carve each shard's backward closure."""
    rng = np.random.default_rng(seed)
    readers = overlay.reader_nodes()
    shard_of_reader = {r: int(h) for r, h in zip(
        readers, rng.integers(0, n_shards, len(readers)))}

    out_edges = overlay.out_edges()  # noqa: F841  (kept for clarity)
    shards, shard_decs, plans, writer_rows = [], [], [], []
    reader_shard = {}
    for s in range(n_shards):
        keep = np.zeros(overlay.n_nodes, dtype=bool)
        stack = [r for r in readers if shard_of_reader[r] == s]
        for r in stack:
            keep[r] = True
            reader_shard[overlay.origin[r]] = s
        while stack:
            v = stack.pop()
            for src, _ in overlay.in_edges[v]:
                if not keep[src]:
                    keep[src] = True
                    stack.append(src)
        remap = {}
        sub = Overlay(kinds=[], origin=[], in_edges=[],
                      dup_insensitive=overlay.dup_insensitive)
        for v in range(overlay.n_nodes):
            if keep[v]:
                kind = overlay.kinds[v]
                if kind == "R" and shard_of_reader.get(v, -1) != s:
                    kind = "I"  # another shard's reader pulled in as interior
                remap[v] = sub.add_node(kind, overlay.origin[v])
        dec = []
        for v in range(overlay.n_nodes):
            if keep[v]:
                for src, sign in overlay.in_edges[v]:
                    sub.add_edge(remap[src], remap[v], sign)
                dec.append(decisions[v])
        sub = sub.pruned()
        # pruning may drop nodes; recompute decisions on the pruned overlay by
        # rebuilding the remap through origin/kind alignment: simplest is to
        # re-run partitioning without pruning; instead keep unpruned sub.
        shards.append(sub)
        # align decisions with pruned overlay via greedy re-derivation:
        # push nodes whose all-inputs-push invariants must hold; reuse the
        # original decision for surviving nodes by matching origins where
        # possible, defaulting interior nodes to PUSH.
        shard_decs.append(_project_decisions(overlay, decisions, sub))
    # One padded plan shape for all shards: execution shares a single
    # compiled program over the unified substrate (paper §7 on one machine).
    plans = align_shard_plans(shards, shard_decs, backend=backend,
                              headroom=headroom)
    writer_rows = [plan.writer_row_of_base for plan in plans]
    return ShardedOverlay(shards=shards, shard_decisions=shard_decs,
                          reader_shard=reader_shard, shard_plans=plans,
                          writer_rows=writer_rows)


def _project_decisions(full: Overlay, decisions: np.ndarray,
                       sub: Overlay) -> np.ndarray:
    """Project dataflow decisions onto a shard's sub-overlay.

    Writers stay PUSH. For interior/reader nodes we match by the node's
    input-writer set signature (unique within one overlay construction)."""
    full_sets = full.input_writer_sets()
    sig_dec: dict[frozenset, int] = {}
    for v in range(full.n_nodes):
        if full.kinds[v] != "W":
            sig_dec.setdefault(frozenset(full_sets[v]), int(decisions[v]))
    dec = np.zeros(sub.n_nodes, dtype=np.int64)
    sub_sets = sub.input_writer_sets()
    for v in range(sub.n_nodes):
        if sub.kinds[v] == "W":
            dec[v] = PUSH
        else:
            dec[v] = sig_dec.get(frozenset(sub_sets[v]), PULL)
    # enforce the push/pull frontier invariant (no pull upstream of a push)
    order = sub.toposort()
    for v in order:
        if dec[v] == PUSH and any(dec[s] == PULL for s, _ in sub.in_edges[v]):
            dec[v] = PULL
    return dec


class ShardedDynamic:
    """Structural churn (§3.3) over a reader-partitioned deployment.

    Each shard adopts its sub-overlay into a ``DynamicOverlay`` (node ids
    align 1:1 with the shard's compiled plan, so deltas patch in place).
    Base-graph mutations are routed to the shards that own the affected
    readers — a writer-side change fans out to every shard consuming the
    writer, mirroring how writes themselves are replicated. ``apply()``
    drains every shard's delta, patches the owning plans (through the shard
    engines when given, migrating their state), then re-runs the
    ``align_shard_plans`` dims check: if any shard fell back to a recompile
    with growth headroom, the remaining shards are recompiled to the same
    padded shape so execution stays on one compiled program."""

    def __init__(self, sharded: ShardedOverlay, engines=None,
                 *, growth: float = 2.0):
        from repro.distributed.stacked import StackedShardedEngine

        self.sharded = sharded
        self.stacked = engines if isinstance(engines, StackedShardedEngine) \
            else None
        self.engines = None if self.stacked is not None else engines
        self.growth = growth
        self.dynamics: list[DynamicOverlay] = []
        for sub in sharded.shards:
            sets = sub.input_writer_sets()
            ris = {sub.origin[v]: set(sets[v]) for v in sub.reader_nodes()}
            self.dynamics.append(DynamicOverlay.from_overlay(sub, ris))

    # --------------------------------------------------------------- routing
    def _owner(self, reader: int, *, allow_new: bool = False) -> int:
        s = self.sharded.reader_shard.get(int(reader))
        if s is None:
            if not allow_new:
                raise ValueError(
                    f"base id {int(reader)} is owned by no shard — register "
                    f"it through add_node() before routing mutations to it")
            # genuinely new reader: deterministic assignment
            s = int(reader) % self.sharded.n_shards
            self.sharded.reader_shard[int(reader)] = s
        return s

    def route(self, affected: dict[int, set[int]], *,
              allow_new: bool = False) -> dict[int, dict[int, set[int]]]:
        """Split one {reader: delta_writers} map by owning shard. Unknown
        readers raise unless ``allow_new`` (the add_node path) is set."""
        per_shard: dict[int, dict[int, set[int]]] = {}
        for r, delta in affected.items():
            per_shard.setdefault(self._owner(r, allow_new=allow_new),
                                 {})[r] = set(delta)
        return per_shard

    def add_edge(self, u: int, v: int,
                 affected: dict[int, set[int]] | None = None) -> None:
        for s, aff in self.route(affected if affected is not None else {v: {u}}).items():
            self.dynamics[s].add_edge(u, v, affected=aff)

    def delete_edge(self, u: int, v: int,
                    affected: dict[int, set[int]] | None = None) -> None:
        for s, aff in self.route(affected if affected is not None else {v: {u}}).items():
            self.dynamics[s].delete_edge(u, v, affected=aff)

    def add_node(self, u: int, in_neighbors: set[int],
                 out_readers: set[int]) -> None:
        # u's home shard tracks its write stream from day one (matching the
        # single-machine engine, where the writer window exists immediately);
        # other shards start u's window empty when a reader there follows u
        # later — cross-shard window backfill on new subscriptions is a known
        # gap (would need a state transfer, see ROADMAP).
        home = self._owner(u, allow_new=True)
        self.dynamics[home].b.add_writer(u)
        for s, aff in self.route({r: {u} for r in out_readers}).items():
            for r, delta in aff.items():
                self.dynamics[s].add_reader_inputs(r, delta)
        if in_neighbors:
            self.dynamics[home].add_reader_inputs(u, set(in_neighbors))

    def delete_node(self, u: int) -> None:
        for s, dyn in enumerate(self.dynamics):
            if u in dyn.b.writer_node or u in dyn.reader_node:
                dyn.delete_node(u)
        self.sharded.reader_shard.pop(int(u), None)

    # ----------------------------------------------------------------- apply
    def apply(self) -> list:
        """Drain every shard's delta and patch the owning plans, then restore
        the one-program-shape invariant. Returns per-shard ``PatchResult``
        (None for untouched shards). With a ``StackedShardedEngine`` each
        in-capacity patch swaps exactly one slice of the stacked pytree; any
        growth fallback realigns every shard and restacks the whole stack."""
        from repro.core.plan_patch import patch_plan

        results = []
        for s, dyn in enumerate(self.dynamics):
            delta = dyn.drain_delta()
            if delta.empty:
                results.append(None)
                continue
            if self.stacked is not None:
                res = self.stacked.apply_delta(s, delta, growth=self.growth)
            elif self.engines is not None:
                res = self.engines[s].apply_delta(delta, growth=self.growth)
                self.sharded.shard_plans[s] = self.engines[s].plan
            else:
                res = patch_plan(self.sharded.shard_plans[s], delta,
                                 overlay=self.sharded.shards[s],
                                 growth=self.growth)
                self.sharded.shard_plans[s] = res.plan
            self.sharded.writer_rows[s] = res.plan.writer_row_of_base
            results.append(res)
        self.ensure_aligned()
        # in-capacity patches refreshed their own slice + owner maps inside
        # apply_delta; only a growth fallback leaves the stack to re-adopt
        if self.stacked is not None and self.stacked._needs_restack:
            self.stacked.restack()
        return results

    def readopt_decisions(self, decisions: list[np.ndarray | None]) -> bool:
        """Recompile shards whose push/pull decisions changed (§4.8 adaptive
        re-decision over a partitioned deployment) and re-establish the
        one-program-shape invariant. ``decisions[s]`` is the shard's new
        decision vector over its *current* (host-mirror) overlay, or None to
        keep the shard as-is. Padded dims are floored at the element-wise
        maximum of every shard's current and re-measured dims, so unchanged
        shards usually skip recompilation entirely. With a stacked engine the
        whole stack re-adopts (``adopt_shard_plans``); host-loop engines adopt
        per shard. Returns True if any shard was recompiled."""
        from repro.core.plan_patch import carry_plan_bookkeeping

        if all(d is None for d in decisions):
            return False
        plans = self.sharded.shard_plans
        overlays = []
        dims = [plan_dims(p) for p in plans]
        for s, dec in enumerate(decisions):
            host = plans[s].host
            ov = host.export_overlay() if host is not None \
                else self.sharded.shards[s]
            overlays.append(ov)
            if dec is not None:
                dims.append(measure_plan(ov, np.asarray(dec, np.int64)))
        target = PlanPad(**{f: max(getattr(d, f) for d in dims)
                            for f in PlanPad.__dataclass_fields__})
        changed = False
        for s, dec in enumerate(decisions):
            p = plans[s]
            if dec is None and plan_dims(p) == target:
                continue
            dec = p.decision if dec is None else np.asarray(dec, np.int64)
            new = compile_plan(overlays[s], dec, backend=p.meta.backend,
                               pad=target)
            carry_plan_bookkeeping(new, p, overlays[s])
            plans[s] = new
            self.sharded.shard_decisions[s] = dec
            self.sharded.writer_rows[s] = new.writer_row_of_base
            changed = True
            if self.engines is not None:
                self.engines[s].adopt_plan(new)
            if self.stacked is not None:
                self.stacked._needs_restack = True
        if self.stacked is not None and self.stacked._needs_restack:
            self.stacked.adopt_shard_plans()
        return changed

    def ensure_aligned(self) -> bool:
        """Re-run the ``align_shard_plans`` dims check; recompile any shard
        whose padded dims diverged (a growth-headroom fallback) to the
        element-wise maximum so all shards share one program shape again.
        Returns True if a realign was needed."""
        from repro.core.plan_patch import carry_plan_bookkeeping

        plans = self.sharded.shard_plans
        dims = [plan_dims(p) for p in plans]
        if all(d == dims[0] for d in dims[1:]):
            return False
        target = PlanPad(**{f: max(getattr(d, f) for d in dims)
                            for f in PlanPad.__dataclass_fields__})
        for s, p in enumerate(plans):
            if plan_dims(p) == target:
                continue
            host = p.host
            ov = host.export_overlay() if host is not None \
                else self.sharded.shards[s]
            new = compile_plan(ov, p.decision, backend=p.meta.backend,
                               pad=target)
            carry_plan_bookkeeping(new, p, ov)
            if self.engines is not None:
                self.engines[s].adopt_plan(new)
            # a stacked engine re-adopts every slice at once via restack()
            if self.stacked is not None:
                self.stacked._needs_restack = True
            plans[s] = new
            self.sharded.writer_rows[s] = new.writer_row_of_base
        return True


def host_loop_write(sharded: ShardedOverlay, engines: list,
                    base_ids: np.ndarray, values: np.ndarray) -> None:
    """The pre-stacking execution path, one jitted dispatch per shard — kept
    as the parity/benchmark baseline the stacked program must match bit for
    bit. ``engines`` are per-shard ``EagrEngine``s over the aligned plans."""
    for eng, (rows, v, m) in zip(engines,
                                 shard_write_batch(sharded, base_ids, values)):
        eng.state = eng._write(eng.state, jnp.asarray(rows),
                               jnp.asarray(v), jnp.asarray(m))
        eng._now_host += 1


def host_loop_read(sharded: ShardedOverlay, engines: list,
                   base_ids: np.ndarray) -> np.ndarray:
    """Per-shard host loop read, gathered host-side (each lane is owned by
    exactly one shard, so the masked sum is a gather)."""
    acc = None
    for eng, (nodes, m) in zip(engines, shard_read_batch(sharded, base_ids)):
        ans, _ = eng._read(eng.state, jnp.asarray(nodes), jnp.asarray(m))
        ans = np.asarray(ans)
        part = np.where(m.reshape(m.shape + (1,) * (ans.ndim - 1)), ans, 0)
        acc = part if acc is None else acc + part
    return acc


def shard_write_batch(sharded: ShardedOverlay, base_ids: np.ndarray,
                      values: np.ndarray):
    """Route one global write batch to every shard that consumes the writer
    (host-side; the device-side equivalent is ``StackedShardedEngine``'s
    all-gather + owner-map mask). Returns per-shard (rows, vals, mask) in
    *batch-lane order* — lane i stays lane i with ``mask[i]`` flagging
    ownership — so the host loop computes bit-identically to the stacked
    program, which sees the same masked layout."""
    B = len(base_ids)
    vals = np.asarray(values, np.float32)
    out = []
    for s in range(sharded.n_shards):
        rows = np.zeros(B, np.int32)
        mask = np.zeros(B, bool)
        wr = sharded.writer_rows[s]
        for i, b in enumerate(base_ids):
            row = wr.get(int(b))
            if row is not None:
                rows[i], mask[i] = row, True
        out.append((rows, vals, mask))
    return out


def shard_read_batch(sharded: ShardedOverlay, base_ids: np.ndarray):
    """Route reads to their unique owner shard, in batch-lane order (lane i
    answers base_ids[i] on the owning shard; mask elsewhere). A base id owned
    by no shard has no answer anywhere — raise instead of silently returning
    a masked lane."""
    def _unowned(b: int) -> bool:
        s = sharded.reader_shard.get(b)
        # a shard assignment without a compiled reader node (e.g. a pure
        # writer registered via add_node, or a pending delta) is unreadable
        return s is None or b not in sharded.shard_plans[s].reader_node_of_base

    unknown = [int(b) for b in base_ids if _unowned(int(b))]
    if unknown:
        raise ValueError(
            f"shard_read_batch: base ids {sorted(set(unknown))[:8]} are "
            f"owned by no shard (not readers of any shard overlay)")
    B = len(base_ids)
    out = []
    for s in range(sharded.n_shards):
        nodes = np.zeros(B, np.int32)
        mask = np.zeros(B, bool)
        plan = sharded.shard_plans[s]
        for i, b in enumerate(base_ids):
            if sharded.reader_shard.get(int(b)) == s:
                nodes[i] = plan.reader_node_of_base[int(b)]
                mask[i] = True
        out.append((nodes, mask))
    return out
