"""Fault tolerance: checkpoint/restart driver, straggler detection, elastic
re-meshing, deterministic data-skip on resume.

The driver wraps any (state, batch) -> state step function:
  * periodic async checkpoints (CheckpointManager),
  * on a step failure (device loss manifests as an exception in the runtime),
    restore the latest checkpoint and REPLAY the data stream deterministically
    (the data iterator is seeded by step index, so skipping to the restored
    step reproduces the exact batch sequence),
  * per-step wall-time tracking with a robust z-score straggler detector —
    on real multi-host deployments this feeds the controller that evicts or
    reshards around slow hosts; here it flags and records,
  * elastic re-mesh: on restart with a different device count, the same
    checkpoint restores under new shardings (restore-with-resharding), and
    the batch size per shard re-balances because inputs are sharded by the
    mesh rules rather than hard-coded counts.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Iterator

import numpy as np

from repro.distributed.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerDetector:
    """Flags steps slower than median + z * MAD over a sliding window."""

    window: int = 64
    z: float = 4.0
    times: deque = dataclasses.field(default_factory=lambda: deque(maxlen=64))
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        ts = np.array(self.times) if self.times else np.array([seconds])
        med = float(np.median(ts))
        mad = float(np.median(np.abs(ts - med))) + 1e-9
        is_straggler = len(self.times) >= 8 and seconds > med + self.z * 1.4826 * mad
        if is_straggler:
            self.flagged.append((step, seconds, med))
        self.times.append(seconds)
        return is_straggler


@dataclasses.dataclass
class RunReport:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    stragglers: list = dataclasses.field(default_factory=list)
    losses: list = dataclasses.field(default_factory=list)


class FaultTolerantRunner:
    """Drives a training loop with checkpoint/restart semantics.

    make_batch(step) must be deterministic in step — that is what makes
    replay-after-restore exact.
    """

    def __init__(self, step_fn: Callable, make_batch: Callable[[int], Any],
                 ckpt: CheckpointManager, *, ckpt_every: int = 50,
                 max_restarts: int = 3):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.detector = StragglerDetector()

    def run(self, state, n_steps: int, *, start_step: int = 0,
            fail_at: set[int] | None = None,
            shardings=None) -> tuple[Any, RunReport]:
        """fail_at: steps at which to inject a simulated node failure (tests)."""
        report = RunReport()
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                if fail_at and step in fail_at:
                    fail_at.discard(step)
                    raise RuntimeError(f"injected node failure at step {step}")
                t0 = time.perf_counter()
                batch = self.make_batch(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                if self.detector.observe(step, dt):
                    report.stragglers.append(step)
                if metrics is not None and "loss" in metrics:
                    report.losses.append(float(metrics["loss"]))
                step += 1
                report.steps_run += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, blocking=False)
                    report.checkpoints += 1
            except Exception:
                restarts += 1
                report.restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                restored = self.ckpt.latest_step()
                if restored is None:
                    step = start_step  # restart from scratch
                    continue
                state, _ = self.ckpt.restore(state, restored, shardings=shardings)
                step = restored      # deterministic data replay from here
        self.ckpt.wait()
        return state, report


class SessionRecoveryDriver:
    """Crash-recovery loop over a durable :class:`repro.session.EagrSession`.

    The session's update-batch sequence number (``session._seq``, the step a
    checkpoint commits under) is the authoritative stream position:
    ``make_batch(seq)`` must be deterministic in ``seq``, and after a crash
    the driver restores the latest committed checkpoint and replays from its
    recorded sequence number — the restored engine state plus the replayed
    suffix reproduces exactly the uninterrupted run (the replay-determinism
    test pins this bit-for-bit).

    ``make_session()`` builds the cold session (used on first start, and
    when a crash precedes the first committed checkpoint).
    """

    def __init__(self, make_session: Callable[[], Any],
                 make_batch: Callable[[int], Any], directory: str, *,
                 ckpt_every: int = 16, max_restarts: int = 3):
        self.make_session = make_session
        self.make_batch = make_batch
        self.directory = directory
        self.ckpt_every = max(1, int(ckpt_every))
        self.max_restarts = max_restarts
        self.report = RunReport()

    def _boot(self):
        mgr = CheckpointManager(self.directory)
        if mgr.latest_step() is None:
            return self.make_session()
        from repro.session import EagrSession
        return EagrSession.restore(self.directory)

    def run(self, n_batches: int, *,
            fail_at: "set[int] | None" = None) -> Any:
        """Feed batches 0..n_batches-1 through the session with periodic
        checkpoints; on a failure (injected via ``fail_at`` step indices, or
        any exception out of the update path) restore and replay. Returns
        the live session positioned at ``_seq == n_batches``."""
        session = self._boot()
        restarts = 0
        while session._seq < n_batches:
            try:
                seq = session._seq
                if fail_at and seq in fail_at:
                    fail_at.discard(seq)
                    raise RuntimeError(
                        f"injected node failure at batch {seq}")
                ids, values = self.make_batch(seq)
                session.update(ids, values)
                self.report.steps_run += 1
                if session._seq % self.ckpt_every == 0:
                    session.save(self.directory, blocking=False)
                    self.report.checkpoints += 1
            except Exception:
                restarts += 1
                self.report.restarts += 1
                if restarts > self.max_restarts:
                    raise
                session.wait_for_checkpoint()
                session = self._boot()  # replay resumes at the saved _seq
        session.wait_for_checkpoint()
        return session
