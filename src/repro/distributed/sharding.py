"""Logical-axis sharding rules (MaxText-style) mapped onto the production mesh.

Every parameter/input declares *logical* axis names (ParamSpec.axes); a rule
table maps logical names to mesh axes. ``data`` composes with ``pod`` for all
data-parallel dims so the same rules serve the single-pod (16, 16) and
multi-pod (2, 16, 16) meshes.

Divisibility guard: a mesh axis is only applied to a dim it divides evenly —
otherwise that axis is dropped (replicated) for that dim. GSPMD could pad
uneven shards, but silent padding skews the roofline byte counts; explicit
replication keeps the analysis honest and is recorded by ``explain_sharding``.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ParamSpec

# logical axis -> mesh axes (in priority order; tuples compose)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # params
    "embed": ("pod", "data"),       # FSDP: shard the d_model dim over data
    "vocab": ("model",),            # TP: vocab/embedding rows
    "heads": ("model",),            # TP: attention heads
    "kv_heads": ("model",),
    "mlp": ("model",),              # TP: FFN hidden
    "expert": ("model",),           # EP: MoE experts
    "layers": (),                   # scan axis: never sharded
    # activations / inputs (act_* names are used by constrain() in model code)
    "batch": ("pod", "data"),
    "sequence": (),                 # sequence parallelism opt-in via seq rules
    # graph node/edge dims never feed TP matmuls -> use the model axis too
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "candidates": ("pod", "data", "model"),
    "cache_seq": ("pod", "data", "model"),  # KV cache seq: whatever batch left
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_res_seq": (),              # residual stream between layers (SP opt-in)
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_expert": ("model",),
    "act_nodes": ("pod", "data", "model"),
    "act_edges": ("pod", "data", "model"),
}

# variant used by the sequence-parallel hillclimb (prefill shapes)
SEQPAR_RULES = {**DEFAULT_RULES, "sequence": ("model",), "act_seq": ("model",),
                "act_heads": (), "act_mlp": (), "act_vocab": ()}

# Megatron-style sequence parallelism on the residual stream only: layer
# boundaries (and therefore remat-saved activations) are sequence-sharded over
# the model axis; attention/FFN internals stay head/mlp-sharded. GSPMD inserts
# the all-gather (entering a layer) / reduce-scatter (leaving it) pair.
RESIDUAL_SP_RULES = {**DEFAULT_RULES, "act_res_seq": ("model",)}

RULE_SETS = {"default": DEFAULT_RULES, "seqpar": SEQPAR_RULES,
             "residual_sp": RESIDUAL_SP_RULES}


# ------------------------------------------------------- activation context
# Model code calls constrain(x, axes) with logical names; outside a context
# (smoke tests, single-device examples) it is a no-op, so models never depend
# on a mesh being present.
_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _TLS.ctx = prev


def constrain(x, axes: tuple[str | None, ...]):
    """with_sharding_constraint by logical axis names (no-op without context)."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@contextlib.contextmanager
def no_constrain():
    """Disable constrain() — required inside shard_map bodies, where arrays
    are per-shard locals and global sharding constraints are meaningless."""
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = None
    try:
        yield
    finally:
        _TLS.ctx = prev


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: tuple[int, ...], axes: tuple[str | None, ...], mesh: Mesh,
             rules: dict[str, tuple[str, ...]] | None = None) -> P:
    """PartitionSpec for one array: apply rules with divisibility guard."""
    rules = rules or DEFAULT_RULES
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        if name is None or name not in rules:
            out.append(None)
            continue
        chosen = []
        prod = 1
        for ax in rules[name]:
            if ax not in sizes or ax in used:
                continue
            if dim % (prod * sizes[ax]) == 0:
                chosen.append(ax)
                prod *= sizes[ax]
        used.update(chosen)
        out.append(tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None))
    return P(*out)


def sharding_for(shape, axes, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def param_shardings(spec_tree, mesh: Mesh, rules=None):
    """ParamSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: sharding_for(s.shape, s.axes, mesh, rules),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def like_tree(sds_tree, axes_fn, mesh, rules=None):
    """Shardings for a ShapeDtypeStruct tree given axes_fn(path, sds) -> axes."""
    def f(path, sds):
        return sharding_for(sds.shape, axes_fn(path, sds), mesh, rules)
    return jax.tree_util.tree_map_with_path(f, sds_tree)


def batch_shardings(sds_tree, mesh: Mesh, rules=None, *, leading="batch"):
    """Shard the leading dim of every array by the ``leading`` logical axis."""
    def f(sds):
        axes = (leading,) + (None,) * (len(sds.shape) - 1)
        return sharding_for(sds.shape, axes, mesh, rules)
    return jax.tree.map(f, sds_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def explain_sharding(spec_tree, mesh, rules=None, max_rows: int = 0) -> str:
    """Human-readable table of param shardings + per-device bytes."""
    rows = []
    total = 0

    def visit(path, s: ParamSpec):
        nonlocal total
        ps = spec_for(s.shape, s.axes, mesh, rules)
        n_shards = 1
        sizes = mesh_axis_sizes(mesh)
        for entry in ps:
            for ax in (entry if isinstance(entry, tuple) else (entry,) if entry else ()):
                n_shards *= sizes[ax]
        nbytes = int(jnp.dtype(s.dtype).itemsize)
        for d in s.shape:
            nbytes *= d
        per_dev = nbytes // n_shards
        total += per_dev
        rows.append((jax.tree_util.keystr(path), s.shape, str(ps), per_dev))

    jax.tree_util.tree_map_with_path(
        visit, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    out = [f"{p}  {sh}  {ps}  {b/1e6:.1f}MB" for p, sh, ps, b in rows[:max_rows or len(rows)]]
    out.append(f"TOTAL per-device param bytes: {total/1e9:.2f} GB")
    return "\n".join(out)
