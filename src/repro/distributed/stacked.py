"""Stacked SPMD execution of reader-partitioned EAGr shards (paper §7).

``eagr_shard.partition_overlay`` + ``align_shard_plans`` already force every
shard's ``ExecPlan`` onto one ``PlanMeta`` and identical array shapes. This
module takes the remaining step: all shards' ``PlanArrays``, window state and
PAOs are stacked along a leading shard axis and write/read run as **one**
compiled program over a device mesh —

  * the incoming batch is split into per-shard chunks and **all-gathered
    on-device** (the write replication the paper describes),
  * each shard masks the gathered batch to its owned writer rows through a
    device-resident owner map (base id -> local writer row, -1 elsewhere),
  * reads run shard-local and the per-shard answers come back with a single
    ``psum`` collective (each reader lives on exactly one shard, so the sum
    over shards is a gather).

The per-shard body is the *pure* engine step (``engine.write_step_sum`` /
``write_step_extremal`` / ``read_step``) — identical math to the per-shard
host loop, which stays in ``eagr_shard`` as the parity / benchmark baseline.
On a mesh of >= n_shards devices the body runs under ``shard_map``; with
fewer devices (CPU tier-1) the same body runs under
``vmap(axis_name=SHARD_AXIS)``, so both paths trace the same collectives.

Structural churn is device-resident too: a shard's delta is lowered once
(``plan_patch.PatchProgram``) and replayed on the owning slice of the stacked
pytree under the same shard_map/vmap machinery (``_stacked_patch`` — masked,
donated, no host scatter), and owner-map rows are patched in place
(``_scatter_owner_rows``). What stays host-side: delta journaling
(``ShardedDynamic``) and the slot-pool bookkeeping inside ``plan_patch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.aggregates import Aggregate
from repro.core.engine import (
    EngineState,
    _refresh_pao,
    plan_arrays_shard,
    read_step,
    stack_plan_arrays,
    write_step_extremal,
    write_step_extremal_sparse,
    write_step_sum,
    write_step_sum_sparse,
)
from repro.core.plan_patch import _OOB, _bucket, apply_patch_program
from repro.core.window import (
    WindowSpec,
    init_windows,
    pad_window_rows,
    place_window_shard,
    reset_window_rows,
    stack_windows,
    window_shard,
)
from repro.launch.mesh import SHARD_AXIS, make_shard_mesh

BASE_BUCKET = 256  # owner maps grow in power-of-two multiples of this


def _bucket_base_cap(n: int) -> int:
    """Owner-map capacity bucket: power-of-two multiples of BASE_BUCKET so a
    growing base-id space rarely changes the stacked program's input shapes."""
    k = -(-max(1, n) // BASE_BUCKET)
    return BASE_BUCKET * (1 << (k - 1).bit_length())


def _run_stacked(mesh, body, args):
    """Run the per-shard ``body`` over every leading-axis slice of ``args`` —
    under ``shard_map`` on a real shard mesh, else under ``vmap`` with the
    same axis name so the body's collectives mean the same thing."""
    if mesh is None:
        return jax.vmap(body, axis_name=SHARD_AXIS)(*args)

    def dev_body(*dev_args):
        # one shard per device: peel the local (length-1) shard axis so the
        # body is written once for both execution paths
        out = body(*jax.tree.map(lambda x: x[0], dev_args))
        return jax.tree.map(lambda x: x[None], out)

    specs = jax.tree.map(lambda _: P(SHARD_AXIS), args)
    return shard_map(dev_body, mesh=mesh, in_specs=specs,
                     out_specs=P(SHARD_AXIS), check_rep=False)(*args)


# ------------------------------------------------------------- jit programs
# One jitted program per (meta, agg, spec, mesh) for the WHOLE stack — the
# trace-count tests assert N-shard execution compiles exactly once.
@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _stacked_write_sum(meta, agg, spec, mesh, arrays, state, wmap,
                       ids, vals, valid):
    def body(arrays, state, wmap, ids_c, vals_c, valid_c):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        vals = lax.all_gather(vals_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        rows = wmap[jnp.clip(ids, 0, wmap.shape[0] - 1)]
        mask = valid & (rows >= 0)
        return write_step_sum(meta, agg, spec, arrays, state,
                              jnp.maximum(rows, 0), vals, mask)

    return _run_stacked(mesh, body, (arrays, state, wmap, ids, vals, valid))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _stacked_write_extremal(meta, agg, spec, mesh, arrays, state, wmap,
                            ids, vals, valid, prev_now):
    def body(arrays, state, wmap, ids_c, vals_c, valid_c, prev):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        vals = lax.all_gather(vals_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        rows = wmap[jnp.clip(ids, 0, wmap.shape[0] - 1)]
        mask = valid & (rows >= 0)
        return write_step_extremal(meta, agg, spec, arrays, state,
                                   jnp.maximum(rows, 0), vals, mask, prev)

    return _run_stacked(mesh, body,
                        (arrays, state, wmap, ids, vals, valid, prev_now))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _stacked_write_sum_sparse(meta, agg, spec, mesh, arrays, state, wmap,
                              ids, vals, valid, active):
    """Frontier-sparse twin of ``_stacked_write_sum``: each shard's slice of
    ``active`` (a per-level tuple of (S, K_l) arrays) is the host-expanded
    active-block list for that shard's own plan — the batch is still
    globally all-gathered, but each shard's level sweep only touches its own
    reachable blocks."""
    def body(arrays, state, wmap, ids_c, vals_c, valid_c, act):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        vals = lax.all_gather(vals_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        rows = wmap[jnp.clip(ids, 0, wmap.shape[0] - 1)]
        mask = valid & (rows >= 0)
        return write_step_sum_sparse(meta, agg, spec, arrays, state,
                                     jnp.maximum(rows, 0), vals, mask, act)

    return _run_stacked(mesh, body,
                        (arrays, state, wmap, ids, vals, valid, active))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _stacked_write_extremal_sparse(meta, agg, spec, mesh, arrays, state,
                                   wmap, ids, vals, valid, prev_now, active):
    def body(arrays, state, wmap, ids_c, vals_c, valid_c, prev, act):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        vals = lax.all_gather(vals_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        rows = wmap[jnp.clip(ids, 0, wmap.shape[0] - 1)]
        mask = valid & (rows >= 0)
        return write_step_extremal_sparse(meta, agg, spec, arrays, state,
                                          jnp.maximum(rows, 0), vals, mask,
                                          prev, act)

    return _run_stacked(
        mesh, body,
        (arrays, state, wmap, ids, vals, valid, prev_now, active))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5))
def _stacked_write_alert(step, meta, agg, spec, cap, mesh, arrays, state,
                         astate, wmap, ids, vals, valid, *extra):
    """Stacked twin of ``streams.alerts._alert_write``: the per-shard pure
    write body (``step`` — dense/sparse x sum/extremal, a static argument)
    plus the alert predicate sweep over that shard's own slice of the alert
    columns. Each reader is owned by exactly one shard, so the per-shard
    compact fired buffers are disjoint by construction and the only
    cross-shard exchange is ONE collective: the psum of the per-shard fired
    counts, which replicates the batch's global total so the host readback
    touches a single scalar."""
    def body(arrays, state, astate, wmap, ids_c, vals_c, valid_c, *extra):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        vals = lax.all_gather(vals_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        rows = wmap[jnp.clip(ids, 0, wmap.shape[0] - 1)]
        mask = valid & (rows >= 0)
        ns = step(meta, agg, spec, arrays, state, jnp.maximum(rows, 0),
                  vals, mask, *extra)
        from repro.streams.alerts import alert_eval
        na, count, idx, avals, fired, m = alert_eval(
            agg, astate, ns.pao, ns.now - 1.0, cap)
        total = lax.psum(count, SHARD_AXIS)
        return ns, na, total, idx, avals, fired, m

    return _run_stacked(
        mesh, body,
        (arrays, state, astate, wmap, ids, vals, valid) + extra)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _stacked_read(meta, agg, mesh, arrays, state, rmap, ids, valid):
    def body(arrays, state, rmap, ids_c, valid_c):
        ids = lax.all_gather(ids_c, SHARD_AXIS, tiled=True)
        valid = lax.all_gather(valid_c, SHARD_AXIS, tiled=True)
        nodes = rmap[jnp.clip(ids, 0, rmap.shape[0] - 1)]
        own = valid & (nodes >= 0)
        ans, _ = read_step(meta, agg, arrays, state,
                           jnp.maximum(nodes, 0), own)
        ownb = own.reshape(own.shape + (1,) * (ans.ndim - own.ndim))
        # every reader is owned by exactly one shard, so the cross-shard sum
        # of masked answers IS the gather of per-shard results
        return lax.psum(jnp.where(ownb, ans, jnp.zeros_like(ans)), SHARD_AXIS)

    out = _run_stacked(mesh, body, (arrays, state, rmap, ids, valid))
    return out[0]  # replicated across the shard axis


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _stacked_patch(mesh, arrays, prog, flags):
    """Patch ONE shard's slice of the stacked ``PlanArrays`` pytree entirely
    on device: every shard runs the same lowered ``PatchProgram`` body
    (``plan_patch.apply_patch_program``) over its own slice, and the per-shard
    flag keeps only the owning shard's patched tables — the stack is donated,
    so churn on shard k rewrites the tables in place with no host scatter and
    no desync of the mesh. One cache entry per (mesh, program-bucket)."""
    def body(arrays, prog, flag):
        patched = apply_patch_program(arrays, prog)
        return jax.tree.map(lambda p, o: jnp.where(flag, p, o),
                            patched, arrays)

    if mesh is None:
        return jax.vmap(body, in_axes=(0, None, 0),
                        axis_name=SHARD_AXIS)(arrays, prog, flags)

    def dev_body(arrays, prog, flag):
        out = body(jax.tree.map(lambda x: x[0], arrays), prog, flag[0])
        return jax.tree.map(lambda x: x[None], out)

    arr_specs = jax.tree.map(lambda _: P(SHARD_AXIS), arrays)
    prog_specs = jax.tree.map(lambda _: P(), prog)
    return shard_map(dev_body, mesh=mesh,
                     in_specs=(arr_specs, prog_specs, P(SHARD_AXIS)),
                     out_specs=P(SHARD_AXIS),
                     check_rep=False)(arrays, prog, flags)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_owner_rows(m, shard, base, val):
    """Rewrite individual (shard, base-id) owner-map entries in place;
    shape-bucket padding carries an out-of-bounds base id and is dropped."""
    return m.at[shard, base].set(val, mode="drop")


# ----------------------------------------------------------------------- API
class StackedShardedEngine:
    """N reader-partitioned shards, one jit trace, one device program.

    Owns the stacked runtime state of a ``ShardedOverlay`` whose plans were
    aligned by ``align_shard_plans``:

      arrays   PlanArrays pytree, every leaf (S, ...)
      state    EngineState — windows (S, n_writers, cap), pao (S, n_nodes, d),
               now (S,)
      maps     writer_map / reader_map (S, base_cap) int32, -1 = not owned

    ``write_batch`` / ``read_batch`` take *global* batches of base ids —
    routing happens on-device (all-gather + owner-map mask), replacing the
    host-side ``shard_write_batch`` / ``shard_read_batch`` scatter. Structural
    churn patches one shard slice at a time (``apply_delta``); a growth
    fallback on any shard triggers a stack-wide realign + ``restack``.
    """

    def __init__(self, sharded, aggregate: Aggregate,
                 window: WindowSpec | None = None, *,
                 mesh: "str | object | None" = "auto",
                 base_capacity: int | None = None):
        metas = {p.meta for p in sharded.shard_plans}
        if len(metas) != 1:
            raise ValueError(
                "shard plans are not aligned to one PlanMeta — build the "
                f"ShardedOverlay through align_shard_plans (got {metas})")
        self.sharded = sharded
        self.agg = aggregate
        self.spec = window or WindowSpec(kind="tuple", size=1)
        self.meta = sharded.shard_plans[0].meta
        self.n_shards = sharded.n_shards
        self.mesh = make_shard_mesh(self.n_shards) if mesh == "auto" else mesh
        self.arrays = self._commit(stack_plan_arrays(
            [p.arrays for p in sharded.shard_plans]))
        self.state = self._commit(self.init_state())
        self._base_cap = _bucket_base_cap(base_capacity or 1)
        self._reader_owner: dict[int, int] = {}
        self._pending_retired: dict[int, list[int]] = {}
        self._needs_restack = False
        self.alerts = None  # streams.alerts.AlertSet (attach_alerts)
        self.pin_push = False  # continuous groups: churn-added nodes stay PUSH
        # host-side clocks mirror EagrEngine's; `now` advances in lockstep
        # (every global batch runs on every shard) but the last PAO-eval
        # instant is PER SHARD — a slice patch refreshes one shard's PAOs
        # without touching its siblings' expiry recompute windows
        self._now_host = 0.0
        self._last_eval_now = np.zeros(self.n_shards, np.float32)
        self.refresh_owner_maps()

    @property
    def shard_plans(self):
        """Aligned per-shard ``ExecPlan`` list (the seam ``AlertSet.sync``
        resolves reader rows against)."""
        return self.sharded.shard_plans

    def attach_alerts(self, alerts) -> None:
        """Attach an ``AlertSet`` over the stack: rows resolve to (owner
        shard, node) and every subsequent global batch runs the fused
        write+eval program with per-shard disjoint fired buffers."""
        self.alerts = alerts
        alerts.sync(self)

    def _put_alert_state(self, host_state):
        """Alert columns are stacked (S, n_rows) leaves — pin them to the
        canonical shard-axis sharding like every other stacked input so the
        fused program keeps one cache entry."""
        return self._commit(jax.device_put(host_state))

    # ------------------------------------------------------------------ state
    def _commit(self, tree):
        """Pin every stacked leaf to the canonical shard-axis sharding. Host-
        side mutations (slice patches, owner-map rebuilds) otherwise leave
        arrays with ad-hoc shardings, and jit keys its cache on input
        shardings — committing keeps the stack on ONE compiled program."""
        if self.mesh is None:
            return tree
        sh = jax.sharding.NamedSharding(self.mesh, P(SHARD_AXIS))
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    def init_state(self) -> EngineState:
        windows = stack_windows(
            [init_windows(self.meta.n_writers, self.spec)
             for _ in range(self.n_shards)])
        pao = jnp.stack([self.agg.init_pao(self.meta.n_nodes)
                         for _ in range(self.n_shards)])
        return EngineState(windows, pao, jnp.zeros((self.n_shards,),
                                                   jnp.float32))

    def adopt_state(self, state: EngineState, *, now_host: float,
                    last_eval_now) -> None:
        """Adopt a restored stacked ``EngineState`` plus the host clock
        mirror and the per-shard last-PAO-eval instants (checkpoint restore
        seam). The state is committed to the canonical shard sharding and
        taken verbatim — no PAO refresh, so restored reads stay bit-identical
        to the saved session's."""
        self.state = self._commit(state)
        self._now_host = float(now_host)
        self._last_eval_now = np.asarray(last_eval_now,
                                         np.float32).reshape(-1).copy()
        if len(self._last_eval_now) != self.n_shards:
            raise ValueError(
                f"last_eval_now has {len(self._last_eval_now)} shards, "
                f"engine has {self.n_shards}")

    def refresh_owner_maps(self) -> None:
        """Rebuild the device-resident base-id routing maps from the host
        plans (after construction and after structural churn). Capacity grows
        in buckets so the stacked programs keep their traced shapes."""
        plans = self.sharded.shard_plans
        top = 0
        for p in plans:
            for m in (p.writer_row_of_base, p.reader_node_of_base):
                if m:
                    top = max(top, max(m) + 1)
        self._base_cap = max(self._base_cap, _bucket_base_cap(top))
        wmap = np.full((self.n_shards, self._base_cap), -1, np.int32)
        rmap = np.full((self.n_shards, self._base_cap), -1, np.int32)
        self._reader_owner = {}
        for s, p in enumerate(plans):
            wm, rm = p.writer_row_of_base, p.reader_node_of_base
            if wm:
                b = np.fromiter(wm.keys(), np.int64, len(wm))
                wmap[s, b] = np.fromiter(wm.values(), np.int64, len(wm))
            if rm:
                b = np.fromiter(rm.keys(), np.int64, len(rm))
                rmap[s, b] = np.fromiter(rm.values(), np.int64, len(rm))
                self._reader_owner.update((int(x), s) for x in b)
        # dense host twin of the reader map's "some shard owns this base id"
        # predicate — the read path's unknown-id check is one vectorized
        # gather against it instead of a per-id dict probe
        self._reader_known = (rmap >= 0).any(axis=0)
        self.writer_map = self._commit(jax.device_put(wmap))
        self.reader_map = self._commit(jax.device_put(rmap))

    def _chunk(self, ids: np.ndarray, vals: np.ndarray | None,
               batch_size: int | None):
        """Pad a global batch to a multiple of n_shards and split it into the
        per-shard chunks the on-device all-gather reassembles."""
        B = batch_size or max(1, len(ids))
        if B < len(ids):
            raise ValueError(f"batch_size={B} < batch of {len(ids)}")
        S = self.n_shards
        Bp = -(-B // S) * S
        idp = np.zeros(Bp, np.int32)
        idp[: len(ids)] = ids
        valid = np.zeros(Bp, bool)
        # ids outside the owner maps' range are owned by no shard (the
        # device-side clip would otherwise alias them onto base id 0)
        valid[: len(ids)] = (ids >= 0) & (ids < self._base_cap)
        # explicit device_put (never jnp.asarray): transfers stay visible to
        # transfer guards, and the arrays are freshly allocated per call so a
        # CPU zero-copy alias can't race the async dispatch
        out = [jax.device_put(idp.reshape(S, -1)),
               jax.device_put(valid.reshape(S, -1))]
        if vals is not None:
            vp = np.zeros((Bp,) + vals.shape[1:], np.float32)
            vp[: len(ids)] = vals
            out.append(jax.device_put(vp.reshape((S, -1) + vals.shape[1:])))
        return out

    # -------------------------------------------------------------- execution
    def _frontier_active(self, base_ids: np.ndarray):
        """Per-shard frontier expansion for one global batch: a ragged
        per-level tuple of stacked (S, K_l) active-block arrays, or ``None``
        for the dense stacked sweep. All shards must go sparse together (one
        program runs the whole stack), sharing each level's max bucketed
        width; shard plans are aligned, so one block count pads every slice.
        Extremal time windows stay dense — the stacked path has no
        expiry-heap bookkeeping to bound the stale set."""
        from repro.core import frontier as F

        mode = F.sparse_mode()
        if mode == "0" or self.meta.backend == "xla_unrolled":
            return None
        if self.agg.combine != "sum" and self.spec.kind == "time":
            return None
        ids = np.asarray(base_ids, np.int64).reshape(-1)
        acts = []
        for p in self.sharded.shard_plans:
            rows, mask = p.routes.writer_rows(ids)
            density = None
            if mode == "auto":
                nb = p.arrays.push.tile_of_block.shape[1]
                n_live = int(np.count_nonzero(mask))
                if nb < 8 or n_live > F.sparse_rowfrac() * p.meta.n_writers:
                    return None
                density = F.sparse_density()
            exact = self.agg.combine == "sum"
            if p.frontier is None or p.frontier.exact != exact:
                p.frontier = F.FrontierIndex.build(p, exact=exact)
            act = p.frontier.expand(np.unique(rows[mask]), density=density)
            if act is None:
                return None
            acts.append(act)
        nb = self.sharded.shard_plans[0].arrays.push.tile_of_block.shape[1]
        out = []
        for l in range(len(acts[0])):
            K = max(a[l].shape[0] for a in acts)  # max of bucketed widths
            out.append(np.stack([
                np.pad(a[l], (0, K - a[l].shape[0]), constant_values=nb)
                for a in acts]).astype(np.int32))
        return tuple(out)

    def write_batch(self, base_ids: np.ndarray, values: np.ndarray,
                    batch_size: int | None = None) -> None:
        """Apply one *global* write batch. Every shard sees the whole batch
        (the paper's write replication) and keeps the writes it consumes;
        writes owned by no shard are dropped on-device, like the single
        engine drops writes that feed no reader. When every shard's batch
        frontier expands (``EAGR_SPARSE_WRITE``), the level sweeps run the
        frontier-sparse bodies over the stacked active-block lists."""
        base_ids = np.asarray(base_ids)
        values = np.asarray(values, np.float32)
        active = self._frontier_active(base_ids)
        ids, valid, vals = self._chunk(base_ids, values, batch_size)
        if active is not None:
            act_d = jax.device_put(tuple(
                np.ascontiguousarray(a) for a in active))
        al = self.alerts
        with_alerts = al is not None and al.enabled and al.n_placed
        if self.agg.combine == "sum":
            extra = () if active is None else (act_d,)
            step = write_step_sum if active is None else \
                write_step_sum_sparse
            plain = _stacked_write_sum if active is None else \
                _stacked_write_sum_sparse
        else:
            # unlike EagrEngine there is no all-dropped-batch skip (a global
            # batch always dispatches), so no expiry-deadline bookkeeping —
            # only the per-shard prev-eval clocks the touched-writer
            # restriction needs. _last_eval_now is treated as immutable and
            # REBOUND, never mutated: jnp.asarray may zero-copy alias the
            # numpy buffer, and an in-place write would race the async
            # dispatch reading it
            prev = jax.device_put(self._last_eval_now)
            self._last_eval_now = np.full(self.n_shards, self._now_host,
                                          np.float32)
            extra = (prev,) if active is None else (prev, act_d)
            step = write_step_extremal if active is None else \
                write_step_extremal_sparse
            plain = _stacked_write_extremal if active is None else \
                _stacked_write_extremal_sparse
        if with_alerts:
            now_eval = self._now_host
            out = _stacked_write_alert(
                step, self.meta, self.agg, self.spec, al.cap, self.mesh,
                self.arrays, self.state, al.state, self.writer_map,
                ids, vals, valid, *extra)
            self.state, al.state, total, idx, avals, fired, m = out
            al.push_pending(now_eval, total, idx, avals, fired, m)
        else:
            self.state = plain(
                self.meta, self.agg, self.spec, self.mesh, self.arrays,
                self.state, self.writer_map, ids, vals, valid, *extra)
        self._now_host += 1.0

    def read_batch(self, base_ids: np.ndarray,
                   batch_size: int | None = None) -> np.ndarray:
        """Answer one global read batch: shard-local pull sweeps, one psum to
        gather the per-shard answers. Raises for base ids no shard owns."""
        base_ids = np.asarray(base_ids)
        ids64 = base_ids.astype(np.int64).reshape(-1)
        known = np.zeros(len(ids64), bool)
        inb = (ids64 >= 0) & (ids64 < len(self._reader_known))
        known[inb] = self._reader_known[ids64[inb]]
        if not known.all():
            raise ValueError(
                f"read_batch: base ids "
                f"{sorted(set(map(int, ids64[~known])))[:8]} are owned "
                f"by no shard (not readers of any shard overlay)")
        ids, valid = self._chunk(base_ids, None, batch_size)
        ans = _stacked_read(self.meta, self.agg, self.mesh, self.arrays,
                            self.state, self.reader_map, ids, valid)
        return np.asarray(jax.device_get(ans))[: len(base_ids)]

    # ----------------------------------------------------- structural updates
    def apply_delta(self, s: int, delta, *, growth: float = 2.0):
        """Patch shard ``s``'s plan (§3.3) and, when the patch stayed within
        capacity, replay the SAME lowered ``PatchProgram`` on exactly that
        slice of the stacked pytree (``_stacked_patch``, masked + donated) —
        the other shards' tables, windows and PAOs are untouched, no table
        travels through the host, and every stacked program keeps its trace.
        Owner-map rows are scattered in place the same way. A growth fallback
        defers to ``restack``."""
        from repro.core.plan_patch import patch_plan

        plan = self.sharded.shard_plans[s]
        wm_before = dict(plan.writer_row_of_base)
        rm_before = dict(plan.reader_node_of_base)
        res = patch_plan(plan, delta, overlay=self.sharded.shards[s],
                         growth=growth, pin_push=self.pin_push)
        if res.reason == "empty delta":
            return res
        self.sharded.shard_plans[s] = res.plan
        self.sharded.writer_rows[s] = res.plan.writer_row_of_base
        if res.recompiled:
            # shapes moved: the caller realigns every shard to the new padded
            # dims (ShardedDynamic.ensure_aligned) and then restacks
            self._pending_retired[s] = list(res.retired_writer_rows)
            self._needs_restack = True
            return res
        flags = np.zeros(self.n_shards, bool)
        flags[s] = True
        self.arrays = self._commit(_stacked_patch(
            self.mesh, self.arrays, res.program, jax.device_put(flags)))
        self._refresh_shard_state(s, res.retired_writer_rows)
        self._patch_owner_maps(s, wm_before, rm_before, res.plan)
        if self.alerts is not None:
            self.alerts.sync(self, retired=res.retired_reader_bases)
        return res

    def _patch_owner_maps(self, s: int, wm_before: dict, rm_before: dict,
                          plan) -> None:
        """Scatter only shard ``s``'s changed owner-map rows (base id ->
        writer row / reader node) instead of rebuilding + re-uploading the
        whole (S, base_cap) maps per delta. A base id past the current
        capacity bucket falls back to the full rebuild (a traced-shape
        growth, so the stacked programs retrace once at the crossing)."""
        wm, rm = plan.writer_row_of_base, plan.reader_node_of_base
        w_edits = [(b, r) for b, r in wm.items() if wm_before.get(b) != r]
        w_edits += [(b, -1) for b in wm_before if b not in wm]
        r_edits = [(b, n) for b, n in rm.items() if rm_before.get(b) != n]
        r_edits += [(b, -1) for b in rm_before if b not in rm]
        if not (w_edits or r_edits):
            return
        if max(b for b, _ in w_edits + r_edits) >= self._base_cap:
            self.refresh_owner_maps()
            return
        for b, n in r_edits:
            if n >= 0:
                self._reader_owner[int(b)] = s
                self._reader_known[int(b)] = True
            elif self._reader_owner.get(int(b)) == s:
                # only the still-owning shard may unregister: a reader that
                # MOVED shards may have been claimed by its new home already
                self._reader_owner.pop(int(b), None)
                self._reader_known[int(b)] = False
        if w_edits:
            self.writer_map = self._commit(
                self._scatter_map_edits(self.writer_map, s, w_edits))
        if r_edits:
            self.reader_map = self._commit(
                self._scatter_map_edits(self.reader_map, s, r_edits))

    def _scatter_map_edits(self, m, s: int, edits: list):
        k = _bucket(len(edits), 16)
        base = np.full(k, _OOB, np.int32)
        val = np.zeros(k, np.int32)
        for i, (b, v) in enumerate(edits):
            base[i], val[i] = b, v
        shard = np.full(k, s, np.int32)
        return _scatter_owner_rows(m, *jax.device_put((shard, base, val)))

    def _refresh_shard_state(self, s: int, retired_rows) -> None:
        """Migrate one shard's window/PAO slice after an in-capacity patch:
        retired writer rows are zeroed and the slice's PAOs repaired by the
        same cached ``_refresh_pao`` program single engines use."""
        win_s = window_shard(self.state.windows, s)
        if retired_rows:
            win_s = reset_window_rows(win_s, retired_rows)
        pao_s = _refresh_pao(self.meta, self.agg, self.spec,
                             plan_arrays_shard(self.arrays, s), win_s,
                             self.state.now[s])
        self.state = self._commit(EngineState(
            place_window_shard(self.state.windows, s, win_s),
            self.state.pao.at[s].set(pao_s),
            self.state.now))
        # only THIS shard's PAOs were just evaluated — its siblings keep
        # their own last-eval instants (and with them their expiry windows);
        # rebind rather than mutate (the old buffer may back a live jnp alias)
        lev = self._last_eval_now.copy()
        lev[s] = self._now_host
        self._last_eval_now = lev

    def adopt_shard_plans(self) -> None:
        """Public seam for externally replaced shard plans (a decision
        re-adoption or an out-of-band realign): re-adopt the whole stack from
        ``sharded.shard_plans``. The overlay structure is unchanged on this
        path, so windows survive by position; arrays restack, PAO slices
        refresh, owner maps rebuild."""
        self._needs_restack = True
        self.restack()

    def restack(self) -> None:
        """Re-adopt every shard plan after a stack-wide realignment (a growth
        fallback on any shard): new meta, re-stacked arrays, window rows
        padded per shard, all PAO slices refreshed, owner maps rebuilt."""
        plans = self.sharded.shard_plans
        metas = {p.meta for p in plans}
        if len(metas) != 1:
            raise ValueError(f"restack on misaligned shard plans: {metas}")
        self.meta = plans[0].meta
        self.arrays = self._commit(stack_plan_arrays([p.arrays for p in plans]))
        wins, paos = [], []
        for s in range(self.n_shards):
            w = pad_window_rows(window_shard(self.state.windows, s),
                                self.meta.n_writers)
            retired = self._pending_retired.pop(s, None)
            if retired:
                w = reset_window_rows(w, retired)
            wins.append(w)
            paos.append(_refresh_pao(self.meta, self.agg, self.spec,
                                     plan_arrays_shard(self.arrays, s), w,
                                     self.state.now[s]))
        self.state = self._commit(EngineState(stack_windows(wins),
                                              jnp.stack(paos),
                                              self.state.now))
        self._last_eval_now = np.full(self.n_shards, self._now_host,
                                      np.float32)
        self._needs_restack = False
        self.refresh_owner_maps()
        if self.alerts is not None:
            self.alerts.sync(self)
