from repro.graphs.csr import CSRGraph
from repro.graphs.generators import (
    rmat_graph,
    erdos_graph,
    cora_like_graph,
    small_example_graph,
)
from repro.graphs.sampler import NeighborSampler

__all__ = [
    "CSRGraph",
    "rmat_graph",
    "erdos_graph",
    "cora_like_graph",
    "small_example_graph",
    "NeighborSampler",
]
