"""Compressed-sparse-row directed graph — the substrate for the data graph G(V, E).

All EAGr compile-phase algorithms (bipartite construction, VNM, IOB, dataflow)
operate on this host-side structure; the JAX runtime consumes flat arrays derived
from it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Directed graph in CSR form. ``indptr[v]:indptr[v+1]`` slices ``indices``
    to give the *out*-neighbors of v. Edge (u -> v) means "v consumes u's content"
    when interpreted for ego-centric queries with N(x) = {y | y -> x}."""

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (m,) int32/int64
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def out_degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        """Build from an edge list; parallel edges are deduplicated."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.size:
            key = src * np.int64(n_nodes) + dst
            key = np.unique(key)
            src = key // n_nodes
            dst = key % n_nodes
        counts = np.bincount(src, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        order = np.argsort(src, kind="stable")
        return CSRGraph(indptr=indptr, indices=dst[order].astype(np.int64), n_nodes=n_nodes)

    def reverse(self) -> "CSRGraph":
        """Reverse all edges (gives in-neighbor adjacency as out-adjacency)."""
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int64), np.diff(self.indptr))
        return CSRGraph.from_edges(self.indices, src, self.n_nodes)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int64), np.diff(self.indptr))
        return src, self.indices.copy()

    def two_hop(self, cap_per_node: int | None = None) -> "CSRGraph":
        """Graph whose out-neighbors are the union of 1- and 2-hop out-neighbors.

        Used for 2-hop ego-centric queries (paper §5.4 "Two-hop Aggregates").
        ``cap_per_node`` optionally truncates huge 2-hop lists (hub protection).
        """
        new_src: list[np.ndarray] = []
        new_dst: list[np.ndarray] = []
        for v in range(self.n_nodes):
            one = self.out_neighbors(v)
            if one.size == 0:
                continue
            pieces = [one]
            for u in one:
                pieces.append(self.out_neighbors(int(u)))
            nbrs = np.unique(np.concatenate(pieces))
            nbrs = nbrs[nbrs != v]
            if cap_per_node is not None and nbrs.size > cap_per_node:
                nbrs = nbrs[:cap_per_node]
            new_src.append(np.full(nbrs.size, v, dtype=np.int64))
            new_dst.append(nbrs)
        if not new_src:
            return CSRGraph(np.zeros(self.n_nodes + 1, np.int64), np.zeros(0, np.int64), self.n_nodes)
        return CSRGraph.from_edges(np.concatenate(new_src), np.concatenate(new_dst), self.n_nodes)
