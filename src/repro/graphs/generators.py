"""Synthetic graph generators.

The paper evaluates on LiveJournal / Google+ / web graphs. Those are not available
offline, so benchmarks use R-MAT graphs (the standard synthetic stand-in with
power-law degree distributions matching social/web graphs) plus small fixtures.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import CSRGraph


def rmat_graph(
    n_nodes: int,
    n_edges: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    symmetric: bool = False,
) -> CSRGraph:
    """R-MAT (Chakrabarti et al.) power-law graph. Defaults mimic social graphs."""
    rng = np.random.default_rng(seed)
    scale = max(1, int(np.ceil(np.log2(max(2, n_nodes)))))
    # Oversample to compensate for dedup + self-loop removal.
    m = int(n_edges * 1.15) + 16
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for level in range(scale):
        r = rng.random(m)
        go_right = (r >= a) & (r < ab) | (r >= abc)
        go_down = r >= ab
        bit = np.int64(1) << (scale - 1 - level)
        src += bit * go_down
        dst += bit * go_right
    src %= n_nodes
    dst %= n_nodes
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if symmetric:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    src, dst = src[: n_edges * (2 if symmetric else 1)], dst[: n_edges * (2 if symmetric else 1)]
    return CSRGraph.from_edges(src, dst, n_nodes)


def powerlaw_graph(n_nodes: int, n_edges: int, *, beta: float = 0.75,
                   sharing: float = 0.0, group: int = 16,
                   seed: int = 0) -> CSRGraph:
    """Memory-lean power-law graph for million-node benches.

    Edge destinations are drawn by inverse-CDF from a rank-weighted
    distribution w_rank ∝ rank^-beta over a random node permutation (tail
    exponent of the in-degree distribution ≈ 1 + 1/beta ≈ 2.3 at the default,
    the social/web-graph regime); sources are uniform. Everything stays in
    flat int32/float64 arrays — no Python edge lists — so peak memory is a
    few hundred MB at 10M edges instead of the GBs a list-of-tuples costs.

    ``sharing`` (0..1) routes that fraction of each reader's in-edges to a
    writer set shared by its group of ``group`` consecutive readers — the
    vectorized analogue of ``copying_graph``'s shared-adjacency structure,
    i.e. the compressible regime where the paper reports high sharing
    indices. 0 keeps pure i.i.d. power-law edges (SI ~ 0).
    """
    rng = np.random.default_rng(seed)
    w = np.arange(1, n_nodes + 1, dtype=np.float64) ** (-beta)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    perm = rng.permutation(n_nodes).astype(np.int32)

    def powerlaw_nodes(k: int) -> np.ndarray:
        return perm[np.searchsorted(cdf, rng.random(k)).astype(np.int32)]

    n_shared = int(round(n_edges / n_nodes * sharing)) if sharing > 0 else 0
    parts_src, parts_dst = [], []
    if n_shared:
        n_groups = (n_nodes + group - 1) // group
        proto = powerlaw_nodes(n_groups * n_shared).reshape(n_groups, n_shared)
        readers = np.arange(n_nodes, dtype=np.int32)
        parts_src.append(proto[readers // group].ravel())
        parts_dst.append(np.repeat(readers, n_shared))
    m = int((n_edges - n_shared * n_nodes) * 1.08) + 16  # dedup/self-loop slack
    parts_src.append(rng.integers(0, n_nodes, m, dtype=np.int32))
    parts_dst.append(powerlaw_nodes(m))
    src = np.concatenate(parts_src)
    dst = np.concatenate(parts_dst)
    keep = src != dst
    src, dst = src[keep][:n_edges], dst[keep][:n_edges]
    return CSRGraph.from_edges(src, dst, n_nodes)


def copying_graph(n_nodes: int, out_degree: int = 8, copy_p: float = 0.7,
                  seed: int = 0) -> CSRGraph:
    """Kleinberg/Kumar 'copying model' web graph: each new node copies a
    random fraction of a prototype's out-links. Produces the shared-adjacency
    structure that makes real web graphs highly compressible — the regime
    where the paper reports SI ~0.7-0.8 (vs ~0.1 for social graphs)."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    adj: list[np.ndarray] = [np.zeros(0, dtype=np.int64)]
    for v in range(1, n_nodes):
        proto = int(rng.integers(0, v))
        proto_links = adj[proto]
        links = []
        for j in range(out_degree):
            if proto_links.size and rng.random() < copy_p:
                links.append(int(proto_links[j % proto_links.size]))
            else:
                links.append(int(rng.integers(0, v)))
        links = np.unique(np.array(links, dtype=np.int64))
        links = links[links != v]
        adj.append(links)
        src.extend([v] * links.size)
        dst.extend(links.tolist())
    return CSRGraph.from_edges(np.array(src), np.array(dst), n_nodes)


def erdos_graph(n_nodes: int, n_edges: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges * 2)
    dst = rng.integers(0, n_nodes, n_edges * 2)
    keep = src != dst
    return CSRGraph.from_edges(src[keep][:n_edges], dst[keep][:n_edges], n_nodes)


def cora_like_graph(
    n_nodes: int = 2708, n_edges: int = 10556, d_feat: int = 1433, n_classes: int = 7, seed: int = 0
):
    """Citation-network stand-in with Cora's statistics: returns (graph, features, labels)."""
    g = rmat_graph(n_nodes, n_edges // 2, seed=seed, symmetric=True)
    rng = np.random.default_rng(seed + 1)
    feats = (rng.random((n_nodes, d_feat)) < 0.012).astype(np.float32)  # sparse bag-of-words
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return g, feats, labels


def small_example_graph() -> CSRGraph:
    """The paper's running example (Figure 1a).

    Nodes a..g = 0..6. N(x) = {y | y -> x}; edges encoded so that the bipartite
    construction reproduces Figure 1(b):
      N(a)={c,d,e,f}, N(b)={c,d,e,f}, N(c)={a,b,d,e,f}, N(d)={a,b,c},
      N(e)={a,b,c,d}, N(f)={a,b,c,d,e}, N(g)={a,b,c,d,e,f}
    """
    N = {
        0: [2, 3, 4, 5],
        1: [2, 3, 4, 5],
        2: [0, 1, 3, 4, 5],
        3: [0, 1, 2],
        4: [0, 1, 2, 3],
        5: [0, 1, 2, 3, 4],
        6: [0, 1, 2, 3, 4, 5],
    }
    src, dst = [], []
    for reader, writers in N.items():
        for w in writers:
            src.append(w)
            dst.append(reader)
    return CSRGraph.from_edges(np.array(src), np.array(dst), 7)
