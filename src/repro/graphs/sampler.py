"""Neighbor sampler for mini-batch GNN training (GraphSAGE-style fanouts).

Produces fixed-shape padded layered subgraphs so the downstream JAX step is
jit-stable: for fanouts [f1, f2] and a seed batch of B nodes, layer sizes are
exactly B, B*f1, B*f1*f2 (with padding + validity masks for nodes with fewer
neighbors). This is the `minibatch_lg` shape's real sampler — not a stub.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """One message-passing block: edges from src-layer nodes to dst-layer nodes."""

    src_nodes: np.ndarray   # (n_src,) global node ids (padded with 0)
    dst_nodes: np.ndarray   # (n_dst,) global node ids
    edge_src: np.ndarray    # (n_dst * fanout,) local indices into src_nodes
    edge_dst: np.ndarray    # (n_dst * fanout,) local indices into dst_nodes (sorted)
    edge_mask: np.ndarray   # (n_dst * fanout,) bool validity
    src_mask: np.ndarray    # (n_src,) bool validity


class NeighborSampler:
    """Uniform neighbor sampling over the *in*-adjacency (aggregation direction)."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.adj = graph  # caller passes the adjacency in aggregation direction
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seed_nodes: np.ndarray) -> list[SampledBlock]:
        """Returns blocks ordered from the input layer to the seed layer."""
        blocks: list[SampledBlock] = []
        dst = np.asarray(seed_nodes, dtype=np.int64)
        dst_mask = np.ones(dst.shape[0], dtype=bool)
        for fanout in self.fanouts:
            n_dst = dst.shape[0]
            edge_src_global = np.zeros(n_dst * fanout, dtype=np.int64)
            edge_mask = np.zeros(n_dst * fanout, dtype=bool)
            for i in range(n_dst):
                if not dst_mask[i]:
                    continue
                nbrs = self.adj.out_neighbors(int(dst[i]))
                if nbrs.size == 0:
                    continue
                take = min(fanout, nbrs.size)
                chosen = self.rng.choice(nbrs, size=take, replace=nbrs.size < fanout)
                edge_src_global[i * fanout : i * fanout + take] = chosen
                edge_mask[i * fanout : i * fanout + take] = True
            # Unique source layer (dst nodes are also carried for self features).
            src_nodes, inverse = np.unique(
                np.concatenate([dst, edge_src_global[edge_mask]]), return_inverse=True
            )
            src_mask = np.ones(src_nodes.shape[0], dtype=bool)
            # local edge indices
            edge_src = np.zeros(n_dst * fanout, dtype=np.int64)
            edge_src[edge_mask] = inverse[n_dst:]
            edge_dst = np.repeat(np.arange(n_dst, dtype=np.int64), fanout)
            blocks.append(
                SampledBlock(
                    src_nodes=src_nodes,
                    dst_nodes=dst,
                    edge_src=edge_src,
                    edge_dst=edge_dst,
                    edge_mask=edge_mask,
                    src_mask=src_mask,
                )
            )
            dst = src_nodes
            dst_mask = src_mask
        blocks.reverse()
        return blocks

    @staticmethod
    def padded_layer_sizes(batch: int, fanouts: tuple[int, ...]) -> list[int]:
        """Upper-bound layer sizes used by input_specs() for jit-stable shapes."""
        sizes = [batch]
        for f in fanouts:
            sizes.append(sizes[-1] * (f + 1))  # dst nodes + sampled neighbors
        return sizes
