# Pallas TPU kernels for the compute hot spots:
#   segment_agg     sorted-segment reduce (EAGr overlay levels, GNN message agg)
#   embedding_bag   fused gather + segment-sum over embedding tables (recsys)
#   flash_attention blockwise causal GQA attention (LM prefill) + decode
# Each package ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper), ref.py (pure-jnp oracle). Validated with interpret=True on CPU;
# BlockSpecs are sized for TPU v5e VMEM (~16 MiB) and MXU 128-alignment.
