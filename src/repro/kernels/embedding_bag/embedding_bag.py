"""EmbeddingBag (gather + segment-sum) as a Pallas TPU kernel.

The hot path of every recsys model is: look up E sparse ids in a huge
embedding table living in HBM and sum them per bag. On TPU the table cannot
be tiled into VMEM up-front (tables are GBs); instead the *ids are
scalar-prefetched* and each grid step DMAs exactly one (1, D_BLK) table row —
the BlockSpec index_map reads ``ids[i]`` at runtime, so the DMA engine
performs the gather:

  grid = (n_feat_tiles, E)        # ids minor; ids are pre-sorted by bag, so
  table block: (1, D_BLK) at row ids[i]          # indexed DMA (the gather)
  out   block: (1, D_BLK) at row bag[i]          # consecutive revisits => VMEM
                                                 # accumulation, one writeback
                                                 # per bag

Padding ids carry weight 0 (they still DMA row 0; a no-op add). Per-id
weights ride in VMEM. This is HBM-bandwidth-bound by construction — exactly
one row read per id — which is the roofline optimum for a gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

D_BLK = 512  # feature lanes per DMA; amortizes the (1, D) thin-row transfer


def _bag_kernel(ids_ref, bags_ref, first_ref, w_ref, table_ref, out_ref):
    i = pl.program_id(1)

    @pl.when(first_ref[i] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[0, 0] * table_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def embedding_bag_call(
    table: jnp.ndarray,    # (V, D_pad)
    ids: jnp.ndarray,      # (E,) int32, sorted by bag; padding ids = 0
    bags: jnp.ndarray,     # (E,) int32, sorted ascending
    first: jnp.ndarray,    # (E,) int32, 1 where bags[i] != bags[i-1]
    weights: jnp.ndarray,  # (E, 1) fp32; 0 for padding lanes
    *,
    n_bags: int,
    interpret: bool = True,
) -> jnp.ndarray:
    E = ids.shape[0]
    D = table.shape[1]
    n_feat_tiles = D // D_BLK
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_feat_tiles, E),
        in_specs=[
            pl.BlockSpec((1, 1), lambda f, i, ids, bags, first: (i, 0)),      # weights
            pl.BlockSpec((1, D_BLK), lambda f, i, ids, bags, first: (ids[i], f)),  # table
        ],
        out_specs=pl.BlockSpec((1, D_BLK), lambda f, i, ids, bags, first: (bags[i], f)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, D), jnp.float32),
        interpret=interpret,
    )(ids, bags, first, weights, table)
