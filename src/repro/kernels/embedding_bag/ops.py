"""jit'd wrapper for the embedding_bag kernel.

Accepts the torch-style (ids, offsets) calling convention with dynamic
runtime ids. All plan quantities the kernel needs (per-id bag index, first-
of-bag flags) are computed with jnp ops and scalar-prefetched, so the whole
wrapper jits. Every bag is guaranteed coverage by appending one zero-weight
sentinel id per bag (empty bags then produce exact zeros, matching torch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import D_BLK, embedding_bag_call


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret", "use_kernel"))
def embedding_bag(
    table: jnp.ndarray,                 # (V, D)
    ids: jnp.ndarray,                   # (E,) int32; entries < 0 are padding
    offsets: jnp.ndarray,               # (n_bags,) int32 start offset per bag
    *,
    n_bags: int,
    weights: jnp.ndarray | None = None, # (E,) fp32
    interpret: bool = True,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Sum-mode EmbeddingBag: out[b] = sum_{i in bag b} w_i * table[ids[i]]."""
    E = ids.shape[0]
    D = table.shape[1]
    bags = jnp.searchsorted(offsets, jnp.arange(E, dtype=offsets.dtype), side="right") - 1
    valid = ids >= 0
    w = jnp.where(valid, 1.0 if weights is None else weights, 0.0).astype(jnp.float32)

    if not use_kernel:
        from repro.kernels.embedding_bag.ref import embedding_bag_ref

        return embedding_bag_ref(table, ids, bags.astype(jnp.int32), n_bags, weights=w)

    # sentinel per bag (covers empty bags), then stable sort by bag
    ids_all = jnp.concatenate([jnp.where(valid, ids, 0),
                               jnp.zeros((n_bags,), ids.dtype)])
    bags_all = jnp.concatenate([bags, jnp.arange(n_bags, dtype=bags.dtype)])
    w_all = jnp.concatenate([w, jnp.zeros((n_bags,), jnp.float32)])
    order = jnp.argsort(bags_all, stable=True)
    ids_s = ids_all[order].astype(jnp.int32)
    bags_s = bags_all[order].astype(jnp.int32)
    w_s = w_all[order]
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (bags_s[1:] != bags_s[:-1]).astype(jnp.int32)])

    d_pad = -(-D // D_BLK) * D_BLK
    table_p = jnp.pad(table, ((0, 0), (0, d_pad - D)))
    out = embedding_bag_call(
        table_p, ids_s, bags_s, first, w_s[:, None],
        n_bags=n_bags, interpret=interpret)
    return out[:, :D]
