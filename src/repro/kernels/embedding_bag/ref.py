"""Pure-jnp oracle: EmbeddingBag = gather + weighted segment-sum.

JAX has no native nn.EmbeddingBag; this jnp.take + segment_sum composition is
the reference the Pallas kernel must match (and the path used by models when
the kernel is off)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,    # (V, D)
    ids: jnp.ndarray,      # (E,) int32; entries < 0 are padding
    bags: jnp.ndarray,     # (E,) int32 bag index per id, sorted ascending
    n_bags: int,
    weights: jnp.ndarray | None = None,  # (E,) fp32 per-id weights
) -> jnp.ndarray:
    valid = ids >= 0
    rows = jnp.take(table, jnp.where(valid, ids, 0), axis=0)
    w = jnp.where(valid, 1.0 if weights is None else weights, 0.0)
    rows = rows * w[:, None]
    safe_bags = jnp.where(valid, bags, n_bags)
    return jax.ops.segment_sum(rows, safe_bags, num_segments=n_bags + 1)[:n_bags]
