"""Blockwise (flash) GQA attention as a Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling: the (Sq, Skv) score
matrix never materializes; per (query-tile, kv-tile) step the kernel keeps a
running row-max m, normalizer l, and output accumulator in VMEM scratch.

  grid = (B, Hq, n_q_tiles, n_kv_tiles)     # kv minor => scratch carries
  q tile (q_blk, d), k/v tile (k_blk, d)    # across kv steps of one q tile
  GQA: kv head index_map = hq // (Hq//Hkv)  # grouped heads share one kv DMA

Causal masking is two-level: whole kv tiles strictly above the diagonal are
skipped with @pl.when, and the diagonal tile is masked with a broadcasted
iota compare. Decode (Sq=1, KV cache with live length) reuses the same body
with the scalar-prefetched per-row length mask; q is padded to 8 rows to
respect the fp32 (8, 128) sublane tile.

VMEM (fp32, q_blk=256, k_blk=512, d=128): q 128K + k/v 512K + acc 128K +
p 512K ≈ 1.3 MiB « 16 MiB. All matmul dims 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLK = 256   # default prefill query tile
K_BLK = 512   # default kv tile
NEG_INF = -3.0e38


def _attn_kernel(len_ref, q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
                 *, causal: bool, scale: float, q_blk: int, k_blk: int):
    b = pl.program_id(0)
    i, j = pl.program_id(2), pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = i * q_blk
    k_first = j * k_blk

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (q_blk, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (k_blk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
        mask = kpos < len_ref[b]                      # live-length mask
        if causal:
            mask &= qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                         # (q_blk, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    if causal:
        pl.when(k_first <= q_first + q_blk - 1)(_step)
    else:
        _step()

    @pl.when(j == n_kv - 1)
    def _finish():
        l = l_ref[...]
        out_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_blk", "k_blk", "interpret")
)
def flash_attention_call(
    q: jnp.ndarray,        # (B, Hq, Sq_pad, d)
    k: jnp.ndarray,        # (B, Hkv, Skv_pad, d)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) int32 live kv length per batch row
    *,
    causal: bool = True,
    q_blk: int = Q_BLK,
    k_blk: int = K_BLK,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = 1.0 / (d ** 0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, Sq // q_blk, Skv // k_blk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, d), lambda b, h, i, j, L: (b, h, i, 0)),
            pl.BlockSpec((1, 1, k_blk, d), lambda b, h, i, j, L: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, k_blk, d), lambda b, h, i, j, L: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, d), lambda b, h, i, j, L: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 128), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((q_blk, 1), jnp.float32),    # l
            pltpu.VMEM((q_blk, d), jnp.float32),    # acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal, scale=scale,
                          q_blk=q_blk, k_blk=k_blk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
