"""jit'd wrappers: pad to tile multiples, then call the flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    K_BLK,
    Q_BLK,
    flash_attention_call,
)


def _pad_seq(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    S = x.shape[2]
    pad = (-S) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("causal", "q_blk", "k_blk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_blk: int = Q_BLK,
                    k_blk: int = K_BLK, interpret: bool = True) -> jnp.ndarray:
    """Prefill attention. q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d)."""
    B, _, Sq, _ = q.shape
    Skv = k.shape[2]
    q_blk = min(q_blk, max(8, Sq))
    k_blk = min(k_blk, max(128, Skv))
    qp = _pad_seq(q, q_blk)
    kp = _pad_seq(k, k_blk)
    vp = _pad_seq(v, k_blk)
    lengths = jnp.full((B,), Skv, dtype=jnp.int32)
    out = flash_attention_call(qp, kp, vp, lengths, causal=causal,
                               q_blk=q_blk, k_blk=k_blk, interpret=interpret)
    return out[:, :, :Sq, :]


@functools.partial(jax.jit, static_argnames=("k_blk", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, k_blk: int = K_BLK,
                 interpret: bool = True) -> jnp.ndarray:
    """One-token decode. q: (B, Hq, d); caches: (B, Hkv, S, d); lengths: (B,).
    q is padded to 8 rows (fp32 sublane tile); row 0 is the live token."""
    B, Hq, d = q.shape
    S = k_cache.shape[2]
    k_blk = min(k_blk, max(128, S))
    q4 = jnp.zeros((B, Hq, 8, d), q.dtype).at[:, :, 0, :].set(q)
    kp = _pad_seq(k_cache, k_blk)
    vp = _pad_seq(v_cache, k_blk)
    out = flash_attention_call(q4, kp, vp, lengths.astype(jnp.int32),
                               causal=False, q_blk=8, k_blk=k_blk,
                               interpret=interpret)
    return out[:, :, 0, :]
