"""Pure-jnp oracles for GQA attention (prefill and decode)."""
from __future__ import annotations

import jax.numpy as jnp


def _expand_kv(k: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """(B, Hkv, S, d) -> (B, Hq, S, d) by repeating each kv head."""
    group = n_q_heads // k.shape[1]
    return jnp.repeat(k, group, axis=1)


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, d); k,v: (B, Hkv, Skv, d). fp32 softmax."""
    B, Hq, Sq, d = q.shape
    k = _expand_kv(k, Hq)
    v = _expand_kv(v, Hq)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        Skv = k.shape[2]
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q, k_cache, v_cache, lengths) -> jnp.ndarray:
    """One-token decode: q (B, Hq, d); caches (B, Hkv, S, d); lengths (B,)."""
    B, Hq, d = q.shape
    k = _expand_kv(k_cache, Hq)
    v = _expand_kv(v_cache, Hq)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(k.shape[2])[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
