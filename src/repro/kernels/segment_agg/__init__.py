from repro.kernels.segment_agg.ops import SegmentPlan, make_plan, segment_agg
from repro.kernels.segment_agg.ref import segment_agg_ref

__all__ = ["SegmentPlan", "make_plan", "segment_agg", "segment_agg_ref"]
