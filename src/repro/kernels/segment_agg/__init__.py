from repro.kernels.segment_agg.ops import (
    LeveledPlan,
    SegmentPlan,
    make_leveled_plan,
    make_plan,
    segment_agg,
    segment_agg_level,
)
from repro.kernels.segment_agg.ref import segment_agg_ref

__all__ = [
    "LeveledPlan",
    "SegmentPlan",
    "make_leveled_plan",
    "make_plan",
    "segment_agg",
    "segment_agg_level",
    "segment_agg_ref",
]
