"""jit'd wrapper for the segment_agg kernel.

The kernel requires edges sorted by destination and padded so no E_BLK edge
block straddles an R_BLK row tile. For static graph structure (GNN adjacency,
EAGr overlay levels) that plan is built once on the host (``make_plan``) and
reused every step; only the edge *values* are runtime data.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_agg.segment_agg import (
    E_BLK,
    F_BLK,
    R_BLK,
    segment_agg_call,
)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash, so a
class SegmentPlan:                             # plan can be a static jit arg
    """Host-compiled routing plan for one static (seg, n_rows) structure."""

    perm: np.ndarray            # (E,) original edge -> slot in padded layout
    seg_padded: np.ndarray      # (E_pad,) int32, -1 padding
    tile_of_block: np.ndarray   # (n_edge_blocks,) int32
    first_of_tile: np.ndarray   # (n_edge_blocks,) int32
    n_rows: int
    n_row_tiles: int
    e_pad: int

    @property
    def pad_overhead(self) -> float:
        return self.e_pad / max(1, len(self.perm)) - 1.0


def make_plan(seg: np.ndarray, n_rows: int) -> SegmentPlan:
    """Group edges by row tile, pad each tile's edge count to a multiple of
    E_BLK, and record block->tile routing for the scalar-prefetch index maps."""
    seg = np.asarray(seg, dtype=np.int64)
    order = np.argsort(seg, kind="stable")
    n_row_tiles = max(1, -(-n_rows // R_BLK))

    tile = seg[order] // R_BLK
    slots = []
    seg_chunks = []
    tob, fot = [], []
    e_cursor = 0
    for t in range(n_row_tiles):
        idx = order[tile == t]
        if idx.size == 0:
            continue
        n_blocks = -(-idx.size // E_BLK)
        padded = n_blocks * E_BLK
        slots.append((idx, e_cursor))
        chunk = np.full(padded, -1, dtype=np.int32)
        chunk[: idx.size] = seg[idx]
        seg_chunks.append(chunk)
        tob.extend([t] * n_blocks)
        fot.extend([1] + [0] * (n_blocks - 1))
        e_cursor += padded
    if e_cursor == 0:  # no edges at all: one dummy block routed to tile 0
        seg_chunks.append(np.full(E_BLK, -1, dtype=np.int32))
        tob, fot = [0], [1]
        e_cursor = E_BLK

    perm = np.zeros(len(seg), dtype=np.int64)
    for idx, base in slots:
        perm[idx] = base + np.arange(idx.size)
    return SegmentPlan(
        perm=perm,
        seg_padded=np.concatenate(seg_chunks),
        tile_of_block=np.asarray(tob, dtype=np.int32),
        first_of_tile=np.asarray(fot, dtype=np.int32),
        n_rows=n_rows,
        n_row_tiles=n_row_tiles,
        e_pad=e_cursor,
    )


@functools.partial(jax.jit, static_argnames=("plan", "op", "interpret"))
def _run(plan: SegmentPlan, x: jnp.ndarray, op: str, interpret: bool) -> jnp.ndarray:
    E, F = x.shape
    f_pad = -(-F // F_BLK) * F_BLK
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, f_pad - F)))
    xp = jnp.zeros((plan.e_pad, f_pad), dtype=jnp.float32)
    xp = xp.at[jnp.asarray(plan.perm)].set(xf)
    out = segment_agg_call(
        xp,
        jnp.asarray(plan.seg_padded),
        jnp.asarray(plan.tile_of_block),
        jnp.asarray(plan.first_of_tile),
        n_row_tiles=plan.n_row_tiles,
        n_feat_tiles=f_pad // F_BLK,
        op=op,
        interpret=interpret,
    )
    out = out[: plan.n_rows, :F]
    if op == "max":
        visited = jax.ops.segment_sum(
            jnp.ones((plan.e_pad,), jnp.float32),
            jnp.where(jnp.asarray(plan.seg_padded) >= 0,
                      jnp.asarray(plan.seg_padded), plan.n_rows),
            num_segments=plan.n_rows + 1)[: plan.n_rows]
        out = jnp.where(visited[:, None] > 0, out, 0.0)
    return out


def segment_agg(x: jnp.ndarray, plan: SegmentPlan, *, op: str = "sum",
                interpret: bool = True) -> jnp.ndarray:
    """Aggregate edge values x (E, F) by the plan's destination rows.
    Returns (n_rows, F) fp32. Rows with no edges are 0 (both ops)."""
    if x.ndim == 1:
        x = x[:, None]
    return _run(plan, x, op, interpret)
