"""jit'd wrapper for the segment_agg kernel.

The kernel requires edges sorted by destination and padded so no E_BLK edge
block straddles an R_BLK row tile. For static graph structure (GNN adjacency,
EAGr overlay levels) that plan is built once on the host (``make_plan``) and
reused every step; only the edge *values* are runtime data.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_agg.segment_agg import (
    E_BLK,
    F_BLK,
    R_BLK,
    segment_agg_call,
)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash, so a
class SegmentPlan:                             # plan can be a static jit arg
    """Host-compiled routing plan for one static (seg, n_rows) structure."""

    perm: np.ndarray            # (E,) original edge -> slot in padded layout
    seg_padded: np.ndarray      # (E_pad,) int32, -1 padding
    tile_of_block: np.ndarray   # (n_edge_blocks,) int32
    first_of_tile: np.ndarray   # (n_edge_blocks,) int32
    n_rows: int
    n_row_tiles: int
    e_pad: int

    @property
    def pad_overhead(self) -> float:
        return self.e_pad / max(1, len(self.perm)) - 1.0


def make_plan(seg: np.ndarray, n_rows: int) -> SegmentPlan:
    """Group edges by row tile, pad each tile's edge count to a multiple of
    E_BLK, and record block->tile routing for the scalar-prefetch index maps."""
    seg = np.asarray(seg, dtype=np.int64)
    order = np.argsort(seg, kind="stable")
    n_row_tiles = max(1, -(-n_rows // R_BLK))

    tile = seg[order] // R_BLK
    slots = []
    seg_chunks = []
    tob, fot = [], []
    e_cursor = 0
    for t in range(n_row_tiles):
        idx = order[tile == t]
        if idx.size == 0:
            continue
        n_blocks = -(-idx.size // E_BLK)
        padded = n_blocks * E_BLK
        slots.append((idx, e_cursor))
        chunk = np.full(padded, -1, dtype=np.int32)
        chunk[: idx.size] = seg[idx]
        seg_chunks.append(chunk)
        tob.extend([t] * n_blocks)
        fot.extend([1] + [0] * (n_blocks - 1))
        e_cursor += padded
    if e_cursor == 0:  # no edges at all: one dummy block routed to tile 0
        seg_chunks.append(np.full(E_BLK, -1, dtype=np.int32))
        tob, fot = [0], [1]
        e_cursor = E_BLK

    perm = np.zeros(len(seg), dtype=np.int64)
    for idx, base in slots:
        perm[idx] = base + np.arange(idx.size)
    return SegmentPlan(
        perm=perm,
        seg_padded=np.concatenate(seg_chunks),
        tile_of_block=np.asarray(tob, dtype=np.int32),
        first_of_tile=np.asarray(fot, dtype=np.int32),
        n_rows=n_rows,
        n_row_tiles=n_row_tiles,
        e_pad=e_cursor,
    )


@functools.partial(jax.jit, static_argnames=("plan", "op", "interpret"))
def _run(plan: SegmentPlan, x: jnp.ndarray, op: str, interpret: bool) -> jnp.ndarray:
    E, F = x.shape
    f_pad = -(-F // F_BLK) * F_BLK
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, f_pad - F)))
    xp = jnp.zeros((plan.e_pad, f_pad), dtype=jnp.float32)
    xp = xp.at[jnp.asarray(plan.perm)].set(xf)
    out = segment_agg_call(
        xp,
        jnp.asarray(plan.seg_padded),
        jnp.asarray(plan.tile_of_block),
        jnp.asarray(plan.first_of_tile),
        n_row_tiles=plan.n_row_tiles,
        n_feat_tiles=f_pad // F_BLK,
        op=op,
        interpret=interpret,
    )
    out = out[: plan.n_rows, :F]
    if op == "max":
        visited = jax.ops.segment_sum(
            jnp.ones((plan.e_pad,), jnp.float32),
            jnp.where(jnp.asarray(plan.seg_padded) >= 0,
                      jnp.asarray(plan.seg_padded), plan.n_rows),
            num_segments=plan.n_rows + 1)[: plan.n_rows]
        out = jnp.where(visited[:, None] > 0, out, 0.0)
    return out


def segment_agg(x: jnp.ndarray, plan: SegmentPlan, *, op: str = "sum",
                interpret: bool = True) -> jnp.ndarray:
    """Aggregate edge values x (E, F) by the plan's destination rows.
    Returns (n_rows, F) fp32. Rows with no edges are 0 (both ops)."""
    if x.ndim == 1:
        x = x[:, None]
    return _run(plan, x, op, interpret)


# --------------------------------------------------------------- leveled plans
@dataclasses.dataclass(frozen=True, eq=False)
class LeveledPlan:
    """A stack of per-level ``SegmentPlan`` routings padded to one shape.

    All levels share the same edge-slot capacity (``e_pad``) and block count,
    so a jitted program can ``fori_loop`` over levels, dynamically slicing one
    level's routing tables per iteration — the program size is independent of
    the number of levels. Padding slots carry ``seg == -1`` (dropped by the
    kernel); padding *blocks* are routed to the last real block's output tile
    so the kernel's consecutive-revisit invariant still holds on hardware.
    """

    seg: np.ndarray             # (L, e_pad) int32, -1 padding
    tile_of_block: np.ndarray   # (L, n_blocks) int32
    first_of_tile: np.ndarray   # (L, n_blocks) int32
    perms: tuple                # per level: original edge index -> padded slot
    tile_slots: np.ndarray      # (L, n_row_tiles, 2) int32 [start, stop) slot
                                # range routed to each row tile (free-slot pool)
    n_rows: int
    n_row_tiles: int
    n_levels: int
    e_pad: int

    def layout(self, level: int, values: np.ndarray, fill=0,
               dtype=None) -> np.ndarray:
        """Place a per-edge companion array (e.g. sources, signs) of one level
        into that level's padded kernel slot order."""
        values = np.asarray(values)
        out = np.full((self.e_pad,) + values.shape[1:], fill,
                      dtype=dtype or values.dtype)
        out[self.perms[level]] = values
        return out


def tile_slot_ranges(tob_row: np.ndarray, n_row_tiles: int) -> np.ndarray:
    """Per-tile claimable slot ranges of one level's block routing.

    Blocks routed to the same tile are consecutive (the kernel's revisit
    invariant), so each tile owns at most one run of blocks; padding blocks
    are routed to the last real tile and therefore extend its run. Returns
    (n_row_tiles, 2) int32 [start, stop) slot ranges; tiles with no blocks
    get an empty range. A slot is *free* iff it lies in its tile's range and
    currently holds ``seg == -1``.
    """
    tob_row = np.asarray(tob_row, dtype=np.int64)
    out = np.zeros((n_row_tiles, 2), dtype=np.int32)
    for t in range(n_row_tiles):
        hit = np.flatnonzero(tob_row == t)
        if hit.size:
            out[t, 0] = hit[0] * E_BLK
            out[t, 1] = (hit[-1] + 1) * E_BLK
    return out


def patch_level(seg: jnp.ndarray, src: jnp.ndarray, sign: jnp.ndarray,
                level: int, slots: np.ndarray, seg_vals: np.ndarray,
                src_vals: np.ndarray, sign_vals: np.ndarray):
    """Rewrite individual edge slots of one level in the stacked tables.

    Retiring an edge writes ``seg=-1, src=0, sign=0`` (the padding pattern —
    every backend drops it); a new edge claims a free slot inside the owning
    tile's block range. Padded dims are untouched, so a jitted program over
    the tables keeps its compiled shape. Returns the three updated tables.

    ``scatter_slots`` / ``scatter_rows`` below are the jit-embeddable
    generalizations (batched across levels, out-of-bounds indices dropped)
    that ``plan_patch.apply_patch_step`` composes into the device-resident
    update program.
    """
    sl = jnp.asarray(np.asarray(slots, dtype=np.int64))
    return (
        seg.at[level, sl].set(jnp.asarray(np.asarray(seg_vals, np.int32))),
        src.at[level, sl].set(jnp.asarray(np.asarray(src_vals, np.int32))),
        sign.at[level, sl].set(jnp.asarray(np.asarray(sign_vals, np.float32))),
    )


def scatter_slots(table: jnp.ndarray, lvl: jnp.ndarray, slot: jnp.ndarray,
                  vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter individual (level, column) edits into one stacked (L, X)
    table. Padding entries carry an out-of-bounds level and are dropped, so
    edit arrays can be shape-bucketed without masking. Edits are unique by
    construction (last-write-wins resolution happens at lowering time).
    Traceable (jit-safe)."""
    return table.at[lvl, slot].set(vals, mode="drop", unique_indices=True)


def scatter_rows(table: jnp.ndarray, lvl: jnp.ndarray,
                 rows: jnp.ndarray) -> jnp.ndarray:
    """Replace whole level rows of a stacked table (the relayout tier).
    Out-of-bounds ``lvl`` entries (shape-bucket padding) are dropped."""
    return table.at[lvl].set(rows, mode="drop", unique_indices=True)


def tile_occupancy(seg: jnp.ndarray, tile_of_block: jnp.ndarray,
                   n_row_tiles: int) -> jnp.ndarray:
    """Per-(level, row-tile) count of live edge slots, computed on device from
    the stacked tables: the occupancy counters the patch path's tier
    escalation mirrors host-side (a tile whose occupancy plus the incoming
    claim exceeds its slot range forces a level relayout)."""
    L, e_pad = seg.shape
    tob = jnp.repeat(tile_of_block, E_BLK, axis=1)         # (L, e_pad)

    def one_level(seg_row, tob_row):
        t = jnp.where(seg_row >= 0, tob_row, n_row_tiles)
        return jax.ops.segment_sum(jnp.ones((e_pad,), jnp.int32), t,
                                   num_segments=n_row_tiles + 1)[:n_row_tiles]

    return jax.vmap(one_level)(seg, tob)


def relayout_level(dst: np.ndarray, src: np.ndarray, sign: np.ndarray,
                   n_rows: int, n_blocks: int, e_pad: int):
    """Rebuild one level's full kernel-layout rows from its current edge set.

    The medium-cost patch path: used when a slot claim fails (tile overflow /
    previously-empty tile) but the level still fits the plan's per-level block
    budget. Returns ``(seg_row, src_row, sign_row, tob_row, fot_row)`` padded
    to ``(e_pad,)`` / ``(n_blocks,)``, or ``None`` if the level needs more
    than ``n_blocks`` blocks (caller falls back to a full recompile).
    """
    p = make_plan(np.asarray(dst, dtype=np.int64), n_rows)
    k = p.tile_of_block.size
    if k > n_blocks:
        return None
    seg_row = np.full(e_pad, -1, dtype=np.int32)
    src_row = np.zeros(e_pad, dtype=np.int32)
    sign_row = np.zeros(e_pad, dtype=np.float32)
    seg_row[: p.e_pad] = p.seg_padded
    src_row[p.perm] = np.asarray(src, dtype=np.int32)
    sign_row[p.perm] = np.asarray(sign, dtype=np.float32)
    tob_row = np.zeros(n_blocks, dtype=np.int32)
    fot_row = np.zeros(n_blocks, dtype=np.int32)
    tob_row[:k] = p.tile_of_block
    tob_row[k:] = p.tile_of_block[-1] if k else 0  # keep revisits consecutive
    fot_row[:k] = p.first_of_tile
    if k == 0:
        fot_row[0] = 1  # empty level: init tile 0, aggregate nothing
    return seg_row, src_row, sign_row, tob_row, fot_row


def count_blocks(seg: np.ndarray) -> int:
    """Edge blocks ``make_plan`` would emit for one segment list: per-tile
    edge counts rounded up to E_BLK blocks (>=1, the dummy block)."""
    seg = np.asarray(seg, dtype=np.int64)
    if seg.size == 0:
        return 1
    _, counts = np.unique(seg // R_BLK, return_counts=True)
    return int(sum(-(-c // E_BLK) for c in counts))


def leveled_plan_blocks(segs: list[np.ndarray]) -> int:
    """The (pre-bucketing) per-level block count ``make_leveled_plan`` pads
    to — without building any tables. Bucket with the same next-power-of-two
    rule to predict the final shape."""
    return max((count_blocks(s) for s in segs), default=1)


def make_leveled_plan(segs: list[np.ndarray], n_rows: int, *,
                      pad_levels: int | None = None,
                      pad_blocks: int | None = None) -> LeveledPlan:
    """Route each level's destination segments through ``make_plan`` and stack
    the results into one padded (L, e_pad) table set.

    ``pad_levels`` / ``pad_blocks`` optionally force the padded level count and
    per-level block count (must be >= the natural sizes) so plans for different
    structures — restructured overlays, sibling shards — share one compiled
    program shape. Defaults bucket levels to a multiple of 4 and blocks to the
    next power of two for the same reason.
    """
    plans = [make_plan(np.asarray(s), n_rows) for s in segs]
    nb_real = max((p.e_pad // E_BLK for p in plans), default=1)
    nb = pad_blocks or max(1, 1 << (nb_real - 1).bit_length())
    if nb < nb_real:
        raise ValueError(f"pad_blocks={nb} < required {nb_real}")
    L_real = len(plans)
    L = pad_levels or max(1, -(-L_real // 4) * 4)
    if L < L_real:
        raise ValueError(f"pad_levels={L} < required {L_real}")
    e_pad = nb * E_BLK

    seg = np.full((L, e_pad), -1, dtype=np.int32)
    tob = np.zeros((L, nb), dtype=np.int32)
    fot = np.zeros((L, nb), dtype=np.int32)
    perms = []
    for l, p in enumerate(plans):
        k = p.tile_of_block.size
        seg[l, : p.e_pad] = p.seg_padded
        tob[l, :k] = p.tile_of_block
        tob[l, k:] = p.tile_of_block[-1] if k else 0  # keep revisits consecutive
        fot[l, :k] = p.first_of_tile
        perms.append(p.perm.copy())
    for l in range(L_real, L):
        fot[l, 0] = 1  # dummy level: init tile 0, aggregate nothing
        perms.append(np.zeros(0, dtype=np.int64))
    n_row_tiles = max(1, -(-n_rows // R_BLK))
    tile_slots = np.stack([tile_slot_ranges(tob[l], n_row_tiles)
                           for l in range(L)])
    return LeveledPlan(
        seg=seg, tile_of_block=tob, first_of_tile=fot, perms=tuple(perms),
        tile_slots=tile_slots, n_rows=n_rows, n_row_tiles=n_row_tiles,
        n_levels=L, e_pad=e_pad,
    )


def segment_agg_level(x: jnp.ndarray, seg: jnp.ndarray, tob: jnp.ndarray,
                      fot: jnp.ndarray, *, n_rows: int, n_row_tiles: int,
                      op: str = "sum", interpret: bool = True,
                      bf16: bool = False) -> jnp.ndarray:
    """Run the kernel on one level of a ``LeveledPlan``.

    ``x`` is (e_pad, F) edge values already in the level's padded slot order
    (use ``LeveledPlan.layout`` for static companions or gather through a
    laid-out source-index array for runtime values). All arguments may be
    traced — in particular slices of the stacked tables inside a loop over
    levels. Returns (n_rows, F); rows the level never touches are whatever the
    kernel initialized them to, so callers mask by their own touched set.
    ``bf16`` streams edge values into VMEM as bfloat16 (2x block headroom);
    the kernels cast per block and accumulate in fp32 either way.
    """
    F = x.shape[1]
    f_pad = -(-F // F_BLK) * F_BLK
    dt = jnp.bfloat16 if bf16 else jnp.float32
    xf = jnp.pad(x.astype(dt), ((0, 0), (0, f_pad - F)))
    out = segment_agg_call(
        xf, seg, tob, fot,
        n_row_tiles=n_row_tiles, n_feat_tiles=f_pad // F_BLK,
        op=op, interpret=interpret,
    )
    return out[:n_rows, :F]


def segment_agg_active(x: jnp.ndarray, seg: jnp.ndarray, tob: jnp.ndarray, *,
                       n_rows: int, n_row_tiles: int, op: str = "sum",
                       interpret: bool = True,
                       bf16: bool = False) -> jnp.ndarray:
    """Run the kernel on a *compacted* active-block subset of one level.

    ``x`` (K*E_BLK, F), ``seg`` (K*E_BLK,) and ``tob`` (K,) are the gathered
    slices of the K active edge blocks, in ascending block order — an
    ascending subset of a sorted level stays sorted, and ``tob`` stays
    non-decreasing, so the kernel's consecutive-revisit invariant holds and
    the grid (which is sized from ``x``) simply shrinks to K blocks. The
    first-of-tile flags are recomputed from the compacted ``tob`` (a tile's
    first *active* block initializes it). Output rows in tiles with no active
    block are uninitialized — callers mask by the active destination set.
    """
    fot = jnp.concatenate([jnp.ones((1,), jnp.int32),
                           (tob[1:] != tob[:-1]).astype(jnp.int32)])
    F = x.shape[1]
    f_pad = -(-F // F_BLK) * F_BLK
    dt = jnp.bfloat16 if bf16 else jnp.float32
    xf = jnp.pad(x.astype(dt), ((0, 0), (0, f_pad - F)))
    out = segment_agg_call(
        xf, seg, tob, fot,
        n_row_tiles=n_row_tiles, n_feat_tiles=f_pad // F_BLK,
        op=op, interpret=interpret,
    )
    return out[:n_rows, :F]
