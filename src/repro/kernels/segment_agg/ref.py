"""Pure-jnp oracle for sorted-segment aggregation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_agg_ref(x: jnp.ndarray, seg: jnp.ndarray, n_rows: int, op: str = "sum") -> jnp.ndarray:
    """x: (E, F) edge values; seg: (E,) destination rows (entries < 0 are
    padding and contribute nothing). Returns (n_rows, F)."""
    valid = seg >= 0
    safe = jnp.where(valid, seg, n_rows)  # park padding on a scratch row
    if op == "sum":
        x = jnp.where(valid[:, None], x, 0.0)
        out = jax.ops.segment_sum(x, safe, num_segments=n_rows + 1)
    elif op == "max":
        x = jnp.where(valid[:, None], x, -jnp.inf)
        out = jax.ops.segment_max(x, safe, num_segments=n_rows + 1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    else:
        raise ValueError(op)
    return out[:n_rows]
