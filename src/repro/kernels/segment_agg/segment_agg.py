"""Sorted-segment aggregation as a Pallas TPU kernel.

TPU has no atomic scatter; the systolic array *is* the scatter engine when
reduce-by-key is expressed as a small matmul. Edges arrive sorted by
destination row and padded (host-side, see ops.py) so that **no edge block
straddles a row-tile boundary**. Then:

  grid = (n_feat_tiles, n_edge_blocks)           # edge blocks minor => all
                                                 # revisits of an output tile
                                                 # are consecutive
  P[i, e] = 1  iff  seg[e] == tile_row0 + i      # (R_BLK, E_BLK) one-hot
  out_tile += P @ x_block                        # MXU matmul, fp32 accum

The block->tile routing (``tile_of_block``) and the first-visit flags are
scalar-prefetched (PrefetchScalarGridSpec) so the output BlockSpec's
index_map can read them — the TPU DMA engine then streams each edge block to
the right output tile with no host involvement.

The 'max' variant replaces the matmul with masked-broadcast maxima over
E_SUB-edge sub-chunks (VPU), keeping the (R, E_SUB, F) intermediate in VMEM.

VMEM working set (fp32, E_BLK=256, R_BLK=128, F_BLK=128):
  x 128 KiB + out 64 KiB + seg 1 KiB + one-hot 128 KiB  ≈  0.4 MiB  « 16 MiB.
All matmul dims are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

E_BLK = 256     # edges per block
R_BLK = 128     # output rows per tile (MXU-aligned)
F_BLK = 128     # feature lanes per tile
E_SUB = 8       # sub-chunk for the max variant (bounds the (R,E_SUB,F) bcast)


def _sum_kernel(tob_ref, fot_ref, seg_ref, x_ref, out_ref):
    b = pl.program_id(1)

    @pl.when(fot_ref[b] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row0 = tob_ref[b] * R_BLK
    seg = seg_ref[...]  # (E_BLK,) int32; padding = -1
    local = seg - row0
    # one-hot scatter matrix on the MXU: (R_BLK, E_BLK) @ (E_BLK, F_BLK)
    rows = jax.lax.broadcasted_iota(jnp.int32, (R_BLK, E_BLK), 0)
    p = (rows == local[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(
        p, x_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )


def _max_kernel(tob_ref, fot_ref, seg_ref, x_ref, out_ref):
    b = pl.program_id(1)
    neg = jnp.float32(-3.0e38)

    @pl.when(fot_ref[b] == 1)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, neg)

    row0 = tob_ref[b] * R_BLK
    local = seg_ref[...] - row0
    x = x_ref[...].astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (R_BLK, E_SUB), 0)

    def body(c, acc):
        sl = jax.lax.dynamic_slice_in_dim(local, c * E_SUB, E_SUB)
        xs = jax.lax.dynamic_slice_in_dim(x, c * E_SUB, E_SUB, axis=0)
        hit = rows == sl[None, :]                       # (R_BLK, E_SUB)
        vals = jnp.where(hit[:, :, None], xs[None, :, :], neg)
        return jnp.maximum(acc, vals.max(axis=1))

    out_ref[...] = jax.lax.fori_loop(0, E_BLK // E_SUB, body, out_ref[...])


@functools.partial(
    jax.jit, static_argnames=("n_row_tiles", "n_feat_tiles", "op", "interpret")
)
def segment_agg_call(
    x: jnp.ndarray,              # (E_pad, F_pad), blocked-by-tile order
    seg: jnp.ndarray,            # (E_pad,) int32, sorted, padding = -1
    tile_of_block: jnp.ndarray,  # (n_edge_blocks,) int32
    first_of_tile: jnp.ndarray,  # (n_edge_blocks,) int32 (1 = first block of tile)
    *,
    n_row_tiles: int,
    n_feat_tiles: int,
    op: str = "sum",
    interpret: bool = True,
) -> jnp.ndarray:
    n_edge_blocks = x.shape[0] // E_BLK
    kernel = _sum_kernel if op == "sum" else _max_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_feat_tiles, n_edge_blocks),
        in_specs=[
            pl.BlockSpec((E_BLK,), lambda f, b, tob, fot: (b,)),          # seg
            pl.BlockSpec((E_BLK, F_BLK), lambda f, b, tob, fot: (b, f)),  # x
        ],
        out_specs=pl.BlockSpec((R_BLK, F_BLK), lambda f, b, tob, fot: (tob[b], f)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_row_tiles * R_BLK, n_feat_tiles * F_BLK), jnp.float32
        ),
        interpret=interpret,
    )(tile_of_block, first_of_tile, seg, x)
