import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and record memory/cost/collective analysis.
#
# The XLA_FLAGS line above MUST run before any other import (jax locks the
# device count at first init); this module is the only place it is set.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun                # all 40 cells
#   PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 512-chip mesh
#   PYTHONPATH=src python -m repro.launch.dryrun --rules seqpar # rule preset
#   PYTHONPATH=src python -m repro.launch.dryrun --json out.json

import argparse
import json
import time
import traceback

import jax


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum operand bytes of every collective op in the (per-device) HLO,
    multiplying ops inside while-loop bodies by the loop trip count
    (composed across nested loops)."""
    import re

    DT = {"f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "pred": 1,
          "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    comps = _computation_blocks(hlo_text)
    mult = _effective_multipliers(comps)
    out = {k: 0.0 for k in kinds}
    for name, lines in comps.items():
        m_comp = mult.get(name, 1.0)
        for ls in lines:
            m = re.match(r".*= \S+ (all-gather|all-reduce|reduce-scatter|"
                         r"all-to-all|collective-permute)(?:-start)?\(", ls)
            if not m:
                continue
            kind = m.group(1)
            shapes = re.findall(r"(f32|bf16|s32|u32|f16|pred|s8|u8|f64|s64|u64)"
                                r"\[([0-9,]*)\]", ls.split("=")[1].split("(")[0])
            nbytes = 0
            for dt, dims in shapes:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * DT[dt]
            out[kind] += nbytes * m_comp
    return out


def _computation_blocks(hlo_text: str) -> dict[str, list[str]]:
    """Split HLO text into named computation blocks (top-level defs)."""
    import re

    comps: dict[str, list[str]] = {}
    cur = None
    for raw in hlo_text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{", raw)
        if m and not raw.startswith(" "):
            cur = m.group(2)
            comps[cur] = []
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(raw.strip())
    return comps


def _effective_multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Per-computation execution multipliers: a while body runs trip-count
    times per execution of the computation containing the while op; nested
    loops compose. Trip count = the largest s32[] constant in the condition
    computation (jax scans compare the induction var with direction=LT)."""
    import re

    # condition computation -> trip bound
    cond_bound: dict[str, int] = {}
    for name, lines in comps.items():
        consts = [int(x) for ls in lines
                  for x in re.findall(r"s32\[\]\s+constant\((\d+)\)", ls)]
        has_lt = any("direction=LT" in ls for ls in lines) or any(
            "wrapped_compare" in ls or "compare" in ls for ls in lines)
        if consts and has_lt:
            cond_bound[name] = max(consts)

    # edges: computation -> (body, trip) for every while op it contains
    edges: dict[str, list[tuple[str, float]]] = {n: [] for n in comps}
    wre = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
    # also follow plain calls/fusions with multiplier 1
    cre = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
    for name, lines in comps.items():
        for ls in lines:
            wm = wre.search(ls)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                edges[name].append((body, float(cond_bound.get(cond, 1))))
                edges[name].append((cond, float(cond_bound.get(cond, 1))))
                continue
            for callee in cre.findall(ls):
                if callee in comps:
                    edges[name].append((callee, 1.0))

    # propagate from roots (computations never referenced = ENTRY and friends)
    referenced = {b for outs in edges.values() for b, _ in outs}
    mult = {n: 1.0 for n in comps if n not in referenced}
    # BFS (computation call graph is a DAG)
    frontier = list(mult)
    while frontier:
        nxt = []
        for n in frontier:
            for b, t in edges.get(n, ()):  # accumulate; callee may be shared
                m_new = mult[n] * t
                if mult.get(b, 0.0) < m_new:
                    mult[b] = m_new
                    nxt.append(b)
        frontier = nxt
    return mult


def run_cell(arch_id: str, shape: str, mesh, rules_name: str | None,
             unroll: bool = False):
    from repro.configs import get_arch
    from repro.distributed.sharding import RULE_SETS

    rules = RULE_SETS[rules_name] if rules_name else None
    arch = get_arch(arch_id)
    t0 = time.time()
    plan = arch.build(shape, mesh, rules, unroll=unroll)
    lowered = plan.lower(mesh)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = dict(
        arch=arch_id, shape=shape,
        mesh=dict(zip(mesh.axis_names, mesh.devices.shape)),
        seconds=round(time.time() - t0, 1),
        temp_bytes=int(ma.temp_size_in_bytes),
        arg_bytes=int(ma.argument_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        alias_bytes=int(ma.alias_size_in_bytes),
        flops=float(ca.get("flops", -1.0)),
        bytes_accessed=float(ca.get("bytes accessed", -1.0)),
        collective_bytes=coll,
        notes=plan.notes,
    )
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--rules", default=None, help="sharding rule preset name")
    p.add_argument("--json", default=None)
    p.add_argument("--unroll", action="store_true",
                   help="analysis mode: unroll scans so cost_analysis counts "
                        "every layer/microbatch (memory numbers NOT "
                        "production-representative)")
    p.add_argument("--include-eagr", action="store_true",
                   help="also run the bonus EAGr engine cell")
    args = p.parse_args(argv)

    from repro.configs import all_cells, get_arch
    from repro.launch.mesh import make_production_mesh

    cells = all_cells()
    if args.include_eagr:
        cells += [("eagr", s) for s in get_arch("eagr").shapes]
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]

    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    records, failures = [], []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = int(jax.numpy.prod(jax.numpy.array(mesh.devices.shape)))
        for a, s in cells:
            tag = f"{a:22s} {s:15s} [{'2x16x16' if multi_pod else '16x16'}]"
            try:
                rec = run_cell(a, s, mesh, args.rules, unroll=args.unroll)
                records.append(rec)
                peak = (rec["temp_bytes"] + rec["arg_bytes"]) / 1e9
                print(f"{tag} OK {rec['seconds']:6.1f}s "
                      f"temp={rec['temp_bytes']/1e9:7.2f}GB "
                      f"peak~{peak:7.2f}GB "
                      f"flops={rec['flops']:.3e} "
                      f"coll={sum(rec['collective_bytes'].values())/1e9:8.3f}GB",
                      flush=True)
            except Exception as e:
                failures.append((a, s, multi_pod, repr(e)))
                print(f"{tag} FAIL {type(e).__name__}: {str(e)[:200]}", flush=True)
                traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
    print(f"\n{len(records)} cells OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
