"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests and benches must see the real single CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
    composes with data for all data-parallel collectives."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names — lets the same sharded
    step functions run on the local CPU for smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


SHARD_AXIS = "shard"


def make_shard_mesh(n_shards: int) -> jax.sharding.Mesh | None:
    """1-D mesh of ``n_shards`` devices along the EAGr shard axis, for the
    stacked ``shard_map`` execution of reader-partitioned overlays.

    Returns None when fewer than ``n_shards`` devices are available — the
    stacked engine then runs the identical per-shard body under
    ``vmap(axis_name=SHARD_AXIS)``, so CPU tier-1 tests and the
    ``--xla_force_host_platform_device_count`` CI mesh exercise one code path.
    """
    devices = jax.devices()
    if n_shards > len(devices):
        return None
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), (SHARD_AXIS,))
