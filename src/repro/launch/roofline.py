"""Roofline analysis over dry-run records (§Roofline deliverable).

Three terms per (arch x shape x mesh), all in seconds-per-step on the target
TPU v5e pod:

  compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips * 819e9  B/s HBM)
  collective = collective_bytes     / (chips * 2 * 50e9 B/s ICI links)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes, HLO-text parsing for
collective operand bytes (launch/dryrun.py). cost_analysis counts a while
body ONCE, so the roofline pass lowers the *unrolled* analysis variant
(--unroll) where every layer and accumulation microbatch is explicit in the
HLO. The production (scanned) variant provides the memory_analysis numbers.

MODEL_FLOPS (analytic "useful" flops) per family:
  LM train    6 * N_active * tokens   (fwd 2ND + bwd 4ND)
  LM prefill  2 * N_active * tokens + attention term
  LM decode   2 * N_active * B + attention 4*B*S*H*hd (one new token)
  GNN/recsys  closed-form per model (see _model_flops)
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 2                # effective links per chip engaged per collective


# ------------------------------------------------------- analytic model flops
def _lm_params(cfg):
    """(N_total, N_active) parameter counts from a TransformerConfig."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.padded_vocab, cfg.n_layers
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = D * Hq * hd + 2 * D * Hkv * hd + Hq * hd * D
    dense_ffn = 3 * D * F if (not cfg.is_moe or cfg.moe_dense_residual) else 0
    moe_total = 3 * D * F * cfg.n_experts if cfg.is_moe else 0
    moe_active = 3 * D * F * cfg.top_k if cfg.is_moe else 0
    router = D * cfg.n_experts if cfg.is_moe else 0
    total = L * (attn + dense_ffn + moe_total + router) + 2 * V * D
    active = L * (attn + dense_ffn + moe_active + router) + 2 * V * D
    return total, active


def model_flops(arch_id: str, shape: str) -> tuple[float, float]:
    """(MODEL_FLOPS for the whole step across all chips, N_params_active)."""
    from repro.configs import get_arch  # noqa: F401  (arch registry import)
    if arch_id in ("granite-3-2b", "internlm2-1.8b", "command-r-plus-104b",
                   "arctic-480b", "dbrx-132b"):
        import importlib
        from repro.configs import _MODULES
        mod = importlib.import_module(_MODULES[arch_id])
        lm = [c.cell_contents for c in mod.ARCH.build.__closure__
              if hasattr(c.cell_contents, "cfg")][0]
        cfg = lm.cfg
        _, n_active = _lm_params(cfg)
        L, Hq, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        from repro.configs.lm_common import SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        B, S = d["batch"], d["seq"]
        tokens = B * S
        attn_fwd = 4 * tokens * S / 2 * Hq * hd * L   # causal: S/2 avg context
        if d["kind"] == "train":
            return 6 * n_active * tokens + 3 * attn_fwd, n_active
        if d["kind"] == "prefill":
            return 2 * n_active * tokens + attn_fwd, n_active
        # decode: 1 token/row against an S-cache
        return 2 * n_active * B + 4 * B * S * Hq * hd * L, n_active
    if arch_id == "graphcast":
        from repro.configs.graphcast import CFG
        from repro.configs.gnn_common import SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        dh = CFG.d_hidden
        e_gm = 4 * d["n"]
        mlp2 = lambda din, dh_: 2 * (din * dh_ + dh_ * dh_)
        per_edge = mlp2(3 * dh, dh)
        per_node = mlp2(2 * dh, dh)
        fwd = (d["n"] * mlp2(CFG.n_vars, dh)                   # grid embed
               + 2 * e_gm * per_edge + (CFG.n_mesh + d["n"]) * per_node
               + CFG.n_layers * (CFG.n_mesh_edges * per_edge
                                 + CFG.n_mesh * per_node)
               + d["n"] * mlp2(dh, dh))
        return 3 * fwd, None
    if arch_id == "gat-cora":
        from repro.configs.gnn_common import SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        fwd = (2 * d["n"] * d["d_feat"] * 64
               + 2 * d["n"] * 64 * d["classes"]
               + 4 * d["e"] * (64 + d["classes"]))
        return 3 * fwd, None
    if arch_id == "gatedgcn":
        from repro.configs.gnn_common import SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        dh = 70
        fwd = (2 * d["n"] * d["d_feat"] * dh
               + 16 * (5 * 2 * d["n"] * dh * dh + 6 * d["e"] * dh))
        return 3 * fwd, None
    if arch_id == "nequip":
        from repro.configs.nequip import CFG
        from repro.configs.gnn_common import SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        mul, P = CFG.d_hidden, len(CFG.paths)
        # per edge per path: intertwiner contraction ~ 2*mul*(2l+1)^2*... ~ 50*mul
        per_edge = P * 70 * mul + 2 * CFG.n_rbf * CFG.radial_hidden \
            + 2 * CFG.radial_hidden * P * mul
        per_node = 2 * (mul * 4) * mul * 9
        fwd = CFG.n_layers * (d["e"] * per_edge + d["n"] * per_node)
        mult = 3 if shape != "molecule" else 9   # force training: grad-of-grad
        return mult * fwd, None
    if arch_id == "dien":
        from repro.configs.dien import CFG, SHAPE_DEFS
        d = SHAPE_DEFS[shape]
        dh, db, S = CFG.gru_dim, CFG.behav_dim, CFG.seq_len
        gru = 2 * S * 3 * dh * (db + dh)
        augru = 2 * S * 3 * dh * 2 * dh + 2 * S * (dh + db) * CFG.att_hidden
        mlp = 2 * (CFG.gru_dim + 2 * db + CFG.embed_dim) * 200 + 2 * 200 * 80
        if shape == "retrieval_cand":
            user = gru + 2 * (dh + db) * CFG.embed_dim
            return user + 2 * d["n_cand"] * CFG.embed_dim, None
        per_user = gru + augru + mlp
        mult = 3 if d["kind"] == "train" else 1
        return mult * d["batch"] * per_user, None
    raise KeyError(arch_id)


# ----------------------------------------------------------------- the table
def analyze(records: list[dict], chips: int | None = None) -> list[dict]:
    out = []
    for r in records:
        n_chips = 1
        for v in r["mesh"].values():
            n_chips *= v
        # cost_analysis numbers are PER DEVICE in the SPMD module
        mult = 1.0
        for tok in str(r.get("notes", "")).split():
            if tok.startswith("step_multiplier="):
                mult = float(tok.split("=")[1])
        flops_dev = max(r.get("flops", 0.0), 0.0) * mult
        bytes_dev = max(r.get("bytes_accessed", 0.0), 0.0) * mult
        coll_dev = sum(r.get("collective_bytes", {}).values()) * mult
        t_compute = flops_dev / PEAK_FLOPS
        t_memory = bytes_dev / HBM_BW
        t_coll = coll_dev / (ICI_LINKS * ICI_BW)
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        try:
            mf, n_active = model_flops(r["arch"], r["shape"])
        except Exception:
            mf, n_active = None, None
        rec = dict(
            arch=r["arch"], shape=r["shape"], chips=n_chips,
            t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
            bottleneck=bottleneck,
            hlo_flops_per_dev=flops_dev,
            hlo_bytes_per_dev=bytes_dev,
            collective_bytes_per_dev=coll_dev,
            model_flops_total=mf,
            useful_ratio=(mf / (flops_dev * n_chips)
                          if mf and flops_dev > 0 else None),
            roofline_fraction=(
                (mf / n_chips / PEAK_FLOPS) / max(max(terms.values()), 1e-30)
                if mf else None),
            # memory term from XLA-CPU bytes_accessed counts operands across
            # fusion boundaries (a strict upper bound, ~10-30x fused TPU HBM
            # traffic); roof_cc uses only the reliable compute/collective terms
            roofline_cc=(
                (mf / n_chips / PEAK_FLOPS) / max(t_compute, t_coll, 1e-30)
                if mf else None),
            temp_gb=r["temp_bytes"] / 1e9,
            notes=r.get("notes", ""),
        )
        out.append(rec)
    return out


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':15s} {'chips':>5s} "
           f"{'compute(s)':>11s} {'memory(s)':>11s} {'collect(s)':>11s} "
           f"{'bound':>10s} {'useful':>7s} {'roofline':>8s} {'roof-cc':>8s} "
           f"{'temp':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        useful = (f"{r['useful_ratio']:6.2f}" if r["useful_ratio"] else "   n/a")
        roof = (f"{r['roofline_fraction']:7.1%}" if r["roofline_fraction"]
                else "    n/a")
        roofcc = (f"{r['roofline_cc']:7.1%}" if r.get("roofline_cc")
                  else "    n/a")
        lines.append(
            f"{r['arch']:22s} {r['shape']:15s} {r['chips']:5d} "
            f"{r['t_compute_s']:11.3e} {r['t_memory_s']:11.3e} "
            f"{r['t_collective_s']:11.3e} {r['bottleneck']:>10s} "
            f"{useful:>7s} {roof:>8s} {roofcc:>8s} {r['temp_gb']:6.1f}G")
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("records", help="json from dryrun --json")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)
    with open(args.records) as f:
        records = json.load(f)
    rows = analyze(records)
    print(format_table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
