"""Serving launcher: batched LM decode or DIEN CTR scoring on the local host
(reduced configs), exercising the real serve step functions.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --requests 64
  PYTHONPATH=src python -m repro.launch.serve --arch dien --requests 4096
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--requests", type=int, default=64)
    p.add_argument("--decode-steps", type=int, default=16)
    args = p.parse_args(argv)

    from repro.configs import get_arch
    arch = get_arch(args.arch)

    if arch.family == "lm":
        plan = arch.build_smoke("decode_32k")
        params, cache, tokens, lengths = plan.args
        fn = jax.jit(plan.fn)
        B = tokens.shape[0]
        n_batches = max(1, args.requests // B)
        fn(params, cache, tokens, lengths)  # compile
        t0 = time.time()
        done = 0
        for _ in range(n_batches):
            c, t, l = cache, tokens, lengths
            for _ in range(args.decode_steps):
                logits, c, l = fn(params, c, t, l)
                t = jnp.argmax(logits, -1).astype(jnp.int32)
                done += B
        jax.block_until_ready(logits)
        dt = time.time() - t0
        print(f"{args.arch}: {done} tokens in {dt:.2f}s "
              f"({done/dt:.0f} tok/s on host CPU, reduced config)")
        return 0

    if arch.arch_id == "dien":
        plan = arch.build_smoke("serve_p99")
        params, batch = plan.args
        fn = jax.jit(plan.fn)
        fn(params, batch)  # compile
        B = batch["item_ids"].shape[0]
        n = max(1, args.requests // B)
        lat = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(params, batch))
            lat.append((time.perf_counter() - t0) * 1e3)
        lat = np.array(lat)
        print(f"dien: {n * B} requests, p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms per batch of {B}")
        return 0

    raise SystemExit(f"{args.arch} ({arch.family}) has no serve path; "
                     "use launch.train")


if __name__ == "__main__":
    raise SystemExit(main())
