"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \\
      --steps 300 --smoke                      # reduced config on local CPU
  PYTHONPATH=src python -m repro.launch.train --arch gatedgcn --steps 50 --smoke

Drives the fault-tolerant runner (checkpoint/restart + straggler detection)
around the arch's train cell; --fail-at N injects a node failure to exercise
restore + deterministic replay end-to-end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default=None, help="defaults to the train shape")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (the only mode on a CPU host)")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--fail-at", type=int, default=None)
    args = p.parse_args(argv)

    from repro.configs import get_arch
    from repro.distributed.checkpoint import CheckpointManager
    from repro.distributed.fault import FaultTolerantRunner

    arch = get_arch(args.arch)
    shape = args.shape or next(s for s in arch.shapes
                               if "train" in s or s == arch.shapes[0])
    if not args.smoke:
        raise SystemExit("full configs need the production mesh; this host "
                         "runs --smoke (reduced config) only")
    plan = arch.build_smoke(shape)
    assert plan.kind == "train", f"{shape} is not a train shape"
    params, opt_state, batch0, _ = plan.args
    step_jit = jax.jit(plan.fn)

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = step_jit(params, opt_state, batch,
                                              jnp.float32(args.lr))
        return (params, opt_state), metrics

    def make_batch(i):
        # deterministic in i => exact replay after restore
        leaves, treedef = jax.tree_util.tree_flatten(batch0)
        key = jax.random.PRNGKey(i)
        out = []
        for j, x in enumerate(leaves):
            if jnp.issubdtype(x.dtype, jnp.integer):
                hi = max(2, int(jnp.max(x)) + 1)
                out.append(jax.random.randint(jax.random.fold_in(key, j),
                                              x.shape, 0, hi, dtype=x.dtype))
            elif jnp.issubdtype(x.dtype, jnp.bool_):
                out.append(jnp.ones_like(x))
            else:
                out.append(jax.random.normal(jax.random.fold_in(key, j),
                                             x.shape, x.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    ckpt = CheckpointManager(args.ckpt_dir)
    runner = FaultTolerantRunner(step_fn, make_batch, ckpt,
                                 ckpt_every=args.ckpt_every)
    t0 = time.time()
    state, report = runner.run(
        (params, opt_state), args.steps,
        fail_at={args.fail_at} if args.fail_at is not None else None)
    dt = time.time() - t0
    print(f"arch={args.arch} shape={shape} steps={report.steps_run} "
          f"restarts={report.restarts} ckpts={report.checkpoints} "
          f"stragglers={len(report.stragglers)} {dt:.1f}s "
          f"({report.steps_run/dt:.2f} steps/s)")
    if report.losses:
        k = max(1, len(report.losses) // 10)
        print("loss curve:", [round(float(np.mean(report.losses[i:i+k])), 4)
                              for i in range(0, len(report.losses), k)])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
