# Assigned-architecture model definitions (pure JAX, shard_map/pjit-ready):
#   transformer.py  GQA/MoE decoder-only LM family (5 archs)
#   gnn/            gat_cora, gatedgcn, graphcast, nequip
#   recsys/         DIEN
# All models expose: param_specs(cfg), init_params(cfg, key), plus family-
# specific step builders consumed by launch/dryrun.py and the smoke tests.
