"""Shared model substrate: parameter specs with logical sharding axes,
norms, RoPE, blocked (flash-style) jnp attention, chunked cross-entropy.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- param specs
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + dtype + one *logical* axis name per dim (None = replicated).
    Logical names are mapped to mesh axes by distributed/sharding.py rules."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec_to_sds(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_from_specs(tree, key):
    """Random init for smoke tests / examples (never used by the dry-run)."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        fan_in = s.shape[0] if len(s.shape) > 1 else max(1, s.shape[-1])
        scale = s.init_scale / np.sqrt(fan_in)
        out.append((jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(dt)


# -------------------------------------------------------------------- rope
def rope_angles(positions: jnp.ndarray, head_dim: int, base: float = 10000.0):
    half = head_dim // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, d). cos/sin: (S, d/2) (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # (S, 1, half) -> broadcast over head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- attention
def blocked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """Memory-bounded jnp attention (the lowered production path; the Pallas
    flash kernel is the TPU-runtime analogue validated against the same math).

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d). Scans over q chunks so the live
    score tensor is (B, Hq, q_chunk, Skv) instead of (B, Hq, Sq, Skv).
    """
    B, Hq, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    # Grouped-query form: fold the group into the q tensor instead of
    # jnp.repeat-ing K/V `group` times (repeat materializes group x the KV
    # cache per layer — measured +3.5 GB/device on arctic decode_32k).
    qg = q.reshape(B, Hkv, group, Sq, d)

    pad = (-Sq) % q_chunk
    qp = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = qp.shape[3] // q_chunk
    qc = qp.reshape(B, Hkv, group, n_chunks, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)

    kpos = jnp.arange(Skv)[None, :]
    lmask = None if lengths is None else (kpos < lengths[:, None])  # (B, Skv)

    # Nested remat: without it, the layer-level backward materializes the
    # softmax probs for ALL q chunks at once — a stacked (n_chunks, B, H,
    # q_chunk, Skv) fp32 tensor (measured 3.8 GB/device on arctic train_4k).
    # checkpointing the chunk makes the backward recompute one chunk's scores
    # at a time: the flash-attention memory property in pure jnp.
    @jax.checkpoint
    def one_chunk(c, qi):
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if causal:
            qpos = c * q_chunk + jnp.arange(q_chunk)[:, None] + (Skv - Sq)
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        if lmask is not None:
            s = jnp.where(lmask[:, None, None, None, :], s, -jnp.inf)
        m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
        return o / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n_chunks), qc))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq + pad, d)
    return out[:, :, :Sq, :].astype(q.dtype)


# ------------------------------------------------------------------- loss
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-mean cross entropy in fp32. logits: (..., V); labels: (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def fused_ce_loss(x: jnp.ndarray, lm_head: jnp.ndarray, labels: jnp.ndarray,
                  *, n_valid_vocab: int, z_loss: float = 0.0,
                  chunk: int = 512) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Memory-efficient vocab projection + cross entropy + z-loss.

    The full (B, S, V) logits tensor never materializes: the sequence is
    scanned in ``chunk``-sized slices with per-chunk rematerialization, so the
    live logits buffer is (B, chunk, V) and the backward pass recomputes each
    chunk's projection instead of storing it. Padded vocab columns
    (>= n_valid_vocab) are masked to -inf. Returns (mean nll, mean z-term).
    """
    B, S, D = x.shape
    V = lm_head.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    xc = x.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    valid = (jnp.arange(V) < n_valid_vocab)[None, None, :]

    @jax.checkpoint
    def one(carry, xl):
        nll_sum, z_sum = carry
        xi, li = xl
        logits = (xi.astype(jnp.float32) @ lm_head.astype(jnp.float32))
        logits = jnp.where(valid, logits, -jnp.inf)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = lse - ll
        return (nll_sum + nll.sum(), z_sum + (lse * lse).sum()), None

    (nll_sum, z_sum), _ = jax.lax.scan(one, (jnp.float32(0), jnp.float32(0)),
                                       (xc, lc))
    n_tok = B * S
    return nll_sum / n_tok, z_loss * z_sum / n_tok
