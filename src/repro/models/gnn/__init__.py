from repro.models.gnn.common import GraphBatch, segment_softmax  # noqa: F401
