"""Shared GNN substrate: flat padded graph batches + segment message passing.

JAX sparse is BCOO-only, so message passing is expressed as gather
(``x[edge_src]``) -> per-edge compute -> ``jax.ops.segment_sum``/``segment_max``
scatter into destination nodes. This IS the system's sparse engine; the Pallas
``segment_agg`` kernel is the TPU-optimized version of the same contraction
(validated against it in tests).

All four GNN shape regimes flatten to one ``GraphBatch``:
  full_graph_sm / ogb_products  one graph, all nodes/edges
  minibatch_lg                  sampled union subgraph (padded, masked)
  molecule                      B small graphs flattened with ``graph_ids``
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Flat padded graph. Edges are (src -> dst); aggregation is into dst."""

    x: jnp.ndarray            # (N, F) node features (or positions for nequip)
    edge_src: jnp.ndarray     # (E,) int32
    edge_dst: jnp.ndarray     # (E,) int32
    edge_mask: jnp.ndarray    # (E,) bool
    node_mask: jnp.ndarray    # (N,) bool
    labels: jnp.ndarray       # (N,) int32 node labels or (G,) graph targets
    label_mask: jnp.ndarray   # same leading dim as labels
    graph_ids: jnp.ndarray | None = None  # (N,) int32 for batched small graphs
    n_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))
    positions: jnp.ndarray | None = None  # (N, 3) for geometric models
    species: jnp.ndarray | None = None    # (N,) int32 atomic species


def mask_edges(vals: jnp.ndarray, edge_mask: jnp.ndarray) -> jnp.ndarray:
    return vals * edge_mask.astype(vals.dtype)[:, None]


def agg_sum(vals: jnp.ndarray, edge_dst: jnp.ndarray, n_nodes: int,
            edge_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Masked segment-sum of per-edge values into destination nodes."""
    if edge_mask is not None:
        vals = mask_edges(vals, edge_mask)
    return jax.ops.segment_sum(vals, edge_dst, num_segments=n_nodes)


def agg_mean(vals, edge_dst, n_nodes, edge_mask=None):
    s = agg_sum(vals, edge_dst, n_nodes, edge_mask)
    ones = jnp.ones((vals.shape[0], 1), vals.dtype)
    if edge_mask is not None:
        ones = mask_edges(ones, edge_mask)
    deg = jax.ops.segment_sum(ones, edge_dst, num_segments=n_nodes)
    return s / jnp.maximum(deg, 1.0)


def segment_softmax(scores: jnp.ndarray, edge_dst: jnp.ndarray, n_nodes: int,
                    edge_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Numerically-stable softmax over the incoming edges of each node.
    scores: (E, H). Returns normalized weights (E, H); masked edges get 0."""
    if edge_mask is not None:
        scores = jnp.where(edge_mask[:, None], scores, -jnp.inf)
    smax = jax.ops.segment_max(scores, edge_dst, num_segments=n_nodes)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[edge_dst])
    if edge_mask is not None:
        ex = mask_edges(ex, edge_mask)
    denom = jax.ops.segment_sum(ex, edge_dst, num_segments=n_nodes)
    return ex / jnp.maximum(denom[edge_dst], 1e-9)


def graph_readout(node_vals: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Sum-pool node values per graph -> (n_graphs, F)."""
    vals = node_vals * batch.node_mask.astype(node_vals.dtype)[:, None]
    if batch.graph_ids is None:
        return vals.sum(axis=0, keepdims=True)
    return jax.ops.segment_sum(vals, batch.graph_ids, num_segments=batch.n_graphs)


def mlp_specs(dims: tuple[int, ...], prefix_axes=("embed", "mlp"), dtype=jnp.float32):
    """ParamSpecs for a plain MLP: w{i} (d_in, d_out), b{i} (d_out,)."""
    specs = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ax = (prefix_axes[0] if i == 0 else None,
              prefix_axes[1] if i == len(dims) - 2 else None)
        specs[f"w{i}"] = ParamSpec((a, b), ax, dtype)
        specs[f"b{i}"] = ParamSpec((b,), (None,), dtype, init_scale=0.0)
    return specs


def mlp_apply(p: dict, x: jnp.ndarray, act=jax.nn.relu, final_act=False) -> jnp.ndarray:
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def node_ce_loss(logits: jnp.ndarray, batch: GraphBatch) -> jnp.ndarray:
    """Masked node-classification cross entropy."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)[:, 0]
    m = batch.label_mask.astype(jnp.float32)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)
