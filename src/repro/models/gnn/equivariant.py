"""O(3)-equivariant substrate for NequIP: real spherical harmonics, numeric
Wigner-D matrices, and Clebsch-Gordan intertwiners.

Convention-free construction: instead of importing a CG table in somebody
else's basis convention, we (a) define explicit real spherical harmonics
Y_l (l <= 3), (b) obtain D_l(R) numerically from the defining property
Y_l(R r) = D_l(R) Y_l(r) by least squares over sample points, and (c) solve
for the unique (up to scale) intertwiner T: l1 (x) l2 -> l3 as the null space
of the equivariance constraints D3 T = T (D1 (x) D2) stacked over random
rotations. Everything is exact to float64 precision and *self-validating* —
if any formula were inconsistent, the null space would be empty. Computed
once on the host at model-build time and baked into the jitted step as
constants.
"""
from __future__ import annotations

import functools

import numpy as np

_rng = np.random.default_rng(1234)


# ------------------------------------------------------- spherical harmonics
def real_sph_harm(l: int, r: np.ndarray) -> np.ndarray:
    """Real solid harmonics of degree l on unit vectors r (..., 3) ->
    (..., 2l+1). Component normalization is `norm`alized so |Y_l(u)| = 1 on
    average over the sphere (the constant factor is absorbed by the radial
    weights; only the rotation behaviour matters)."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    if l == 0:
        return np.ones(r.shape[:-1] + (1,))
    if l == 1:
        return np.stack([x, y, z], axis=-1)
    if l == 2:
        return np.stack(
            [
                x * y,
                y * z,
                (2 * z * z - x * x - y * y) / (2 * np.sqrt(3.0)),
                x * z,
                (x * x - y * y) / 2.0,
            ],
            axis=-1,
        ) * np.sqrt(3.0)
    if l == 3:
        return np.stack(
            [
                np.sqrt(2.5) * y * (3 * x * x - y * y) / 2,
                np.sqrt(15.0) * x * y * z,
                np.sqrt(1.5) * y * (4 * z * z - x * x - y * y) / 2,
                z * (2 * z * z - 3 * x * x - 3 * y * y) / 2,
                np.sqrt(1.5) * x * (4 * z * z - x * x - y * y) / 2,
                np.sqrt(15.0) * z * (x * x - y * y) / 2,
                np.sqrt(2.5) * x * (x * x - 3 * y * y) / 2,
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l}")


def random_rotation(rng=None) -> np.ndarray:
    """Haar-ish random SO(3) matrix via QR."""
    rng = rng or _rng
    q, r = np.linalg.qr(rng.normal(size=(3, 3)))
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def wigner_d(l: int, R: np.ndarray) -> np.ndarray:
    """D_l(R) with Y_l(R r) = D_l(R) @ Y_l(r), solved by least squares over
    sample directions (exact: Y_l spans an irreducible representation)."""
    if l == 0:
        return np.ones((1, 1))
    pts = _rng.normal(size=(max(64, 4 * (2 * l + 1)), 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    A = real_sph_harm(l, pts)             # (P, 2l+1)
    B = real_sph_harm(l, pts @ R.T)       # (P, 2l+1)
    D, *_ = np.linalg.lstsq(A, B, rcond=None)
    return D.T                            # B^T = D @ A^T


@functools.lru_cache(maxsize=None)
def intertwiner(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """The (unique up to sign) equivariant map T[(m3), (m1), (m2)] with
    (u (x) v)_{m3} = sum T[m3, m1, m2] u_{m1} v_{m2}, normalized to
    ||T||_F = 1. None if l3 not in |l1-l2| .. l1+l2 (no intertwiner)."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    if l1 == l2 == l3 == 0:
        return np.ones((1, 1, 1))
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rows = []
    for _ in range(4):
        R = random_rotation()
        D1, D2, D3 = wigner_d(l1, R), wigner_d(l2, R), wigner_d(l3, R)
        # constraint: D3 @ T_mat = T_mat @ (D1 (x) D2), T_mat is (d3, d1*d2)
        K = np.kron(D1, D2)
        # vec(D3 T - T K) = (I (x) D3 - K^T (x) I) vec(T)
        rows.append(np.kron(np.eye(d1 * d2), D3) - np.kron(K.T, np.eye(d3)))
    M = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(M)
    # null space should be exactly 1-dimensional for l3 in the CG range
    null = vt[s.size - 1 :]
    assert s[-1] < 1e-8 and s[-2] > 1e-4, (l1, l2, l3, s[-3:])
    T = null[0].reshape(d1 * d2, d3).T.reshape(d3, d1, d2)
    T /= np.linalg.norm(T)
    # fix the sign deterministically (largest-|.| entry positive)
    flat = T.ravel()
    T = T * np.sign(flat[np.argmax(np.abs(flat))])
    return T


def tp_paths(l_in: tuple[int, ...], l_edge: tuple[int, ...],
             l_out: tuple[int, ...]):
    """All (l1, l2, l3) with nonzero intertwiner — the tensor-product paths."""
    return [
        (l1, l2, l3)
        for l1 in l_in
        for l2 in l_edge
        for l3 in l_out
        if abs(l1 - l2) <= l3 <= l1 + l2
    ]
