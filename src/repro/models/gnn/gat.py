"""GAT (gat-cora): multi-head graph attention network [arXiv:1710.10903].

Kernel regime: SDDMM (per-edge attention logits) -> segment-softmax ->
SpMM (attention-weighted neighbor sum), all via gather + segment ops.

Paper-exact Cora config: 2 layers, 8 hidden units per head, 8 heads (concat)
in layer 1; 1 output layer with n_classes units averaged over heads.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec
from repro.models.gnn.common import (
    GraphBatch,
    agg_sum,
    graph_readout,
    node_ce_loss,
    segment_softmax,
)


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    task: str = "node"            # 'node' | 'graph'
    dropout: float = 0.0          # inference/smoke default; train examples set it
    compute_dtype: Any = jnp.float32


def param_specs(cfg: GATConfig):
    specs = {}
    d = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        out = cfg.n_classes if last else cfg.d_hidden
        specs[f"layer{i}"] = {
            "w": ParamSpec((d, heads, out), ("embed", "heads", None)),
            "a_src": ParamSpec((heads, out), ("heads", None)),
            "a_dst": ParamSpec((heads, out), ("heads", None)),
            "bias": ParamSpec((heads, out), ("heads", None), init_scale=0.0),
        }
        d = out * heads
    if cfg.task == "graph":
        specs["readout_w"] = ParamSpec((d, cfg.n_classes), ("embed", None))
        specs["readout_b"] = ParamSpec((cfg.n_classes,), (None,), init_scale=0.0)
    return specs


def _gat_layer(p, x, batch: GraphBatch, *, concat: bool, act) -> jnp.ndarray:
    """x: (N, F). Returns (N, heads*out) if concat else (N, out)."""
    n = x.shape[0]
    h = jnp.einsum("nf,fho->nho", x, p["w"].astype(x.dtype))      # (N, H, O)
    h = constrain(h, ("act_nodes", None, None))
    e_src = jnp.einsum("nho,ho->nh", h, p["a_src"].astype(x.dtype))
    e_dst = jnp.einsum("nho,ho->nh", h, p["a_dst"].astype(x.dtype))
    scores = jax.nn.leaky_relu(
        e_src[batch.edge_src] + e_dst[batch.edge_dst], negative_slope=0.2)
    alpha = segment_softmax(scores, batch.edge_dst, n, batch.edge_mask)  # (E, H)
    msgs = h[batch.edge_src] * alpha[..., None]                    # (E, H, O)
    msgs = constrain(msgs, ("act_edges", None, None))
    agg = agg_sum(msgs.reshape(msgs.shape[0], -1), batch.edge_dst, n,
                  batch.edge_mask).reshape(n, *h.shape[1:])
    agg = agg + p["bias"].astype(x.dtype)[None]
    if concat:
        return act(agg).reshape(n, -1)
    return agg.mean(axis=1)                                        # head average


def forward(params, batch: GraphBatch, cfg: GATConfig) -> jnp.ndarray:
    x = batch.x.astype(cfg.compute_dtype)
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        x = _gat_layer(params[f"layer{i}"], x, batch,
                       concat=not last, act=jax.nn.elu)
    if cfg.task == "graph":
        g = graph_readout(x, batch)
        return g @ params["readout_w"].astype(x.dtype) + params["readout_b"]
    return x


def loss_fn(params, batch: GraphBatch, cfg: GATConfig):
    logits = forward(params, batch, cfg)
    if cfg.task == "graph":
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)[:, 0]
        m = batch.label_mask.astype(jnp.float32)
        loss = -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)
    else:
        loss = node_ce_loss(logits, batch)
    return loss, {"ce": loss}
