"""GatedGCN [arXiv:2003.00982 benchmark config; layer from arXiv:1711.07553].

Edge-featured MPNN with dense gating:
  e'_ij = A h_i + B h_j + C e_ij                    (edge update)
  eta_ij = sigmoid(e'_ij) / (sum_j sigmoid(e'_ij) + eps)
  h'_i  = U h_i + sum_{j->i} eta_ij (.) (V h_j)     (gated aggregation)
with residual connections and layer norm on both node and edge streams.

Benchmark config: 16 layers, d_hidden = 70.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, layer_norm
from repro.models.gnn.common import (
    GraphBatch,
    agg_sum,
    graph_readout,
    node_ce_loss,
)


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 0            # 0 => learned constant edge init
    n_classes: int = 7
    task: str = "node"
    scan_unroll: int = 1
    compute_dtype: Any = jnp.float32


def param_specs(cfg: GatedGCNConfig):
    d = cfg.d_hidden
    layer = {
        "A": ParamSpec((cfg.n_layers, d, d), ("layers", "embed", None)),
        "B": ParamSpec((cfg.n_layers, d, d), ("layers", "embed", None)),
        "C": ParamSpec((cfg.n_layers, d, d), ("layers", "embed", None)),
        "U": ParamSpec((cfg.n_layers, d, d), ("layers", "embed", None)),
        "V": ParamSpec((cfg.n_layers, d, d), ("layers", "embed", None)),
        "ln_h_g": ParamSpec((cfg.n_layers, d), ("layers", None)),
        "ln_h_b": ParamSpec((cfg.n_layers, d), ("layers", None), init_scale=0.0),
        "ln_e_g": ParamSpec((cfg.n_layers, d), ("layers", None)),
        "ln_e_b": ParamSpec((cfg.n_layers, d), ("layers", None), init_scale=0.0),
    }
    specs = {
        "embed_in": ParamSpec((cfg.d_in, d), ("embed", None)),
        "edge_init": (ParamSpec((cfg.d_edge_in, d), ("embed", None))
                      if cfg.d_edge_in else ParamSpec((d,), (None,))),
        "layers": layer,
        "head_w": ParamSpec((d, cfg.n_classes), ("embed", None)),
        "head_b": ParamSpec((cfg.n_classes,), (None,), init_scale=0.0),
    }
    return specs


def forward(params, batch: GraphBatch, cfg: GatedGCNConfig) -> jnp.ndarray:
    cdt = cfg.compute_dtype
    n = batch.x.shape[0]
    h = (batch.x.astype(cdt) @ params["embed_in"].astype(cdt))
    if cfg.d_edge_in:
        e = jnp.zeros((batch.edge_src.shape[0], cfg.d_hidden), cdt)
    else:
        e = jnp.broadcast_to(params["edge_init"].astype(cdt),
                             (batch.edge_src.shape[0], cfg.d_hidden))

    def body(carry, lp):
        h, e = carry
        hs, hd = h[batch.edge_src], h[batch.edge_dst]
        hs = constrain(hs, ("act_edges", None))
        hd = constrain(hd, ("act_edges", None))
        e_new = constrain(hd @ lp["A"].astype(cdt) + hs @ lp["B"].astype(cdt)
                          + e @ lp["C"].astype(cdt), ("act_edges", None))
        e_out = layer_norm(e + jax.nn.relu(e_new), lp["ln_e_g"], lp["ln_e_b"])
        sig = jax.nn.sigmoid(e_new.astype(jnp.float32)).astype(cdt)
        num = agg_sum(sig * (hs @ lp["V"].astype(cdt)), batch.edge_dst, n,
                      batch.edge_mask)
        den = agg_sum(sig, batch.edge_dst, n, batch.edge_mask)
        h_new = h @ lp["U"].astype(cdt) + num / (den + 1e-6)
        h_out = constrain(layer_norm(h + jax.nn.relu(h_new), lp["ln_h_g"],
                                     lp["ln_h_b"]), ("act_nodes", None))
        return (h_out, e_out), None

    body_fn = jax.checkpoint(body, prevent_cse=False)
    (h, e), _ = jax.lax.scan(body_fn, (h, e), params["layers"],
                             unroll=cfg.scan_unroll)
    if cfg.task == "graph":
        h = graph_readout(h, batch)
    return h @ params["head_w"].astype(cdt) + params["head_b"]


def loss_fn(params, batch: GraphBatch, cfg: GatedGCNConfig):
    logits = forward(params, batch, cfg)
    if cfg.task == "graph":
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch.labels[:, None], axis=-1)[:, 0]
        m = batch.label_mask.astype(jnp.float32)
        loss = -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)
    else:
        loss = node_ce_loss(logits, batch)
    return loss, {"ce": loss}
