"""GraphCast-style encoder-processor-decoder mesh GNN [arXiv:2212.12794].

Three bipartite/homogeneous message-passing stages, all with per-edge MLPs of
(src, dst, edge) features, sum aggregation, residual node/edge MLP updates:

  grid2mesh encoder : grid nodes (n_vars features) -> icosahedral mesh nodes
  processor (16x)   : multimesh message passing on mesh nodes
  mesh2grid decoder : mesh nodes -> grid nodes -> per-grid-node output (n_vars)

mesh_refinement=6 fixes the mesh statically: 10*4^6+2 = 40,962 mesh nodes and
sum_r 30*4^r (r=0..6) = 163,830 undirected multimesh edges (the multi-scale
edge set GraphCast uses) = 327,660 directed. Grid size and grid<->mesh edge
lists come from the input shape (they are data, not parameters).

For the generic graph shapes (full_graph_sm etc.) the same model runs with the
shape's node/edge counts standing in for grid/mesh sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec, layer_norm
from repro.models.gnn.common import agg_sum, mlp_apply, mlp_specs


def mesh_sizes(refinement: int) -> tuple[int, int]:
    """(n_mesh_nodes, n_directed_multimesh_edges) for an icosahedron refined
    ``refinement`` times, with the multimesh keeping every level's edges."""
    nodes = 10 * 4**refinement + 2
    und = sum(30 * 4**r for r in range(refinement + 1))
    return nodes, 2 * und


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16              # processor depth
    d_hidden: int = 512
    mesh_refinement: int = 6
    n_vars: int = 227               # input/output channels per grid node
    scan_unroll: int = 1
    compute_dtype: Any = jnp.bfloat16

    @property
    def n_mesh(self) -> int:
        return mesh_sizes(self.mesh_refinement)[0]

    @property
    def n_mesh_edges(self) -> int:
        return mesh_sizes(self.mesh_refinement)[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphCastBatch:
    """Inputs for one step. Grid->mesh and mesh->grid edges are data."""

    grid_x: jnp.ndarray        # (G, n_vars)
    g2m_src: jnp.ndarray       # (E_g2m,) grid ids
    g2m_dst: jnp.ndarray       # (E_g2m,) mesh ids
    mesh_src: jnp.ndarray      # (E_mesh,)
    mesh_dst: jnp.ndarray      # (E_mesh,)
    m2g_src: jnp.ndarray       # (E_m2g,) mesh ids
    m2g_dst: jnp.ndarray       # (E_m2g,) grid ids
    targets: jnp.ndarray       # (G, n_vars)
    grid_mask: jnp.ndarray | None = None  # (G,) bool; padded grid rows False.
    # Padded EDGES point at the sink node (last padded slot) on both ends, so
    # they only pollute sink rows, which grid_mask excludes from the loss.
    # static; 0 => cfg.n_mesh
    n_mesh: int = dataclasses.field(default=0, metadata=dict(static=True))


def _edge_mlp_specs(d):
    return mlp_specs((3 * d, d, d))


def _node_mlp_specs(d):
    return mlp_specs((2 * d, d, d))


def param_specs(cfg: GraphCastConfig):
    d = cfg.d_hidden
    proc_layer = {
        "edge": {k: ParamSpec((cfg.n_layers, *s.shape), ("layers", *s.axes), s.dtype, s.init_scale)
                 for k, s in _edge_mlp_specs(d).items()},
        "node": {k: ParamSpec((cfg.n_layers, *s.shape), ("layers", *s.axes), s.dtype, s.init_scale)
                 for k, s in _node_mlp_specs(d).items()},
        "ln_e_g": ParamSpec((cfg.n_layers, d), ("layers", None)),
        "ln_e_b": ParamSpec((cfg.n_layers, d), ("layers", None), init_scale=0.0),
        "ln_n_g": ParamSpec((cfg.n_layers, d), ("layers", None)),
        "ln_n_b": ParamSpec((cfg.n_layers, d), ("layers", None), init_scale=0.0),
    }
    return {
        "grid_embed": mlp_specs((cfg.n_vars, d, d)),
        "mesh_init": ParamSpec((d,), (None,)),
        "g2m_edge": _edge_mlp_specs(d),
        "g2m_node": _node_mlp_specs(d),
        "proc": proc_layer,
        "m2g_edge": _edge_mlp_specs(d),
        "m2g_node": _node_mlp_specs(d),
        "out": mlp_specs((d, d, cfg.n_vars)),
    }


def _mp_step(edge_p, node_p, h_src, h_dst, e, src, dst, n_dst):
    """One GraphCast message-passing block: edge MLP -> sum agg -> node MLP.
    Returns (new_dst_nodes, new_edges); caller applies residual/norm."""
    e_in = jnp.concatenate([h_src[src], h_dst[dst], e], axis=-1)
    e_in = constrain(e_in, ("act_edges", None))
    e_new = constrain(mlp_apply(edge_p, e_in, act=jax.nn.silu),
                      ("act_edges", None))
    agg = agg_sum(e_new, dst, n_dst)
    n_in = jnp.concatenate([h_dst, agg], axis=-1)
    out = mlp_apply(node_p, n_in, act=jax.nn.silu)
    return constrain(out, ("act_nodes", None)), e_new


def forward(params, batch: GraphCastBatch, cfg: GraphCastConfig) -> jnp.ndarray:
    cdt = cfg.compute_dtype
    n_mesh = batch.n_mesh or cfg.n_mesh
    G = batch.grid_x.shape[0]
    d = cfg.d_hidden

    hg = mlp_apply(params["grid_embed"], batch.grid_x.astype(cdt), act=jax.nn.silu)
    hg = constrain(hg, ("act_nodes", None))
    hm = jnp.broadcast_to(params["mesh_init"].astype(cdt), (n_mesh, d))
    hm = constrain(hm, ("act_nodes", None))

    # ---- grid2mesh encode
    e0 = jnp.zeros((batch.g2m_src.shape[0], d), cdt)
    hm_new, _ = _mp_step(params["g2m_edge"], params["g2m_node"], hg, hm, e0,
                         batch.g2m_src, batch.g2m_dst, n_mesh)
    hm = hm + hm_new

    # ---- processor: scan over the 16 multimesh layers
    e_mesh = jnp.zeros((batch.mesh_src.shape[0], d), cdt)

    def body(carry, lp):
        hm, e = carry
        hm_new, e_new = _mp_step(lp["edge"], lp["node"], hm, hm, e,
                                 batch.mesh_src, batch.mesh_dst, n_mesh)
        hm = layer_norm(hm + hm_new, lp["ln_n_g"], lp["ln_n_b"])
        e = layer_norm(e + e_new, lp["ln_e_g"], lp["ln_e_b"])
        return (hm, e), None

    body_fn = jax.checkpoint(body, prevent_cse=False)
    (hm, _), _ = jax.lax.scan(body_fn, (hm, e_mesh), params["proc"],
                              unroll=cfg.scan_unroll)

    # ---- mesh2grid decode
    e1 = jnp.zeros((batch.m2g_src.shape[0], d), cdt)
    hg_new, _ = _mp_step(params["m2g_edge"], params["m2g_node"], hm, hg, e1,
                         batch.m2g_src, batch.m2g_dst, G)
    hg = hg + hg_new
    return mlp_apply(params["out"], hg, act=jax.nn.silu)


def loss_fn(params, batch: GraphCastBatch, cfg: GraphCastConfig):
    pred = forward(params, batch, cfg)
    err = (pred.astype(jnp.float32) - batch.targets.astype(jnp.float32))
    if batch.grid_mask is not None:
        m = batch.grid_mask.astype(jnp.float32)[:, None]
        loss = (err * err * m).sum() / jnp.maximum(m.sum() * err.shape[-1], 1.0)
    else:
        loss = jnp.mean(err * err)
    return loss, {"mse": loss}
