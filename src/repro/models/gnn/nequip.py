"""NequIP: E(3)-equivariant interatomic potential [arXiv:2101.03164].

Features are direct sums of O(3) irreps, stored as {l: (N, mul, 2l+1)}.
One interaction block (paper Fig. 1):

  for each path (l1, l2, l3) with l1 in features, l2 in Y(r_ij), l3 <= l_max:
     m_ij^{l3} += R_path(|r_ij|) * CG[l3 l1 l2] (h_j^{l1} (x) Y^{l2}(r_ij))
  h_i <- SelfInteraction( h_i , sum_{j in N(i)} m_ij )       (per-l linear)
  h_i <- Gate(h_i)     (silu on l=0; l>0 gated by learned scalar sigmoid)

R_path is an MLP over n_rbf Bessel radial basis functions with a smooth
polynomial cutoff envelope. CG intertwiners come from
repro.models.gnn.equivariant (numerically exact, host-side constants).

Config: 5 layers, 32 channels per irrep, l_max = 2, 8 RBFs, cutoff 5 A.

Output: per-atom scalar energies (l=0 head) summed per graph; forces =
-grad(E, positions), exercised in tests for equivariance.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.common import ParamSpec
from repro.models.gnn.equivariant import intertwiner, real_sph_harm, tp_paths


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # multiplicity per irrep
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 10
    radial_hidden: int = 64
    compute_dtype: Any = jnp.float32

    @property
    def ls(self) -> tuple[int, ...]:
        return tuple(range(self.l_max + 1))

    @property
    def paths(self):
        return tp_paths(self.ls, self.ls, self.ls)


# --------------------------------------------------------------- radial basis
def bessel_rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """sin(n pi d / rc) / d Bessel basis with smooth polynomial envelope."""
    d = jnp.maximum(dist, 1e-6)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=d.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    u = jnp.clip(dist / cutoff, 0.0, 1.0)[..., None]
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5   # C2 cutoff poly
    return basis * env


# -------------------------------------------------------------------- params
def param_specs(cfg: NequIPConfig):
    mul, ls, L = cfg.d_hidden, cfg.ls, cfg.n_layers
    n_paths = len(cfg.paths)
    layer = {
        # radial MLP -> one weight per (path, channel)
        "rad_w0": ParamSpec((L, cfg.n_rbf, cfg.radial_hidden), ("layers", None, None)),
        "rad_b0": ParamSpec((L, cfg.radial_hidden), ("layers", None), init_scale=0.0),
        "rad_w1": ParamSpec((L, cfg.radial_hidden, n_paths * mul),
                            ("layers", None, "mlp")),
        # per-l self-interaction mixing after aggregation (input: mul * n_in_paths)
        **{f"self_l{l}": ParamSpec(
            (L, mul * (1 + sum(1 for (a, b, c) in cfg.paths if c == l)), mul),
            ("layers", None, None)) for l in ls},
        # gate scalars for l>0 irreps
        "gate_w": ParamSpec((L, mul, mul * cfg.l_max), ("layers", None, None)),
        "gate_b": ParamSpec((L, mul * cfg.l_max), ("layers", None), init_scale=0.0),
    }
    return {
        "embed": ParamSpec((cfg.n_species, mul), ("vocab", None)),
        "layers": layer,
        "out_w0": ParamSpec((mul, mul), (None, None)),
        "out_b0": ParamSpec((mul,), (None,), init_scale=0.0),
        "out_w1": ParamSpec((mul, 1), (None, None)),
    }


# ------------------------------------------------------------------- forward
def _interaction(lp, feats, sh, rad, edge_src, edge_dst, n_nodes, cfg,
                 *, gather=None, scatter=None):
    """One NequIP interaction block. feats: {l: (N, mul, 2l+1)}.

    gather/scatter hooks let the shard_map path (forward_energy_shardmap)
    reuse the exact same math with destination-partitioned edges:
      gather(f)  default f[edge_src]       (pjit: GSPMD all-gathers f)
      scatter(m) default segment_sum(m, edge_dst, n_nodes)
                                           (pjit: full-size local buffers)
    """
    mul = cfg.d_hidden
    gather = gather or (lambda f: f[edge_src])
    scatter = scatter or (lambda m: constrain(
        jax.ops.segment_sum(m, edge_dst, num_segments=n_nodes),
        ("act_nodes", None, None)))
    # per-edge, per-path, per-channel radial weights
    h = jax.nn.silu(rad @ lp["rad_w0"] + lp["rad_b0"])
    w = (h @ lp["rad_w1"]).reshape(-1, len(cfg.paths), mul)   # (E, P, mul)

    src_full = {l: gather(feats[l]) for l in cfg.ls}     # one gather per l
    msgs = {l: [] for l in cfg.ls}
    for p_idx, (l1, l2, l3) in enumerate(cfg.paths):
        T = jnp.asarray(intertwiner(l1, l2, l3), feats[l1].dtype)  # (2l3+1,2l1+1,2l2+1)
        src_f = src_full[l1]                             # (E, mul, 2l1+1)
        y = sh[l2]                                       # (E, 2l2+1)
        m = jnp.einsum("kij,eci,ej->eck", T, src_f, y)   # (E, mul, 2l3+1)
        msgs[l3].append(constrain(m * w[:, p_idx, :, None],
                                  ("act_edges", None, None)))
    out = {}
    for l in cfg.ls:
        # NOTE (measured, see EXPERIMENTS.md §Perf I10): under GSPMD each
        # segment_sum scatter builds a full-size local node buffer per shard
        # and each gather all-gathers the node features — the structural fix
        # is forward_energy_shardmap (EAGr's reader partitioning applied to
        # GNNs), used for the huge full-graph shapes.
        stack = [feats[l]] + [scatter(m) for m in msgs[l]]
        cat = jnp.concatenate(stack, axis=1)             # (N, mul*(1+P_l), 2l+1)
        out[l] = constrain(jnp.einsum("nci,cd->ndi", cat, lp[f"self_l{l}"]),
                           ("act_nodes", None, None))
    # gate nonlinearity
    scalars = out[0][..., 0]                              # (N, mul)
    gates = jax.nn.sigmoid(scalars @ lp["gate_w"] + lp["gate_b"])
    gates = gates.reshape(-1, cfg.l_max, mul)
    new = {0: jax.nn.silu(scalars)[..., None]}
    for i, l in enumerate(range(1, cfg.l_max + 1)):
        new[l] = out[l] * gates[:, i, :, None]
    # residual on scalars (higher l start at zero features in layer 0)
    new[0] = new[0] + feats[0]
    return new


def forward_energy(params, positions, species, edge_src, edge_dst, edge_mask,
                   node_mask, graph_ids, n_graphs, cfg: NequIPConfig):
    """positions (N,3), species (N,), edges (E,). Returns (n_graphs,) energies."""
    cdt = cfg.compute_dtype
    n = positions.shape[0]
    rel = positions[edge_dst] - positions[edge_src]       # (E, 3)
    # grad-safe norm: masked/self edges have rel = 0; plain norm() gives NaN grads
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    unit = rel / dist[:, None]
    emask = (edge_mask & (dist < cfg.cutoff)).astype(cdt)[:, None]
    # cast basis functions to compute dtype: fp32 sh/rad would silently
    # promote every edge message back to fp32
    sh = {l: (jnp.asarray(_sph(l, unit)) * emask).astype(cdt) for l in cfg.ls}
    rad = (bessel_rbf(dist, cfg.n_rbf, cfg.cutoff) * emask).astype(cdt)

    mul = cfg.d_hidden
    feats = {0: (jnp.take(params["embed"], species, axis=0)
                 * node_mask.astype(cdt)[:, None])[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, mul, 2 * l + 1), cdt)

    inter = jax.checkpoint(
        lambda lp, feats: _interaction(lp, feats, sh, rad, edge_src,
                                       edge_dst, n, cfg))
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda x, i=i: x[i], params["layers"])
        feats = inter(lp, feats)

    scalars = feats[0][..., 0]
    e_atom = jax.nn.silu(scalars @ params["out_w0"] + params["out_b0"])
    e_atom = (e_atom @ params["out_w1"])[:, 0] * node_mask.astype(cdt)
    return jax.ops.segment_sum(e_atom, graph_ids, num_segments=n_graphs)


def forward_energy_shardmap(params, positions, species, edge_src, edge_dst,
                            edge_mask, node_mask, graph_ids, n_graphs,
                            cfg: NequIPConfig, mesh, axis_names):
    """Destination-partitioned message passing via shard_map — EAGr §7's
    reader partitioning applied to GNNs.

    INPUT CONTRACT (the input pipeline's job, declared here): node arrays are
    sharded into contiguous ranges over ``axis_names``; shard s's edge slice
    contains only edges whose DESTINATION lies in s's node range (any source).
    Then each shard: all-gathers the (small) per-l node features ONCE per
    layer, computes its local edges' messages, and segment-sums into its OWN
    node range — no full-size scatter buffers, no per-path all-gathers.

    graph_ids are ignored: the huge full-graph shapes have n_graphs == 1
    (energy = psum of local atom energies), which is the only regime where
    this path is selected.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed.sharding import no_constrain

    cdt = cfg.compute_dtype
    n = positions.shape[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_shards = 1
    for a in axis_names:
        n_shards *= sizes[a]
    assert n % n_shards == 0, (n, n_shards)
    n_local = n // n_shards
    mul = cfg.d_hidden

    def shard_fn(prm, pos_l, spec_l, esrc, edst, emask_l, nmask_l):
        # flattened shard rank in the row-major order of axis_names
        rank = jnp.int32(0)
        for a in axis_names:
            rank = rank * sizes[a] + jax.lax.axis_index(a)

        pos_f = jax.lax.all_gather(pos_l, axis_names, axis=0, tiled=True)
        rel = pos_f[edst] - pos_f[esrc]
        dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
        unit = rel / dist[:, None]
        em = (emask_l & (dist < cfg.cutoff)).astype(cdt)[:, None]
        sh = {l: (jnp.asarray(_sph(l, unit)) * em).astype(cdt) for l in cfg.ls}
        rad = (bessel_rbf(dist, cfg.n_rbf, cfg.cutoff) * em).astype(cdt)

        # local destination segment ids; foreign/masked edges -> sink n_local
        edst_loc = edst - rank * n_local
        ok = (edst_loc >= 0) & (edst_loc < n_local)
        seg = jnp.where(ok, edst_loc, n_local)

        def gather(f_local):
            f_full = jax.lax.all_gather(f_local, axis_names, axis=0, tiled=True)
            return f_full[esrc]

        def scatter(m):
            return jax.ops.segment_sum(m, seg, num_segments=n_local + 1)[:n_local]

        feats = {0: (jnp.take(prm["embed"], spec_l, axis=0)
                     * nmask_l.astype(cdt)[:, None])[..., None]}
        for l in range(1, cfg.l_max + 1):
            feats[l] = jnp.zeros((n_local, mul, 2 * l + 1), cdt)

        inter = jax.checkpoint(
            lambda lp, feats: _interaction(lp, feats, sh, rad, esrc, None,
                                           None, cfg, gather=gather,
                                           scatter=scatter))
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x, i=i: x[i], prm["layers"])
            feats = inter(lp, feats)

        scalars = feats[0][..., 0]
        e_atom = jax.nn.silu(scalars @ prm["out_w0"] + prm["out_b0"])
        e_atom = (e_atom @ prm["out_w1"])[:, 0] * nmask_l.astype(cdt)
        return jax.lax.psum(e_atom.sum()[None], axis_names)

    spec_n = P(axis_names)         # node/edge arrays: dim0 sharded
    p_specs = jax.tree.map(lambda _: P(), params)   # params replicated
    with no_constrain():
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(p_specs, spec_n, spec_n, spec_n, spec_n, spec_n, spec_n),
            out_specs=P(),
            check_rep=False,
        )(params, positions, species, edge_src, edge_dst, edge_mask, node_mask)


def _sph(l: int, unit: jnp.ndarray) -> jnp.ndarray:
    """jnp version of the host real_sph_harm formulas (traceable)."""
    x, y, z = unit[..., 0], unit[..., 1], unit[..., 2]
    if l == 0:
        return jnp.ones(unit.shape[:-1] + (1,), unit.dtype)
    if l == 1:
        return unit
    if l == 2:
        s3 = np.sqrt(3.0)
        return jnp.stack([
            x * y, y * z, (2 * z * z - x * x - y * y) / (2 * s3),
            x * z, (x * x - y * y) / 2.0], axis=-1) * s3
    raise NotImplementedError(l)


def energy_and_forces(params, positions, species, edge_src, edge_dst, edge_mask,
                      node_mask, graph_ids, n_graphs, cfg: NequIPConfig):
    def e_total(pos):
        return forward_energy(params, pos, species, edge_src, edge_dst,
                              edge_mask, node_mask, graph_ids, n_graphs, cfg).sum()
    e, grad = jax.value_and_grad(e_total)(positions)
    energies = forward_energy(params, positions, species, edge_src, edge_dst,
                              edge_mask, node_mask, graph_ids, n_graphs, cfg)
    return energies, -grad, e


def loss_fn(params, batch, cfg: NequIPConfig, force_weight: float = 1.0,
            use_forces: bool = True):
    """batch: dict with positions/species/edge_src/edge_dst/edge_mask/node_mask/
    graph_ids/energy_targets/force_targets. n_graphs = len(energy_targets).
    use_forces=False skips the grad-through-energy force term (used for the
    huge assigned graph shapes where there is no force supervision anyway)."""
    n_graphs = batch["energy_targets"].shape[0]

    def e_total(pos):
        e = forward_energy(params, pos, batch["species"], batch["edge_src"],
                           batch["edge_dst"], batch["edge_mask"],
                           batch["node_mask"], batch["graph_ids"],
                           n_graphs, cfg)
        return e.sum(), e

    if not use_forces:
        _, energies = e_total(batch["positions"])
        e_loss = jnp.mean((energies - batch["energy_targets"].astype(jnp.float32)) ** 2)
        return e_loss, {"e_mse": e_loss, "f_mse": jnp.float32(0.0)}

    (_, energies), grad = jax.value_and_grad(e_total, has_aux=True)(batch["positions"])
    forces = -grad
    e_loss = jnp.mean((energies - batch["energy_targets"].astype(jnp.float32)) ** 2)
    nm = batch["node_mask"].astype(jnp.float32)[:, None]
    f_loss = jnp.sum(((forces - batch["force_targets"]) ** 2) * nm) / jnp.maximum(nm.sum() * 3, 1.0)
    loss = e_loss + force_weight * f_loss
    return loss, {"e_mse": e_loss, "f_mse": f_loss}
