"""DIEN: Deep Interest Evolution Network [arXiv:1809.03672].

Pipeline (paper Fig. 2):
  behavior embeddings  e_t = [item_embed ; cat_embed]            (2 * 18 = 36)
  interest extraction  GRU over the 100-step behavior sequence   (hidden 108)
    + auxiliary loss: sigmoid(h_t . e_{t+1}) vs sampled negatives
  interest evolution   AUGRU — GRU whose update gate is scaled by
                       attention(h_t, target embedding)
  prediction MLP       [user features] -> 200 -> 80 -> 1 (sigmoid CTR)

The embedding lookup is the hot path: JAX has no native EmbeddingBag, so the
multi-hot user-profile features go through gather + segment_sum (the
``embedding_bag`` Pallas kernel is the TPU analogue, validated in tests).

``retrieval_score`` (the retrieval_cand shape) scores one user against 10^6
candidates with the candidate-independent interest state computed once and a
batched MLP over candidates — the two-tower approximation of DIEN's ranking
path (full AUGRU re-evaluation per candidate is O(n_cand * seq_len) and is
exactly what retrieval setups avoid; documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    n_items: int = 1_000_000
    n_cats: int = 1_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple[int, ...] = (200, 80)
    n_profile_feats: int = 100_000     # multi-hot user-profile vocabulary
    profile_bag_size: int = 16         # multi-hot ids per user (padded)
    att_hidden: int = 80
    scan_unroll: int = 1          # analysis mode: seq_len => straight-line HLO
    compute_dtype: Any = jnp.float32

    @property
    def behav_dim(self) -> int:
        return 2 * self.embed_dim      # [item ; cat]


def _gru_specs(d_in: int, d_h: int, prefix: str):
    return {
        f"{prefix}_wx": ParamSpec((d_in, 3 * d_h), ("embed", "mlp")),
        f"{prefix}_wh": ParamSpec((d_h, 3 * d_h), (None, "mlp")),
        f"{prefix}_b": ParamSpec((3 * d_h,), (None,), init_scale=0.0),
    }


def param_specs(cfg: DIENConfig):
    d_b, d_h = cfg.behav_dim, cfg.gru_dim
    mlp_in = d_h + 2 * d_b + cfg.embed_dim   # interest + target + pooled + profile
    dims = (mlp_in, *cfg.mlp_dims, 1)
    mlp = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        mlp[f"w{i}"] = ParamSpec((a, b), ("embed" if i == 0 else None, None))
        mlp[f"b{i}"] = ParamSpec((b,), (None,), init_scale=0.0)
    return {
        "item_embed": ParamSpec((cfg.n_items, cfg.embed_dim), ("vocab", None)),
        "cat_embed": ParamSpec((cfg.n_cats, cfg.embed_dim), (None, None)),
        "profile_embed": ParamSpec((cfg.n_profile_feats, cfg.embed_dim),
                                   ("vocab", None)),
        **_gru_specs(d_b, d_h, "gru"),        # interest extraction
        **_gru_specs(d_h, d_h, "augru"),      # interest evolution (input: h_t)
        "att_w0": ParamSpec((d_h + d_b, cfg.att_hidden), (None, None)),
        "att_b0": ParamSpec((cfg.att_hidden,), (None,), init_scale=0.0),
        "att_w1": ParamSpec((cfg.att_hidden, 1), (None, None)),
        "mlp": mlp,
        # retrieval tower: project user state into candidate-embedding space
        "ret_w": ParamSpec((d_h + d_b, cfg.embed_dim), (None, None)),
    }


# ----------------------------------------------------------------- GRU cells
def _gru_step(p, prefix, x, h):
    """Standard GRU. x: (B, d_in), h: (B, d_h)."""
    d_h = h.shape[-1]
    gates = x @ p[f"{prefix}_wx"].astype(x.dtype) \
        + h @ p[f"{prefix}_wh"].astype(x.dtype) + p[f"{prefix}_b"].astype(x.dtype)
    r, z, n = jnp.split(gates, 3, axis=-1)
    r, z = jax.nn.sigmoid(r), jax.nn.sigmoid(z)
    # candidate uses reset-scaled recurrent term
    n = jnp.tanh(x @ p[f"{prefix}_wx"].astype(x.dtype)[:, 2 * d_h:]
                 + r * (h @ p[f"{prefix}_wh"].astype(x.dtype)[:, 2 * d_h:])
                 + p[f"{prefix}_b"].astype(x.dtype)[2 * d_h:])
    return (1.0 - z) * n + z * h


def _augru_step(p, x, h, att):
    """AUGRU: attention scales the update gate (DIEN eq. 8):
    u' = att * u;  h_t = (1 - u') h_{t-1} + u' h~_t  — att = 0 freezes h."""
    d_h = h.shape[-1]
    gates = x @ p["augru_wx"].astype(x.dtype) \
        + h @ p["augru_wh"].astype(x.dtype) + p["augru_b"].astype(x.dtype)
    r, z, _ = jnp.split(gates, 3, axis=-1)
    r, z = jax.nn.sigmoid(r), jax.nn.sigmoid(z)
    z = att[:, None] * z
    n = jnp.tanh(x @ p["augru_wx"].astype(x.dtype)[:, 2 * d_h:]
                 + r * (h @ p["augru_wh"].astype(x.dtype)[:, 2 * d_h:])
                 + p["augru_b"].astype(x.dtype)[2 * d_h:])
    return (1.0 - z) * h + z * n


# ------------------------------------------------------------------ embedding
def behavior_embed(params, item_ids, cat_ids, cfg: DIENConfig):
    """(B, S) ids -> (B, S, 2*embed_dim)."""
    ei = jnp.take(params["item_embed"], item_ids, axis=0)
    ec = jnp.take(params["cat_embed"], cat_ids, axis=0)
    return jnp.concatenate([ei, ec], axis=-1).astype(cfg.compute_dtype)


def profile_embed(params, profile_ids, profile_mask, cfg: DIENConfig):
    """EmbeddingBag: multi-hot profile ids (B, n_bag) -> mean-pooled (B, d).
    gather + masked mean == segment_sum over the flattened bag layout."""
    e = jnp.take(params["profile_embed"], profile_ids, axis=0)
    m = profile_mask.astype(e.dtype)[..., None]
    return ((e * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)).astype(cfg.compute_dtype)


# -------------------------------------------------------------------- forward
def interest_states(params, behav, mask, cfg: DIENConfig):
    """GRU over the behavior sequence. behav: (B, S, d_b). Returns (B, S, d_h)."""
    B = behav.shape[0]
    h0 = jnp.zeros((B, cfg.gru_dim), behav.dtype)

    def step(h, xm):
        x, m = xm
        h_new = _gru_step(params, "gru", x, h)
        h = jnp.where(m[:, None], h_new, h)
        return h, h

    xs = (behav.transpose(1, 0, 2), mask.T)
    _, hs = jax.lax.scan(step, h0, xs, unroll=cfg.scan_unroll)
    return hs.transpose(1, 0, 2)


def attention_scores(params, hs, target, mask):
    """(B, S, d_h) x (B, d_b) -> softmax scores (B, S)."""
    B, S, _ = hs.shape
    t = jnp.broadcast_to(target[:, None, :], (B, S, target.shape[-1]))
    a = jnp.concatenate([hs, t], axis=-1)
    a = jax.nn.sigmoid(a @ params["att_w0"].astype(a.dtype)
                       + params["att_b0"].astype(a.dtype))
    logits = (a @ params["att_w1"].astype(a.dtype))[..., 0]
    logits = jnp.where(mask, logits, -1e9)
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(hs.dtype)


def evolve_interest(params, behav, hs, att, mask, cfg: DIENConfig):
    """AUGRU over interest states. Returns final state (B, d_h)."""
    B = behav.shape[0]
    h0 = jnp.zeros((B, cfg.gru_dim), behav.dtype)

    def step(h, xs_t):
        h_in, a, m = xs_t
        h_new = _augru_step(params, h_in, h, a)
        return jnp.where(m[:, None], h_new, h), None

    xs = (hs.transpose(1, 0, 2), att.T, mask.T)
    h, _ = jax.lax.scan(step, h0, xs, unroll=cfg.scan_unroll)
    return h


def ctr_logits(params, batch, cfg: DIENConfig):
    """Full ranking path. batch keys: item_ids, cat_ids (B,S) int32; mask (B,S)
    bool; target_item, target_cat (B,); profile_ids (B,n_bag); profile_mask."""
    behav = behavior_embed(params, batch["item_ids"], batch["cat_ids"], cfg)
    target = behavior_embed(params, batch["target_item"][:, None],
                            batch["target_cat"][:, None], cfg)[:, 0]
    mask = batch["mask"]
    hs = interest_states(params, behav, mask, cfg)
    att = attention_scores(params, hs, target, mask)
    final = evolve_interest(params, behav, hs, att, mask, cfg)
    pooled = (behav * mask[..., None].astype(behav.dtype)).sum(1) \
        / jnp.maximum(mask.sum(1, keepdims=True).astype(behav.dtype), 1.0)
    prof = profile_embed(params, batch["profile_ids"], batch["profile_mask"], cfg)
    x = jnp.concatenate([final, target, pooled, prof], axis=-1)
    mlp = params["mlp"]
    n = len([k for k in mlp if k.startswith("w")])
    for i in range(n):
        x = x @ mlp[f"w{i}"].astype(x.dtype) + mlp[f"b{i}"].astype(x.dtype)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x[:, 0], hs, behav


def aux_loss(params, hs, behav, neg_behav, mask):
    """DIEN auxiliary loss: h_t should predict e_{t+1} against a sampled
    negative. hs: (B,S,d_h), behav/neg_behav: (B,S,d_b)."""
    d_h = hs.shape[-1]
    h, e_next = hs[:, :-1], behav[:, 1:]
    e_neg = neg_behav[:, 1:]
    m = mask[:, 1:].astype(jnp.float32)
    # score by inner product on the shared prefix of dims
    d = min(d_h, e_next.shape[-1])
    pos = jnp.sum(h[..., :d] * e_next[..., :d], axis=-1).astype(jnp.float32)
    neg = jnp.sum(h[..., :d] * e_neg[..., :d], axis=-1).astype(jnp.float32)
    ll = jax.nn.log_sigmoid(pos) + jax.nn.log_sigmoid(-neg)
    return -(ll * m).sum() / jnp.maximum(m.sum(), 1.0)


def loss_fn(params, batch, cfg: DIENConfig, aux_weight: float = 1.0):
    logits, hs, behav = ctr_logits(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    ce = -jnp.mean(y * jax.nn.log_sigmoid(logits)
                   + (1 - y) * jax.nn.log_sigmoid(-logits))
    neg_behav = behavior_embed(params, batch["neg_item_ids"],
                               batch["neg_cat_ids"], cfg)
    al = aux_loss(params, hs, behav, neg_behav, batch["mask"])
    return ce + aux_weight * al, {"ce": ce, "aux": al}


def serve(params, batch, cfg: DIENConfig):
    """Online/offline CTR scoring (serve_p99 / serve_bulk shapes)."""
    logits, _, _ = ctr_logits(params, batch, cfg)
    return jax.nn.sigmoid(logits)


def retrieval_score(params, batch, cfg: DIENConfig):
    """Score 1 user against n_cand candidates (retrieval_cand shape).
    batch: item_ids/cat_ids/mask (1, S); profile_ids/profile_mask (1, n_bag);
    cand_items, cand_cats (n_cand,). Returns (n_cand,) scores."""
    behav = behavior_embed(params, batch["item_ids"], batch["cat_ids"], cfg)
    mask = batch["mask"]
    hs = interest_states(params, behav, mask, cfg)
    final = hs[:, -1]                                        # (1, d_h)
    pooled = (behav * mask[..., None].astype(behav.dtype)).sum(1) \
        / jnp.maximum(mask.sum(1, keepdims=True).astype(behav.dtype), 1.0)
    user = jnp.concatenate([final, pooled], axis=-1) @ params["ret_w"].astype(behav.dtype)
    cand = jnp.take(params["item_embed"], batch["cand_items"], axis=0) \
        + jnp.take(params["cat_embed"], batch["cand_cats"], axis=0)
    return (cand.astype(user.dtype) @ user[0])               # (n_cand,)
