"""Decoder-only GQA transformer family (granite / internlm2 / command-r /
arctic / dbrx) with optional Mixture-of-Experts FFNs.

Pure-functional JAX: ``param_specs`` declares every parameter with logical
sharding axes (mapped to the mesh by distributed/sharding.py), the forward
pass is a ``lax.scan`` over layers (small HLO, fast multi-pod compiles) with
a configurable remat policy, and three entry points mirror the assigned
input shapes:

  loss_fn        train_4k             (tokens -> mean CE, z-loss)
  prefill        prefill_32k          (tokens -> logits + KV cache)
  decode_step    decode_32k/long_500k (1 new token against a live KV cache)

MoE supports two dispatch implementations (perf hillclimb §Perf):
  'einsum'  GShard-style group-wise one-hot dispatch/combine einsums
            (the SPMD-classic baseline; dispatch matmuls cost ~T/3F of
            expert FLOPs),
  'sort'    dropless sort-based dispatch: argsort tokens by expert, scatter
            into (E, C) slots, gather back (no dispatch matmuls).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import (
    ParamSpec,
    apply_rope,
    blocked_attention,
    cross_entropy,
    fused_ce_loss,
    rms_norm,
    rope_angles,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_base: float = 10000.0
    # MoE (n_experts == 0 => dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    moe_impl: str = "einsum"           # 'einsum' | 'sort'
    # numerics / memory
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"                # 'none' | 'full'
    q_chunk: int = 512
    ce_chunk: int = 512                # fused-CE sequence chunk
    z_loss: float = 1e-4
    vocab_pad_to: int = 128            # pad vocab so TP shards evenly / MXU-aligned
    scan_unroll: int = 1               # analysis mode: n_layers => straight-line HLO
                                       # (XLA cost_analysis counts a while body once)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad_to) * self.vocab_pad_to


# ------------------------------------------------------------------ params
def param_specs(cfg: TransformerConfig):
    D, F, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    Hq, Hkv, hd, L = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    pdt = cfg.param_dtype

    def lp(shape, axes, scale=1.0):   # layer-stacked param
        return ParamSpec((L, *shape), ("layers", *axes), pdt, scale)

    layers: dict[str, ParamSpec] = {
        "ln1": lp((D,), (None,)),
        "ln2": lp((D,), (None,)),
        "wq": lp((D, Hq, hd), ("embed", "heads", None)),
        "wk": lp((D, Hkv, hd), ("embed", "kv_heads", None)),
        "wv": lp((D, Hkv, hd), ("embed", "kv_heads", None)),
        "wo": lp((Hq, hd, D), ("heads", None, "embed")),
    }
    if cfg.is_moe:
        E = cfg.n_experts
        layers |= {
            "router": lp((D, E), ("embed", None)),
            "we_gate": lp((E, D, F), ("expert", "embed", None)),
            "we_up": lp((E, D, F), ("expert", "embed", None)),
            "we_down": lp((E, F, D), ("expert", None, "embed")),
        }
    if (not cfg.is_moe) or cfg.moe_dense_residual:
        layers |= {
            "w_gate": lp((D, F), ("embed", "mlp")),
            "w_up": lp((D, F), ("embed", "mlp")),
            "w_down": lp((F, D), ("mlp", "embed")),
        }
    return {
        "embed": ParamSpec((V, D), ("vocab", "embed"), pdt),
        "layers": layers,
        "final_norm": ParamSpec((D,), (None,), pdt),
        "lm_head": ParamSpec((D, V), ("embed", "vocab"), pdt),
    }


# --------------------------------------------------------------------- ffn
def _swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    return h @ w_down


def _moe_ffn_einsum(x, lp, cfg: TransformerConfig):
    """GShard-style group-wise einsum dispatch. x: (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(4, int(cfg.capacity_factor * S * k / E + 0.999) // 4 * 4)
    logits = jnp.einsum("gsd,de->gse", x, lp["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    disp = jnp.zeros((B, S, E, C), dtype=x.dtype)
    comb = jnp.zeros((B, S, E, C), dtype=jnp.float32)
    counts = jnp.zeros((B, E), dtype=jnp.int32)
    for j in range(k):                                      # static top-k unroll
        mask_j = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.int32)  # (B,S,E)
        pos_j = jnp.cumsum(mask_j, axis=1) - 1 + counts[:, None, :]
        keep = (mask_j > 0) & (pos_j < C)
        slot = jax.nn.one_hot(jnp.where(keep, pos_j, C), C + 1,
                              dtype=x.dtype)[..., :C]        # (B,S,E,C)
        disp = disp + slot
        comb = comb + slot.astype(jnp.float32) * gate_vals[..., j][..., None, None]
        counts = counts + mask_j.sum(axis=1)

    xd = jnp.einsum("gsec,gsd->egcd", disp, x)               # dispatch
    xd = constrain(xd, ("act_expert", "act_batch", None, None))
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xd, lp["we_gate"].astype(x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xd, lp["we_up"].astype(x.dtype))
    y = jnp.einsum("egcf,efd->egcd", h, lp["we_down"].astype(x.dtype))
    y = constrain(y, ("act_expert", "act_batch", None, None))
    out = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), y)
    aux = _load_balance_loss(probs.reshape(-1, E), gate_idx.reshape(-1, k), E)
    return out, aux


def _moe_ffn_sort(x, lp, cfg: TransformerConfig):
    """Per-row sort-based dispatch: no (T,E,C) dispatch matmuls. x: (B, S, D).

    The sort/permutation is vmapped over the batch row so every gather/scatter
    is *batched* — GSPMD keeps the batch dim sharded over (pod, data). A
    global argsort over all B*S*k assignments (the naive MegaBlocks port)
    defeats the SPMD partitioner: arbitrary cross-shard permutation indices
    force it to replicate the (T*k, D) tensors (measured: 103 GB/device for
    dbrx prefill; see EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(4, int(cfg.capacity_factor * S * k / E + 3.0) // 4 * 4)
    logits = jnp.einsum("bsd,de->bse", x, lp["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(xr, idxr):
        """xr (S, D), idxr (S, k) -> per-row expert buffers + inverse map."""
        flat_e = idxr.reshape(-1)                            # (S*k,)
        order = jnp.argsort(flat_e)
        tok_of = order // k
        e_sorted = flat_e[order]
        ar = jnp.arange(S * k)
        run_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
        slot = ar - run_start[e_sorted]
        keep = slot < C
        buf = jnp.zeros((E, C, D), dtype=xr.dtype)
        buf = buf.at[e_sorted, jnp.where(keep, slot, 0)].add(
            jnp.where(keep[:, None], xr[tok_of], 0.0))
        inv = jnp.zeros_like(order).at[order].set(ar)
        return buf, slot[inv].reshape(S, k), keep[inv].reshape(S, k)

    buf, slot_sk, keep_sk = jax.vmap(dispatch_row)(x, gate_idx)  # (B,E,C,D)
    buf = constrain(buf, ("act_batch", "act_expert", None, None))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, lp["we_gate"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, lp["we_up"].astype(x.dtype))
    y = jnp.einsum("becf,efd->becd", h, lp["we_down"].astype(x.dtype))
    y = constrain(y, ("act_batch", "act_expert", None, None))

    def gather_row(yr, idxr, slotr, keepr):
        picked = yr[idxr, jnp.where(keepr, slotr, 0)]        # (S, k, D)
        return jnp.where(keepr[..., None], picked, 0.0)

    picked = jax.vmap(gather_row)(y, gate_idx, slot_sk, keep_sk)  # (B,S,k,D)
    out = (picked * gate_vals[..., None].astype(x.dtype)).sum(axis=2)
    aux = _load_balance_loss(probs.reshape(-1, E), gate_idx.reshape(-1, k), E)
    return out, aux


def _load_balance_loss(probs, gate_idx, E):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    f = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32).mean(axis=0)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


# ------------------------------------------------------------------- layer
def _attention(x, lp, cfg, cos, sin, *, causal, kv_cache=None, lengths=None):
    """x: (B, S, D). Returns (out, (k, v)) with k/v (B, Hkv, S_total, hd)."""
    cdt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"].astype(cdt))
    kk = jnp.einsum("bsd,dhk->bshk", x, lp["wk"].astype(cdt))
    vv = jnp.einsum("bsd,dhk->bshk", x, lp["wv"].astype(cdt))
    q = constrain(apply_rope(q, cos, sin), ("act_batch", "act_seq", "act_heads", None))
    kk = constrain(apply_rope(kk, cos, sin), ("act_batch", "act_seq", "act_kv_heads", None))
    vv = constrain(vv, ("act_batch", "act_seq", "act_kv_heads", None))
    q = q.transpose(0, 2, 1, 3)      # (B, Hq, S, hd)
    kk = kk.transpose(0, 2, 1, 3)
    vv = vv.transpose(0, 2, 1, 3)
    if kv_cache is not None:
        k_all, v_all = kv_cache
    else:
        k_all, v_all = kk, vv
    o = blocked_attention(q, k_all, v_all, causal=causal,
                          q_chunk=cfg.q_chunk, lengths=lengths)
    o = constrain(o, ("act_batch", "act_heads", "act_seq", None))
    out = jnp.einsum("bhsk,hkd->bsd", o.astype(cdt), lp["wo"].astype(cdt))
    out = constrain(out, ("act_batch", "act_seq", "act_embed"))
    return out, (kk, vv)


def _ffn(x, lp, cfg: TransformerConfig):
    cdt = cfg.compute_dtype
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        moe = _moe_ffn_einsum if cfg.moe_impl == "einsum" else _moe_ffn_sort
        out, aux = moe(x, lp, cfg)
        if cfg.moe_dense_residual:
            out = out + _swiglu(x, lp["w_gate"].astype(cdt),
                                lp["w_up"].astype(cdt), lp["w_down"].astype(cdt))
        return out, aux
    return _swiglu(x, lp["w_gate"].astype(cdt), lp["w_up"].astype(cdt),
                   lp["w_down"].astype(cdt)), aux


def _layer(x, lp, cfg, cos, sin):
    a, _ = _attention(rms_norm(x, lp["ln1"]), lp, cfg, cos, sin, causal=True)
    x = x + a
    f, aux = _ffn(rms_norm(x, lp["ln2"]), lp, cfg)
    return constrain(x + f, ("act_batch", "act_res_seq", "act_embed")), aux


# ----------------------------------------------------------------- forward
def forward(params, tokens: jnp.ndarray, cfg: TransformerConfig) -> tuple:
    """tokens: (B, S) int32 -> logits (B, S, V) in compute dtype, aux loss."""
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = constrain(x, ("act_batch", "act_res_seq", "act_embed"))
    S = tokens.shape[1]
    cos, sin = rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_base)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer(x, lp, cfg, cos, sin)
        return (x, aux + a), None

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cdt))
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits, aux / cfg.n_layers


def trunk(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Embedding + all layers + final norm (no vocab projection)."""
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = constrain(x, ("act_batch", "act_res_seq", "act_embed"))
    S = tokens.shape[1]
    cos, sin = rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_base)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer(x, lp, cfg, cos, sin)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "full" else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.scan_unroll)
    return rms_norm(x, params["final_norm"]), aux / cfg.n_layers


def loss_fn(params, batch, cfg: TransformerConfig):
    """Fused vocab projection + CE: the (B, S, V) logits never materialize."""
    x, aux = trunk(params, batch["tokens"], cfg)
    ce, zl = fused_ce_loss(
        x, params["lm_head"], batch["labels"],
        n_valid_vocab=cfg.vocab, z_loss=cfg.z_loss, chunk=cfg.ce_chunk)
    return ce + zl + 0.01 * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------- serving
def prefill(params, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Returns (last-token logits, kv cache stacked over layers)."""
    cdt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = constrain(x, ("act_batch", "act_res_seq", "act_embed"))
    S = tokens.shape[1]
    cos, sin = rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_base)

    def body(x, lp):
        a, kv = _attention(rms_norm(x, lp["ln1"]), lp, cfg, cos, sin, causal=True)
        x = x + a
        f, _ = _ffn(rms_norm(x, lp["ln2"]), lp, cfg)
        x = constrain(x + f, ("act_batch", "act_res_seq", "act_embed"))
        kv = jax.tree.map(
            lambda t: constrain(t, ("act_batch", "act_kv_heads", "cache_seq", None)), kv)
        return x, kv

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat == "full" else body
    x, cache = jax.lax.scan(body_fn, x, params["layers"], unroll=cfg.scan_unroll)
    x = rms_norm(x[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cdt))[:, 0]
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e9)
    logits = constrain(logits, ("act_batch", "act_vocab"))
    return logits, cache


def decode_step(params, cache, tokens: jnp.ndarray, lengths: jnp.ndarray,
                cfg: TransformerConfig):
    """One new token per batch row against a live KV cache.

    cache: (k, v) each (L, B, Hkv, S_max, hd); tokens (B,); lengths (B,)
    live-prefix lengths. Returns (logits (B, V), new cache, new lengths).
    """
    cdt = cfg.compute_dtype
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(cdt)  # (B,1,D)
    cos, sin = rope_angles(lengths[:, None], cfg.head_dim, cfg.rope_base)  # (B,1,half)

    def body(x, scanned):
        lp, (k_l, v_l) = scanned
        xn = rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dhk->bshk", xn, lp["wq"].astype(cdt))
        kk = jnp.einsum("bsd,dhk->bshk", xn, lp["wk"].astype(cdt))
        vv = jnp.einsum("bsd,dhk->bshk", xn, lp["wv"].astype(cdt))
        q = apply_rope(q, cos, sin).transpose(0, 2, 1, 3)       # (B,Hq,1,hd)
        kk = apply_rope(kk, cos, sin).transpose(0, 2, 1, 3)     # (B,Hkv,1,hd)
        vv = vv.transpose(0, 2, 1, 3)
        bidx = jnp.arange(B)
        k_l = k_l.at[bidx, :, lengths].set(kk[:, :, 0])
        v_l = v_l.at[bidx, :, lengths].set(vv[:, :, 0])
        k_l = constrain(k_l, ("act_batch", "act_kv_heads", "cache_seq", None))
        v_l = constrain(v_l, ("act_batch", "act_kv_heads", "cache_seq", None))
        o = blocked_attention(q, k_l, v_l, causal=False, q_chunk=8,
                              lengths=lengths + 1)
        a = jnp.einsum("bhsk,hkd->bsd", o.astype(cdt), lp["wo"].astype(cdt))
        x = x + a
        f, _ = _ffn(rms_norm(x, lp["ln2"]), lp, cfg)
        return constrain(x + f, ("act_batch", "act_seq", "act_embed")), (k_l, v_l)

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cdt))[:, 0]
    logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e9)
    logits = constrain(logits, ("act_batch", "act_vocab"))
    return logits, new_cache, lengths + 1
