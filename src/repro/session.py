"""``EagrSession`` — one declarative front door for continuous ego-centric
aggregation (the paper's multi-query system surface).

EAGr's pitch is *many* simultaneous ego-centric queries sharing one overlay's
partial aggregates. The substrate beneath (PR 1-4) delivers that — shared
compiled plans, in-place device patching, stacked SPMD shards — but reaching
it meant hand-assembling ``build_bipartite -> construct_vnm ->
cost_model_for/decide_mincut -> EagrEngine`` and choosing among four engine
entry points. The session owns that pipeline:

    session = EagrSession(graph)                       # overlay built once
    trends  = session.register(Query(agg="topk", agg_kwargs={"k": 3},
                                     window=WindowSpec("tuple", 16)))
    session.update(writer_ids, topic_ids)              # one write stream
    session.read(trends, user_ids)                     # per-query reads

Queries registered with equal ``(aggregate, window, continuous)`` specs are
grouped into one *engine group* — one set of push/pull decisions, one
compiled plan, one window/PAO state — the paper's aggregate sharing expressed
in the API. Distinct specs get their own group over the *same* overlay
construction (the expensive VNM/IOB pass runs exactly once per session).

Deployment shape is a constructor argument, not a different API:
``EagrSession(graph)`` runs each group on an :class:`EagrEngine`;
``EagrSession(graph, shards=N)`` stands up ``partition_overlay ->
align_shard_plans -> StackedShardedEngine`` behind the same methods.

Graph mutations (``add_edge``/``delete_edge``/``add_node``/``delete_node``)
journal through each group's :class:`DynamicOverlay` (or per-shard
``ShardedDynamic``) and land on the live plans on :meth:`flush` via the
device-resident patch path (§3.3 / PR 4) — zero table uploads and one
compiled program as long as churn stays inside headroom. ``update``/``read``
auto-flush a pending journal so reads are never stale.

Push/pull decisions are chosen per group by ``decide_mincut`` under the
aggregate's cost model, using observed write/read frequencies when the
session has seen traffic (uniform otherwise; ``write_freq=``/``read_freq=``
pin them explicitly, ``Query(continuous=True)`` pins all-push freshness).
With ``adapt_every=N``, every N update/read calls the session re-runs the
§4.8 frontier adaptation against observed frequencies and re-adopts plans
whose decisions flipped.

The low-level tier (``EagrEngine``, ``DynamicOverlay``, ``partition_overlay``,
``StackedShardedEngine``) stays public and unchanged underneath — the parity
suite (``tests/test_session.py``) holds the session bit-identical to it.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Iterable, Mapping

import numpy as np

from repro.core import dataflow as D
from repro.core.aggregates import Aggregate, make_aggregate
from repro.core.bipartite import Bipartite, build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, bucket_batch
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec

__all__ = ["Query", "QueryHandle", "EagrSession", "bucket_batch",
           "SessionStats", "FlushReport", "AdaptReport", "AlertHandle"]


# ------------------------------------------------------------------- queries
@dataclasses.dataclass(frozen=True)
class Query:
    """Declarative spec of one continuous ego-centric aggregate query.

    ``agg`` is a built-in aggregate name (see ``aggregates.BUILTINS``) or a
    constructed :class:`Aggregate`; ``agg_kwargs`` feed the built-in
    constructor (e.g. ``{"k": 3, "domain": 64}`` for top-k). ``window``
    defaults to the paper's ``c = 1`` last-value tuple window. ``readers``
    optionally scopes the query to a subset of ego nodes — reads outside the
    scope are rejected. ``continuous=True`` pins all-push decisions (results
    always fresh, the paper's continuous class) instead of cost-optimized
    push/pull.
    """

    agg: "str | Aggregate" = "count"
    window: WindowSpec | None = None
    readers: "Iterable[int] | None" = None
    continuous: bool = False
    agg_kwargs: Mapping | None = None

    def __post_init__(self):
        if self.readers is not None:
            object.__setattr__(self, "readers",
                               frozenset(int(r) for r in self.readers))

    def resolve(self) -> tuple[Aggregate, WindowSpec]:
        """Construct the aggregate and validate aggregate/window compatibility
        *now*, so a bad spec fails at ``register`` with a naming error instead
        of deep inside plan compilation or the first masked write."""
        agg = make_aggregate(self.agg, **dict(self.agg_kwargs or {}))
        spec = self.window or WindowSpec(kind="tuple", size=1)
        if not isinstance(spec, WindowSpec):
            raise ValueError(f"Query.window must be a WindowSpec, "
                             f"got {type(spec).__name__}")
        if spec.kind not in ("tuple", "time"):
            raise ValueError(f"unknown window kind {spec.kind!r}; "
                             f"choose 'tuple' or 'time'")
        if spec.kind == "time" and not spec.capacity:
            raise ValueError(
                "time windows need an explicit ring capacity: "
                "WindowSpec('time', T, capacity=...) — the ring must hold "
                "every write that can arrive within T")
        if spec.size < 1:
            raise ValueError(f"window size must be >= 1, got {spec.size}")
        if spec.capacity and spec.kind == "tuple" \
                and spec.capacity < int(spec.size):
            raise ValueError(
                f"tuple window of c={int(spec.size)} cannot fit in a ring of "
                f"capacity {spec.capacity}")
        # the aggregate declares the raw write arity its lift consumes
        # (vector sum/max/min match their pao_dim; count/avg/topk lift
        # scalars; custom aggregates set Aggregate(value_dim=...))
        expected = agg.value_dim
        if spec.value_dim != expected:
            raise ValueError(
                f"aggregate {agg.name!r} consumes value_dim={expected} "
                f"writes but the window carries value_dim={spec.value_dim}")
        if self.readers is not None and not self.readers:
            raise ValueError("Query.readers is empty — omit it (None) to "
                             "cover every reader")
        return agg, spec


@dataclasses.dataclass(frozen=True, eq=False)
class QueryHandle:
    """Registered query: the ticket ``read`` answers against. Handles of one
    engine group share plan, windows and PAOs (aggregate sharing)."""

    qid: int
    query: Query
    agg: Aggregate
    spec: WindowSpec
    session: "EagrSession"
    group: "_EngineGroup"
    # sorted array cache of `readers` for the vectorized scope check —
    # lazily materialized by EagrSession.read (the handle is frozen, so the
    # cache installs through object.__setattr__)
    _reader_arr: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def readers(self) -> "frozenset[int] | None":
        return self.query.readers

    def read(self, ids) -> np.ndarray:
        return self.session.read(self, ids)

    def on_threshold(self, *, above=None, below=None, delta=None,
                     hysteresis: float = 0.0, debounce: float = 0.0,
                     component: int = 0, readers=None) -> "AlertHandle":
        """Register a standing alert on this query — sugar for
        :meth:`EagrSession.register_alert`. Thresholds may be scalars or
        per-reader arrays (matched against the sorted reader list)."""
        from repro.streams.alerts import AlertSpec
        return self.session.register_alert(
            self, AlertSpec(above=above, below=below, delta=delta,
                            hysteresis=hysteresis, debounce=debounce,
                            component=component),
            readers=readers)


@dataclasses.dataclass(frozen=True, eq=False)
class AlertHandle:
    """Registered standing alert: the ticket fired batches are attributed to
    and drained with (:meth:`EagrSession.drain_fired`)."""

    aid: int
    spec: "object"           # streams.alerts.AlertSpec
    query: QueryHandle
    session: "EagrSession"

    def fired(self) -> list:
        """Drain this alert's :class:`~repro.streams.alerts.FiredBatch` es."""
        return self.session.drain_fired(self)


# -------------------------------------------------------------- typed reports
class FlushReport(list):
    """Typed result of :meth:`EagrSession.flush`.

    Still the list of per-group patch results it always was (``PatchResult``
    / nested per-shard lists / ``None`` for groups with an empty journal), so
    existing unpacking — ``(res,) = session.flush()``, iteration — keeps
    working; plus counters over every result in the batch:

    * ``patched`` — plans updated in place through the §3.3 device patch path
    * ``relayout`` — patches that rebuilt level tables within capacity
    * ``recompiled`` — genuine capacity overflows (full re-trace)
    * ``journal_nodes`` — overlay nodes the drained journals carried
    """

    def __init__(self, results, *, patched: int = 0, recompiled: int = 0,
                 relayout: int = 0, journal_nodes: int = 0):
        super().__init__(results)
        self.patched = patched
        self.recompiled = recompiled
        self.relayout = relayout
        self.journal_nodes = journal_nodes

    def __repr__(self) -> str:
        return (f"FlushReport(groups={len(self)}, patched={self.patched}, "
                f"relayout={self.relayout}, recompiled={self.recompiled}, "
                f"journal_nodes={self.journal_nodes})")


class AdaptReport(int):
    """Typed result of :meth:`EagrSession.adapt`: still the total §4.8
    decision-flip count as an ``int`` (all existing arithmetic holds), plus
    the per-group breakdown."""

    per_group: tuple

    def __new__(cls, per_group=()):
        self = super().__new__(cls, sum(per_group))
        self.per_group = tuple(int(f) for f in per_group)
        return self

    @property
    def flips(self) -> int:
        return int(self)

    def __repr__(self) -> str:
        return f"AdaptReport(flips={int(self)}, per_group={self.per_group})"


@dataclasses.dataclass
class SessionStats:
    """One consolidated counter surface for a session (:meth:`EagrSession.
    stats`): ingest, construction, frontier and patch counters that
    previously lived on three separate ad-hoc attributes."""

    n_queries: int
    n_engine_groups: int
    n_shards: int
    updates: int                    # update() batches applied (= checkpoint
                                    # sequence number: replay resumes here)
    pending_mutations: bool
    journal_nodes: int              # overlay nodes awaiting the next flush
    patches_applied: int            # in-place device patches, all plans
    frontier: dict                  # frontier-size distribution (write path)
    ingest: "object | None"         # streams.ingest.IngestStats
    construction: "object | None"   # core.vnm.ConstructionStats
    last_checkpoint_step: "int | None"


# ------------------------------------------------------------- engine groups
class _EngineGroup:
    """One (aggregate, window, continuous) equivalence class of queries: a
    decision vector, an engine (single or stacked-sharded) and its churn
    journal, shared by every query registered with the compatible spec."""

    def __init__(self, session: "EagrSession", key: tuple,
                 agg: Aggregate, spec: WindowSpec, continuous: bool):
        self.session = session
        self.key = key
        self.agg = agg
        self.spec = spec
        self.continuous = continuous
        self.handles: list[int] = []
        self.window_int = int(max(1, spec.capacity or spec.size))
        self.cost = session._cost_model(agg, self.window_int)
        # sharded groups journal through per-shard DynamicOverlays inside
        # ShardedDynamic — only single-engine groups need their own fork
        basis_dyn = None if session.n_shards else session._master.fork()
        basis = (basis_dyn or session._master).to_overlay(prune=False)
        if continuous:
            decisions = np.full(basis.n_nodes, D.PUSH, np.int64)
        else:
            wf, rf = session._frequencies(basis)
            decisions, _ = D.decide_mincut(basis, wf, rf, self.cost,
                                           window=self.window_int)
        if session.n_shards:
            from repro.distributed.eagr_shard import (
                ShardedDynamic,
                partition_overlay,
            )
            from repro.distributed.stacked import StackedShardedEngine

            self.dyn = None
            # creation-time global decisions over the basis id space — the
            # repartition key a checkpoint needs to reshard N -> M
            self.dec_global = decisions
            self.sharded = partition_overlay(
                basis, decisions, n_shards=session.n_shards,
                seed=session.seed, backend=session.backend,
                headroom=session.headroom)
            self.engine = StackedShardedEngine(
                self.sharded, agg, spec, base_capacity=session.n_base)
            self.sdyn = ShardedDynamic(self.sharded, self.engine,
                                       growth=session.growth)
        else:
            self.dyn = basis_dyn
            self.sdyn = None
            self.engine = EagrEngine(basis, decisions, agg, spec,
                                     backend=session.backend,
                                     headroom=session.headroom)
        # churn-added nodes must inherit the all-push pin, or alerted
        # readers added mid-stream would go stale (and fail alert sync)
        self.engine.pin_push = bool(continuous)

    # ------------------------------------------------------------- mutations
    @property
    def _journal(self):
        return self.sdyn if self.sdyn is not None else self.dyn

    def ensure_journal(self) -> None:
        """Materialize the churn journal of a restored group. Restored groups
        come up journal-less (rebuilding every group's DynamicOverlay at
        restore would cost more than the restore itself); the session calls
        this before the first post-restore mutation, so the journal forks the
        master in its pre-mutation state."""
        if self.dyn is not None or self.sdyn is not None:
            return
        if self.session.n_shards:
            from repro.distributed.checkpoint import scrub_dead_writers
            from repro.distributed.eagr_shard import ShardedDynamic

            self.sdyn = ShardedDynamic(self.sharded, self.engine,
                                       growth=self.session.growth)
            # the saved per-shard overlays are unpruned exports — deleted
            # writer nodes linger with their 'W' label and must not be
            # re-registered as live by the rebuilt journal
            for s, dyn in enumerate(self.sdyn.dynamics):
                scrub_dead_writers(
                    dyn, set(self.sharded.shard_plans[s].writer_row_of_base))
        else:
            self.dyn = self.session._master.fork()

    def journal_nodes(self) -> int:
        """Overlay nodes the next flush() will drain across this group."""
        if self.sdyn is not None:
            return sum(d.pending_nodes for d in self.sdyn.dynamics)
        return self.dyn.pending_nodes if self.dyn is not None else 0

    def flush(self, growth: float):
        if self.sdyn is not None:
            return self.sdyn.apply()
        if self.dyn is None:
            return None  # restored group, no churn since restore
        delta = self.dyn.drain_delta()
        if delta.empty:
            return None
        return self.engine.apply_delta(delta, growth=growth)

    # ------------------------------------------------------------ adaptation
    def adapt(self) -> int:
        """§4.8 frontier re-decision against observed frequencies; recompiles
        + re-adopts only when a flip actually happened. Continuous groups are
        pinned all-push and never adapt."""
        if self.continuous:
            return 0
        if getattr(self.engine, "alerts", None):
            # standing alerts predicate on push-maintained reader PAOs; a
            # pull flip would silence them — alerted groups never adapt
            return 0
        if self.sdyn is None:
            plan = self.engine.plan
            ov = plan.host.export_overlay() if plan.host is not None \
                else self.engine.overlay
            obs_w, obs_r = self.session._observed(ov)
            dec, flips = D.adapt_decisions(ov, plan.decision, obs_w, obs_r,
                                           self.cost, window=self.window_int)
            if flips:
                self.engine.adopt_decisions(dec)
            return flips
        decs: list[np.ndarray | None] = []
        total = 0
        for s, plan in enumerate(self.sharded.shard_plans):
            ov = plan.host.export_overlay() if plan.host is not None \
                else self.sharded.shards[s]
            obs_w, obs_r = self.session._observed(ov)
            dec, flips = D.adapt_decisions(ov, plan.decision, obs_w, obs_r,
                                           self.cost, window=self.window_int)
            decs.append(dec if flips else None)
            total += flips
        if total:
            self.sdyn.readopt_decisions(decs)
        return total


# ----------------------------------------------------------------------- API
class EagrSession:
    """Session over one data graph: overlay construction, cost-model
    calibration and push/pull decisions happen inside; queries, writes, reads
    and graph mutations are the whole public surface.

    ``session.overlay_stats`` keeps the :class:`ConstructionStats` of the
    one-time VNM pass, including ``phase_seconds`` — the per-phase build
    breakdown (``shingle``/``chunk``/``build``/``mine``/``apply``/
    ``assemble``) of the vectorized construction engine.

    Parameters
    ----------
    graph : CSRGraph | Bipartite
        The data graph (1-hop in-neighborhood queries by default; ``hops``/
        ``pred``/``neighborhood`` forward to :func:`build_bipartite`), or a
        pre-built bipartite writer/reader spec.
    shards : int | None
        ``None`` runs each engine group on one :class:`EagrEngine`;
        ``N`` reader-partitions the overlay and runs groups as one
        ``shard_map`` program (:class:`StackedShardedEngine`).
    backend : 'pallas' | 'xla' | 'xla_unrolled' | None
        Per-level reduce backend; defaults to ``EAGR_BACKEND`` / platform.
    headroom : float
        Slot/node/level padding growth at first compile, so structural churn
        patches in place (§3.3) instead of recompiling.
    write_freq, read_freq : np.ndarray | None
        Per-base-id frequencies for ``decide_mincut``. Default: observed
        session traffic when any exists, else uniform.
    calibrate : bool
        Learn the cost model by timing the aggregate (§4.2) instead of the
        analytic model.
    adapt_every : int
        Re-run §4.8 frontier adaptation every N ``update``/``read`` calls
        (0 disables).
    """

    def __init__(self, graph, *, shards: int | None = None,
                 backend: str | None = None, headroom: float = 2.0,
                 growth: float = 2.0, variant: str = "vnm_a",
                 max_iterations: int = 3, seed: int = 0, threshold: int = 4,
                 split_limit: int = 5, hops: int = 1, pred=None,
                 neighborhood=None, write_freq=None, read_freq=None,
                 calibrate: bool = False, adapt_every: int = 0,
                 ingest_depth: int | None = None,
                 ingest_batch: int | None = None,
                 ckpt_dir: str | None = None,
                 ckpt_every: int | None = None,
                 ckpt_keep: int | None = None):
        bp = graph if isinstance(graph, Bipartite) else build_bipartite(
            graph, hops=hops, pred=pred, neighborhood=neighborhood)
        self.bipartite = bp
        self.n_base = bp.n_base
        self.n_shards = int(shards) if shards else 0
        if shards is not None and self.n_shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.backend = backend
        self.headroom = headroom
        self.growth = growth
        self.seed = seed
        self.threshold = int(threshold)
        self.split_limit = int(split_limit)
        self.calibrate = calibrate
        self.adapt_every = int(adapt_every)
        self.write_freq = None if write_freq is None \
            else np.asarray(write_freq, np.float64)
        self.read_freq = None if read_freq is None \
            else np.asarray(read_freq, np.float64)
        overlay, self.overlay_stats = construct_vnm(
            bp, variant=variant, max_iterations=max_iterations, seed=seed)
        self._master_obj = DynamicOverlay.from_overlay(
            overlay, bp.reader_input_sets(),
            threshold=self.threshold, split_limit=self.split_limit)
        self._master_src = None  # restored sessions carry the payload instead
        self._master_dup = bool(overlay.dup_insensitive)
        self._groups: dict[tuple, _EngineGroup] = {}
        self._handles: dict[int, QueryHandle] = {}
        self._next_qid = 0
        self._alerts: dict[int, AlertHandle] = {}
        self._next_aid = 0
        self._value_dim: int | None = None
        self._wcount = np.zeros(self.n_base, np.float64)
        self._rcount = np.zeros(self.n_base, np.float64)
        self._ops_since_adapt = 0
        self._pending = False
        # streaming ingest (PR 7): depth 0 keeps the synchronous write path
        # (one blocking-free dispatch per update, one tick per call); depth
        # >= 1 routes `update` through an async IngestPipeline ring — see
        # src/repro/streams/ingest.py for the coalescing/clock semantics
        if ingest_depth is None:
            ingest_depth = int(os.environ.get("EAGR_INGEST_DEPTH", "0") or 0)
        if ingest_batch is None:
            ingest_batch = int(os.environ.get("EAGR_INGEST_BATCH", "0") or 0)
        self.ingest_depth = max(0, int(ingest_depth))
        self.ingest_batch = int(ingest_batch) or 8192
        self._pipeline = None
        self._carry_ingest = None  # IngestStats carried across restores
        # durable sessions (PR 9): the update-batch sequence number doubles
        # as the checkpoint step — replay resumes the event stream from it
        self._seq = 0
        if ckpt_dir is None:
            ckpt_dir = os.environ.get("EAGR_CKPT_DIR") or None
        if ckpt_every is None:
            ckpt_every = int(os.environ.get("EAGR_CKPT_EVERY", "0") or 0)
        if ckpt_keep is None:
            ckpt_keep = int(os.environ.get("EAGR_CKPT_KEEP", "3") or 3)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = max(0, int(ckpt_every))
        self.ckpt_keep = max(1, int(ckpt_keep))
        self._ckpt_mgrs: dict = {}
        self._last_ckpt_step: int | None = None

    @property
    def _master(self) -> DynamicOverlay:
        """The session-wide master overlay journal. Restored sessions carry
        the checkpoint payload instead and materialize the DynamicOverlay
        only when something needs it (a mutation, a late register, a
        neighborhood query) — a same-shape restore followed by pure
        update/read traffic never pays the O(nodes) Python rebuild."""
        if self._master_obj is None:
            from repro.distributed.checkpoint import master_from_arrays
            self._master_obj = master_from_arrays(
                self._master_src, threshold=self.threshold,
                split_limit=self.split_limit, dup=self._master_dup)
        return self._master_obj

    # ------------------------------------------------------------- lifecycle
    def register(self, query: Query) -> QueryHandle:
        """Validate and register one query. Compatible specs share an engine
        group (and with it plan, windows and partial aggregates); the first
        query of a new spec compiles the group's plan. A query registered
        after traffic starts with empty windows (it observes writes from its
        registration on)."""
        if not isinstance(query, Query):
            raise ValueError(f"register() takes a Query, "
                             f"got {type(query).__name__}")
        agg, spec = query.resolve()
        if self._value_dim is None:
            self._value_dim = spec.value_dim
        elif spec.value_dim != self._value_dim:
            raise ValueError(
                f"session write stream carries value_dim={self._value_dim} "
                f"but this query's window wants value_dim={spec.value_dim}; "
                f"one session serves one write-value shape")
        key = (agg, spec, bool(query.continuous))
        group = self._groups.get(key)
        if group is None:
            self._retire_pipeline()  # the engine set is about to change
            group = _EngineGroup(self, key, agg, spec, bool(query.continuous))
            self._groups[key] = group
        handle = QueryHandle(qid=self._next_qid, query=query, agg=agg,
                             spec=spec, session=self, group=group)
        self._next_qid += 1
        group.handles.append(handle.qid)
        self._handles[handle.qid] = handle
        return handle

    def unregister(self, handle: QueryHandle) -> None:
        """Retire one query; the last query of a group releases its engine."""
        self._check_handle(handle)
        for ah in [a for a in self._alerts.values() if a.query is handle]:
            self.unregister_alert(ah)
        del self._handles[handle.qid]
        handle.group.handles.remove(handle.qid)
        if not handle.group.handles:
            self._retire_pipeline()  # the engine set is about to change
            del self._groups[handle.group.key]
        if not self._groups:
            self._value_dim = None  # nothing constrains the stream anymore

    # ---------------------------------------------------------- standing alerts
    def register_alert(self, handle: QueryHandle, spec=None, *,
                       readers=None, **predicates) -> AlertHandle:
        """Register a standing alert against a registered query: the
        predicate (``AlertSpec``, or keyword thresholds ``above``/``below``/
        ``delta`` + ``hysteresis``/``debounce``/``component``) is evaluated
        **on device inside the query's write step** from then on, and only
        the readers that fired come back per batch (:meth:`drain_fired`).

        ``readers`` scopes the alert (defaults to the query's own scope;
        ``None`` on an unscoped query tracks every reader through churn).
        Requires push-maintained readers — register the query with
        ``continuous=True``. Thresholds may be per-reader arrays, matched
        positionally against the sorted reader list."""
        from repro.streams.alerts import (
            AlertSet,
            AlertSpec,
            check_alert_aggregate,
        )

        self._check_handle(handle)
        if spec is None:
            spec = AlertSpec(**predicates)
        elif predicates:
            raise ValueError("pass an AlertSpec OR keyword thresholds, "
                             "not both")
        md = check_alert_aggregate(handle.agg)
        if not (0 <= int(spec.component) < md):
            raise ValueError(f"component={spec.component} out of range for "
                             f"{handle.agg.name!r} (measure dim {md})")
        # alerts resolve against the live plan — land pending churn first
        # and quiesce the ingest ring so the attach sees settled state
        if self._pending:
            self.flush()
        elif self._pipeline is not None:
            self._pipeline.flush()
        scope = handle.readers
        if readers is None:
            readers = scope  # None + unscoped query = dynamic (all readers)
        elif scope is not None:
            outside = [int(r) for r in readers if int(r) not in scope]
            if outside:
                raise ValueError(f"alert readers {sorted(outside)[:8]} are "
                                 "outside the query's readers scope")
        engine = handle.group.engine
        alerts = engine.alerts
        if alerts is None:
            alerts = AlertSet()
        aid = self._next_aid
        alerts.register(aid, spec, () if readers is None else readers,
                        dynamic=readers is None,
                        engine=engine if engine.alerts is alerts else None)
        if engine.alerts is not alerts:
            engine.attach_alerts(alerts)
        self._next_aid += 1
        ahandle = AlertHandle(aid=aid, spec=spec, query=handle, session=self)
        self._alerts[aid] = ahandle
        return ahandle

    def unregister_alert(self, ahandle: AlertHandle) -> None:
        """Retire one standing alert; the last alert of an engine detaches
        alert evaluation from its write path entirely."""
        if self._alerts.get(getattr(ahandle, "aid", -1)) is not ahandle:
            raise ValueError("unknown alert handle")
        del self._alerts[ahandle.aid]
        engine = ahandle.query.group.engine
        alerts = engine.alerts
        if alerts is None:
            return
        if self._pipeline is not None:
            self._pipeline.flush()  # quiesce in-flight fused steps
        alerts.collect()
        alerts.unregister(ahandle.aid, engine)
        if not alerts:
            engine.alerts = None

    @property
    def alerts(self) -> list[AlertHandle]:
        return list(self._alerts.values())

    def drain_fired(self, ahandle: AlertHandle | None = None) -> list:
        """Collect every fired batch produced since the last drain — the
        compact readback of all standing alerts (optionally filtered to one
        :class:`AlertHandle`). With a pipelined session the ring has already
        collected completed slots at its boundaries; this adds a partial-slot
        dispatch so every submitted event is observed."""
        if self._pipeline is not None:
            self._pipeline.drain()
        out = []
        for g in self._groups.values():
            alerts = getattr(g.engine, "alerts", None)
            if alerts is None:
                continue
            alerts.collect()
            out.extend(alerts.pop_fired())
        out.sort(key=lambda b: b.now)
        if ahandle is not None:
            keep = []
            for b in out:
                sel = b.aids == ahandle.aid
                if sel.any():
                    keep.append(dataclasses.replace(
                        b, base_ids=b.base_ids[sel], values=b.values[sel],
                        aids=b.aids[sel]))
            return keep
        return out

    @property
    def queries(self) -> list[QueryHandle]:
        return list(self._handles.values())

    @property
    def n_engine_groups(self) -> int:
        return len(self._groups)

    @property
    def readers(self) -> list[int]:
        """Base ids currently readable (non-empty ego neighborhoods)."""
        return sorted(r for r, ws in self._master.reader_inputs.items() if ws)

    @property
    def writers(self) -> list[int]:
        """Base ids with a registered write stream."""
        return sorted(self._master.b.writer_node)

    def neighborhood(self, reader: int) -> set[int]:
        """The reader's current writer set N(reader), live under churn."""
        return set(self._master.reader_inputs.get(int(reader), set()))

    # -------------------------------------------------------------- execution
    def update(self, src_ids, values=None) -> None:
        """Apply one batch of writes (base writer ids + raw values) to every
        registered query — the session's single shared write stream. Values
        default to ones (pure count/presence streams). Writes to ids no query
        consumes are dropped, exactly as the engines drop them."""
        if not self._groups:
            raise ValueError("no queries registered — register() one before "
                             "streaming updates")
        if self._pending:
            self.flush()
        ids = np.asarray(src_ids, np.int64).reshape(-1)
        if len(ids) and ids.min() < 0:
            raise ValueError("negative base ids in update batch")
        if values is None:
            values = np.ones(len(ids), np.float32)
        vals = np.asarray(values, np.float32)
        want = (len(ids),) if self._value_dim == 1 \
            else (len(ids), self._value_dim)
        if vals.shape != want:
            raise ValueError(f"update values shape {vals.shape} != {want} "
                             f"(session value_dim={self._value_dim})")
        if self.ingest_depth:
            self._ingest().submit(ids, vals)
        else:
            B = bucket_batch(len(ids))
            for group in self._groups.values():
                group.engine.write_batch(ids, vals, batch_size=B)
        if len(ids):
            self._grow_counts(int(ids.max()))
            np.add.at(self._wcount, ids, 1.0)
        self._tick()
        self._seq += 1
        if self.ckpt_dir and self.ckpt_every \
                and self._seq % self.ckpt_every == 0:
            self.save(blocking=False)

    def read(self, handle: QueryHandle, ids) -> np.ndarray:
        """Answer one batch of ego-centric reads for a registered query.
        Raises for ids outside the query's ``readers`` scope or unknown to
        the overlay."""
        self._check_handle(handle)
        if self._pending:
            self.flush()
        if self._pipeline is not None:
            # reads must observe every submitted event: dispatch the partial
            # slot (no barrier — the read's data dependency on the engine
            # state sequences it after every in-flight write step)
            self._pipeline.drain()
        ids = np.asarray(ids, np.int64).reshape(-1)
        if handle.readers is not None:
            arr = handle._reader_arr
            if arr is None:
                arr = np.fromiter(handle.readers, np.int64,
                                  len(handle.readers))
                arr.sort()
                object.__setattr__(handle, "_reader_arr", arr)
            inside = np.isin(ids, arr)
            if not inside.all():
                raise ValueError(
                    f"read: base ids "
                    f"{sorted(set(map(int, ids[~inside])))[:8]} are outside "
                    f"this query's readers scope")
        out = handle.group.engine.read_batch(ids,
                                             batch_size=bucket_batch(len(ids)))
        if len(ids):
            self._grow_counts(int(ids.max()))
            np.add.at(self._rcount, ids, 1.0)
        self._tick()
        return out

    # --------------------------------------------------------------- mutations
    def add_edge(self, u: int, v: int, *, affected=None) -> None:
        """Data-graph edge u -> v appeared (reader v's neighborhood gains
        writer u for 1-hop queries; pass ``affected={reader: {writers}}`` for
        custom neighborhoods). Journaled; lands on the plans at flush()."""
        self._touch(u, v)
        self._ensure_journals()
        self._master.add_edge(u, v, affected=affected)
        for group in self._groups.values():
            group._journal.add_edge(u, v, affected=affected)

    def delete_edge(self, u: int, v: int, *, affected=None) -> None:
        self._touch(u, v)
        self._ensure_journals()
        self._master.delete_edge(u, v, affected=affected)
        for group in self._groups.values():
            group._journal.delete_edge(u, v, affected=affected)

    def add_node(self, u: int, in_neighbors: Iterable[int] = (),
                 out_readers: Iterable[int] = ()) -> None:
        """New base node u: a writer feeding ``out_readers`` and a reader
        over ``in_neighbors``."""
        ins, outs = set(map(int, in_neighbors)), set(map(int, out_readers))
        self._touch(u, *ins, *outs)
        self._ensure_journals()
        self._master.add_node(u, ins, outs)
        for group in self._groups.values():
            group._journal.add_node(u, ins, outs)

    def delete_node(self, u: int) -> None:
        self._touch(u)
        self._ensure_journals()
        self._master.delete_node(u)
        for group in self._groups.values():
            group._journal.delete_node(u)

    def flush(self) -> FlushReport:
        """Drain every group's mutation journal into its live plan through
        the §3.3 patch path (device-resident ``PatchProgram``; recompile only
        on genuine capacity overflow). Called automatically by the next
        ``update``/``read`` after a mutation; explicit calls let callers
        batch churn bursts. Returns a :class:`FlushReport` — still the list
        of per-group patch results, plus patched/relayout/recompiled
        counters."""
        if self._pipeline is not None:
            # pipeline barrier BEFORE patches land: writes submitted so far
            # hit the plans they were routed against, and donated/aliased
            # buffers are quiescent when the patch path swaps arrays
            self._pipeline.flush()
        if self._master_obj is not None:
            # master only snapshots for late register; a restored session
            # with an unmaterialized master has nothing to drain
            self._master_obj.drain_delta()
        journal = sum(g.journal_nodes() for g in self._groups.values())
        results = [group.flush(self.growth)
                   for group in self._groups.values()]
        self._pending = False
        counts = {"patched": 0, "recompiled": 0, "relayout": 0}

        def count(res):
            if isinstance(res, (list, tuple)):
                for r in res:
                    count(r)
            elif res is not None:
                kind = getattr(res, "kind", None)
                if kind in counts:
                    counts[kind] += 1

        count(results)
        return FlushReport(results, journal_nodes=journal, **counts)

    def adapt(self) -> AdaptReport:
        """Re-run the §4.8 frontier adaptation on every group against
        observed frequencies now (also triggered every ``adapt_every``
        operations). Returns an :class:`AdaptReport` — still the total
        decision-flip count as an int, plus the per-group breakdown."""
        if self._pipeline is not None:
            self._pipeline.flush()  # plans may swap underneath the ring
        if self._pending:
            self.flush()
        return AdaptReport([group.adapt()
                            for group in self._groups.values()])

    # ------------------------------------------------------------ diagnostics
    def stats(self) -> SessionStats:
        """One consolidated :class:`SessionStats` snapshot: ingest,
        construction, frontier and patch counters plus the checkpoint
        position. Supersedes reaching for ``ingest_stats`` /
        ``overlay_stats`` / hand-rolled frontier summaries."""
        from repro.core.frontier import frontier_summary

        logs: list[int] = []
        patches = 0
        for g in self._groups.values():
            logs.extend(getattr(g.engine, "frontier_log", []))
            if self.n_shards:
                patches += sum(p.patches_applied
                               for p in g.sharded.shard_plans)
            else:
                patches += g.engine.plan.patches_applied
        return SessionStats(
            n_queries=len(self._handles),
            n_engine_groups=len(self._groups),
            n_shards=self.n_shards,
            updates=self._seq,
            pending_mutations=self._pending,
            journal_nodes=sum(g.journal_nodes()
                              for g in self._groups.values()),
            patches_applied=patches,
            frontier=frontier_summary(logs),
            ingest=self._ingest_stats(),
            construction=self.overlay_stats,
            last_checkpoint_step=self._last_ckpt_step,
        )

    def _ingest_stats(self):
        if self._pipeline is not None:
            return self._pipeline.stats
        return self._carry_ingest

    @property
    def ingest_stats(self):
        """Deprecated alias for ``stats().ingest`` — the live
        :class:`repro.streams.ingest.IngestStats` (``None`` until the first
        pipelined update; survives checkpoint/restore)."""
        warnings.warn(
            "EagrSession.ingest_stats is deprecated; use stats().ingest",
            DeprecationWarning, stacklevel=2)
        return self._ingest_stats()

    # ------------------------------------------------------------- durability
    def save(self, directory: str | None = None, *, step: int | None = None,
             blocking: bool = False, keep: int | None = None) -> int:
        """Checkpoint the live session; returns the committed step number.

        Quiesces first — pending structural churn lands via :meth:`flush`,
        the ingest ring drains — then takes a synchronous ``device_get``
        snapshot of every group's plan/window/PAO state and hands
        serialization to the checkpoint thread (``blocking=False``), so
        update traffic resumes immediately while files land. The commit is
        atomic (two-phase manifest + rename): a crash mid-save leaves the
        previous committed checkpoint restorable.

        ``directory`` defaults to the session's ``ckpt_dir``; ``step``
        defaults to the update-batch sequence number, which is what
        :class:`repro.distributed.fault.SessionRecoveryDriver` replays from.
        """
        directory = directory or self.ckpt_dir
        if directory is None:
            raise ValueError("no checkpoint directory — pass save(dir) or "
                             "construct with ckpt_dir=/EAGR_CKPT_DIR")
        if self._pending:
            self.flush()
        elif self._pipeline is not None:
            self._pipeline.flush()
        from repro.distributed.checkpoint import snapshot_session
        arrays, objs = snapshot_session(self)
        step = self._seq if step is None else int(step)
        self._ckpt_manager(directory, keep).save_payload(
            step, arrays, objs, blocking=blocking)
        self._last_ckpt_step = step
        return step

    def wait_for_checkpoint(self) -> None:
        """Block until every in-flight background save committed."""
        for mgr in self._ckpt_mgrs.values():
            mgr.wait()

    @classmethod
    def restore(cls, directory: str, *, step: int | None = None,
                graph=None, shards: int | None = None) -> "EagrSession":
        """Rebuild a session from a checkpoint directory (latest committed
        step unless ``step=`` pins one).

        ``shards=None`` restores the saved deployment shape bit-identically
        — compiled plans, window rings, PAOs and clocks adopt verbatim, no
        construction or compilation. ``shards=M`` (``M >= 1``, or ``0`` for
        a single engine) reshards: plans recompile over the saved master
        overlay and window rings redistribute by base writer id."""
        from repro.distributed.checkpoint import restore_session
        return restore_session(directory, step=step, graph=graph,
                               shards=shards)

    def _ckpt_manager(self, directory: str, keep: int | None = None):
        from repro.distributed.checkpoint import CheckpointManager
        mgr = self._ckpt_mgrs.get(directory)
        if mgr is None:
            mgr = CheckpointManager(
                directory, keep=self.ckpt_keep if keep is None else keep)
            self._ckpt_mgrs[directory] = mgr
        elif keep is not None:
            mgr.keep = keep
        return mgr

    # ---------------------------------------------------------------- internal
    def _check_handle(self, handle) -> None:
        if not isinstance(handle, QueryHandle) \
                or self._handles.get(getattr(handle, "qid", -1)) is not handle:
            raise ValueError("unknown query handle (not registered with this "
                             "session, or already unregistered)")

    def _ensure_journals(self) -> None:
        """Materialize restored groups' churn journals before a mutation
        touches the master, so each fork snapshots pre-mutation state."""
        for group in self._groups.values():
            group.ensure_journal()

    def _ingest(self):
        if self._pipeline is None:
            from repro.streams.ingest import IngestPipeline
            self._pipeline = IngestPipeline(
                [g.engine for g in self._groups.values()],
                depth=self.ingest_depth, device_batch=self.ingest_batch,
                value_dim=self._value_dim or 1,
                stats=self._carry_ingest)
            # lifetime counters survive pipeline retirement and restore
            self._carry_ingest = self._pipeline.stats
        return self._pipeline

    def _retire_pipeline(self) -> None:
        """Barrier + drop the pipeline: the next pipelined update rebuilds
        it over the current engine set."""
        if self._pipeline is not None:
            self._pipeline.flush()
            self._pipeline = None

    def _tick(self) -> None:
        self._ops_since_adapt += 1
        if self.adapt_every and self._ops_since_adapt >= self.adapt_every:
            self._ops_since_adapt = 0
            self.adapt()  # barriers the ingest ring before plans swap

    def _touch(self, *ids) -> None:
        self._pending = True
        top = max((int(i) for i in ids), default=-1)
        if top >= 0:
            self._grow_counts(top)

    def _grow_counts(self, top: int) -> None:
        if top < len(self._wcount):
            return
        size = 1 << max(1, int(top)).bit_length()
        grow = lambda a: np.concatenate([a, np.zeros(size - len(a))])
        self._wcount, self._rcount = grow(self._wcount), grow(self._rcount)

    def _need(self, overlay) -> int:
        top = max((o for o in overlay.origin if o >= 0), default=0)
        return max(self.n_base, top + 1, len(self._wcount))

    def _observed(self, overlay) -> tuple[np.ndarray, np.ndarray]:
        """Raw observed per-base-id frequencies, sized to cover the overlay's
        origin space (zeros for never-seen ids)."""
        need = self._need(overlay)
        pad = lambda a: np.concatenate([a, np.zeros(need - len(a))]) \
            if need > len(a) else a[:need]
        return pad(self._wcount), pad(self._rcount)

    def _frequencies(self, overlay) -> tuple[np.ndarray, np.ndarray]:
        """Decision-time frequencies: explicit constructor arrays win, then
        observed traffic (+1 smoothing so unseen nodes keep a floor), then
        uniform."""
        need = self._need(overlay)

        def resolve(explicit, observed):
            out = np.ones(need, np.float64)
            if explicit is not None:
                out[: min(need, len(explicit))] = explicit[:need]
            elif observed.sum() > 0:
                out += observed[:need] if len(observed) >= need else \
                    np.concatenate([observed,
                                    np.zeros(need - len(observed))])
            return out

        return (resolve(self.write_freq, self._wcount),
                resolve(self.read_freq, self._rcount))

    def _cost_model(self, agg: Aggregate, window: int) -> D.CostModel:
        if self.calibrate:
            return D.calibrate_cost_model(agg, pao_dim=agg.pao_dim)
        try:
            return D.cost_model_for(agg.name, window=window)
        except ValueError:
            # custom aggregate: assume O(1) incremental update, O(k) merge
            return D.CostModel(H=lambda k: 1.0,
                               L=lambda k: float(max(1, k)), name=agg.name)
