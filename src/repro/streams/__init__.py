from repro.streams.alerts import AlertSet, AlertSpec, FiredBatch, PollOracle
from repro.streams.ingest import IngestPipeline, IngestStats
from repro.streams.traces import (
    Trace,
    zipf_frequencies,
    generate_trace,
    shift_workload,
    batched_playback,
)

__all__ = [
    "Trace",
    "zipf_frequencies",
    "generate_trace",
    "shift_workload",
    "batched_playback",
    "IngestPipeline",
    "IngestStats",
    "AlertSpec",
    "AlertSet",
    "FiredBatch",
    "PollOracle",
]
