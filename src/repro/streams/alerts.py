"""Standing alerts: device-evaluated predicate queries over reader PAOs.

EAGr's motivating workloads are continuous *alerting* queries — anomaly
detection, local threshold alerts — yet a poll-everything client must read
back O(readers) measures per batch just to notice the handful that moved.
This module turns the predicate around: alerts are registered once as dense
per-reader threshold arrays plus an armed/fired state vector
(:class:`AlertState`), and evaluation is **fused into the write step**
(:func:`alert_write_step`): after the (frontier-sparse) write body lands, the
finalized measure of every *alerted* row is compared against its previous
value — only rows the batch (or a time-window expiry) actually changed can
differ, so the predicate check is exactly the reachable-reader restriction,
expressed as one vectorized compare instead of a gather. What crosses the
host boundary per batch is a compact fired set: a count plus a
fixed-capacity padded index/value buffer (``jnp.nonzero(..., size=K)``), so
steady state keeps one trace and one tiny transfer, never an O(readers)
poll.

Semantics (canonical — the poll oracle replicates them bit for bit):

* a row *fires* when its measure **changes** to a tripping value while the
  row is armed and its debounce interval has elapsed:
  ``trip = (m > above) | (m < below) | (|m - ref| > delta)``
* firing disarms the row; it re-arms when a later change lands back
  *inside* the band by the hysteresis margin
  (``below + hysteresis <= m <= above - hysteresis``), so a reader
  flapping across a threshold re-fires at most once per excursion;
* ``debounce`` (logical ticks = device batches) lower-bounds the spacing
  between fires of one row regardless of arming;
* ``ref`` — the delta-vs-previous baseline — re-bases to the fired value.

Unset thresholds default to the never-trip identities (``+inf`` / ``-inf``),
so a spec may use any subset of the three predicates.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import Aggregate
from repro.core.dataflow import PUSH

# Aggregates whose finalize output is value-shaped (comparable against a
# threshold). topk finalizes to *indices* — order predicates on it are
# meaningless, so it is rejected at registration.
ALERT_COMPATIBLE = ("sum", "count", "avg", "max", "min")


def alert_cap(default: int = 1024) -> int:
    """Fired-set capacity K (``EAGR_ALERT_CAP``): the padded per-batch fired
    buffer holds up to K (index, value) pairs. A batch firing more than K
    alerts still reports the exact set — the collector falls back to reading
    the full fired vector for that batch (rare; size K for your worst batch
    to stay on the compact path)."""
    return int(os.environ.get("EAGR_ALERT_CAP", str(default)) or default)


def alert_eval_enabled() -> bool:
    """``EAGR_ALERT_EVAL=0`` detaches alert evaluation from the write path
    (registered state is kept; nothing fires) — the A/B switch the benchmark
    uses to measure the piggyback's marginal cost."""
    return os.environ.get("EAGR_ALERT_EVAL", "1").strip() != "0"


# --------------------------------------------------------------------- specs
@dataclasses.dataclass(frozen=True)
class AlertSpec:
    """One standing predicate, broadcast over the readers it is registered
    on. ``above`` / ``below`` / ``delta`` may each be a scalar or a
    per-reader array (matched positionally against the registration's reader
    list); unset predicates never trip. ``component`` selects the payload
    lane of vector-valued aggregates."""

    above: float | np.ndarray | None = None   # fire when measure > above
    below: float | np.ndarray | None = None   # fire when measure < below
    delta: float | np.ndarray | None = None   # fire when |m - ref| > delta
    hysteresis: float = 0.0                   # re-arm margin inside the band
    debounce: float = 0.0                     # min ticks between fires
    component: int = 0                        # payload lane for vector values

    def _field(self, name: str, n: int, fill: float) -> np.ndarray:
        v = getattr(self, name)
        if v is None:
            return np.full(n, fill, np.float32)
        arr = np.broadcast_to(np.asarray(v, np.float32), (n,))
        return np.ascontiguousarray(arr)

    def tables(self, n: int) -> dict[str, np.ndarray]:
        """Dense per-reader threshold columns for ``n`` registered readers."""
        return {
            "hi": self._field("above", n, np.inf),
            "lo": self._field("below", n, -np.inf),
            "dthr": self._field("delta", n, np.inf),
            "hys": np.full(n, float(self.hysteresis), np.float32),
            "deb": np.full(n, float(self.debounce), np.float32),
            "comp": np.full(n, int(self.component), np.int32),
        }

    def to_json(self) -> dict:
        out = {"hysteresis": float(self.hysteresis),
               "debounce": float(self.debounce),
               "component": int(self.component)}
        for f in ("above", "below", "delta"):
            v = getattr(self, f)
            if v is None:
                out[f] = None
            elif np.ndim(v) == 0:
                out[f] = float(v)
            else:
                out[f] = np.asarray(v, np.float32).tolist()
        return out

    @classmethod
    def from_json(cls, d: dict) -> "AlertSpec":
        kw = {}
        for f in ("above", "below", "delta"):
            v = d.get(f)
            kw[f] = None if v is None else (
                float(v) if np.ndim(v) == 0 else np.asarray(v, np.float32))
        return cls(hysteresis=float(d.get("hysteresis", 0.0)),
                   debounce=float(d.get("debounce", 0.0)),
                   component=int(d.get("component", 0)), **kw)


class AlertState(NamedTuple):
    """Device half of the alert set: dense per-row columns over the overlay's
    node axis ((n_rows,) single-engine, (S, n_rows) stacked) so the fused
    write+eval body indexes them with no gather. Rows without an alert are
    ``active=False`` and carry never-trip thresholds."""

    active: jnp.ndarray      # bool — row has a registered alert
    armed: jnp.ndarray       # bool — eligible to fire
    hi: jnp.ndarray          # f32 upper threshold (+inf = unset)
    lo: jnp.ndarray          # f32 lower threshold (-inf = unset)
    dthr: jnp.ndarray        # f32 delta-vs-ref threshold (+inf = unset)
    hys: jnp.ndarray         # f32 hysteresis margin
    deb: jnp.ndarray         # f32 debounce (logical ticks)
    comp: jnp.ndarray        # i32 payload component
    last_fire: jnp.ndarray   # f32 eval time of the last fire (-inf = never)
    ref: jnp.ndarray         # f32 delta baseline (re-based on fire)
    last_m: jnp.ndarray      # f32 measure at the last evaluation


DYNAMIC_FIELDS = ("armed", "last_fire", "ref", "last_m")


@dataclasses.dataclass(frozen=True)
class FiredBatch:
    """One device batch's fired set, in ascending base-id order."""

    now: float               # logical eval time of the triggering batch
    base_ids: np.ndarray     # (k,) int64 fired reader base ids
    values: np.ndarray       # (k,) f32 measures at fire time
    aids: np.ndarray         # (k,) int64 alert handle id per fired reader
    overflow: bool = False   # fired count exceeded the compact capacity
                             # (set is still exact — recovered densely)

    def __len__(self) -> int:
        return len(self.base_ids)


# ------------------------------------------------------------- device bodies
def _measure(agg: Aggregate, pao: jnp.ndarray, comp: jnp.ndarray
             ) -> jnp.ndarray:
    """(n_rows,) finalized measure per row, at each row's payload lane."""
    fin = agg.finalize(pao)
    if fin.ndim == 1:
        fin = fin[:, None]
    c = jnp.clip(comp, 0, fin.shape[1] - 1)
    return jnp.take_along_axis(fin, c[:, None], axis=1)[:, 0]


def alert_eval(agg: Aggregate, astate: AlertState, pao: jnp.ndarray,
               now: jnp.ndarray, cap: int):
    """Evaluate every alerted row against the post-write PAO. Pure and
    jit-safe; all shapes are fixed, so the fused write+eval program keeps one
    trace per batch bucket. Returns ``(new_state, count, idx, vals, fired,
    m)`` — ``idx``/``vals`` are the compact (K,) fired buffer (-1 padded, row
    order), ``fired``/``m`` the dense vectors the collector falls back to
    when ``count > K``."""
    m = _measure(agg, pao, astate.comp)
    # only rows whose *measure* changed this batch are evaluated — untouched
    # rows compare equal by construction, so this is exactly the batch's
    # reachable-reader restriction (plus time-window expiries)
    changed = astate.active & (m != astate.last_m)
    trip = (m > astate.hi) | (m < astate.lo) | \
        (jnp.abs(m - astate.ref) > astate.dthr)
    can_fire = (now - astate.last_fire) >= astate.deb
    fired = changed & astate.armed & trip & can_fire
    inside = (m <= astate.hi - astate.hys) & (m >= astate.lo + astate.hys)
    armed = jnp.where(fired, False, astate.armed | (changed & inside))
    new = astate._replace(
        armed=armed,
        last_fire=jnp.where(fired, now, astate.last_fire),
        ref=jnp.where(fired, m, astate.ref),
        last_m=jnp.where(changed, m, astate.last_m),
    )
    idx = jnp.nonzero(fired, size=cap, fill_value=-1)[0].astype(jnp.int32)
    count = jnp.sum(fired, dtype=jnp.int32)
    vals = jnp.where(idx >= 0, m[jnp.maximum(idx, 0)], 0.0)
    return new, count, idx, vals, fired, m


def alert_write_step(step, meta, agg: Aggregate, spec, cap: int, arrays,
                     state, astate: AlertState, rows, vals, mask, *extra):
    """A write step with alert evaluation fused in: ``step`` is one of the
    pure engine write bodies (dense/sparse x sum/extremal — a static
    argument, so each combination keeps its own cache entry) and the
    evaluation reads the post-step PAO at the step's own eval instant
    (``new_now - 1``: the step increments the clock on return)."""
    ns = step(meta, agg, spec, arrays, state, rows, vals, mask, *extra)
    new_a, count, idx, avals, fired, m = alert_eval(
        agg, astate, ns.pao, ns.now - 1.0, cap)
    return ns, new_a, count, idx, avals, fired, m


# One jitted entry for every (step body, plan shape) combination. Non-alert
# sessions never call this — their write bodies, traces, and transfer
# behavior are untouched. Engine state and alert state are donated (callers
# rebind both every step, like the plain write bodies).
_alert_write = functools.partial(
    jax.jit, static_argnums=(0, 1, 2, 3, 4),
    donate_argnums=(6, 7))(alert_write_step)


def _reader_nodes(plan, bases: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(node, found) for each base against one plan — the dense route LUT
    when the plan carries one, the host dict otherwise (stacked shard
    plans)."""
    routes = getattr(plan, "routes", None)
    if routes is not None:
        return routes.reader_nodes(bases)
    rnb = plan.reader_node_of_base
    node = np.fromiter((rnb.get(int(b), -1) for b in bases),
                       np.int64, len(bases)).astype(np.int32)
    return node, node >= 0


# --------------------------------------------------------------- host manager
class AlertSet:
    """Host bookkeeping for the alerts attached to one engine (single or
    stacked): the registered specs as per-base SoA columns, the device
    :class:`AlertState`, row placement (base id -> (shard, node)), the
    in-flight fired buffers awaiting readback, and the host-side queue of
    collected :class:`FiredBatch` es.

    Lifecycle: ``register``/``unregister`` edit the SoA and rebuild the
    device columns via :meth:`sync`; the engine calls ``sync`` again after
    every structural patch so churn carries alert rows (retired readers drop
    out, moved readers follow their node, query-wide alerts pick up new
    readers). ``push_pending`` (engine write path) and ``collect``
    (ring-boundary readback) move fired sets host-side without adding a sync
    point."""

    def __init__(self, cap: int | None = None):
        self.cap = int(cap) if cap else alert_cap()
        self.enabled = alert_eval_enabled()
        # ------------------------- per-base SoA (registration order)
        self._base = np.zeros(0, np.int64)
        self._aid = np.zeros(0, np.int64)
        self._static = {f: np.zeros(0, np.float32) for f in
                        ("hi", "lo", "dthr", "hys", "deb")}
        self._static["comp"] = np.zeros(0, np.int32)
        self._dyn = {"armed": np.zeros(0, bool),
                     "last_fire": np.zeros(0, np.float32),
                     "ref": np.zeros(0, np.float32),
                     "last_m": np.zeros(0, np.float32)}
        self._specs: dict[int, AlertSpec] = {}
        self._dynamic_aids: set[int] = set()  # readers=None registrations
        # ------------------------- placement (rebuilt by sync)
        self._shard = np.zeros(0, np.int32)   # owner shard per base (0 single)
        self._node = np.zeros(0, np.int32)    # overlay node per base
        self._placed = np.zeros(0, bool)      # base resolved to a live row
        self._row_base: np.ndarray | None = None  # (S, n_rows) node -> base
        self._row_aid: np.ndarray | None = None   # (S, n_rows) node -> aid
        self.state: AlertState | None = None
        self._stacked = False
        # ------------------------- fired-set plumbing
        self._pending: collections.deque = collections.deque()
        self.fired: collections.deque[FiredBatch] = collections.deque()
        self.dropped_bases = 0   # alerted readers retired by churn (cumulative)
        # monotone dispatch/readback sequence numbers: the ingest ring marks
        # each slot with `seq` at dispatch and collects up to that mark when
        # the slot's token barrier proves those steps completed
        self.seq = 0        # fused steps dispatched (push_pending calls)
        self.seq_done = 0   # pending entries read back (collect pops)

    # ------------------------------------------------------------ properties
    @property
    def n_alerts(self) -> int:
        return len(self._base)

    @property
    def n_placed(self) -> int:
        return int(np.count_nonzero(self._placed))

    def __bool__(self) -> bool:
        return self.n_alerts > 0

    # ---------------------------------------------------------- registration
    def register(self, aid: int, spec: AlertSpec, bases, *, dynamic: bool,
                 engine=None) -> None:
        """Add one spec over ``bases`` (ascending base ids). ``dynamic``
        registrations (session ``readers=None``) re-resolve to the engine's
        full reader set on every sync, so churn-added readers inherit the
        spec. Overlapping a base already alerted by another registration is
        an error — each reader row holds one predicate."""
        bases = np.unique(np.asarray(bases, np.int64).reshape(-1))
        if len(bases) == 0 and not dynamic:
            raise ValueError("register_alert: empty reader set")
        clash = np.intersect1d(bases, self._base)
        if len(clash):
            raise ValueError(
                f"readers {clash[:8].tolist()} already carry an alert; "
                "unregister it first (one predicate per reader row)")
        tables = spec.tables(len(bases))
        self._base = np.concatenate([self._base, bases])
        self._aid = np.concatenate(
            [self._aid, np.full(len(bases), aid, np.int64)])
        for f, col in tables.items():
            self._static[f] = np.concatenate([self._static[f], col])
        self._dyn["armed"] = np.concatenate(
            [self._dyn["armed"], np.ones(len(bases), bool)])
        self._dyn["last_fire"] = np.concatenate(
            [self._dyn["last_fire"], np.full(len(bases), -np.inf, np.float32)])
        # ref / last_m seed from the current measure at sync (NaN sentinel)
        for f in ("ref", "last_m"):
            self._dyn[f] = np.concatenate(
                [self._dyn[f], np.full(len(bases), np.nan, np.float32)])
        self._specs[aid] = spec
        if dynamic:
            self._dynamic_aids.add(aid)
        if engine is not None:
            try:
                self.sync(engine)
            except Exception:
                # roll the rejected registration back (e.g. PULL-decided
                # readers) so the set stays consistent for its peers
                self._take(self._aid != aid)
                self._specs.pop(aid, None)
                self._dynamic_aids.discard(aid)
                raise

    def unregister(self, aid: int, engine=None) -> None:
        if aid not in self._specs:
            return
        self._pull_dynamic()
        keep = self._aid != aid
        self._take(keep)
        del self._specs[aid]
        self._dynamic_aids.discard(aid)
        if engine is not None:
            self.sync(engine)

    def _take(self, keep: np.ndarray) -> None:
        self._base = self._base[keep]
        self._aid = self._aid[keep]
        for d in (self._static, self._dyn):
            for f in d:
                d[f] = d[f][keep]
        self._shard = self._shard[: len(self._base)]
        self._node = self._node[: len(self._base)]
        self._placed = np.zeros(len(self._base), bool)  # sync re-resolves

    # ----------------------------------------------------------------- sync
    def _plans(self, engine) -> list:
        sp = getattr(engine, "shard_plans", None)
        return list(sp) if sp is not None else [engine.plan]

    def _pull_dynamic(self) -> None:
        """Fold the device dynamic columns (armed/debounce/ref state) back
        into the per-base host mirrors at the current placement — the carry
        step before any re-layout (churn sync, checkpoint snapshot)."""
        if self.state is None or not self._placed.any():
            return
        host = {f: np.asarray(jax.device_get(getattr(self.state, f)))
                for f in DYNAMIC_FIELDS}
        p = self._placed
        for f in DYNAMIC_FIELDS:
            col = host[f] if self._stacked else host[f][None]
            self._dyn[f][p] = col[self._shard[p], self._node[p]]

    def sync(self, engine, retired=()) -> None:
        """(Re)build placement + device columns against the engine's current
        plan(s). Called at registration and after every structural patch /
        plan adoption: alerted bases follow their reader node, bases whose
        reader retired are dropped (``retired`` from the patch result speeds
        the common case; a full re-resolve catches the rest), and dynamic
        registrations pick up readers that churn added."""
        self._pull_dynamic()
        plans = self._plans(engine)
        self._stacked = getattr(engine, "shard_plans", None) is not None
        S, n_rows = len(plans), plans[0].meta.n_nodes

        if retired is not None and len(retired):
            gone = np.isin(self._base, np.asarray(list(retired), np.int64))
            if gone.any():
                self.dropped_bases += int(np.count_nonzero(gone))
                self._take(~gone)
        # dynamic registrations: adopt any reader base not yet alerted
        for aid in sorted(self._dynamic_aids):
            have = set(self._base.tolist())
            fresh = sorted(
                b for p in plans for b in p.reader_node_of_base
                if b not in have)
            if fresh:
                spec = self._specs[aid]
                del self._specs[aid]  # re-entrant register() guard
                dyn_flag = True
                self._dynamic_aids.discard(aid)
                try:
                    self.register(aid, spec, fresh, dynamic=dyn_flag)
                finally:
                    self._specs[aid] = spec
                    if dyn_flag:
                        self._dynamic_aids.add(aid)

        # ---------------------------------------------------- row placement
        M = len(self._base)
        shard = np.zeros(M, np.int32)
        node = np.full(M, -1, np.int32)
        for s, p in enumerate(plans):
            rn, ok = _reader_nodes(p, self._base) if M else \
                (np.zeros(0, np.int32), np.zeros(0, bool))
            place = ok & (node < 0)
            shard[place] = s
            node[place] = rn[place]
        placed = node >= 0
        lost = ~placed
        if lost.any():
            self.dropped_bases += int(np.count_nonzero(lost))
            self._take(placed)
            shard, node, placed = shard[placed], node[placed], \
                placed[placed]
            M = len(self._base)
        # alerts predicate on PAO currency: only PUSH-decided readers are
        # always current after a write step
        for s, p in enumerate(plans):
            mine = placed & (shard == s)
            if mine.any() and (p.decision[node[mine]] != PUSH).any():
                bad = self._base[mine][p.decision[node[mine]] != PUSH]
                raise ValueError(
                    f"alerted readers {bad[:8].tolist()} are PULL-decided — "
                    "alerts need push-maintained readers (register the query "
                    "with continuous=True)")
        self._shard, self._node, self._placed = shard, node, placed

        # ------------------------------------------- node -> base/aid LUTs
        self._row_base = np.full((S, n_rows), -1, np.int64)
        self._row_aid = np.full((S, n_rows), -1, np.int64)
        self._row_base[shard, node] = self._base
        self._row_aid[shard, node] = self._aid

        # ------------------------------------------------ measure seeding
        nan = np.isnan(self._dyn["last_m"]) | np.isnan(self._dyn["ref"])
        if nan.any():
            m = self._measures_host(engine, plans)
            for f in ("ref", "last_m"):
                col = self._dyn[f]
                col[np.isnan(col)] = m[np.isnan(col)]

        # ------------------------------------------------- device columns
        shape = (S, n_rows) if self._stacked else (n_rows,)
        cols = {
            "active": np.zeros(shape, bool),
            "armed": np.zeros(shape, bool),
            "hi": np.full(shape, np.inf, np.float32),
            "lo": np.full(shape, -np.inf, np.float32),
            "dthr": np.full(shape, np.inf, np.float32),
            "hys": np.zeros(shape, np.float32),
            "deb": np.zeros(shape, np.float32),
            "comp": np.zeros(shape, np.int32),
            "last_fire": np.full(shape, -np.inf, np.float32),
            "ref": np.zeros(shape, np.float32),
            "last_m": np.zeros(shape, np.float32),
        }
        at = (shard, node) if self._stacked else (node,)
        cols["active"][at] = True
        for f, col in self._static.items():
            cols[f][at] = col
        for f, col in self._dyn.items():
            cols[f][at] = col
        host_state = AlertState(**cols)
        put = getattr(engine, "_put_alert_state", jax.device_put)
        self.state = put(host_state)

    def _measures_host(self, engine, plans) -> np.ndarray:
        """Current finalized measure per registered base (one device_get;
        only runs at registration / churn barriers, never per batch)."""
        pao = np.asarray(jax.device_get(engine.state.pao))
        if not self._stacked:
            pao = pao[None]
        fin = engine.agg.FINALIZE(pao.reshape(-1, pao.shape[-1]))
        fin = np.asarray(fin, np.float32).reshape(pao.shape[0],
                                                  pao.shape[1], -1)
        comp = np.clip(self._static["comp"], 0, fin.shape[-1] - 1)
        return fin[self._shard, self._node, comp]

    # --------------------------------------------------------- fired plumbing
    def push_pending(self, now: float, count, idx, vals, fired, m) -> None:
        """Stash one step's device fired buffers (no transfer, no sync —
        readback happens at :meth:`collect`)."""
        self._pending.append((float(now), count, idx, vals, fired, m))
        self.seq += 1

    @property
    def pending(self) -> int:
        return len(self._pending)

    def collect(self, n: int | None = None) -> int:
        """Read back up to ``n`` pending fired sets (all when ``None``) into
        host :class:`FiredBatch` es. Callers sequence this after the device
        steps have completed (the ingest ring collects exactly the freed
        slot's batches after its token barrier), so the ``device_get`` here
        is a completed-buffer copy, not a synchronization point."""
        n = len(self._pending) if n is None else min(n, len(self._pending))
        out = 0
        for _ in range(n):
            now, count, idx, vals, fired, m = self._pending.popleft()
            self.seq_done += 1
            cd = np.asarray(jax.device_get(count))
            # stacked: the psum'd global total, replicated over the shard
            # axis — one scalar readback regardless of shard count
            total = int(cd.reshape(-1)[0]) if cd.ndim else int(cd)
            if total == 0:
                continue
            batch = self._to_batch(now, idx, vals, fired, m)
            if len(batch):
                self.fired.append(batch)
                out += 1
        return out

    def _to_batch(self, now, idx, vals, fired, m) -> FiredBatch:
        idx_h = np.asarray(jax.device_get(idx))
        vals_h = np.asarray(jax.device_get(vals))
        if not self._stacked:
            idx_h, vals_h = idx_h[None], vals_h[None]
        S = idx_h.shape[0]
        overflow = False
        rows_s, rows_n, rows_v = [], [], []
        fired_h = None
        for s in range(S):
            live = idx_h[s] >= 0
            k = int(np.count_nonzero(live))
            # per-shard overflow: the compact buffer truncated — recover the
            # exact set from the dense fired vector (rare path, one transfer)
            if k == self.cap:
                if fired_h is None:
                    fired_h = np.asarray(jax.device_get(fired))
                    m_h = np.asarray(jax.device_get(m))
                    if not self._stacked:
                        fired_h, m_h = fired_h[None], m_h[None]
                nodes = np.flatnonzero(fired_h[s])
                if len(nodes) > k:
                    overflow = True
                    rows_s.append(np.full(len(nodes), s, np.int32))
                    rows_n.append(nodes.astype(np.int32))
                    rows_v.append(m_h[s][nodes].astype(np.float32))
                    continue
            rows_s.append(np.full(k, s, np.int32))
            rows_n.append(idx_h[s][live])
            rows_v.append(vals_h[s][live])
        sh = np.concatenate(rows_s) if rows_s else np.zeros(0, np.int32)
        nd = np.concatenate(rows_n) if rows_n else np.zeros(0, np.int32)
        vv = np.concatenate(rows_v) if rows_v else np.zeros(0, np.float32)
        bases = self._row_base[sh, nd]
        aids = self._row_aid[sh, nd]
        live = bases >= 0
        order = np.argsort(bases[live], kind="stable")
        return FiredBatch(now=now, base_ids=bases[live][order],
                          values=vv[live][order], aids=aids[live][order],
                          overflow=overflow)

    def collect_upto(self, upto: int) -> int:
        """Read back pending fired sets up through dispatch sequence ``upto``
        (a :attr:`seq` value recorded when those steps were enqueued). A
        no-op when an interleaved :meth:`collect` already drained past the
        mark, so ring-boundary bookkeeping stays correct even if the user
        drains mid-ring."""
        return self.collect(max(0, upto - self.seq_done))

    def pop_fired(self) -> list[FiredBatch]:
        out = list(self.fired)
        self.fired.clear()
        return out

    # ------------------------------------------------------------ checkpoint
    def snapshot(self) -> tuple[dict, list]:
        """Per-base packed arrays + JSON spec descriptors. The packed layout
        is placement-free (base ids, not rows), so a reshard restore places
        the same armed/debounce state onto whatever layout the restored
        session compiles — restored sessions never re-fire stale alerts."""
        self._pull_dynamic()
        arrays = {"base": self._base.copy(), "aid": self._aid.copy()}
        for d in (self._static, self._dyn):
            for f, col in d.items():
                arrays[f] = col.copy()
        specs = [{"aid": int(a), "dynamic": a in self._dynamic_aids,
                  "spec": self._specs[a].to_json()}
                 for a in sorted(self._specs)]
        return arrays, specs

    @classmethod
    def from_snapshot(cls, arrays: dict, specs: list, *,
                      cap: int | None = None) -> "AlertSet":
        alerts = cls(cap)
        alerts._base = np.asarray(arrays["base"], np.int64)
        alerts._aid = np.asarray(arrays["aid"], np.int64)
        M = len(alerts._base)
        for f in alerts._static:
            alerts._static[f] = np.asarray(
                arrays[f], alerts._static[f].dtype)
        for f in alerts._dyn:
            alerts._dyn[f] = np.asarray(arrays[f], alerts._dyn[f].dtype)
        alerts._shard = np.zeros(M, np.int32)
        alerts._node = np.full(M, -1, np.int32)
        alerts._placed = np.zeros(M, bool)
        for s in specs:
            alerts._specs[int(s["aid"])] = AlertSpec.from_json(s["spec"])
            if s.get("dynamic"):
                alerts._dynamic_aids.add(int(s["aid"]))
        return alerts


# ----------------------------------------------------------------- validation
def check_alert_aggregate(agg: Aggregate) -> int:
    """Reject aggregates whose finalize output is not value-shaped and
    return the measure dimensionality (payload lanes ``component`` may
    select)."""
    if agg.name not in ALERT_COMPATIBLE:
        raise ValueError(
            f"aggregate {agg.name!r} cannot back an alert — its finalize "
            f"output is not an ordered value (supported: "
            f"{', '.join(ALERT_COMPATIBLE)})")
    fin = np.asarray(agg.FINALIZE(np.zeros((1, agg.pao_dim), np.float32)))
    return int(fin.reshape(1, -1).shape[1])


# ------------------------------------------------------------------ poll oracle
class PollOracle:
    """The baseline this subsystem replaces, kept as the parity/bench
    reference: after every device batch, gather + ``device_get`` the
    finalized measures of **all** alerted readers (O(alerts) transfer per
    batch) and run the identical state machine on host. Same f32 values,
    same comparisons — fired sets must match the push path bit for bit."""

    def __init__(self, alerts: AlertSet):
        arrays, _ = alerts.snapshot()
        self.base = arrays["base"]
        self.aid = arrays["aid"]
        self.static = {f: arrays[f] for f in
                       ("hi", "lo", "dthr", "hys", "deb", "comp")}
        # adopt the full dynamic state, not just ref/last_m — an oracle
        # seeded from a mid-stream alert set (post-restore parity) must
        # carry armed/debounce state or it re-fires what already fired
        self.armed = arrays["armed"].copy()
        self.last_fire = arrays["last_fire"].copy()
        self.ref = arrays["ref"].copy()
        self.last_m = arrays["last_m"].copy()
        self._nodes = None

    def resync(self, engine) -> None:
        """Re-resolve reader nodes (registration / after churn)."""
        nodes, ok = _reader_nodes(engine.plan, self.base)
        keep = ok
        if not keep.all():
            self.base, self.aid = self.base[keep], self.aid[keep]
            for f in self.static:
                self.static[f] = self.static[f][keep]
            for f in ("armed", "last_fire", "ref", "last_m"):
                setattr(self, f, getattr(self, f)[keep])
            nodes = nodes[keep]
        self._nodes = jnp.asarray(nodes.astype(np.int32))

    def poll(self, engine, now: float) -> FiredBatch:
        """One poll step: the O(alerts) readback the push path avoids."""
        if self._nodes is None:
            self.resync(engine)
        fin = np.asarray(jax.device_get(
            engine.agg.finalize(engine.state.pao[self._nodes])),
            np.float32)
        if fin.ndim == 1:
            fin = fin[:, None]
        m = fin[np.arange(len(self.base)),
                np.clip(self.static["comp"], 0, fin.shape[1] - 1)]
        now32 = np.float32(now)
        changed = m != self.last_m
        trip = (m > self.static["hi"]) | (m < self.static["lo"]) | \
            (np.abs(m - self.ref) > self.static["dthr"])
        can_fire = (now32 - self.last_fire) >= self.static["deb"]
        fired = changed & self.armed & trip & can_fire
        inside = (m <= self.static["hi"] - self.static["hys"]) & \
            (m >= self.static["lo"] + self.static["hys"])
        self.armed = np.where(fired, False, self.armed | (changed & inside))
        self.last_fire = np.where(fired, now32, self.last_fire)
        self.ref = np.where(fired, m, self.ref)
        self.last_m = np.where(changed, m, self.last_m)
        hit = np.flatnonzero(fired)
        order = np.argsort(self.base[hit], kind="stable")
        return FiredBatch(now=float(now), base_ids=self.base[hit][order],
                          values=m[hit][order], aids=self.aid[hit][order])
