"""Async double-buffered streaming ingest (ROADMAP: millions of events/s).

The synchronous write path pays, per arrival batch: host routing, a
``device_put``, one O(n_nodes) device step, and the Python gap between
batches. :class:`IngestPipeline` restructures that into a pipeline over a
small ring of pre-allocated power-of-two host batch buffers:

  * arriving events accumulate into the current ring slot (vectorized
    copies — no per-event Python anywhere on this path);
  * when a slot reaches ``device_batch`` events it is routed (one
    ``BaseRoutes`` table lookup per engine) and dispatched through
    ``EagrEngine.write_rows`` — JAX async dispatch returns immediately, so
    the host fills and routes slot N+1 while the device still runs the step
    for slot N;
  * backpressure is explicit: the only steady-state ``block_until_ready``
    sits at the ring boundary — a slot's buffers are reused only once its
    in-flight step finished, which is also what makes buffer reuse safe when
    ``device_put`` zero-copy aliases host memory on CPU;
  * :meth:`flush` dispatches the partial slot and drains every token — a
    full pipeline barrier. ``EagrSession.flush`` runs it *before* draining
    churn journals, so structural patches keep their ordering with respect
    to writes. :meth:`drain` dispatches without blocking: a subsequent
    read's data dependency through the engine state already observes every
    dispatched batch in order.

Coalescing — ``device_batch`` larger than the arrival batch — is where the
sustained-throughput win comes from: the device step sweeps O(n_nodes +
E_push) state per batch regardless of batch size, so folding k arrival
batches into one device batch amortizes that sweep k ways. The logical
clock consequently ticks once per *device* batch, not once per ``submit``;
for time windows pick ``device_batch`` so one tick still means what the
window size expects. Bit-for-bit parity with the synchronous path holds
whenever the synchronous driver uses the same batch boundaries
(``write_batch(ids, vals, batch_size=device_batch)`` per full slot) — the
parity tests in ``tests/test_ingest.py`` pin exactly that.

``IngestStats`` is the counter block (in the style of PR 6's
``ConstructionStats``): events in/dispatched/dropped, batches, stall and
barrier time, ring occupancy high-water.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import bucket_batch

__all__ = ["IngestPipeline", "IngestStats"]


@dataclasses.dataclass
class IngestStats:
    """Ingest counters; ``events_in`` minus ``events_dispatched`` is the
    current fill of the accumulating slot."""

    events_in: int = 0          # events submitted to the pipeline
    events_dispatched: int = 0  # events handed to the device (incl. masked)
    events_dropped: int = 0     # lanes no engine routed (unknown writers)
    batches: int = 0            # device batches dispatched
    partial_batches: int = 0    # dispatches below device_batch (flush/drain)
    flushes: int = 0            # full pipeline barriers
    stall_s: float = 0.0        # time blocked on ring backpressure
    barrier_s: float = 0.0      # time blocked inside flush()
    max_in_flight: int = 0      # ring occupancy high-water mark

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class IngestPipeline:
    """Double-buffered ingest ring over one or more engines sharing a write
    stream (an ``EagrSession``'s engine groups, or hand-assembled engines).

    Parameters
    ----------
    engines : list
        ``EagrEngine`` and/or ``StackedShardedEngine`` instances. Single
        engines dispatch through the pre-routed ``write_rows`` entry;
        stacked engines route on-device and go through ``write_batch``.
    depth : int
        Ring slots (>= 1). ``depth=1`` degenerates to synchronous-but-
        coalesced; ``depth=2`` is classic double buffering. More depth only
        helps when device steps vary a lot in latency.
    device_batch : int
        Events per device step; bucketed to a power of two. This is the
        coalescing factor — and the logical-clock granularity.
    value_dim : int | None
        Raw write arity; defaults to the first engine's window spec.
    """

    def __init__(self, engines, *, depth: int = 2, device_batch: int = 8192,
                 value_dim: int | None = None,
                 stats: "IngestStats | None" = None):
        if not engines:
            raise ValueError("IngestPipeline needs at least one engine")
        self.engines = list(engines)
        self.depth = max(1, int(depth))
        self.device_batch = bucket_batch(int(device_batch))
        if value_dim is None:
            value_dim = self.engines[0].spec.value_dim
        self.value_dim = int(value_dim)
        B = self.device_batch
        vshape = (B,) if self.value_dim == 1 else (B, self.value_dim)
        self._ids = [np.zeros(B, np.int64) for _ in range(self.depth)]
        self._vals = [np.zeros(vshape, np.float32) for _ in range(self.depth)]
        self._tokens: list = [None] * self.depth
        # per-slot fired-set readback marks: [(AlertSet, seq)] recorded at
        # dispatch — collected once the slot's token barrier proves those
        # steps completed. Empty for sessions without standing alerts, so
        # the non-alert path adds no transfers (transfer-guard invariant).
        self._alert_marks: list = [None] * self.depth
        self._slot = 0
        self._fill = 0
        # a restored session hands back its saved counter block so lifetime
        # ingest stats survive checkpoint/restore (and pipeline re-creation)
        self.stats = stats if stats is not None else IngestStats()

    # ------------------------------------------------------------------ intake
    def submit(self, ids, values=None) -> None:
        """Feed a batch of events (any size) into the ring. Dispatches each
        slot the moment it fills; never blocks except on ring backpressure."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if values is None:
            values = np.ones(len(ids), np.float32)
        vals = np.asarray(values, np.float32)
        want = (len(ids),) if self.value_dim == 1 \
            else (len(ids), self.value_dim)
        if vals.shape != want:
            raise ValueError(f"submit values shape {vals.shape} != {want} "
                             f"(pipeline value_dim={self.value_dim})")
        self.stats.events_in += len(ids)
        B, off = self.device_batch, 0
        while off < len(ids):
            take = min(B - self._fill, len(ids) - off)
            s, f = self._slot, self._fill
            self._ids[s][f: f + take] = ids[off: off + take]
            self._vals[s][f: f + take] = vals[off: off + take]
            self._fill += take
            off += take
            if self._fill == B:
                self._dispatch(B)

    # ---------------------------------------------------------------- dispatch
    def _dispatch(self, n: int) -> None:
        s, B = self._slot, self.device_batch
        ids, vals = self._ids[s], self._vals[s]
        if n < B:
            # partial slot (flush/drain): poison the tail so routing masks it
            ids[n:] = -1
            vals[n:] = 0.0
            self.stats.partial_batches += 1
        dropped = n
        for eng in self.engines:
            routes = getattr(getattr(eng, "plan", None), "routes", None)
            if routes is None:
                # stacked shard engine: ids route on-device via owner maps
                eng.write_batch(ids[:n], vals[:n], batch_size=B)
                dropped = 0
                continue
            rows, mask = routes.writer_rows(ids)
            n_live = int(np.count_nonzero(mask))
            dropped = min(dropped, n - n_live)
            v = vals
            if n_live < n:
                # zero dead lanes: their values are dead under the mask, but
                # keep non-finite garbage out of the masked multiply
                v = np.where(mask.reshape((-1,) + (1,) * (vals.ndim - 1)),
                             vals, 0.0)
            # expand this coalesced device batch's frontier here (host side,
            # before dispatch) so the sparse-vs-dense decision and the
            # active-block bucket are pinned per batch, not per event
            act = eng.frontier_active(rows, mask, n_live=n_live)
            eng.write_rows(rows, v, mask, n_live=n_live, active=act)
        self.stats.events_dispatched += n
        self.stats.events_dropped += dropped
        self.stats.batches += 1
        # `state.now` is an output of the step just dispatched: readiness of
        # this token == completion of every engine's device batch for slot s.
        # It is also DONATED into the engine's next step — token a detached
        # copy (dispatched now, before any later donation) so the ring
        # barrier never blocks on a donated buffer. ``jnp.copy``, not
        # ``+ 0``: the scalar constant would be an implicit transfer under
        # the transfer guard
        self._tokens[s] = [jnp.copy(eng.state.now) for eng in self.engines]
        marks = [(al, al.seq) for al in
                 (getattr(eng, "alerts", None) for eng in self.engines)
                 if al is not None]
        self._alert_marks[s] = marks or None
        self.stats.max_in_flight = max(
            self.stats.max_in_flight,
            sum(t is not None for t in self._tokens))
        # advance the ring; the next slot's buffers may still back an
        # in-flight step — the pipeline's only steady-state sync point
        self._slot = (self._slot + 1) % self.depth
        self._fill = 0
        tok = self._tokens[self._slot]
        if tok is not None:
            t0 = time.perf_counter()
            jax.block_until_ready(tok)
            self.stats.stall_s += time.perf_counter() - t0
            self._tokens[self._slot] = None
            self._collect_marks(self._slot)

    def _collect_marks(self, slot: int) -> None:
        """Pop the fired sets whose steps the freed slot's token proves done.
        The ``device_get`` inside ``collect_upto`` copies completed buffers —
        it never becomes a steady-state sync point."""
        marks = self._alert_marks[slot]
        if marks is not None:
            self._alert_marks[slot] = None
            for al, upto in marks:
                al.collect_upto(upto)

    # ----------------------------------------------------------------- control
    def drain(self) -> None:
        """Dispatch the partial slot without blocking: a read issued next
        observes every submitted event through its data dependency on the
        engine state (device steps execute in dispatch order)."""
        if self._fill:
            self._dispatch(self._fill)

    def flush(self) -> None:
        """Pipeline barrier: dispatch the partial slot, then block until
        every in-flight device step completed. Run before structural churn
        lands (``EagrSession.flush`` does) so patch ordering — and donated /
        host-aliased buffer reuse — stays safe."""
        self.drain()
        t0 = time.perf_counter()
        for i, tok in enumerate(self._tokens):
            if tok is not None:
                jax.block_until_ready(tok)
                self._tokens[i] = None
            self._collect_marks(i)
        self.stats.barrier_s += time.perf_counter() - t0
        self.stats.flushes += 1

    @property
    def in_flight(self) -> int:
        return sum(t is not None for t in self._tokens)

    @property
    def pending(self) -> int:
        """Events accumulated in the current slot, not yet dispatched."""
        return self._fill
