"""Read/write trace generation and playback (paper §5.1).

The paper drives experiments with Zipfian read/write frequencies (event rates
in Twitter/Yahoo! follow Zipf [Breslau et al.; Silberstein et al.]) plus real
HTTP packet traces. Offline here, we generate Zipfian traces with a
configurable write:read ratio and linear read~write correlation, plus a
``shift_workload`` transform reproducing the §5.3 adaptivity experiment
(read frequencies of the worst-latency nodes are boosted mid-trace).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

WRITE, READ = 0, 1


@dataclasses.dataclass
class Trace:
    kind: np.ndarray    # (n_events,) 0=write 1=read
    node: np.ndarray    # (n_events,) base node id
    value: np.ndarray   # (n_events,) fp32 payload (writes; topic id for TOP-K)
    write_freq: np.ndarray  # per-base-node expected write frequency
    read_freq: np.ndarray   # per-base-node expected read frequency

    @property
    def n_events(self) -> int:
        return int(self.kind.size)


def zipf_frequencies(n: int, alpha: float = 1.0, seed: int = 0) -> np.ndarray:
    """Normalized Zipf(alpha) frequencies randomly assigned to n nodes."""
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(n) + 1
    f = 1.0 / np.power(ranks.astype(np.float64), alpha)
    return f / f.sum()


def generate_trace(
    writers: np.ndarray,
    readers: np.ndarray,
    n_events: int,
    *,
    write_read_ratio: float = 1.0,
    alpha: float = 1.0,
    value_domain: int = 64,
    seed: int = 0,
    n_base: int | None = None,
    value_dim: int = 1,
) -> Trace:
    """Zipfian trace over the given writer/reader id sets. Read frequency of a
    node is linearly related to its write frequency (paper §5.1).
    ``value_dim > 1`` emits vector payloads (n_events, value_dim) — e.g.
    topic-distribution writes for vector-PAO workloads."""
    rng = np.random.default_rng(seed)
    n_base = n_base or int(max(writers.max(initial=0), readers.max(initial=0))) + 1

    wf = np.zeros(n_base)
    wf[writers] = zipf_frequencies(len(writers), alpha, seed)
    rf = np.zeros(n_base)
    # linear read~write correlation where both roles exist; fresh Zipf otherwise
    common = np.intersect1d(writers, readers)
    rf[common] = wf[common]
    only_read = np.setdiff1d(readers, common)
    if only_read.size:
        rf[only_read] = zipf_frequencies(len(only_read), alpha, seed + 1) * wf.sum() * 0.1
    rf = rf / max(rf.sum(), 1e-12)

    p_write = write_read_ratio / (1.0 + write_read_ratio)
    kind = (rng.random(n_events) >= p_write).astype(np.int8)
    node = np.empty(n_events, dtype=np.int64)
    n_w = int((kind == WRITE).sum())
    node[kind == WRITE] = rng.choice(writers, size=n_w, p=wf[writers] / wf[writers].sum())
    node[kind == READ] = rng.choice(readers, size=n_events - n_w,
                                    p=rf[readers] / rf[readers].sum())
    vshape = (n_events,) if value_dim == 1 else (n_events, value_dim)
    value = rng.integers(0, value_domain, size=vshape).astype(np.float32)
    scale = n_events / max(1.0, 1.0 + write_read_ratio)
    return Trace(kind=kind, node=node, value=value,
                 write_freq=wf * write_read_ratio * scale, read_freq=rf * scale)


def shift_workload(trace: Trace, boost_nodes: np.ndarray, factor: float = 10.0,
                   seed: int = 0) -> Trace:
    """§5.3 adaptivity experiment: boost read frequencies of ``boost_nodes``
    and resample the read events accordingly."""
    rng = np.random.default_rng(seed)
    rf = trace.read_freq.copy()
    rf[boost_nodes] *= factor
    readers = np.flatnonzero(rf > 0)
    node = trace.node.copy()
    rmask = trace.kind == READ
    node[rmask] = rng.choice(readers, size=int(rmask.sum()), p=rf[readers] / rf[readers].sum())
    return Trace(kind=trace.kind.copy(), node=node, value=trace.value.copy(),
                 write_freq=trace.write_freq.copy(), read_freq=rf)


def batched_playback(trace: Trace, batch: int, pad: bool = False) -> Iterator[tuple]:
    """Play the trace back as homogeneous batches: consecutive events of the
    same kind are grouped (up to ``batch``), matching the engine's batched
    write/read entry points while preserving global order across kinds.

    With ``pad=True`` every yielded batch has exactly ``batch`` rows and an
    extra ``n_live`` count: (kind, ids, vals, n_live). Padding rows repeat the
    run's last event id (so padded ids stay valid for their kind) with zeroed
    values; consumers must mask or slice by ``n_live`` — e.g. slice before
    ``write_batch``, or ignore answer rows past ``n_live`` after a read.
    Fixed shapes mean downstream batch routers never see ragged tails."""
    i = 0
    n = trace.n_events
    while i < n:
        k = trace.kind[i]
        j = i
        while j < n and j - i < batch and trace.kind[j] == k:
            j += 1
        ids = trace.node[i:j]
        vals = trace.value[i:j]
        if pad:
            n_live = j - i
            ids = np.concatenate(
                [ids, np.full(batch - n_live, ids[-1], ids.dtype)])
            vals = np.concatenate(
                [vals, np.zeros((batch - n_live,) + vals.shape[1:], vals.dtype)])
            yield ("write" if k == WRITE else "read", ids, vals, n_live)
        else:
            yield ("write" if k == WRITE else "read", ids, vals)
        i = j
