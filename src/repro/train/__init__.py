from repro.train.optimizer import adafactor, adamw, sgd  # noqa: F401
from repro.train.trainer import make_train_step  # noqa: F401
