"""Optimizers in pure JAX (no optax dependency): AdamW, Adafactor, SGD.

Each optimizer is an (init, update) pair:
  init(params)                         -> opt_state (pytree of arrays)
  update(grads, opt_state, params, lr) -> (new_params, new_opt_state)

Adafactor keeps a factored second moment (row/col running means) so the
optimizer state for a (m, n) matrix is m + n floats instead of 2*m*n — the
standard choice for 100B+ models where Adam states would blow the HBM budget
(see DESIGN.md memory table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


# --------------------------------------------------------------------- adamw
class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamState(jax.tree.map(z, params), jax.tree.map(z, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state: AdamState, params, lr):
        c = state.count + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g32)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                    m.astype(state_dtype), v.astype(state_dtype))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamState(new_m, new_v, c)

    return Optimizer("adamw", init, update)


# ----------------------------------------------------------------- adafactor
class FactorState(NamedTuple):
    vr: Any       # row second moments (or full v for <2D params)
    vc: Any       # col second moments (zeros() placeholder for <2D)
    count: jnp.ndarray


def adafactor(decay: float = 0.8, eps: float = 1e-30, clip: float = 1.0,
              weight_decay: float = 0.0, layer_chunked: bool = True) -> Optimizer:
    """Factored RMS optimizer (Shazeer & Stern, arXiv:1804.04235), momentum-free.
    Factored over the two trailing dims of >=2D params; 1D params keep full v.

    layer_chunked: apply the update to >=3D (layer-stacked) params one leading
    slice at a time via lax.map — bounds the fp32 elementwise temps to one
    layer's worth instead of the full stacked tensor (for arctic-480b that is
    35 MB instead of 1.22 GB per temp; several are live at once). Clipping
    becomes per-layer, which matches per-tensor semantics of non-stacked
    frameworks."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
                else jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _factored(p) else jnp.zeros((1,), jnp.float32)

        return FactorState(jax.tree.map(vr, params), jax.tree.map(vc, params),
                           jnp.zeros((), jnp.int32))

    def update(grads, state: FactorState, params, lr):
        c = state.count + 1
        beta = 1.0 - c.astype(jnp.float32) ** (-decay)

        def upd_one(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
            else:
                vr = beta * vr + (1 - beta) * g2
                denom = jnp.sqrt(vr)
            step = g32 / jnp.maximum(denom, eps)
            # relative step clipping (RMS(update) <= clip)
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * step).astype(p.dtype), vr, vc)

        def upd(g, vr, vc, p):
            if layer_chunked and p.ndim >= 3:
                return jax.lax.map(lambda a: upd_one(*a), (g, vr, vc, p))
            return upd_one(g, vr, vc, p)

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_vr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_vc = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, FactorState(new_vr, new_vc, c)

    return Optimizer("adafactor", init, update)


# ----------------------------------------------------------------------- sgd
def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(
            lambda g, m: momentum * m + g.astype(jnp.float32), grads, state)
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_m)
        return new_p, new_m

    return Optimizer("sgd", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](**kw)
