"""Training step builder: microbatched gradient accumulation + optimizer.

``make_train_step(loss_fn, optimizer, accum_steps)`` returns
``step(params, opt_state, batch, lr) -> (params, opt_state, metrics)``.

With accum_steps > 1, the global batch is split on the leading axis and
scanned, accumulating fp32 gradients — this divides peak activation memory by
accum_steps (the saved-activation term dominates for the 100B+ configs; see
DESIGN.md). Optional int8 gradient compression with error feedback
(distributed/compression.py) hooks in between accumulation and the update.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer


def _split_batch(batch, n):
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by accum {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(
    loss_fn: Callable[..., tuple[jnp.ndarray, dict]],
    optimizer: Optimizer,
    *,
    accum_steps: int = 1,
    accum_dtype=None,
    unroll_accum: bool = False,
    grad_transform: Callable[[Any], Any] | None = None,
    clip_norm: float | None = 1.0,
):
    """loss_fn(params, microbatch) -> (loss, metrics dict of scalars).

    accum_dtype: gradient-accumulation buffer dtype. None -> per-param dtype
    (bf16 params accumulate in bf16 — halves the largest train-step buffer for
    the 100B+ configs; their adafactor update renormalizes per-tensor so the
    low-precision sum is benign). Pass jnp.float32 to force fp32 accumulation.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch, lr):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_batch(batch, accum_steps)
            adt = (lambda p: accum_dtype or p.dtype)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt(p)), params)

            def body(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            (grads, loss), metrics = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), micro,
                unroll=accum_steps if unroll_accum else 1)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        if clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
            metrics = {**metrics, "grad_norm": gnorm}

        if grad_transform is not None:
            grads, opt_state = grad_transform(grads, opt_state)

        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        return params, opt_state, {**metrics, "loss": loss}

    return step


def make_eval_step(loss_fn):
    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {**metrics, "loss": loss}
    return step
