# Never set xla_force_host_platform_device_count here (dryrun.py owns that
# flag). CI's mesh-8 matrix entry exports it in the environment instead, so
# the suite must pass on the real single CPU device AND on a forced 8-device
# host mesh (the stacked shard_map path picks whichever is available).
import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# ---------------------------------------------------------------- hypothesis
# Property tests use hypothesis, but it is an optional dev dependency
# (requirements-dev.txt). Without it, collection must still succeed: install a
# stub whose @given marks the test skipped, so only property tests are lost.
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def _strategy(*_a, **_k):
        return None

    def _composite(fn):
        return _strategy

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "tuples", "sampled_from",
                  "booleans", "text", "one_of", "just", "none"):
        setattr(_st, _name, _strategy)
    _st.composite = _composite
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.core.bipartite import Bipartite, build_bipartite
from repro.graphs.generators import rmat_graph, small_example_graph


@pytest.fixture(scope="session")
def example_bipartite() -> Bipartite:
    return build_bipartite(small_example_graph())


@pytest.fixture(scope="session")
def rmat_bipartite() -> Bipartite:
    return build_bipartite(rmat_graph(400, 2400, seed=7))


def make_freqs(n: int, seed: int = 0, ratio: float = 1.0):
    rng = np.random.default_rng(seed)
    wf = rng.zipf(1.6, n).clip(1, 1000).astype(np.float64)
    rf = (wf * ratio)[rng.permutation(n)]
    return wf, rf
