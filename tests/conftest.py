# Tests must see the real single CPU device — never set
# xla_force_host_platform_device_count here (dryrun.py owns that flag).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core.bipartite import Bipartite, build_bipartite
from repro.graphs.generators import rmat_graph, small_example_graph


@pytest.fixture(scope="session")
def example_bipartite() -> Bipartite:
    return build_bipartite(small_example_graph())


@pytest.fixture(scope="session")
def rmat_bipartite() -> Bipartite:
    return build_bipartite(rmat_graph(400, 2400, seed=7))


def make_freqs(n: int, seed: int = 0, ratio: float = 1.0):
    rng = np.random.default_rng(seed)
    wf = rng.zipf(1.6, n).clip(1, 1000).astype(np.float64)
    rf = (wf * ratio)[rng.permutation(n)]
    return wf, rf
