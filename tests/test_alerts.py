"""Standing alerts (PR 10): device-evaluated predicates fused into the write
step must produce fired sets BIT-identical to the poll-everything oracle —
across aggregates, window kinds, scalar/vector payloads, fired-set overflow,
structural churn, and sharded stacking — while keeping the substrate's
steady-state discipline (one trace, no implicit host transfers) and
round-tripping armed/debounce state through checkpoints.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine, bucket_batch
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.session import EagrSession, Query
from repro.streams.alerts import (
    AlertSet,
    AlertSpec,
    AlertState,
    FiredBatch,
    PollOracle,
    alert_eval,
    check_alert_aggregate,
)
from repro.streams.ingest import IngestPipeline


# ---------------------------------------------------------------- fixtures
def _basis(seed=3, n=150, e=900):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dyn = DynamicOverlay.from_overlay(ov, bp.reader_input_sets())
    return g, bp, dyn.to_overlay(prune=False)


def _engine(basis, *, agg="sum", spec=None, **agg_kwargs):
    # alerts require push-maintained readers, so the fixtures are all-PUSH
    dec = np.full(basis.n_nodes, D.PUSH, np.int64)
    return EagrEngine(basis, dec, make_aggregate(agg, **agg_kwargs),
                      spec or WindowSpec("tuple", 4), headroom=2.0)


def _batches(eng, *, n_batches, arrival, value_dim=1, seed=7, lo=0, hi=8):
    writers = np.flatnonzero(eng.plan.routes.writer_row >= 0)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        ids = rng.choice(writers, size=arrival).astype(np.int64)
        shape = (arrival,) if value_dim == 1 else (arrival, value_dim)
        vals = rng.integers(lo, hi, shape).astype(np.float32)
        out.append((ids, vals))
    return out


def _alert_bases(eng, k=None):
    bases = np.flatnonzero(eng.plan.routes.reader_node >= 0).astype(np.int64)
    return bases if k is None else bases[:k]


def _flat(batches):
    """Order-free canonical form of a FiredBatch list for parity asserts."""
    out = []
    for b in batches:
        for i in range(len(b)):
            out.append((float(b.now), int(b.base_ids[i]),
                        float(np.float32(b.values[i])), int(b.aids[i])))
    return sorted(out)


def _drive_parity(eng, spec, batches, *, bases=None, cap=None):
    """Run identical batches through the fused push path and the poll
    oracle; return (push, poll) canonical fired lists."""
    bases = _alert_bases(eng) if bases is None else bases
    al = AlertSet(cap)
    al.register(0, spec, bases.tolist(), dynamic=False, engine=None)
    eng.attach_alerts(al)
    oracle = PollOracle(al)
    oracle.resync(eng)
    push, poll = [], []
    for ids, vals in batches:
        eng.write_batch(ids, vals, batch_size=len(ids))
        ob = oracle.poll(eng, float(eng._now_host) - 1.0)
        if len(ob):
            poll.append(ob)
    al.collect()
    push = al.pop_fired()
    eng.alerts = None
    return _flat(push), _flat(poll)


# ---------------------------------------------------- push-vs-poll parity
def test_parity_sum_tuple_window():
    _, _, basis = _basis()
    eng = _engine(basis)
    push, poll = _drive_parity(
        eng, AlertSpec(above=10.0, hysteresis=1.0),
        _batches(eng, n_batches=24, arrival=32))
    assert push, "fixture never fired — thresholds too loose to test parity"
    assert push == poll


def test_parity_max_time_window():
    """Extremal aggregate + time window: expiries change measures without a
    write touching the reader — the fused eval must still see them."""
    _, _, basis = _basis(seed=5)
    eng = _engine(basis, agg="max", spec=WindowSpec("time", 3.0, capacity=8))
    push, poll = _drive_parity(
        eng, AlertSpec(above=6.0, below=0.5, hysteresis=0.25),
        _batches(eng, n_batches=30, arrival=16, seed=11))
    assert push
    assert push == poll


def test_parity_delta_predicate_with_debounce():
    _, _, basis = _basis(seed=9)
    eng = _engine(basis)
    push, poll = _drive_parity(
        eng, AlertSpec(delta=4.0, debounce=3.0),
        _batches(eng, n_batches=24, arrival=32, seed=2))
    assert push
    assert push == poll


def test_parity_vector_payload_component():
    """Vector-valued windows: the alert predicates on one payload lane."""
    _, _, basis = _basis(seed=4)
    eng = _engine(basis, agg="sum", value_dim=3,
                  spec=WindowSpec("tuple", 4, value_dim=3))
    push, poll = _drive_parity(
        eng, AlertSpec(above=9.0, component=2),
        _batches(eng, n_batches=20, arrival=24, value_dim=3, seed=13))
    assert push
    assert push == poll


def test_parity_per_reader_threshold_arrays():
    _, _, basis = _basis(seed=6)
    eng = _engine(basis)
    bases = _alert_bases(eng)
    rng = np.random.default_rng(0)
    spec = AlertSpec(above=rng.uniform(4.0, 14.0, len(bases)).astype(
        np.float32))
    push, poll = _drive_parity(
        eng, spec, _batches(eng, n_batches=24, arrival=32, seed=21),
        bases=bases)
    assert push
    assert push == poll


def test_overflow_recovers_exact_fired_set():
    """A batch firing more than the compact capacity K must still report the
    exact set (dense fallback), flagged with overflow=True."""
    _, _, basis = _basis(seed=8)
    eng = _engine(basis)
    # above=-1 + a first batch touching many readers => mass fire through a
    # 4-slot compact buffer
    bases = _alert_bases(eng)
    al = AlertSet(cap=4)
    al.register(0, AlertSpec(above=-1.0), bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    oracle = PollOracle(al)
    oracle.resync(eng)
    fired_poll = []
    for ids, vals in _batches(eng, n_batches=6, arrival=64, seed=3, lo=1):
        eng.write_batch(ids, vals, batch_size=len(ids))
        ob = oracle.poll(eng, float(eng._now_host) - 1.0)
        if len(ob):
            fired_poll.append(ob)
    al.collect()
    push = al.pop_fired()
    assert any(b.overflow for b in push)
    assert max(len(b) for b in push) > 4
    assert _flat(push) == _flat(fired_poll)
    eng.alerts = None


# ----------------------------------------------- state-machine unit semantics
def _mk_state(n, **over):
    cols = {
        "active": np.ones(n, bool),
        "armed": np.ones(n, bool),
        "hi": np.full(n, np.inf, np.float32),
        "lo": np.full(n, -np.inf, np.float32),
        "dthr": np.full(n, np.inf, np.float32),
        "hys": np.zeros(n, np.float32),
        "deb": np.zeros(n, np.float32),
        "comp": np.zeros(n, np.int32),
        "last_fire": np.full(n, -np.inf, np.float32),
        "ref": np.zeros(n, np.float32),
        "last_m": np.zeros(n, np.float32),
    }
    for k, v in over.items():
        cols[k] = np.asarray(v, cols[k].dtype)
    return AlertState(**{k: jax.device_put(v) for k, v in cols.items()})


def _eval_seq(state, measures, cap=8):
    """Feed a per-tick measure sequence for one row through alert_eval via a
    sum aggregate (finalize = identity); return the fire ticks."""
    agg = make_aggregate("sum")
    fires = []
    for t, m in enumerate(measures):
        pao = jnp.full((1, agg.pao_dim), np.float32(m))
        state, count, idx, vals, fired, _ = alert_eval(
            agg, state, pao, jnp.float32(t), cap)
        if int(count):
            fires.append((t, float(np.asarray(vals)[0])))
    return fires


def test_hysteresis_one_fire_per_excursion():
    """A reader flapping just across the threshold fires once; it must drop
    back inside by the hysteresis margin before it can fire again."""
    st0 = _mk_state(1, hi=[5.0], hys=[1.0], last_m=[0.0], ref=[0.0])
    #        fire   flap (never re-arms: m stays > hi - hys = 4) re-arm  fire
    seq = [6.0, 4.5, 6.0, 4.5, 6.0, 3.0, 7.0]
    fires = _eval_seq(st0, seq)
    assert [t for t, _ in fires] == [0, 6]


def test_debounce_spaces_fires():
    st0 = _mk_state(1, dthr=[0.5], deb=[3.0], last_m=[0.0], ref=[0.0])
    # every tick trips the delta predicate; debounce admits every 3rd tick
    seq = [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0]
    fires = _eval_seq(st0, seq)
    assert [t for t, _ in fires] == [0, 3, 6]


def test_delta_ref_rebases_on_fire():
    st0 = _mk_state(1, dthr=[3.0], last_m=[0.0], ref=[0.0])
    # 0 -> 4 fires (|4-0|>3, ref := 4); 4 -> 6 quiet; 6 -> 8 fires (|8-4|>3)
    fires = _eval_seq(st0, [4.0, 6.0, 8.0])
    assert [t for t, _ in fires] == [0, 2]
    assert fires[1][1] == 8.0


def test_unchanged_measure_never_fires():
    st0 = _mk_state(1, hi=[1.0], last_m=[5.0], ref=[5.0], armed=[True])
    # measure sits above the threshold but never *changes* => no fire
    assert _eval_seq(st0, [5.0, 5.0, 5.0]) == []


# ------------------------------------------------------------ hypothesis sweep
@settings(max_examples=20, deadline=None)
@given(
    agg=st.sampled_from(["sum", "max"]),
    window=st.sampled_from([WindowSpec("tuple", 4),
                            WindowSpec("time", 3.0, capacity=8)]),
    above=st.floats(2.0, 20.0),
    hys=st.floats(0.0, 2.0),
    deb=st.floats(0.0, 4.0),
    seed=st.integers(0, 50),
)
def test_parity_sweep(agg, window, above, hys, deb, seed):
    _, _, basis = _basis(seed=3)
    eng = _engine(basis, agg=agg, spec=window)
    push, poll = _drive_parity(
        eng, AlertSpec(above=np.float32(above), hysteresis=float(hys),
                       debounce=float(deb)),
        _batches(eng, n_batches=16, arrival=24, seed=seed))
    assert push == poll


# --------------------------------------------------------------- churn parity
def test_parity_across_structural_churn():
    """Edge churn mid-stream: alerted readers follow their node through the
    patch, retired rows drop, and parity with a resynced oracle holds."""
    g = rmat_graph(150, 900, seed=3)
    sess = EagrSession(g, seed=0, ingest_batch=32, ingest_depth=2)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4),
                            continuous=True))
    ah = q.on_threshold(above=8.0, hysteresis=0.5)
    eng = q.group.engine
    oracle = PollOracle(eng.alerts)
    oracle.resync(eng)
    rng = np.random.default_rng(1)
    push, poll = [], []

    def drive(steps):
        for _ in range(steps):
            ids = rng.integers(0, 150, size=32)
            vals = rng.integers(0, 8, 32).astype(np.float32)
            sess.update(ids, vals)
            if sess._pipeline is not None:
                sess._pipeline.flush()
            push.extend(sess.drain_fired())
            ob = oracle.poll(eng, float(eng._now_host) - 1.0)
            if len(ob):
                poll.append(ob)

    drive(8)
    n_before = eng.alerts.n_alerts
    for k in range(6):  # interleave structural churn with the stream
        sess.add_edge(int(rng.integers(0, 150)), int(rng.integers(0, 150)))
    sess.flush()
    oracle2 = PollOracle(eng.alerts)   # oracle re-seeds from carried state
    oracle2.resync(eng)
    oracle = oracle2
    drive(8)
    assert _flat(push) == _flat(poll)
    assert push, "churn parity fixture never fired"
    # dynamic (unscoped) registration adopted any churn-added readers
    assert eng.alerts.n_alerts >= n_before
    sess.unregister_alert(ah)


def test_dynamic_registration_adopts_new_readers():
    g = rmat_graph(80, 400, seed=2)
    sess = EagrSession(g, seed=0)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4),
                            continuous=True))
    q.on_threshold(above=1e9)  # readers=None on an unscoped query = dynamic
    eng = q.group.engine
    n0 = eng.alerts.n_alerts
    assert n0 == len(_alert_bases(eng))
    # a brand-new node with in-edges becomes a reader; the spec must follow
    sess.add_node(80, in_neighbors=[0, 1, 2])
    sess.flush()
    eng = q.group.engine
    assert eng.alerts.n_alerts > n0
    assert 80 in eng.alerts._base.tolist()


# ------------------------------------------------------ steady-state discipline
def test_fused_step_keeps_one_trace():
    from repro.streams.alerts import _alert_write

    _, _, basis = _basis(seed=3)
    eng = _engine(basis)
    bases = _alert_bases(eng)
    al = AlertSet()
    al.register(0, AlertSpec(above=20.0), bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    _alert_write._clear_cache()
    for ids, vals in _batches(eng, n_batches=12, arrival=32):
        eng.write_batch(ids, vals, batch_size=32)
    assert _alert_write._cache_size() == 1
    al.collect()
    eng.alerts = None


def test_pipeline_steady_state_no_host_transfers():
    """The fused write+eval through the ingest ring must stay transfer-clean:
    fired-set marks are recorded at dispatch and read back only at slot
    reuse, never as an implicit host->device upload."""
    _, _, basis = _basis(seed=3)
    eng = _engine(basis)
    bases = _alert_bases(eng)
    al = AlertSet()
    al.register(0, AlertSpec(above=15.0), bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    pipe = IngestPipeline([eng], depth=2, device_batch=32)
    batches = _batches(eng, n_batches=12, arrival=32, seed=5)
    for ids, vals in batches[:6]:   # warm: compile + wrap the ring once
        pipe.submit(ids, vals)
    with jax.transfer_guard_host_to_device("disallow"):
        for ids, vals in batches[6:]:
            pipe.submit(ids, vals)
        pipe.flush()
    assert al.seq_done == al.seq and al.pending == 0
    al.pop_fired()
    eng.alerts = None


def test_ring_boundary_collects_fired_sets():
    """Fired sets land host-side at ring-slot reuse without any explicit
    drain; an interleaved user drain must not double-count (seq marks)."""
    _, _, basis = _basis(seed=3)
    eng = _engine(basis)
    bases = _alert_bases(eng)
    al = AlertSet()
    al.register(0, AlertSpec(above=8.0), bases.tolist(), dynamic=False)
    eng.attach_alerts(al)
    pipe = IngestPipeline([eng], depth=2, device_batch=32)
    seen = []
    for i, (ids, vals) in enumerate(
            _batches(eng, n_batches=16, arrival=32, seed=9)):
        pipe.submit(ids, vals)
        if i == 7:       # user drains mid-ring: collect() races the marks
            al.collect()
        seen.extend(al.pop_fired())
    pipe.flush()
    seen.extend(al.pop_fired())
    assert al.seq == al.seq_done
    assert sum(len(b) for b in seen) > 0
    # every dispatched step was collected exactly once
    assert len({float(b.now) for b in seen}) == len(seen)
    eng.alerts = None


# ------------------------------------------------------------- stacked engines
def test_stacked_fired_sets_match_single_engine():
    from repro.distributed.eagr_shard import partition_overlay
    from repro.distributed.stacked import StackedShardedEngine

    g = rmat_graph(200, 1200, seed=9)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=3, seed=0)
    dec = np.full(ov.n_nodes, D.PUSH, np.int64)
    agg, spec = make_aggregate("sum"), WindowSpec("tuple", 4)
    single = EagrEngine(ov, dec, agg, spec)
    sharded = partition_overlay(ov, dec, n_shards=4, seed=0)
    stacked = StackedShardedEngine(sharded, agg, spec)

    bases = _alert_bases(single)
    aspec = AlertSpec(above=10.0, hysteresis=0.5)
    for e in (single, stacked):
        al = AlertSet()
        al.register(0, aspec, bases.tolist(), dynamic=False)
        e.attach_alerts(al)

    rng = np.random.default_rng(4)
    for _ in range(16):
        ids = rng.choice(bp.writers, 64)
        vals = rng.integers(0, 8, 64).astype(np.float32)
        single.write_batch(ids, vals, batch_size=64)
        stacked.write_batch(ids, vals, batch_size=64)
    single.alerts.collect()
    stacked.alerts.collect()
    a = _flat(single.alerts.pop_fired())
    b = _flat(stacked.alerts.pop_fired())
    assert a, "stacked parity fixture never fired"
    assert a == b
    single.alerts = None
    stacked.alerts = None


# ----------------------------------------------------------- session API edges
def test_register_alert_rejects_pull_readers():
    g = rmat_graph(150, 900, seed=3)
    sess = EagrSession(g, seed=0)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    eng = q.group.engine
    pull_readers = [int(b) for b in _alert_bases(eng)
                    if eng.plan.decision[
                        eng.plan.routes.reader_node[b]] != D.PUSH]
    if not pull_readers:
        pytest.skip("mincut made every reader PUSH on this fixture")
    with pytest.raises(ValueError, match="PULL-decided"):
        sess.register_alert(q, above=5.0, readers=pull_readers[:4])
    assert eng.alerts is None  # rejected registration fully rolled back


def test_register_alert_rejects_topk_and_bad_component():
    g = rmat_graph(80, 400, seed=2)
    sess = EagrSession(g, seed=0)
    qk = sess.register(Query(agg=make_aggregate("topk", k=3, domain=16),
                             window=WindowSpec("tuple", 4), continuous=True))
    with pytest.raises(ValueError, match="topk"):
        qk.on_threshold(above=1.0)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4),
                            continuous=True))
    with pytest.raises(ValueError, match="component"):
        q.on_threshold(above=1.0, component=5)


def test_one_predicate_per_reader_row():
    g = rmat_graph(80, 400, seed=2)
    sess = EagrSession(g, seed=0)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4),
                            continuous=True))
    eng = q.group.engine
    bases = _alert_bases(eng)[:4].tolist()
    sess.register_alert(q, above=5.0, readers=bases)
    with pytest.raises(ValueError, match="already carry an alert"):
        sess.register_alert(q, below=0.0, readers=bases[:1])


def test_unregister_last_alert_detaches_eval():
    from repro.streams.alerts import _alert_write

    g = rmat_graph(80, 400, seed=2)
    sess = EagrSession(g, seed=0)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4),
                            continuous=True))
    eng = q.group.engine
    ah = sess.register_alert(q, above=5.0,
                             readers=_alert_bases(eng)[:4].tolist())
    assert eng.alerts is not None and eng.alerts.n_alerts == 4
    sess.unregister_alert(ah)
    assert eng.alerts is None
    with pytest.raises(ValueError, match="unknown alert handle"):
        sess.unregister_alert(ah)


def test_alert_eval_kill_switch(monkeypatch):
    monkeypatch.setenv("EAGR_ALERT_EVAL", "0")
    _, _, basis = _basis(seed=3)
    eng = _engine(basis)
    al = AlertSet()
    al.register(0, AlertSpec(above=-1.0), _alert_bases(eng).tolist(),
                dynamic=False)
    eng.attach_alerts(al)
    for ids, vals in _batches(eng, n_batches=4, arrival=32, lo=1):
        eng.write_batch(ids, vals, batch_size=32)
    al.collect()
    assert not al.pop_fired()   # registered but detached: nothing fires
    eng.alerts = None


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrips_armed_and_debounce_state():
    g = rmat_graph(120, 600, seed=3)
    sess = EagrSession(g, seed=0, ingest_batch=48, ingest_depth=2)
    q = sess.register(Query(agg="sum", window=WindowSpec("tuple", 8),
                            continuous=True))
    ah = q.on_threshold(above=4.0, hysteresis=0.5, debounce=2.0)
    eng = q.group.engine
    rng = np.random.default_rng(0)
    for _ in range(12):
        ids = rng.integers(0, 120, size=48)
        sess.update(ids, rng.random(48).astype(np.float32) * 2.0)
    sess.drain_fired()
    with tempfile.TemporaryDirectory() as d:
        sess.ckpt_dir = d
        sess.save(blocking=True)
        restored = EagrSession.restore(d, graph=g)
        (q2,) = restored.queries
        e2 = q2.group.engine
        al2 = e2.alerts
        assert al2 is not None and al2.n_alerts == eng.alerts.n_alerts
        assert [a.aid for a in restored.alerts] == [ah.aid]
        assert restored.alerts[0].spec.debounce == 2.0
        eng.alerts._pull_dynamic()
        al2._pull_dynamic()
        for f in ("armed", "last_fire", "ref", "last_m"):
            np.testing.assert_array_equal(eng.alerts._dyn[f], al2._dyn[f])
        # restored stream continues in lockstep with the original
        push_a, push_b = [], []
        for _ in range(8):
            ids = rng.integers(0, 120, size=48)
            vals = rng.random(48).astype(np.float32) * 2.0
            sess.update(ids, vals)
            restored.update(ids, vals)
        push_a = _flat(sess.drain_fired())
        push_b = _flat(restored.drain_fired())
        assert push_a == push_b
