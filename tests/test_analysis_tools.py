"""Dry-run/roofline analysis tooling: HLO collective parsing with while-loop
trip counts, computation-block splitting, analytic model-FLOPs sanity."""
import numpy as np
import pytest

from repro.launch.dryrun import (
    _computation_blocks,
    _effective_multipliers,
    collective_bytes,
)

HLO = """\
HloModule jit_step

%region_cond (p0: (s32[], f32[4])) -> pred[] {
  %p0 = (s32[], f32[4]) parameter(0)
  %gte = s32[] get-tuple-element(%p0), index=0
  %c7 = s32[] constant(7)
  ROOT %lt = pred[] compare(%gte, %c7), direction=LT
}

%region_body (p0: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p0 = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p0), index=1
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%add_comp
  %i = s32[] get-tuple-element(%p0), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]) tuple(%ip, %ar)
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[4]) -> f32[4] {
  %arg = f32[4] parameter(0)
  %ag = f32[32]{0} all-gather(%arg), dimensions={0}
  %zero = s32[] constant(0)
  %tup = (s32[], f32[4]) tuple(%zero, %arg)
  %w = (s32[], f32[4]) while(%tup), condition=%region_cond, body=%region_body
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""


def test_computation_blocks():
    comps = _computation_blocks(HLO)
    assert set(comps) == {"region_cond", "region_body", "add_comp", "main"}
    assert any("while(" in ls for ls in comps["main"])


def test_effective_multipliers_trip_count():
    comps = _computation_blocks(HLO)
    mult = _effective_multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["region_body"] == 7.0


def test_collective_bytes_with_loops():
    coll = collective_bytes(HLO)
    # entry all-gather counted once: f32[32] = 128 B
    assert coll["all-gather"] == 128.0
    # loop all-reduce f32[4] = 16 B x 7 trips
    assert coll["all-reduce"] == 16.0 * 7


def test_model_flops_lm_magnitudes():
    from repro.launch.roofline import model_flops
    mf, n_active = model_flops("granite-3-2b", "train_4k")
    # ~2.5e9 active params, 1.05e6 tokens: 6ND ~ 1.6e16 + attention
    assert 1e16 < mf < 1e17
    assert 2e9 < n_active < 4e9
    mf_moe, n_act_moe = model_flops("arctic-480b", "train_4k")
    # arctic active ~ 17B + dense residual: far below total 480B
    assert n_act_moe < 6e10
    mf_d, _ = model_flops("granite-3-2b", "decode_32k")
    assert mf_d < mf / 100      # one token vs a full batch of sequences


def test_model_flops_every_cell_defined():
    from repro.configs import all_cells
    from repro.launch.roofline import model_flops
    for a, s in all_cells():
        mf, _ = model_flops(a, s)
        assert mf and mf > 0, (a, s)


def test_roofline_analyze_shapes():
    from repro.launch.roofline import analyze, format_table
    rec = dict(arch="granite-3-2b", shape="train_4k",
               mesh={"data": 16, "model": 16}, temp_bytes=10 ** 9,
               arg_bytes=0, out_bytes=0, alias_bytes=0,
               flops=1e12, bytes_accessed=1e11,
               collective_bytes={"all-reduce": 1e9},
               notes="accum=4 opt=adamw step_multiplier=4")
    rows = analyze([rec])
    r = rows[0]
    # step_multiplier applied
    np.testing.assert_allclose(r["t_compute_s"], 4e12 / 197e12)
    np.testing.assert_allclose(r["t_collective_s"], 4e9 / 100e9)
    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert r["roofline_fraction"] and 0 < r["roofline_fraction"]
    assert "granite" in format_table(rows)
