"""Per-architecture smoke tests (the required deliverable): every assigned
architecture instantiates a REDUCED config of the same family and runs one
forward/train step on CPU, asserting output shapes + no NaNs. The full
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_cells, get_arch

CELLS = all_cells()


def test_forty_cells_assigned():
    assert len(CELLS) == 40
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch_id,shape", CELLS,
                         ids=[f"{a}-{s}" for a, s in CELLS])
def test_smoke_cell(arch_id, shape):
    arch = get_arch(arch_id)
    plan = arch.build_smoke(shape)
    out = jax.jit(plan.fn)(*plan.args) if plan.args else plan.fn()
    leaves = [x for x in jax.tree.leaves(out) if hasattr(x, "dtype")]
    assert leaves, "smoke cell produced no outputs"
    for x in leaves:
        if jnp.issubdtype(x.dtype, jnp.floating):
            assert bool(jnp.isfinite(x).all()), f"{arch_id}/{shape}: NaN/Inf"
    if plan.kind == "train":
        params, opt_state, metrics = out
        assert np.isfinite(float(metrics["loss"]))
        # one step actually changed the parameters
        before = jax.tree.leaves(plan.args[0])
        after = jax.tree.leaves(params)
        assert any(not np.allclose(np.asarray(a), np.asarray(b))
                   for a, b in zip(before, after))


def test_eagr_reference_smoke():
    arch = get_arch("eagr")
    plan = arch.build_smoke("stream_mixed")
    out = plan.fn()
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full (non-smoke) configs carry the exact assigned hyperparameters."""
    arch = get_arch(arch_id)
    assigned = {
        "granite-3-2b": dict(n_layers=40, d_model=2048, n_heads=32,
                             n_kv_heads=8, d_ff=8192, vocab=49155),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                               n_kv_heads=8, d_ff=8192, vocab=92544),
        "command-r-plus-104b": dict(n_layers=64, d_model=12288, n_heads=96,
                                    n_kv_heads=8, d_ff=33792, vocab=256000),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128, top_k=2),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48,
                          n_kv_heads=8, d_ff=10752, vocab=100352,
                          n_experts=16, top_k=4),
    }
    if arch_id in assigned:
        import repro.configs as C
        import importlib
        mod = importlib.import_module(C._MODULES[arch_id])
        cfg = mod.ARCH  # ArchSpec
        from repro.configs.lm_common import LMArch  # noqa: F401
        # reach the TransformerConfig through the build closure's lm
        lm_cfg = mod.ARCH.build.__closure__
        # simpler: import the module-level config via its source LMArch
        tcfg = [c.cell_contents for c in lm_cfg
                if hasattr(c.cell_contents, "cfg")][0].cfg
        for k, v in assigned[arch_id].items():
            assert getattr(tcfg, k) == v, (arch_id, k)
    elif arch_id == "graphcast":
        from repro.configs.graphcast import CFG
        assert (CFG.n_layers, CFG.d_hidden, CFG.mesh_refinement, CFG.n_vars) \
            == (16, 512, 6, 227)
    elif arch_id == "gat-cora":
        from repro.configs.gat_cora import _mk
        c = _mk(dict(d_feat=1433, classes=7), False)
        assert (c.n_layers, c.d_hidden, c.n_heads) == (2, 8, 8)
    elif arch_id == "nequip":
        from repro.configs.nequip import CFG
        assert (CFG.n_layers, CFG.d_hidden, CFG.l_max, CFG.n_rbf,
                CFG.cutoff) == (5, 32, 2, 8, 5.0)
    elif arch_id == "gatedgcn":
        from repro.configs.gatedgcn import _mk
        c = _mk(dict(d_feat=100, classes=47), False)
        assert (c.n_layers, c.d_hidden) == (16, 70)
    elif arch_id == "dien":
        from repro.configs.dien import CFG
        assert (CFG.embed_dim, CFG.seq_len, CFG.gru_dim, CFG.mlp_dims) \
            == (18, 100, 108, (200, 80))
