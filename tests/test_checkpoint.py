"""Durable sessions: checkpoint/restore of live EAGr state.

The contract under test (PR 9):

  * ``EagrSession.save`` -> ``EagrSession.restore`` is BIT-identical — the
    restored session answers every read exactly as the saved one would, for
    scalar and vector aggregates, tuple and time windows, single-engine and
    stacked-sharded deployments — without re-running construction or plan
    compilation;
  * restore may RESHARD (N -> M shards, or to a single engine): window rings
    redistribute by base writer id, plans recompile over the saved master
    overlay, answers stay exact;
  * a process killed mid-save (before or after the manifest lands in the
    temp directory) never corrupts the latest committed checkpoint;
  * ``SessionRecoveryDriver`` replays the event stream deterministically
    from the recorded sequence number — a crashed-and-recovered run is
    bit-identical to an uninterrupted one;
  * the lifecycle satellites: ``stats()`` / ``SessionStats``, typed
    ``FlushReport`` / ``AdaptReport`` (back-compatible with list/int use),
    deprecated stat aliases.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.window import WindowSpec
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import SessionRecoveryDriver
from repro.graphs.generators import rmat_graph
from repro.session import (
    AdaptReport,
    EagrSession,
    FlushReport,
    Query,
    SessionStats,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _graph():
    return rmat_graph(90, 500, seed=7)


def _drive(sess, handle, *, rounds=5, n=20, seed=3, vd=1, integral=False):
    """Deterministic traffic; returns a read probe + its pre-save answer."""
    rng = np.random.default_rng(seed)
    W = np.asarray(sess.writers)
    for _ in range(rounds):
        ids = rng.choice(W, n)
        if integral:
            vals = rng.integers(-4, 5, size=(n, vd) if vd > 1 else n)
            vals = vals.astype(np.float32)
        elif vd > 1:
            vals = rng.normal(size=(n, vd)).astype(np.float32)
        else:
            vals = np.abs(rng.normal(size=n)).astype(np.float32)
        sess.update(ids, vals)
    q = rng.choice(np.asarray(sess.readers), 16)
    return q, np.asarray(sess.read(handle, q))


# ------------------------------------------------------------ bit-identical
@pytest.mark.parametrize("qkw,vd", [
    (dict(agg="sum", window=WindowSpec("tuple", 4)), 1),
    (dict(agg="max", window=WindowSpec("time", 3.0, capacity=8)), 1),
    (dict(agg="topk", agg_kwargs={"k": 3, "domain": 32},
          window=WindowSpec("tuple", 6, capacity=8)), 1),
    (dict(agg="sum", agg_kwargs={"value_dim": 3},
          window=WindowSpec("tuple", 4, value_dim=3)), 3),
    (dict(agg="avg", window=WindowSpec("tuple", 5, capacity=8),
          continuous=True), 1),
], ids=["sum-tuple", "max-time", "topk", "sum-vec3", "avg-continuous"])
def test_roundtrip_bit_identical_single(tmp_path, qkw, vd):
    sess = EagrSession(_graph())
    h = sess.register(Query(**qkw))
    q, want = _drive(sess, h, vd=vd)
    step = sess.save(str(tmp_path), blocking=True)
    assert step == sess._seq

    r = EagrSession.restore(str(tmp_path))
    (h2,) = r.queries
    assert r._seq == sess._seq
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)), want)

    # continued identical traffic stays in lockstep (exercises window
    # advance, expiry deadlines for time windows, PAO reuse)
    rng = np.random.default_rng(11)
    W = np.asarray(sess.writers)
    for _ in range(3):
        ids = rng.choice(W, 10)
        vals = rng.normal(size=(10, vd)).astype(np.float32) if vd > 1 \
            else np.abs(rng.normal(size=10)).astype(np.float32)
        sess.update(ids, vals)
        r.update(ids, vals)
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)),
                                  np.asarray(sess.read(h, q)))


def test_roundtrip_sharded_with_churn(tmp_path):
    sess = EagrSession(_graph(), shards=4)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    W, R = np.asarray(sess.writers), np.asarray(sess.readers)
    # structural churn BEFORE save: the checkpoint must carry the patched
    # per-shard overlays, not the construction-time partition
    sess.delete_edge(int(W[0]), int(R[3]))
    sess.add_edge(int(W[1]), int(R[3]))
    report = sess.flush()
    assert report.patched + report.recompiled >= 1
    q, want = _drive(sess, h)
    sess.save(str(tmp_path), blocking=True)

    r = EagrSession.restore(str(tmp_path))
    (h2,) = r.queries
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)), want)

    # post-restore churn: the lazily rebuilt journals must patch the
    # restored plans exactly as the original session's journals do
    new = int(max(W.max(), R.max())) + 1
    for s in (sess, r):
        s.add_node(new, out_readers=[int(R[2]), int(R[5])])
    rep_r = r.flush()
    assert rep_r.journal_nodes >= 1
    for s, hh in ((sess, h), (r, h2)):
        s.update(np.full(6, new, np.int64), np.ones(6, np.float32))
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)),
                                  np.asarray(sess.read(h, q)))


def test_restore_skips_construction_and_compile(tmp_path, monkeypatch):
    sess = EagrSession(_graph())
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    q, want = _drive(sess, h)
    sess.save(str(tmp_path), blocking=True)

    # a same-shape restore must never re-run VNM construction or plan
    # compilation — that is the whole recovery-time claim
    import repro.core.engine as engine_mod
    import repro.session as session_mod

    def boom(*a, **k):
        raise AssertionError("restore re-ran the cold path")

    monkeypatch.setattr(session_mod, "construct_vnm", boom)
    monkeypatch.setattr(engine_mod, "compile_plan", boom)
    r = EagrSession.restore(str(tmp_path))
    (h2,) = r.queries
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)), want)


# ---------------------------------------------------------------- resharding
@pytest.mark.parametrize("old,new", [(4, 2), (4, 8), (4, 0), (0, 2)],
                         ids=["4to2", "4to8", "4tosingle", "singleto2"])
def test_restore_with_resharding(tmp_path, old, new):
    sess = EagrSession(_graph(), shards=old or None)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    # integral values: resharding may legitimately change reduction order,
    # integer-valued float32 keeps every order exact
    q, want = _drive(sess, h, integral=True)
    sess.save(str(tmp_path), blocking=True)

    r = EagrSession.restore(str(tmp_path), shards=new)
    assert r.n_shards == new
    (h2,) = r.queries
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)), want)

    # the resharded session keeps serving: writes, reads, churn
    rng = np.random.default_rng(5)
    W = np.asarray(sess.writers)
    for _ in range(2):
        ids = rng.choice(W, 12)
        vals = rng.integers(0, 5, size=12).astype(np.float32)
        sess.update(ids, vals)
        r.update(ids, vals)
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)),
                                  np.asarray(sess.read(h, q)))


def test_reshard_time_window_expiry(tmp_path):
    """Extremal aggregate + time window across a reshard: the rebuilt expiry
    deadlines must still force re-evaluation when entries age out."""
    sess = EagrSession(_graph(), shards=2)
    h = sess.register(Query(agg="max",
                            window=WindowSpec("time", 3.0, capacity=8)))
    q, _ = _drive(sess, h, integral=True)
    sess.save(str(tmp_path), blocking=True)
    r = EagrSession.restore(str(tmp_path), shards=0)
    (h2,) = r.queries
    # advance the clock past the window with writes to a single writer: old
    # maxima must expire identically on both sides
    w = int(np.asarray(sess.writers)[0])
    for _ in range(6):
        sess.update(np.asarray([w]), np.zeros(1, np.float32))
        r.update(np.asarray([w]), np.zeros(1, np.float32))
        np.testing.assert_array_equal(np.asarray(r.read(h2, q)),
                                      np.asarray(sess.read(h, q)))


# ------------------------------------------------------------ property-based
@given(st.sampled_from(["sum", "max", "count"]),
       st.sampled_from([0, 2]),
       st.integers(0, 3))
@settings(max_examples=8, deadline=None)
def test_roundtrip_parity_property(tmp_path_factory, agg, shards, seed):
    tmp = tmp_path_factory.mktemp("ckpt")
    sess = EagrSession(rmat_graph(70, 360, seed=9), shards=shards or None)
    spec = WindowSpec("time", 2.0, capacity=6) if agg == "max" \
        else WindowSpec("tuple", 3)
    h = sess.register(Query(agg=agg, window=spec))
    q, want = _drive(sess, h, rounds=4, n=12, seed=seed, integral=True)
    sess.save(str(tmp), blocking=True)
    r = EagrSession.restore(str(tmp))
    (h2,) = r.queries
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)), want)


# ------------------------------------------------------------- crash safety
_CRASH_CHILD = """
import os, sys
import numpy as np
from repro.graphs.generators import rmat_graph
from repro.session import EagrSession, Query
from repro.core.window import WindowSpec

g = rmat_graph(90, 500, seed=7)
sess = EagrSession(g)
sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
rng = np.random.default_rng(0)
W = np.asarray(sess.writers)
sess.update(rng.choice(W, 20), np.ones(20, np.float32))
sess.save(sys.argv[1], blocking=True)        # step 1 commits
sess.update(rng.choice(W, 20), np.ones(20, np.float32))
os.environ["EAGR_CKPT_CRASH"] = sys.argv[2]  # arm the fault
sess.save(sys.argv[1], blocking=True)        # step 2 dies mid-write
raise SystemExit("unreachable: crash hook did not fire")
"""


@pytest.mark.parametrize("stage", ["arrays", "manifest"])
def test_kill_mid_save_preserves_committed_manifest(tmp_path, stage):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("EAGR_CKPT_CRASH", None)
    p = subprocess.run([sys.executable, "-c", _CRASH_CHILD,
                        str(tmp_path), stage],
                       env=env, capture_output=True, text=True, timeout=300)
    assert p.returncode == 17, p.stderr[-2000:]
    # the aborted step must not be listed as restorable...
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.all_steps() == [1]
    # ...and the previous committed checkpoint restores cleanly
    r = EagrSession.restore(str(tmp_path))
    assert r._seq == 1
    (h,) = r.queries
    q = np.asarray(r.readers)[:8]
    assert np.isfinite(np.asarray(r.read(h, q))).all()


def test_recovery_driver_replay_determinism(tmp_path):
    g = _graph()

    def make_session():
        s = EagrSession(g)
        s.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
        return s

    W = np.asarray(make_session().writers)

    def make_batch(seq):
        rng = np.random.default_rng(1000 + seq)
        return rng.choice(W, 16), rng.normal(size=16).astype(np.float32)

    d_fault, d_clean = str(tmp_path / "a"), str(tmp_path / "b")
    drv = SessionRecoveryDriver(make_session, make_batch, d_fault,
                                ckpt_every=8)
    s_fault = drv.run(30, fail_at={13, 27})
    assert drv.report.restarts == 2
    assert s_fault._seq == 30

    s_clean = SessionRecoveryDriver(make_session, make_batch, d_clean,
                                    ckpt_every=8).run(30)
    (hf,), (hc,) = s_fault.queries, s_clean.queries
    q = np.asarray(s_clean.readers)[:20]
    np.testing.assert_array_equal(np.asarray(s_fault.read(hf, q)),
                                  np.asarray(s_clean.read(hc, q)))


def test_auto_checkpoint_and_gc(tmp_path):
    sess = EagrSession(_graph(), ckpt_dir=str(tmp_path), ckpt_every=2,
                       ckpt_keep=2)
    sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    W = np.asarray(sess.writers)
    for _ in range(7):
        sess.update(W[:5], np.ones(5, np.float32))
    sess.wait_for_checkpoint()
    steps = CheckpointManager(str(tmp_path)).all_steps()
    assert steps[-1] == 6          # every 2nd update batch checkpointed
    assert len(steps) <= 2         # keep-count enforced by gc
    assert sess.stats().last_checkpoint_step == 6


def test_save_quiesces_pending_churn(tmp_path):
    sess = EagrSession(_graph())
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    q, _ = _drive(sess, h)
    W, R = np.asarray(sess.writers), np.asarray(sess.readers)
    sess.add_edge(int(W[0]), int(R[1]))
    assert sess._pending
    sess.save(str(tmp_path), blocking=True)    # must flush first
    assert not sess._pending
    r = EagrSession.restore(str(tmp_path))
    (h2,) = r.queries
    np.testing.assert_array_equal(np.asarray(r.read(h2, q)),
                                  np.asarray(sess.read(h, q)))


# ------------------------------------------------------ lifecycle satellites
def test_stats_and_typed_reports(tmp_path):
    sess = EagrSession(_graph(), ingest_depth=2, ingest_batch=64)
    h = sess.register(Query(agg="sum", window=WindowSpec("tuple", 4)))
    q, _ = _drive(sess, h, rounds=3)

    stats = sess.stats()
    assert isinstance(stats, SessionStats)
    assert stats.n_queries == 1 and stats.n_engine_groups == 1
    assert stats.updates == sess._seq == 3
    assert stats.frontier.get("steps", 0) >= 1
    assert stats.ingest is not None and stats.ingest.events_in == 60
    assert stats.construction is sess.overlay_stats
    # deprecated alias stays a thin view of the same counters, but warns
    with pytest.warns(DeprecationWarning, match="stats\\(\\).ingest"):
        assert sess.ingest_stats is stats.ingest

    W, R = np.asarray(sess.writers), np.asarray(sess.readers)
    r0 = int(R[1])
    w0 = next(int(w) for w in W if int(w) not in sess.neighborhood(r0))
    sess.add_edge(w0, r0)
    report = sess.flush()
    assert isinstance(report, FlushReport)
    # back-compat: still the per-group result list
    (res,) = report
    assert res is None or not res.recompiled
    assert report.patched + report.recompiled + report.relayout >= 1

    flips = sess.adapt()
    assert isinstance(flips, AdaptReport)
    assert flips == sum(flips.per_group) and flips.flips == int(flips)
    assert flips + 0 == int(flips)  # int arithmetic holds

    # ingest counters survive save/restore
    sess.save(str(tmp_path), blocking=True)
    r = EagrSession.restore(str(tmp_path))
    assert r.stats().ingest.events_in == 60
    (h2,) = r.queries
    r.update(W[:4], np.ones(4, np.float32))
    assert r.stats().ingest.events_in == 64


def test_restore_errors(tmp_path):
    with pytest.raises(FileNotFoundError):
        EagrSession.restore(str(tmp_path / "empty"))
    sess = EagrSession(_graph())
    with pytest.raises(ValueError, match="no checkpoint directory"):
        sess.save()
    # a raw (non-session) checkpoint payload is rejected up front
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_payload(0, {"x": np.zeros(3)}, {}, blocking=True)
    with pytest.raises(ValueError, match="not an EagrSession payload"):
        EagrSession.restore(str(tmp_path))


def test_payload_manager_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    arrays = {"a.b": np.arange(6).reshape(2, 3),
              "c": np.float32([1.5, -2.0])}
    mgr.save_payload(3, arrays, {"k": [1, 2]}, blocking=True)
    got, objs, step = mgr.restore_payload()
    assert step == 3 and objs == {"k": [1, 2]}
    for k, v in arrays.items():
        np.testing.assert_array_equal(got[k], v)
        assert got[k].dtype == np.asarray(v).dtype
