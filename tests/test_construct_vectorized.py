"""Vectorized-vs-reference construction parity (PR 6).

The vectorized engine (rank-sorted rows + flat-array assembly) and the
object-based reference pipeline (``EAGR_CONSTRUCT_REFERENCE=1``) implement the
same semantics — frozen per-group item order, incremental detach/reinsert,
canonical tie-breaks — so for every variant they must produce *bit-identical*
overlays: same node kinds/origins, same in-edge lists, same signs, after
``pruned()``. Also pins the shingle hash values so the reader ordering (and
with it every downstream overlay) stays stable across rewrites.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bipartite import build_bipartite
from repro.core.shingles import (min_hashes_csr, shingle_order,
                                 shingle_order_csr, shingle_value)
from repro.core.vnm import construct_vnm
from repro.graphs.csr import CSRGraph
from repro.graphs.generators import powerlaw_graph, rmat_graph, small_example_graph

ALGOS = ["vnm", "vnm_a", "vnm_n", "vnm_d"]


def assert_same_overlay(a, b):
    assert a.kinds == b.kinds
    assert a.origin == b.origin
    assert a.in_edges == b.in_edges
    assert a.dup_insensitive == b.dup_insensitive


def assert_parity(bp, variant, *, max_iterations=4, seed=0):
    ov_f, st_f = construct_vnm(bp, variant=variant,
                               max_iterations=max_iterations, seed=seed)
    ov_r, st_r = construct_vnm(bp, variant=variant,
                               max_iterations=max_iterations, seed=seed,
                               reference=True)
    assert_same_overlay(ov_f, ov_r)
    assert st_f.iterations == st_r.iterations
    assert st_f.bicliques == st_r.bicliques
    assert st_f.chunk_sizes == st_r.chunk_sizes
    assert np.allclose(st_f.si_per_iteration, st_r.si_per_iteration)
    ov_f.validate(bp.reader_input_sets())
    return ov_f, st_f


# ------------------------------------------------------------- deterministic
@pytest.mark.parametrize("variant", ALGOS)
def test_parity_on_example(example_bipartite, variant):
    assert_parity(example_bipartite, variant)


@pytest.mark.parametrize("variant", ALGOS)
def test_parity_on_rmat(rmat_bipartite, variant):
    assert_parity(rmat_bipartite, variant)


@pytest.mark.parametrize("variant", ["vnm_a", "vnm_d"])
def test_parity_on_powerlaw(variant):
    bp = build_bipartite(powerlaw_graph(600, 4_000, seed=5))
    assert_parity(bp, variant)


def test_env_flag_selects_reference(monkeypatch, example_bipartite):
    monkeypatch.setenv("EAGR_CONSTRUCT_REFERENCE", "1")
    ov_env, _ = construct_vnm(example_bipartite, variant="vnm_a",
                              max_iterations=3, seed=0)
    monkeypatch.delenv("EAGR_CONSTRUCT_REFERENCE")
    ov_ref, _ = construct_vnm(example_bipartite, variant="vnm_a",
                              max_iterations=3, seed=0, reference=True)
    assert_same_overlay(ov_env, ov_ref)


def test_phase_seconds_breakdown(rmat_bipartite):
    _, stats = construct_vnm(rmat_bipartite, variant="vnm_a",
                             max_iterations=3, seed=0)
    assert set(stats.phase_seconds) == {"shingle", "chunk", "build", "mine",
                                        "apply", "assemble"}
    assert all(v >= 0.0 for v in stats.phase_seconds.values())
    # phases cover the bulk of the measured wall clock
    assert sum(stats.phase_seconds.values()) <= stats.seconds * 1.01


# ------------------------------------------------------------- property sweep
@st.composite
def random_bipartite(draw):
    n = draw(st.integers(8, 40))
    density = draw(st.floats(0.05, 0.5))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) < density
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    if src.size == 0:
        src, dst = np.array([0]), np.array([1])
    g = CSRGraph.from_edges(src, dst, n)
    return build_bipartite(g)


@settings(max_examples=40, deadline=None)
@given(random_bipartite(), st.sampled_from(ALGOS), st.integers(0, 3))
def test_property_vectorized_matches_reference(bp, variant, seed):
    assert_parity(bp, variant, max_iterations=3, seed=seed)


# ------------------------------------------------------------- shingles
def test_shingle_values_pinned():
    # values recorded from the pre-vectorization implementation: the reader
    # ordering (hence every constructed overlay) depends on them bit-for-bit
    assert [shingle_value(np.array([1, 2, 3]), s) for s in (0, 1, 7)] == [
        627405149472732430, 9716232063330790915, 4414019431610648415]
    assert shingle_value(np.array([0]), 0) == 12035550249420947055
    assert shingle_value(np.array([], dtype=np.int64), 5) == 0
    assert shingle_value(np.array([10**6, 42, 99999]), 12345) == \
        4157696482687128331


def test_shingle_order_pinned():
    lists = {3: np.array([1, 2, 3]), 0: np.array([2, 3, 4]),
             7: np.array([1, 2, 3]), 5: np.array([9])}
    assert shingle_order(lists, seed=0) == [3, 7, 0, 5]
    assert shingle_order(lists, n_hashes=3, seed=11) == [5, 3, 7, 0]


def test_batched_minhash_matches_scalar():
    rng = np.random.default_rng(3)
    lists = [np.unique(rng.integers(0, 500, rng.integers(0, 12)))
             for _ in range(50)]
    indptr = np.zeros(len(lists) + 1, dtype=np.int64)
    np.cumsum([a.size for a in lists], out=indptr[1:])
    values = np.concatenate(lists)
    mh = min_hashes_csr(indptr, values, n_hashes=3, seed=17)
    for i, a in enumerate(lists):
        for h in range(3):
            assert int(mh[i, h]) == shingle_value(a, 17 + h)


def test_csr_order_matches_dict_order():
    rng = np.random.default_rng(9)
    lists = {int(r): np.unique(rng.integers(0, 100, 5)) + 1
             for r in rng.permutation(60)[:30]}
    rids = np.fromiter(lists.keys(), dtype=np.int64)
    indptr = np.zeros(rids.size + 1, dtype=np.int64)
    np.cumsum([lists[int(r)].size for r in rids], out=indptr[1:])
    values = np.concatenate([lists[int(r)] for r in rids])
    got = shingle_order_csr(rids, indptr, values, seed=4)
    assert [int(x) for x in got] == shingle_order(lists, seed=4)


# ------------------------------------------------------------- generator
def test_powerlaw_generator_shape_and_tail():
    n, m = 20_000, 120_000
    g = powerlaw_graph(n, m, seed=1)
    assert g.n_nodes == n
    assert g.indices.size == g.indptr[-1]
    assert m * 0.75 <= g.n_edges <= m  # dedup/self-loop losses only
    bp = build_bipartite(g)
    indeg = np.array([v.size for v in bp.reader_inputs.values()])
    # power-law in-degrees: a heavy tail far above the mean, but most
    # readers stay small
    assert indeg.max() > 30 * indeg.mean()
    assert np.median(indeg) <= 2 * indeg.mean()


def test_powerlaw_generator_deterministic():
    a = powerlaw_graph(500, 3_000, seed=7)
    b = powerlaw_graph(500, 3_000, seed=7)
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
