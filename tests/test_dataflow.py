"""Dataflow decisions: optimality of the min-cut algorithm (vs brute force),
pruning soundness (Theorem 4.2), greedy validity, node splitting, adaptation.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.overlay import Overlay
from repro.core.vnm import construct_vnm
from repro.graphs.generators import rmat_graph
from repro.core.bipartite import build_bipartite


def _valid(overlay: Overlay, dec: np.ndarray) -> bool:
    """No edge from a PULL node into a PUSH node (paper §2.2.1)."""
    for dst in range(overlay.n_nodes):
        for src, _ in overlay.in_edges[dst]:
            if dec[src] == D.PULL and dec[dst] == D.PUSH:
                return False
    return all(dec[v] == D.PUSH for v in overlay.writer_nodes())


def _brute_force(overlay: Overlay, f_h, f_l, cost, window=1) -> float:
    push, pull = D.push_pull_costs(overlay, f_h, f_l, cost, window)
    writers = set(overlay.writer_nodes())
    free = [v for v in range(overlay.n_nodes) if v not in writers]
    best = np.inf
    for bits in itertools.product([D.PUSH, D.PULL], repeat=len(free)):
        dec = np.zeros(overlay.n_nodes, dtype=np.int64)
        for v, b in zip(free, bits):
            dec[v] = b
        if not _valid(overlay, dec):
            continue
        best = min(best, float(np.where(dec == D.PUSH, push, pull).sum()))
    return best


@st.composite
def small_overlay(draw):
    """Random small layered DAG overlay with frequencies."""
    n_w = draw(st.integers(2, 4))
    n_i = draw(st.integers(0, 3))
    n_r = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 99999))
    rng = np.random.default_rng(seed)
    ov = Overlay(kinds=[], origin=[], in_edges=[])
    ws = [ov.add_node("W", i) for i in range(n_w)]
    iis = []
    for j in range(n_i):
        v = ov.add_node("I", -1)
        srcs = rng.choice(ws + iis, size=rng.integers(1, 3), replace=False)
        for s in srcs:
            ov.add_edge(int(s), v)
        iis.append(v)
    for r in range(n_r):
        v = ov.add_node("R", 100 + r)
        pool = ws + iis
        srcs = rng.choice(pool, size=rng.integers(1, min(3, len(pool)) + 1),
                          replace=False)
        for s in srcs:
            ov.add_edge(int(s), v)
    wf = np.zeros(200)
    rf = np.zeros(200)
    wf[:n_w] = rng.integers(1, 50, n_w)
    rf[100:100 + n_r] = rng.integers(1, 50, n_r)
    return ov, wf, rf


@settings(max_examples=40, deadline=None)
@given(small_overlay(), st.sampled_from(["sum", "max"]))
def test_mincut_optimal_vs_bruteforce(ovwfrf, aggname):
    ov, wf, rf = ovwfrf
    ov = ov.pruned()
    if not ov.reader_nodes():
        return
    cost = D.cost_model_for(aggname)
    dec, _ = D.decide_mincut(ov, wf, rf, cost)
    assert _valid(ov, dec)
    f_h, f_l = D.compute_frequencies(ov, wf, rf)
    got = D.total_cost(ov, dec, f_h, f_l, cost)
    best = _brute_force(ov, f_h, f_l, cost)
    assert got <= best + 1e-6, (got, best)


@settings(max_examples=30, deadline=None)
@given(small_overlay())
def test_greedy_valid_and_never_better_than_mincut(ovwfrf):
    ov, wf, rf = ovwfrf
    ov = ov.pruned()
    if not ov.reader_nodes():
        return
    cost = D.cost_model_for("sum")
    dec_g = D.decide_greedy(ov, wf, rf, cost)
    assert _valid(ov, dec_g)
    dec_m, _ = D.decide_mincut(ov, wf, rf, cost)
    f_h, f_l = D.compute_frequencies(ov, wf, rf)
    assert (D.total_cost(ov, dec_m, f_h, f_l, cost)
            <= D.total_cost(ov, dec_g, f_h, f_l, cost) + 1e-6)


def test_pruning_preserves_optimality_and_shrinks(rmat_bipartite):
    ov, _ = construct_vnm(rmat_bipartite, variant="vnm_a", max_iterations=3)
    wf, rf = make_freqs(rmat_bipartite.n_base, seed=1)
    cost = D.cost_model_for("sum")
    dec, stats = D.decide_mincut(ov, wf, rf, cost)
    assert _valid(ov, dec)
    assert stats.pruned_fraction > 0.5  # paper fig 12: >86% pruned typically
    # all-push / all-pull are never better
    f_h, f_l = D.compute_frequencies(ov, wf, rf)
    c = D.total_cost(ov, dec, f_h, f_l, cost)
    all_push = np.full(ov.n_nodes, D.PUSH)
    all_pull = np.array([D.PUSH if ov.kinds[v] == "W" else D.PULL
                         for v in range(ov.n_nodes)])
    assert c <= D.total_cost(ov, all_push, f_h, f_l, cost) + 1e-6
    assert c <= D.total_cost(ov, all_pull, f_h, f_l, cost) + 1e-6


@pytest.mark.parametrize("ratio", [0.1, 1.0, 10.0])
def test_ratio_shifts_decisions(rmat_bipartite, ratio):
    """Write-heavy workloads should pull more; read-heavy should push more."""
    ov, _ = construct_vnm(rmat_bipartite, variant="vnm_a", max_iterations=3)
    wf, rf = make_freqs(rmat_bipartite.n_base, seed=2, ratio=ratio)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    assert _valid(ov, dec)


def test_split_nodes_reduces_cost(rmat_bipartite):
    ov, _ = construct_vnm(rmat_bipartite, variant="vnm_a", max_iterations=3)
    wf, rf = make_freqs(rmat_bipartite.n_base, seed=3)
    cost = D.cost_model_for("sum")
    dec, _ = D.decide_mincut(ov, wf, rf, cost)
    f_h, f_l = D.compute_frequencies(ov, wf, rf)
    before = D.total_cost(ov, dec, f_h, f_l, cost)
    ov2, dec2, n_split = D.split_nodes(ov, dec, wf, rf, cost)
    assert _valid(ov2, dec2)
    f_h2, f_l2 = D.compute_frequencies(ov2, wf, rf)
    after = D.total_cost(ov2, dec2, f_h2, f_l2, cost)
    if n_split:
        assert after <= before + 1e-6
    # split overlay still computes the right answers
    ov2.validate(rmat_bipartite.reader_input_sets())


def test_adaptation_moves_toward_new_optimum(rmat_bipartite):
    ov, _ = construct_vnm(rmat_bipartite, variant="vnm_a", max_iterations=3)
    wf, rf = make_freqs(rmat_bipartite.n_base, seed=4)
    cost = D.cost_model_for("sum")
    dec, _ = D.decide_mincut(ov, wf, rf, cost)
    # the workload flips: reads 10x writes
    wf2, rf2 = wf * 0.1, rf * 10
    f_h2, f_l2 = D.compute_frequencies(ov, wf2, rf2)
    before = D.total_cost(ov, dec, f_h2, f_l2, cost)
    dec2, n_flips = D.adapt_decisions(ov, dec, wf2, rf2, cost)
    assert _valid(ov, dec2)
    after = D.total_cost(ov, dec2, f_h2, f_l2, cost)
    assert after <= before + 1e-6
    if n_flips:
        assert after < before


def test_calibrated_cost_model():
    """Calibration measures wall time, so monotonicity is load-sensitive;
    assert the structural contract only (positive costs, H normalized)."""
    from repro.core.aggregates import make_aggregate
    cm = D.calibrate_cost_model(make_aggregate("sum"))
    assert cm.L(1) >= 1.0 and cm.L(16) >= 1.0
    assert cm.H(4) == 1.0
    assert cm.name == "calibrated"
