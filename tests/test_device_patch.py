"""Device-resident plan patching (the ``PatchProgram`` path, §3.3 on TPU
terms): in-capacity churn must perform ZERO host->device table uploads — the
delta is lowered to bucketed edit arrays and applied by one donated jitted
``apply_patch_step`` — with the host mirror demoted to a parity oracle that,
when enabled, must stay bit-identical to the device tables. The stacked
deployment replays the same program on one masked slice without leaving the
device.

These tests build engines on the *default* backend (no explicit pin) so the
CI matrix entry ``EAGR_BACKEND=pallas`` drives the whole path — device
scatters included — through the segment_agg kernel in interpret mode.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_freqs
from repro.core import dataflow as D
from repro.core.aggregates import make_aggregate
from repro.core.bipartite import build_bipartite
from repro.core.dynamic import DynamicOverlay
from repro.core.engine import EagrEngine
from repro.core.plan_patch import apply_patch_step
from repro.core.vnm import construct_vnm
from repro.core.window import WindowSpec
from repro.graphs.generators import rmat_graph
from repro.kernels.segment_agg.ops import tile_occupancy


def _system(n=120, e=700, seed=3, agg="sum", spec=None, headroom=2.0,
            rng_seed=1):
    g = rmat_graph(n, e, seed=seed)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    ris = bp.reader_input_sets()
    dyn = DynamicOverlay.from_overlay(ov, ris)
    ov0 = dyn.to_overlay(prune=False)
    wf, rf = make_freqs(n, seed=rng_seed)
    dec, _ = D.decide_mincut(ov0, wf, rf, D.cost_model_for(agg))
    eng = EagrEngine(ov0, dec, make_aggregate(agg),
                     spec or WindowSpec("tuple", 4), headroom=headroom)
    return eng, dyn, bp


def _check_reads(eng, dyn, rng, k=6, batch=8):
    pool = [r for r in dyn.reader_inputs
            if dyn.reader_inputs[r] and r in eng.plan.reader_node_of_base]
    q = rng.choice(pool, k)
    out = eng.read_batch(q, batch_size=batch)
    for i, b in enumerate(q):
        want = eng.oracle_read(int(b), dyn.reader_inputs)
        np.testing.assert_allclose(np.ravel(out[i]), np.ravel(want),
                                   rtol=1e-4, atol=1e-4, err_msg=f"reader {b}")


def _churn_step(dyn, rng, readers, n_base=120):
    op = int(rng.integers(0, 4))
    if op == 0:
        dyn.add_edge(int(rng.integers(0, n_base)), int(rng.choice(readers)))
    elif op == 1:
        r = int(rng.choice(readers))
        if dyn.reader_inputs.get(r):
            dyn.delete_edge(int(next(iter(dyn.reader_inputs[r]))), r)
    elif op == 2:
        nid = int(rng.integers(1000, 2000))
        dyn.add_node(nid,
                     in_neighbors={int(x) for x in rng.integers(0, n_base, 3)},
                     out_readers={int(rng.choice(readers))})
    else:
        victims = [k for k in list(dyn.reader_inputs) if k >= 1000]
        if victims:
            dyn.delete_node(int(rng.choice(victims)))


# ----------------------------------------------------------- zero table uploads
def test_zero_host_uploads_during_in_capacity_churn():
    """The acceptance invariant of device-resident patching: once the patch
    machinery is warm, in-capacity churn performs NO implicit host->device
    transfer — tables never re-upload; only the explicitly-placed
    (``jax.device_put``) edit arrays of the ``PatchProgram`` travel."""
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(5)
    readers = list(dyn.reader_inputs)
    eng.write_batch(rng.choice(bp.writers, 16),
                    rng.normal(size=16).astype(np.float32), batch_size=16)
    # warm every patch-path program once: slot claim, retire, node add with a
    # fresh writer row, node retire (window-row reset)
    dyn.add_edge(int(bp.writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    dyn.delete_edge(int(bp.writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    dyn.add_node(1900, in_neighbors={int(bp.writers[0])},
                 out_readers={int(readers[0])})
    eng.apply_delta(dyn.drain_delta())
    dyn.delete_node(1900)
    eng.apply_delta(dyn.drain_delta())

    with jax.transfer_guard_host_to_device("disallow"):
        for step in range(12):
            _churn_step(dyn, rng, readers)
            res = eng.apply_delta(dyn.drain_delta())
            assert not res.recompiled, "churn exceeded headroom"
    eng.write_batch(rng.choice(bp.writers, 16),
                    rng.normal(size=16).astype(np.float32), batch_size=16)
    _check_reads(eng, dyn, rng)


def test_apply_patch_step_single_trace_and_donation():
    """Small in-capacity bursts stay on exactly ONE cached apply_patch_step
    executable, and the donated input pytree is actually consumed (tables are
    rewritten in place, not copied)."""
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(3)
    readers = list(dyn.reader_inputs)
    dyn.add_edge(int(bp.writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())
    c0 = apply_patch_step._cache_size()
    old_arrays = eng.plan.arrays
    for _ in range(8):
        dyn.add_edge(int(rng.integers(0, 120)), int(rng.choice(readers)))
        res = eng.apply_delta(dyn.drain_delta())
        if res.reason == "empty delta":  # already-present edge: no-op add
            continue
        assert not res.recompiled and res.program is not None
    assert apply_patch_step._cache_size() == c0, \
        "uniform slot churn must stay on one apply_patch_step trace"
    # the pre-patch buffers were donated into the step
    assert old_arrays.push.seg.is_deleted()
    eng.write_batch(rng.choice(bp.writers, 16),
                    rng.normal(size=16).astype(np.float32), batch_size=16)
    _check_reads(eng, dyn, rng)


# ------------------------------------------------------------- parity oracle
def _parity_sweep(seed: int, steps: int = 12) -> None:
    eng, dyn, bp = _system(n=100, e=550, seed=seed % 7, headroom=2.5,
                           rng_seed=seed % 5)
    rng = np.random.default_rng(seed)
    readers = list(dyn.reader_inputs)
    eng.write_batch(rng.choice(bp.writers, 12),
                    rng.normal(size=12).astype(np.float32), batch_size=12)
    dyn.add_edge(int(bp.writers[0]), int(readers[0]))
    eng.apply_delta(dyn.drain_delta())   # seeds the host bookkeeping
    eng.plan.host.enable_mirror(eng.plan)
    for _ in range(steps):
        _churn_step(dyn, rng, readers, n_base=100)
        eng.apply_delta(dyn.drain_delta())
        # bit-identical PlanArrays after every random add/retire/flip burst
        eng.plan.host.verify_device(eng.plan)
        eng.write_batch(rng.choice(bp.writers, 12),
                        rng.normal(size=12).astype(np.float32), batch_size=12)
    _check_reads(eng, dyn, rng, k=4, batch=4)


@pytest.mark.parametrize("seed", [0, 11])
def test_device_patch_bit_identical_to_mirror(seed):
    """Deterministic parity sweep: the device tables a PatchProgram produces
    equal the host parity mirror bit for bit after every churn burst."""
    _parity_sweep(seed)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_property_device_patch_parity(seed):
    """Hypothesis sweep over random add/retire/flip sequences — including
    level relayouts and recompile fallbacks — asserting host/device parity
    after every burst."""
    _parity_sweep(seed, steps=10)


def test_tile_occupancy_matches_host_counters():
    """The host tier-escalation counters mirror ``ops.tile_occupancy``
    computed on device from the live tables."""
    eng, dyn, bp = _system(headroom=2.0)
    rng = np.random.default_rng(1)
    readers = list(dyn.reader_inputs)
    for _ in range(8):
        _churn_step(dyn, rng, readers)
        eng.apply_delta(dyn.drain_delta())
    host = eng.plan.host
    meta = eng.plan.meta
    for name in ("push", "pull"):
        t = getattr(eng.plan.arrays, name)
        dev = np.asarray(tile_occupancy(t.seg, t.tile_of_block,
                                        meta.n_row_tiles))
        np.testing.assert_array_equal(dev, getattr(host, name).occ,
                                      err_msg=f"{name} occupancy diverged")


# ------------------------------------------------------------- stacked slices
def test_stacked_slice_patch_stays_device_resident():
    """Stacked churn replays the shard's PatchProgram on the stacked pytree:
    the patched slice must equal the per-shard plan arrays bit for bit, the
    incrementally-scattered owner maps must equal a full rebuild, and uniform
    bursts stay on one stacked patch trace."""
    from repro.distributed.eagr_shard import ShardedDynamic, partition_overlay
    from repro.distributed.stacked import StackedShardedEngine, _stacked_patch

    g = rmat_graph(150, 900, seed=3)
    bp = build_bipartite(g)
    ov, _ = construct_vnm(bp, variant="vnm_a", max_iterations=2, seed=0)
    wf, rf = make_freqs(150, seed=3)
    dec, _ = D.decide_mincut(ov, wf, rf, D.cost_model_for("sum"))
    sharded = partition_overlay(ov, dec, n_shards=4, seed=0, headroom=2.0)
    stacked = StackedShardedEngine(sharded, make_aggregate("sum"),
                                   WindowSpec("tuple", 4), base_capacity=2048)
    sd = ShardedDynamic(sharded, stacked)
    rng = np.random.default_rng(2)
    ris = bp.reader_input_sets()

    def write():
        ids = rng.choice(bp.writers, 48)
        stacked.write_batch(ids, rng.normal(size=48).astype(np.float32),
                            batch_size=48)

    write()
    sd.add_edge(int(rng.integers(0, 150)), int(rng.choice(list(ris))))
    sd.apply()  # warm the stacked patch program
    c0 = _stacked_patch._cache_size()
    recompiles = 0
    for _ in range(10):
        sd.add_edge(int(rng.integers(0, 150)), int(rng.choice(list(ris))))
        res = sd.apply()
        recompiles += sum(bool(x and x.recompiled) for x in res)
        write()
    assert recompiles == 0
    assert _stacked_patch._cache_size() == c0, \
        "uniform stacked churn must stay on one patch trace"
    # every stacked slice equals its shard plan's own (donated-step) arrays
    for s, p in enumerate(sharded.shard_plans):
        got = jax.tree.leaves(jax.tree.map(lambda x: x[s], stacked.arrays))
        want = jax.tree.leaves(p.arrays)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # incrementally-patched owner maps == a from-scratch rebuild
    wmap_inc = np.asarray(stacked.writer_map).copy()
    rmap_inc = np.asarray(stacked.reader_map).copy()
    owner_inc = dict(stacked._reader_owner)
    stacked.refresh_owner_maps()
    np.testing.assert_array_equal(wmap_inc, np.asarray(stacked.writer_map))
    np.testing.assert_array_equal(rmap_inc, np.asarray(stacked.reader_map))
    assert owner_inc == stacked._reader_owner
